"""Serving example: batched prefill -> token-by-token decode.

Runs a reduced config through the same prefill/serve steps the dry-run
lowers at production scale (32k cache, 512 chips).

PYTHONPATH=src python examples/serve.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.lm import LM

cfg = get_config("gemma_7b").reduced()
model = LM(cfg)
params = model.init(jax.random.PRNGKey(0))

B, S, GEN, MAXLEN = 4, 48, 16, 64
requests = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

# prefill: last-token logits + packed kv cache (stacked layout)
t0 = time.perf_counter()
logits, stacked = model.prefill(params, requests)
print(f"prefill  B={B} S={S}: {time.perf_counter()-t0:.2f}s "
      f"logits {logits.shape}")

# convert to the flat per-layer serving layout and right-size to MAXLEN
flat = model.unstack_cache(stacked)
cache = model.init_cache(B, MAXLEN)
cache = jax.tree.map(
    lambda dst, src: dst.at[tuple(slice(0, s) for s in src.shape)].set(src)
    if dst.shape != src.shape else src, cache, flat)

decode = jax.jit(model.decode_step, donate_argnums=(1,))
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
out = [tok]
t0 = time.perf_counter()
for t in range(GEN):
    logits, cache = decode(params, cache, tok,
                           jnp.full((B,), S + t, jnp.int32))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out.append(tok)
dt = time.perf_counter() - t0
gen = jnp.concatenate(out, axis=1)
print(f"decode   {GEN} steps x {B} seqs: {dt:.2f}s "
      f"({B*GEN/dt:.1f} tok/s on CPU interpret path)")
print("generated ids[0]:", gen[0].tolist())
assert bool(jnp.isfinite(logits).all())
print("OK")
