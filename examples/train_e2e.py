"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on synthetic data (CPU-runnable; identical code path to the cluster
launcher).

PYTHONPATH=src python examples/train_e2e.py --steps 300        # full run
PYTHONPATH=src python examples/train_e2e.py --steps 40 --small # smoke
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.models.lm import LM
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="10M-param config for quick verification")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    args = ap.parse_args()

    # ~100M dense decoder in the qwen2 family (GQA + swiglu).
    base = get_config("qwen2_7b")
    if args.small:
        cfg = base.reduced(n_layers=4, d_model=256, vocab=4096, d_ff=1024,
                           n_heads=4, n_kv_heads=2, head_dim=64)
    else:
        cfg = dataclasses.replace(
            base, name="qwen2-100m", n_layers=10, d_model=640, n_heads=10,
            n_kv_heads=2, head_dim=64, d_ff=2560, vocab=32768,
            dtype="float32", attn_q_chunk=256)
    n = LM(cfg).n_params()
    print(f"model: {cfg.name}  params={n/1e6:.1f}M  "
          f"steps={args.steps} batch={args.global_batch} seq={args.seq}")

    tcfg = TrainerConfig(
        arch=cfg, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq, ckpt_dir="/tmp/repro_e2e", ckpt_every=100,
        log_every=10,
        opt=AdamWConfig(peak_lr=1e-3, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps, weight_decay=0.01))
    trainer = Trainer(tcfg)
    _, hist = trainer.run()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.3 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
