"""Quickstart: plan a heterogeneous cluster, inspect the plan, train briefly.

PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs import get_config
from repro.core import hetero_cluster, plan_hybrid
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig

# 1. Describe the cluster with the multi-edge model (paper §3.1): four
#    current-gen consumer GPUs + four older V100s, PCIe vs NVLink edges.
topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
print(topo.describe())

# 2. Auto-plan (paper §3.3): enumerate + prune strategies, refine layer
#    assignment with branch-and-bound, score with the simulator.
cfg = get_config("qwen2_7b")
res = plan_hybrid(topo, cfg.to_model_desc(), global_batch=32, seq=1024)
print(f"\nbest plan       : {res.plan.describe()}")
print(f"predicted step  : {res.predicted.step_time*1e3:.0f} ms")
print(f"vs megatron-default: {res.speedup_vs_baseline:.2f}x "
      f"| vs tuned-uniform: {res.speedup_vs_tuned:.2f}x")
print(f"candidates: {res.candidates_evaluated} evaluated, "
      f"{res.candidates_pruned} pruned in {res.wall_time:.2f}s")

# 3. Execute a reduced config on this host with the plan's knobs.
print("\ntraining reduced config on", jax.devices())
tcfg = TrainerConfig(arch=cfg.reduced(), steps=20, global_batch=8,
                     seq_len=128, ckpt_every=0, log_every=5,
                     microbatches=res.plan.microbatches // res.plan.pp or 1,
                     opt=AdamWConfig(peak_lr=3e-3, warmup_steps=5,
                                     total_steps=20))
trainer = Trainer(tcfg, plan=res.plan)
_, hist = trainer.run()
print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
