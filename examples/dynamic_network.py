"""Dynamic-network scenarios S1/S2/S3 (paper Fig. 1) end to end.

A training run over a temporal topology: bandwidth drop (S1), straggler
(S2), node failure (S3).  Each event flows through the DynamicOrchestrator
(threshold re-plan / ReCycle-style reassignment / Oobleck-style template
failover), the trainer checkpoints, re-plans, reshards elastically and
resumes.

PYTHONPATH=src python examples/dynamic_network.py
"""

from repro.configs import get_config
from repro.core import NetworkEvent, ParallelPlan, hetero_cluster
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig

topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
print(topo.describe())

events = [
    (6, NetworkEvent(0.0, "bandwidth", factor=0.3, selector="ib")),   # S1
    (12, NetworkEvent(0.0, "slowdown", device_id=2, factor=0.4)),     # S2
    (18, NetworkEvent(0.0, "fail", device_id=7)),                     # S3
]

cfg = TrainerConfig(
    arch=get_config("qwen2_7b").reduced(n_layers=2, d_model=64, vocab=256,
                                        d_ff=128),
    steps=24, global_batch=8, seq_len=64, ckpt_dir="/tmp/repro_dyn",
    ckpt_every=5, log_every=4,
    opt=AdamWConfig(peak_lr=2e-3, warmup_steps=3, total_steps=24))

trainer = Trainer(cfg, topo=topo, events=events,
                  plan=ParallelPlan(dp=2, tp=2, pp=2, microbatches=2))
state, hist = trainer.run()

print("\nadaptation history (paper §2.2 mechanisms):")
for rec in trainer._orch.history:
    print(f"  t={rec.time:5.1f} {rec.event.kind:9s} -> {rec.action:20s} "
          f"predicted step {rec.old_step_time*1e3:7.1f} -> "
          f"{rec.new_step_time*1e3:7.1f} ms")
print("\nincremental re-planning engine telemetry:")
print(trainer._engine.describe())
print(f"\n{trainer.replans} re-plans; final loss {hist[-1]['loss']:.3f} "
      f"(training continued through all events)")
