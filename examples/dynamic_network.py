"""Dynamic-network scenarios S1/S2/S3 (paper Fig. 1) end to end.

A training run over a temporal topology: bandwidth drop (S1), straggler
(S2), node failure (S3).  The timeline is expressed as a scenario *trace*
(repro.scenarios): recorded to JSONL, loaded back, and handed to the
trainer, which maps event times onto training steps.  Each event flows
through the DynamicOrchestrator + ReplanEngine; the trainer checkpoints,
re-plans, reshards elastically and resumes.

PYTHONPATH=src python examples/dynamic_network.py
"""

from repro.configs import get_config
from repro.core import NetworkEvent, ParallelPlan, hetero_cluster
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.scenarios import Trace

topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
print(topo.describe())

STEPS = 24
# hand-written timeline over a horizon of STEPS "seconds", one unit per
# step: S1 at step 6, S2 at step 12, S3 at step 18
trace = Trace.from_events(
    "s1s2s3_demo",
    [NetworkEvent(6.0, "bandwidth", factor=0.3, selector="ib"),   # S1
     NetworkEvent(12.0, "slowdown", device_id=2, factor=0.4),     # S2
     NetworkEvent(18.0, "fail", device_id=7)],                    # S3
    horizon=float(STEPS))
path = trace.record("/tmp/repro_dyn/s1s2s3_demo.trace.jsonl")
trace = Trace.load(path)                     # JSONL round-trip
print(trace.describe(), f"-> {path}")

cfg = TrainerConfig(
    arch=get_config("qwen2_7b").reduced(n_layers=2, d_model=64, vocab=256,
                                        d_ff=128),
    steps=STEPS, global_batch=8, seq_len=64, ckpt_dir="/tmp/repro_dyn",
    ckpt_every=5, log_every=4,
    opt=AdamWConfig(peak_lr=2e-3, warmup_steps=3, total_steps=STEPS))

trainer = Trainer(cfg, topo=topo, scenario=trace,
                  plan=ParallelPlan(dp=2, tp=2, pp=2, microbatches=2))
state, hist = trainer.run()

print("\nadaptation history (paper §2.2 mechanisms):")
for rec in trainer.adaptations:
    print(f"  t={rec.time:5.1f} {rec.event.kind:9s} -> {rec.action:20s} "
          f"predicted step {rec.old_step_time*1e3:7.1f} -> "
          f"{rec.new_step_time*1e3:7.1f} ms")
print("\nincremental re-planning engine telemetry:")
print(trainer.engine.describe())
print("\nmodeled reconfiguration charges (repro.core.reconfig, calibrated "
      "against the measured checkpoint-restore path):")
for r in trainer.engine.history:
    if not r.cold:
        verdict = "kept incumbent" if r.kept else "switched"
        print(f"  {r.path:22s} modeled switch cost {r.switch_cost:6.3f} s "
              f"-> {verdict}")
print(f"  calibrated store bandwidth "
      f"{trainer.engine.reconfig.io_bw / 1e9:.2f} GB/s")
print(f"\n{trainer.replans} re-plans; final loss {hist[-1]['loss']:.3f} "
      f"(training continued through all events)")
