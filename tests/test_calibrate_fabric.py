"""Fabric calibration fit math (ISSUE 8 tentpole closer): pure-stdlib
least squares + roofline + gate, unit-tested without JAX (the sweep side
is exercised by running the tool; the fit side is what the sim depends
on)."""

import json
import random

import pytest

from tools.calibrate_fabric import (fit_alpha_beta, fit_report, main,
                                    predict_step, roofline_terms)


def _synthetic(alpha, beta, *, noise=0.0, seed=0, n=24):
    rng = random.Random(seed)
    samples = []
    for _ in range(n):
        # log-uniform: small sizes keep the latency term identifiable
        size = 10.0 ** rng.uniform(2, 8)
        bw = rng.choice([10e9, 25e9, 100e9])
        lat = rng.choice([1e-6, 5e-6, 2e-5])
        t = alpha * lat + size / (beta * bw)
        t *= 1.0 + rng.uniform(-noise, noise)
        samples.append({"size": size, "bw": bw, "lat": lat, "t": t})
    return samples


def test_fit_recovers_known_calibration():
    a, b = fit_alpha_beta(_synthetic(1.8, 0.6))
    assert a == pytest.approx(1.8, rel=1e-9)
    assert b == pytest.approx(0.6, rel=1e-9)


def test_fit_is_stable_under_noise():
    a, b = fit_alpha_beta(_synthetic(1.5, 0.8, noise=0.05, seed=3))
    assert a == pytest.approx(1.5, rel=0.25)
    assert b == pytest.approx(0.8, rel=0.1)


def test_fit_clamps_beta_for_admissibility():
    """A machine beating its nominal bandwidth must not calibrate the sim
    below the search tier's coarse caps: beta is capped at 1."""
    a, b = fit_alpha_beta(_synthetic(1.0, 1.4))
    assert b == 1.0
    # ... unless the caller raises the ceiling explicitly
    a2, b2 = fit_alpha_beta(_synthetic(1.0, 1.4), clamp_beta=2.0)
    assert b2 == pytest.approx(1.4, rel=1e-9)


def test_fit_rejects_empty_and_survives_degenerate_sweeps():
    with pytest.raises(ValueError):
        fit_alpha_beta([])
    # one repeated (size, lat) point: rank-deficient normal equations fall
    # back to the bandwidth-only fit instead of dividing by ~zero
    s = [{"size": 1e6, "bw": 10e9, "lat": 0.0, "t": 2e-4}] * 4
    a, b = fit_alpha_beta(s)
    assert 0 < b <= 1.0


def test_roofline_terms_report_per_class_peaks():
    samples = [
        {"size": 1e6, "bw": 10e9, "lat": 0, "t": 1e-6 + 1e6 / 8e9,
         "cls": "host"},
        {"size": 1e8, "bw": 10e9, "lat": 0, "t": 1e8 / 9e9, "cls": "host"},
        {"size": 1e8, "bw": 100e9, "lat": 0, "t": 1e8 / 50e9, "cls": "ib",
         "flops": 2e11},
    ]
    rows = roofline_terms(samples)
    assert rows["host"]["peak_bw"] == pytest.approx(9e9)
    assert rows["host"]["bw_eff"] == pytest.approx(0.9)
    assert rows["ib"]["peak_bw"] == pytest.approx(50e9)
    assert rows["ib"]["bw_eff"] == pytest.approx(0.5)
    assert rows["ib"]["peak_flops"] == pytest.approx(2e11 / (1e8 / 50e9))


def test_fit_report_gates_step_error():
    samples = _synthetic(1.0, 0.9, seed=7)
    good = predict_step(samples, *fit_alpha_beta(samples))
    rep = fit_report(samples, gate=0.25, measured_step=good * 1.1)
    assert rep["step"]["passed"]
    rep = fit_report(samples, gate=0.25, measured_step=good * 2.0)
    assert not rep["step"]["passed"]
    assert rep["beta"] == pytest.approx(0.9, rel=1e-9)


def test_cli_fit_only_roundtrip(tmp_path, capsys):
    samples = _synthetic(1.2, 0.7, seed=1)
    src = tmp_path / "sweep.json"
    src.write_text(json.dumps({"samples": samples}))
    out = tmp_path / "calib.json"
    assert main(["--fit-only", str(src), "--out", str(out)]) == 0
    rep = json.loads(out.read_text())["report"]
    assert rep["alpha"] == pytest.approx(1.2, rel=1e-6)
    assert rep["beta"] == pytest.approx(0.7, rel=1e-6)
    assert "alpha=1.2" in capsys.readouterr().out


def test_cli_gate_failure_exits_nonzero(tmp_path):
    samples = _synthetic(1.0, 0.9, seed=5)
    good = predict_step(samples, *fit_alpha_beta(samples))
    src = tmp_path / "sweep.json"
    src.write_text(json.dumps({"samples": samples,
                               "measured_step": good * 10}))
    assert main(["--fit-only", str(src), "--gate", "0.25"]) == 1
    assert main(["--fit-only", str(src), "--no-gate"]) == 0
