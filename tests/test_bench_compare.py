"""CI bench-regression gate (ISSUE 5 satellite): benchmarks/compare.py
detects perturbed metrics, honors tolerances, and hard-fails structural
gates."""

import json

import pytest

from benchmarks.compare import (SPECS, Gate, Violation, compare_dirs,
                                compare_rows)

PS_ROW = {"topology": "hetero", "gpus": 16, "argmin_matches_exhaustive": True,
          "parallel_matches_serial": True, "prune_rate": 0.5,
          "pruned_coarse": 40}
TORUS_ROW = {"topology": "tpu-torus", "gpus": 32,
             "argmin_matches_exhaustive": True,
             "parallel_matches_serial": True, "prune_rate": 0.6,
             "pruned_coarse": 54}
RP_ROW = {"model": "LLaMA_7B", "gpus": 16, "scenario": "bandwidth",
          "path": "bandwidth-rescore", "speedup": 10.0, "quality_ok": True}
SC_ROW = {"scenario": "cloud_spot", "seed": 0, "greedy_over_dp": 1.02,
          "replans": 3, "adapted_over_static": 0.88,
          "adapted_over_oracle": 1.04, "parallel_matches_sequential": True}
SV_ROW = {"family": "multi_tenant_storm", "serial_matches_threaded": True,
          "admitted": 32, "rejected": 0, "cold_searches": 14,
          "replans": 109, "invalidated": 12, "cache_hit_rate": 0.56,
          "p99_replan_s": 0.03}


def test_identical_rows_pass():
    assert compare_rows("planner_search", [PS_ROW, TORUS_ROW],
                        [PS_ROW, TORUS_ROW]) == []
    assert compare_rows("bench_replan", [RP_ROW], [RP_ROW]) == []
    assert compare_rows("bench_scenarios", [SC_ROW], [SC_ROW]) == []


def test_structural_bool_flip_hard_fails():
    bad = dict(TORUS_ROW, argmin_matches_exhaustive=False)
    v = compare_rows("planner_search", [PS_ROW, TORUS_ROW], [PS_ROW, bad])
    assert any(x.metric == "argmin_matches_exhaustive" for x in v)
    # bench-internal gates mirrored into rows stay blocking through compare
    # even though the bench steps run continue-on-error in CI
    v = compare_rows("bench_replan", [RP_ROW],
                     [dict(RP_ROW, quality_ok=False)])
    assert [x.metric for x in v] == ["quality_ok"]
    v = compare_rows("bench_scenarios", [SC_ROW],
                     [dict(SC_ROW, parallel_matches_sequential=False)])
    assert [x.metric for x in v] == ["parallel_matches_sequential"]


def test_ratio_metric_within_tolerance_passes():
    wobble = dict(PS_ROW, prune_rate=0.47)        # -6% < 10% tolerance
    assert compare_rows("planner_search", [PS_ROW], [wobble]) == []
    slow = dict(RP_ROW, speedup=4.0)              # -60% < 80% tolerance
    assert compare_rows("bench_replan", [RP_ROW], [slow]) == []


def test_perturbed_ratio_metric_fails():
    """The acceptance criterion: a deliberately perturbed metric fails."""
    degraded = dict(PS_ROW, prune_rate=0.2)       # -60% > 10% tolerance
    v = compare_rows("planner_search", [PS_ROW], [degraded])
    assert [x.metric for x in v] == ["prune_rate"]
    collapsed = dict(RP_ROW, speedup=1.1)         # warm path went cold
    v = compare_rows("bench_replan", [RP_ROW], [collapsed])
    assert [x.metric for x in v] == ["speedup"]
    worse = dict(SC_ROW, adapted_over_static=1.05)
    v = compare_rows("bench_scenarios", [SC_ROW], [worse])
    assert [x.metric for x in v] == ["adapted_over_static"]


def test_improvements_always_pass():
    better = dict(PS_ROW, prune_rate=0.9, pruned_coarse=120)
    assert compare_rows("planner_search", [PS_ROW], [better]) == []
    faster = dict(RP_ROW, speedup=40.0)
    assert compare_rows("bench_replan", [RP_ROW], [faster]) == []


def test_dp_le_greedy_structural_floor():
    bad = dict(SC_ROW, greedy_over_dp=0.97)       # DP worse than greedy
    v = compare_rows("bench_scenarios", [SC_ROW], [bad])
    assert [x.metric for x in v] == ["greedy_over_dp"]


def test_structural_equal_gate():
    drifted = dict(RP_ROW, path="full-replan")
    v = compare_rows("bench_replan", [RP_ROW], [drifted])
    assert [x.metric for x in v] == ["path"]
    changed = dict(SC_ROW, replans=7)
    v = compare_rows("bench_scenarios", [SC_ROW], [changed])
    assert [x.metric for x in v] == ["replans"]


def test_missing_row_fails_extra_row_allowed():
    v = compare_rows("planner_search", [PS_ROW, TORUS_ROW], [PS_ROW])
    assert len(v) == 1 and v[0].metric == "<row>"
    # fresh-only rows (new coverage) are not gated
    extra = dict(PS_ROW, gpus=64)
    assert compare_rows("planner_search", [PS_ROW], [PS_ROW, extra]) == []


def test_nan_agreement_semantics():
    nan_row = dict(SC_ROW, adapted_over_static=float("nan"))
    assert compare_rows("bench_scenarios", [nan_row], [nan_row]) == []
    v = compare_rows("bench_scenarios", [SC_ROW], [nan_row])
    assert any(x.metric == "adapted_over_static" for x in v)
    # min-kind gates share the agreement semantics: a legitimately
    # non-finite baseline must not turn the gate permanently red
    nan_dp = dict(SC_ROW, greedy_over_dp=float("nan"))
    assert compare_rows("bench_scenarios", [nan_dp], [nan_dp]) == []
    v = compare_rows("bench_scenarios", [SC_ROW], [nan_dp])
    assert any(x.metric == "greedy_over_dp" for x in v)


def test_family_summary_rows_skipped():
    fam = {"kind": "family_summary", "scenario": "cloud_spot",
           "adapted_over_static_mean": 0.9}
    assert compare_rows("bench_scenarios", [SC_ROW, fam], [SC_ROW]) == []


def test_compare_dirs_missing_fresh_file_fails(tmp_path):
    base = tmp_path / "baselines"
    fresh = tmp_path / "bench_out"
    base.mkdir()
    fresh.mkdir()
    for spec, rows in ((SPECS["planner_search"], [PS_ROW]),
                       (SPECS["bench_replan"], [RP_ROW]),
                       (SPECS["bench_scenarios"], [SC_ROW]),
                       (SPECS["bench_service"], [SV_ROW])):
        (base / spec.baseline_file).write_text(json.dumps(rows))
        (fresh / spec.fresh_file).write_text(json.dumps(rows))
    assert compare_dirs(base, fresh) == []
    (fresh / SPECS["bench_replan"].fresh_file).unlink()
    v = compare_dirs(base, fresh)
    assert len(v) == 1 and v[0].metric == "<fresh>"


def test_committed_baselines_parse_against_specs():
    """The committed baselines exist, parse, and carry every gated metric
    in at least one row — the blocking CI step cannot run on an empty or
    drifted schema.  (A spec may gate two row families — flat vs fleet
    planner rows — so per-row coverage is not required, per-bench is.)"""
    from benchmarks.compare import BASELINE_DIR
    for bench, spec in SPECS.items():
        path = BASELINE_DIR / spec.baseline_file
        assert path.exists(), path
        rows = spec.rows(json.loads(path.read_text()))
        assert rows, path
        for gate in spec.gates:
            assert any(gate.metric in row for row in rows.values()), \
                (bench, gate.metric)


MP_ROW = {"topology": "multi-pod", "gpus": 1024, "path": "hierarchical",
          "n_islands": 4, "n_signatures": 1, "islands_deduped": 3,
          "islands_dropped": 0, "hier_wall_s": 16.0}


def test_max_gate_absolute_ceiling():
    """`max` gates an absolute wall budget: slower-but-under passes (no
    ratio vs baseline), over-ceiling and non-finite fresh values fail."""
    ok = dict(MP_ROW, hier_wall_s=55.0)
    assert compare_rows("planner_search", [MP_ROW], [ok]) == []
    blown = dict(MP_ROW, hier_wall_s=90.0)
    v = compare_rows("planner_search", [MP_ROW], [blown])
    assert [x.metric for x in v] == ["hier_wall_s"]
    nan = dict(MP_ROW, hier_wall_s=float("nan"))
    v = compare_rows("planner_search", [MP_ROW], [nan])
    assert [x.metric for x in v] == ["hier_wall_s"]


def test_gates_skip_metrics_absent_from_baseline_row():
    """One spec gates two row families (flat cascade rows vs fleet island
    rows): a gate whose metric is absent from a baseline row is skipped
    for EVERY gate kind, so mixed schemas do not cross-fire."""
    both = [PS_ROW, MP_ROW]
    assert compare_rows("planner_search", both, both) == []


def test_service_determinism_and_counters_hard_fail():
    assert compare_rows("bench_service", [SV_ROW], [SV_ROW]) == []
    v = compare_rows("bench_service", [SV_ROW],
                     [dict(SV_ROW, serial_matches_threaded=False)])
    assert [x.metric for x in v] == ["serial_matches_threaded"]
    v = compare_rows("bench_service", [SV_ROW],
                     [dict(SV_ROW, cold_searches=20, replans=100)])
    assert sorted(x.metric for x in v) == ["cold_searches", "replans"]


def test_service_hit_rate_floor_and_drift():
    # under the absolute 0.5 acceptance floor: fails even if baseline agrees
    low = dict(SV_ROW, cache_hit_rate=0.4)
    v = compare_rows("bench_service", [low], [low])
    assert [x.metric for x in v] == ["cache_hit_rate"]
    # above the floor but >10% below baseline: the ratio gate fires
    drifted = dict(SV_ROW, cache_hit_rate=0.50)
    v = compare_rows("bench_service", [SV_ROW], [drifted])
    assert [x.metric for x in v] == ["cache_hit_rate"]


def test_service_p99_absolute_ceiling():
    slower_but_under = dict(SV_ROW, p99_replan_s=0.5)
    assert compare_rows("bench_service", [SV_ROW], [slower_but_under]) == []
    blown = dict(SV_ROW, p99_replan_s=1.2)
    v = compare_rows("bench_service", [SV_ROW], [blown])
    assert [x.metric for x in v] == ["p99_replan_s"]


def test_fleet_partition_drift_fails():
    v = compare_rows("planner_search", [MP_ROW],
                     [dict(MP_ROW, n_islands=5, islands_deduped=4)])
    assert sorted(x.metric for x in v) == ["islands_deduped", "n_islands"]
    v = compare_rows("planner_search", [MP_ROW],
                     [dict(MP_ROW, path="flat")])
    assert [x.metric for x in v] == ["path"]
