"""Pallas kernel sweeps vs pure-jnp oracles (interpret=True on CPU).

Shape/dtype sweep per kernel as required: GQA ratios, causal/window,
decode (Sq=1), non-square, odd head counts, bf16/f32.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm

CASES = [
    # (B, Sq, Skv, H, KV, hd, causal, window)
    (2, 128, 128, 4, 2, 64, True, 0),
    (1, 64, 256, 8, 8, 32, True, 0),       # cross/decode-aligned
    (2, 128, 128, 4, 4, 64, True, 48),     # sliding window
    (1, 1, 128, 4, 2, 64, True, 0),        # single-token decode
    (2, 96, 96, 6, 2, 32, False, 0),       # bidirectional (encoder)
    (1, 256, 256, 2, 1, 128, True, 0),     # MQA, MXU-aligned head_dim
    (1, 32, 32, 4, 4, 16, True, 8),        # tiny window
]


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_reference(case, dtype):
    B, Sq, Skv, H, KV, hd, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, hd), dtype)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        block_q=32, block_kv=32, interpret=True)
    r = ref.mha_reference(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("blocks", [(16, 16), (32, 64), (64, 32), (128, 128)])
def test_flash_attention_block_shape_invariance(blocks):
    """Output must not depend on the BlockSpec tiling."""
    bq, bk = blocks
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    o = flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bk,
                        interpret=True)
    r = ref.mha_reference(q, k, v, causal=True)
    np.testing.assert_allclose(o, r, atol=3e-5, rtol=3e-5)


def test_flash_attention_softcap():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32)) * 3
    k = jax.random.normal(ks[1], (1, 64, 2, 32)) * 3
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    o = flash_attention(q, k, v, causal=True, softcap=20.0,
                        block_q=32, block_kv=32, interpret=True)
    r = ref.mha_reference(q, k, v, causal=True, softcap=20.0)
    np.testing.assert_allclose(o, r, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", [(4, 37, 128), (1, 1, 256), (8, 512),
                                   (2, 3, 5, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_reference(shape, dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, shape, dtype)
    w = (jax.random.normal(key, shape[-1:]) * 0.1 + 1).astype(dtype)
    o = rmsnorm(x, w, interpret=True)
    r = ref.rmsnorm_reference(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_grad_flows():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 32))
    k = jax.random.normal(ks[1], (1, 32, 2, 32))
    v = jax.random.normal(ks[2], (1, 32, 2, 32))

    def f(q):
        return jnp.sum(flash_attention(q, k, v, causal=True, block_q=16,
                                       block_kv=16, interpret=True) ** 2)

    def fr(q):
        return jnp.sum(ref.mha_reference(q, k, v, causal=True) ** 2)

    g = jax.grad(f)(q)
    gr = jax.grad(fr)(q)
    np.testing.assert_allclose(g, gr, atol=1e-3, rtol=1e-3)
