"""Multi-edge topology model (paper §3.1) + temporal events (§2.2)."""

import math

import pytest

from repro.core import (DEVICE_PROFILES, ClusterTopology, DeviceInstance,
                        Edge, MultiEdgeLink, NetworkEvent, dgx_h100_node,
                        hetero_cluster, homogeneous_cluster, multi_pod_tpu,
                        tpu_pod)


def test_multi_edge_best_and_aggregate():
    link = MultiEdgeLink(0, 1, [
        Edge(450e9, 1e-6, "nvlink", ("pcie",)),
        Edge(16e9, 5e-6, "pcie", ("nvlink",)),
        Edge(50e9, 1e-6, "ici-x"),
    ])
    # big transfer: nvlink wins
    assert link.best_edge(1 << 30).tag == "nvlink"
    # conflicting edges share one class; independent edges add
    agg = link.aggregate_bandwidth()
    assert agg == pytest.approx(450e9 + 50e9)


def test_unequal_bandwidth_dgx(paper_fig="5a"):
    topo = dgx_h100_node()
    # pairs touching GPU 0/7 have the extra NVSwitch edge
    assert len(topo.link(0, 3).edges) == 3
    assert len(topo.link(2, 3).edges) == 2


def test_tpu_torus_multi_edge_axes():
    topo = tpu_pod(16, torus=(4, 4))
    # each chip connects along both torus axes with distinct edge classes
    tags = {e.tag for link in topo.links.values() for e in link.edges}
    assert tags == {"ici-x", "ici-y"}


def test_multi_pod_has_slow_dci():
    topo = multi_pod_tpu(pods=2, chips_per_pod=16)
    dci = [e for link in topo.links.values() for e in link.edges
           if e.tag == "dci"]
    assert len(dci) == 16
    assert all(e.bandwidth < 50e9 for e in dci)


def test_events_and_snapshot_isolation():
    topo = homogeneous_cluster(4, "V100", gpus_per_node=4)
    topo.events = [NetworkEvent(5.0, "bandwidth", factor=0.25,
                                selector="nvlink"),
                   NetworkEvent(9.0, "fail", device_id=3)]
    snap4 = topo.snapshot(4.0)
    snap6 = topo.snapshot(6.0)
    snap10 = topo.snapshot(10.0)
    bw = lambda t: t.link(0, 1).edges[0].effective_bandwidth
    assert bw(snap6) == pytest.approx(0.25 * bw(snap4))
    assert len(snap10.alive_ids()) == 3
    # snapshots never mutate the base topology
    assert len(topo.alive_ids()) == 4
    assert bw(topo.snapshot(0.0)) == bw(snap4)


def test_hetero_cluster_types_and_intra_bw():
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    assert topo.is_heterogeneous()
    assert sorted(topo.device_types()) == ["RTX4090D", "V100"]
    # consumer card nodes are PCIe-only; V100 nodes have NVLink
    tags_ada = {e.tag for e in topo.link(0, 1).edges}
    tags_v = {e.tag for e in topo.link(4, 5).edges}
    assert tags_ada == {"pcie"}
    assert "nvlink" in tags_v


def test_apply_event_snapshot_roundtrip_all_kinds():
    """All four event kinds round-trip through apply_event/snapshot,
    including a join that revives a failed device."""
    topo = homogeneous_cluster(4, "V100", gpus_per_node=4)
    topo.events = [
        NetworkEvent(1.0, "bandwidth", factor=0.5, selector="nvlink"),
        NetworkEvent(2.0, "slowdown", device_id=1, factor=0.4),
        NetworkEvent(3.0, "fail", device_id=2),
        NetworkEvent(4.0, "join", device_id=2, factor=0.8),
    ]
    s1 = topo.snapshot(1.5)
    assert s1.link(0, 1).edges[0].bw_factor == pytest.approx(0.5)
    s2 = topo.snapshot(2.5)
    assert s2.device(1).perf_factor == pytest.approx(0.4)
    s3 = topo.snapshot(3.5)
    assert s3.alive_ids() == [0, 1, 3]
    s4 = topo.snapshot(4.5)
    assert s4.alive_ids() == [0, 1, 2, 3]          # join after fail revives
    assert s4.device(2).perf_factor == pytest.approx(0.8)
    # earlier state still reconstructable after later queries
    assert topo.snapshot(0.5).device(1).perf_factor == 1.0


def test_unknown_event_kind_and_mode_raise():
    topo = homogeneous_cluster(2, "V100", gpus_per_node=2)
    with pytest.raises(ValueError, match="unknown event kind"):
        topo.apply_event(NetworkEvent(0.0, "meteor", device_id=0))
    with pytest.raises(ValueError, match="unknown event mode"):
        topo.apply_event(NetworkEvent(0.0, "bandwidth", factor=0.5,
                                      mode="wobble"))


def test_scale_mode_composes_and_restores():
    """Overlapping scale-mode events multiply; reciprocal factors restore
    the previous level exactly (the congestion-burst contract).  Set-mode
    events remain absolute."""
    topo = homogeneous_cluster(4, "V100", gpus_per_node=2)
    e = topo.link(0, 1).edges[0]
    topo.apply_event(NetworkEvent(1.0, "bandwidth", factor=0.5,
                                  selector=e.tag, mode="scale"))
    topo.apply_event(NetworkEvent(2.0, "bandwidth", factor=0.5,
                                  selector=e.tag, mode="scale"))
    assert e.bw_factor == pytest.approx(0.25)       # bursts compose
    topo.apply_event(NetworkEvent(3.0, "bandwidth", factor=2.0,
                                  selector=e.tag, mode="scale"))
    topo.apply_event(NetworkEvent(4.0, "bandwidth", factor=2.0,
                                  selector=e.tag, mode="scale"))
    assert e.bw_factor == pytest.approx(1.0)        # full restore
    topo.apply_event(NetworkEvent(5.0, "bandwidth", factor=0.3,
                                  selector=e.tag, mode="set"))
    topo.apply_event(NetworkEvent(6.0, "bandwidth", factor=0.7,
                                  selector=e.tag, mode="set"))
    assert e.bw_factor == pytest.approx(0.7)        # set stays absolute
    # slowdown composes the same way
    topo.apply_event(NetworkEvent(7.0, "slowdown", device_id=0, factor=0.5,
                                  mode="scale"))
    topo.apply_event(NetworkEvent(8.0, "slowdown", device_id=0, factor=0.5,
                                  mode="scale"))
    assert topo.device(0).perf_factor == pytest.approx(0.25)


def test_snapshot_incremental_cache_matches_full_replay():
    """The incremental snapshot cache must be invisible: any query order
    matches a from-scratch replay, and base-topology mutations invalidate."""
    def fresh():
        t = homogeneous_cluster(4, "V100", gpus_per_node=4)
        t.events = [NetworkEvent(float(i), "bandwidth",
                                 factor=0.9 ** (i % 5 + 1),
                                 selector="nvlink", mode="set")
                    for i in range(1, 40)] + \
                   [NetworkEvent(10.5, "slowdown", device_id=1, factor=0.5),
                    NetworkEvent(20.5, "fail", device_id=3),
                    NetworkEvent(30.5, "join", device_id=3)]
        return t

    def state(t):
        return ([(d.device_id, d.alive, d.perf_factor)
                 for d in t.devices.values()],
                [(k, e.tag, e.bw_factor) for k, link in sorted(t.links.items())
                 for e in link.edges])

    inc = fresh()
    for t in (0.0, 5.0, 10.7, 20.7, 25.0, 30.7, 39.0, 12.0, 39.0):
        assert state(inc.snapshot(t)) == state(fresh().snapshot(t)), t
    # mutating the base invalidates the cache
    inc.apply_event(NetworkEvent(0.0, "slowdown", device_id=0, factor=0.25))
    snap = inc.snapshot(5.0)
    assert snap.device(0).perf_factor == pytest.approx(0.25)


def test_roofline_eq1_regimes():
    spec = DEVICE_PROFILES["V100"]
    # compute-bound: huge flops, tiny traffic
    t_c = spec.roofline_time(1e15, 1e6)
    assert t_c == pytest.approx(1e15 / (spec.peak_flops * spec.matmul_eff))
    # memory-bound: tiny flops, huge traffic
    t_m = spec.roofline_time(1e6, 1e12)
    assert t_m == pytest.approx(1e12 / spec.hbm_bw)
