"""Cost model: Eq. 1-2 roofline, collectives, Fig. 3 decomposition."""

import pytest

from repro.core import (CommOp, allreduce_time, collective_time,
                        hetero_cluster, homogeneous_cluster, transfer_time,
                        tpu_pod)
from repro.core.costmodel import MeshCollectiveModel


def test_transfer_picks_best_edge():
    topo = homogeneous_cluster(8, "V100", gpus_per_node=8)
    t = transfer_time(topo, 0, 1, 1e9)
    # NVLink 300 GB/s
    assert t == pytest.approx(1e9 / 300e9, rel=0.01)


def test_decomposed_allreduce_beats_naive():
    """Paper Fig. 3: RS+AG removes the single-root bottleneck."""
    topo = homogeneous_cluster(8, "V100", gpus_per_node=8)
    ranks = topo.alive_ids()
    naive = allreduce_time(topo, 1e9, ranks, decomposed=False)
    dec = allreduce_time(topo, 1e9, ranks, decomposed=True)
    assert dec < naive
    # ring RS+AG moves 2(n-1)/n of the data; naive funnels 2(n-1)x
    assert naive / dec == pytest.approx((2 * 7) / (2 * 7 / 8), rel=0.2)


def test_collective_scaling_with_participants():
    topo = homogeneous_cluster(16, "V100", gpus_per_node=16)
    t8 = collective_time(topo, CommOp("c", "all_reduce", 1e9,
                                      tuple(range(8))))
    t16 = collective_time(topo, CommOp("c", "all_reduce", 1e9,
                                       tuple(range(16))))
    # ring all-reduce cost grows with (n-1)/n -> saturates, never shrinks
    assert t16 >= t8


def test_allreduce_degrades_with_bandwidth():
    lo = hetero_cluster({"V100": 8}, inter_bw=5e9, gpus_per_node=4)
    hi = hetero_cluster({"V100": 8}, inter_bw=50e9, gpus_per_node=4)
    ranks = list(range(8))
    assert allreduce_time(lo, 1e9, ranks) > allreduce_time(hi, 1e9, ranks)


def test_mesh_collective_model_axes_independent():
    m = MeshCollectiveModel()
    # same-axis volumes serialize; the model exposes per-axis costs so the
    # planner can overlap different axes (multi-edge: ici-x vs ici-y)
    t_ar = m.axis_allreduce(1e9, 16)
    t_ag = m.axis_allgather(1e9, 16)
    assert t_ar == pytest.approx(2 * t_ag, rel=0.01)
    assert m.axis_allreduce(1e9, 16, inter_pod=True) > t_ar
