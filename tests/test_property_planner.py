"""Property-based tests (hypothesis) for the planner/simulator invariants."""

import math

import pytest

# randomized search over graph/cluster instances — long-running, slow suite
pytestmark = pytest.mark.slow

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (ClusterTopology, DeviceInstance, DeviceSpec, Edge,
                        OpGraph, OpNode, branch_and_bound_assign,
                        bnb_layer_split, exhaustive_assign, greedy_assign,
                        simulate_schedule, ModelDesc)
from repro.core.planner import _stage_rate


@st.composite
def graph_and_cluster(draw):
    n_ops = draw(st.integers(2, 5))
    n_dev = draw(st.integers(2, 3))
    g = OpGraph()
    for i in range(n_ops):
        g.add(OpNode(f"op{i}", "mm",
                     flops=draw(st.floats(1e10, 1e13)),
                     bytes_accessed=draw(st.floats(1e6, 1e9)),
                     mem_required=1e6,
                     out_bytes=draw(st.floats(1e5, 1e8))))
    # random DAG edges i -> j (i < j)
    for j in range(1, n_ops):
        for i in range(j):
            if draw(st.booleans()):
                g.connect(f"op{i}", f"op{j}")
    devs = []
    for d in range(n_dev):
        peak = draw(st.floats(1e13, 2e14))
        devs.append(DeviceInstance(d, DeviceSpec(f"d{d}", peak, 1e12, 64e9)))
    topo = ClusterTopology(devs)
    for a in range(n_dev):
        for b in range(a + 1, n_dev):
            topo.add_link(a, b, Edge(draw(st.floats(1e9, 1e11)), 1e-6, "l"))
    return g, topo


@settings(max_examples=25, deadline=None)
@given(graph_and_cluster())
def test_bnb_optimal_and_sound(gc):
    """Alg. 1 soundness: equals exhaustive optimum, never beats it (the
    bound is admissible), and never loses to its own greedy warm start."""
    g, topo = gc
    a_ex, c_ex = exhaustive_assign(g, topo)
    a_bb, c_bb, stats = branch_and_bound_assign(g, topo, n_workers=2)
    assert c_bb <= simulate_schedule(g, greedy_assign(g, topo), topo).makespan + 1e-9
    assert c_bb == pytest.approx(c_ex, rel=1e-6, abs=1e-9)


@settings(max_examples=25, deadline=None)
@given(graph_and_cluster())
def test_simulated_schedule_respects_dependencies(gc):
    g, topo = gc
    assignment = greedy_assign(g, topo)
    res = simulate_schedule(g, assignment, topo)
    for (u, v) in g.edges:
        assert res.op_start[v] >= res.op_end[u] - 1e-9
    # busy time never exceeds makespan per device
    for d, busy in res.device_busy.items():
        assert busy <= res.makespan + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 4), st.integers(6, 24),
       st.lists(st.floats(0.3, 3.0), min_size=2, max_size=4))
def test_layer_split_partitions_exactly(n_stages_raw, n_layers, speeds):
    n_stages = min(len(speeds), n_stages_raw, n_layers)
    speeds = speeds[:n_stages]
    desc = ModelDesc(name="m", n_layers=n_layers, d_model=256, n_heads=4,
                     n_kv_heads=4, d_ff=1024, vocab=1000)
    devs = [DeviceInstance(i, DeviceSpec(f"d{i}", s * 1e14, 1e12, 640e9))
            for i, s in enumerate(speeds)]
    topo = ClusterTopology(devs)
    groups = [[i] for i in range(n_stages)]
    sizes, _ = bnb_layer_split(desc, topo, groups, tp=1, batch=4, seq=128)
    assert len(sizes) == n_stages
    assert sum(sizes) == n_layers
    assert all(s >= 1 for s in sizes)
    # optimality: no single-layer move improves the bottleneck
    from repro.core.opgraph import layer_flops
    costs = [layer_flops(desc, i, 4, 128) * 3 for i in range(n_layers)]
    rates = [_stage_rate(topo, gr, 1) for gr in groups]

    def bottleneck(sz):
        t, lo = 0.0, 0
        for s, k in enumerate(sz):
            t = max(t, sum(costs[lo:lo + k]) / rates[s])
            lo += k
        return t

    base = bottleneck(sizes)
    for i in range(n_stages - 1):
        for delta in (-1, 1):
            cand = list(sizes)
            cand[i] += delta
            cand[i + 1] -= delta
            if min(cand) >= 1:
                assert bottleneck(cand) >= base - 1e-9


@st.composite
def sparse_routed_topology(draw):
    """A random sparse (but connected) cluster plus a pair forced to
    relay: a spanning chain with extra random chords, where the probe
    pair's direct link is never added."""
    n = draw(st.integers(3, 7))
    spec = DeviceSpec("d", 1e14, 1e12, 64e9)
    topo = ClusterTopology([DeviceInstance(i, spec) for i in range(n)])
    for i in range(n - 1):
        topo.add_link(i, i + 1, Edge(draw(st.floats(1e9, 4e11)),
                                     draw(st.floats(1e-7, 1e-4)), "l"))
    for a in range(n):
        for b in range(a + 2, n):
            if (a, b) != (0, n - 1) and draw(st.booleans()):
                topo.add_link(a, b, Edge(draw(st.floats(1e9, 4e11)),
                                         draw(st.floats(1e-7, 1e-4)), "l"))
    size = draw(st.floats(1.0, 2e10))
    return topo, (0, n - 1), size


@settings(max_examples=40, deadline=None)
@given(sparse_routed_topology())
def test_fabric_pipelined_invariants_on_random_sparse_graphs(ts):
    """ISSUE 8 satellite: over randomized sparse graphs the pipelined
    routed price is <= store-and-forward, == the direct-link price on
    single-hop routes, and >= the slowest hop's own price."""
    from repro.core import FabricModel, default_fabric, use_fabric
    from repro.core.costmodel import transfer_time

    topo, (a, b), size = ts
    route = topo.routing().route(a, b)
    assert route is not None
    fab = default_fabric()
    pip = transfer_time(topo, a, b, size)
    assert math.isfinite(pip)
    assert pip == fab.route_time(route, size)
    with use_fabric(FabricModel(pipelining=False)):
        snf = transfer_time(topo, a, b, size)
    assert pip <= snf * (1 + 1e-12)
    for u, v in zip(route.path, route.path[1:]):
        hop = transfer_time(topo, u, v, size)
        assert pip >= hop * (1 - 1e-12)
    # direct pairs price as their best physical edge (single-hop identity)
    assert transfer_time(topo, 0, 1, size) == pytest.approx(
        fab.edge_time(topo.link(0, 1).best_edge(size), size))


@st.composite
def hetero_model_and_cluster(draw):
    """Random hetero/sparse cluster + small model, mirroring the ISSUE 5
    cascade-soundness generator: random device mixes, random inter-node
    bandwidth, and an optional random link-subset deletion that leaves
    multi-hop-routed (possibly partitioned) pairs."""
    from repro.core import hetero_cluster
    heads = draw(st.sampled_from([2, 4]))
    model = ModelDesc(name="h", n_layers=draw(st.integers(2, 6)),
                      d_model=128 * heads, n_heads=heads, n_kv_heads=heads,
                      d_ff=draw(st.sampled_from([512, 1024])), vocab=1000)
    kinds = draw(st.sampled_from([{"V100": 4}, {"RTX4090D": 2, "V100": 2},
                                  {"RTX4090D": 4, "V100": 4},
                                  {"H100": 2, "V100": 2}]))
    inter = draw(st.sampled_from([5e9, 25e9, 100e9]))
    topo = hetero_cluster(kinds, inter_bw=inter,
                          gpus_per_node=draw(st.sampled_from([2, 4])))
    keys = sorted(topo.links)
    if len(keys) > 1 and draw(st.booleans()):
        for k in draw(st.sets(st.sampled_from(keys), max_size=len(keys) - 1)):
            del topo.links[k]
        topo.invalidate_snapshots()
    gb = draw(st.sampled_from([4, 8, 16]))
    return model, topo, gb


@settings(max_examples=25, deadline=None)
@given(hetero_model_and_cluster())
def test_lp_lower_bound_admissible_on_random_clusters(mc):
    """ISSUE 9 satellite: the tier-2.5 LP relaxation undershoots the
    simulated step time of every (point, refine) candidate on randomized
    sparse/hetero clusters, and the tier chain stays monotone
    (point <= coarse <= lp <= sim)."""
    from repro.core import (coarse_lower_bound, enumerate_strategies,
                            lp_bound_context, lp_lower_bound,
                            materialize_variant, simulate_training_step)
    model, topo, gb = mc
    pts, _ = enumerate_strategies(topo, model, global_batch=gb)
    ctx = lp_bound_context(topo, model, global_batch=gb, seq=256)
    variants = (True, False) if topo.is_heterogeneous() else (False,)
    for p in pts:
        lb2 = coarse_lower_bound(p, topo, model, global_batch=gb, seq=256)
        lb3p = lp_lower_bound(p, topo, model, global_batch=gb, seq=256,
                              ctx=ctx)
        assert lb3p >= lb2 - 1e-12, p
        for refine in variants:
            lb3 = lp_lower_bound(p, topo, model, global_batch=gb, seq=256,
                                 refine=refine, ctx=ctx)
            assert lb3 >= lb3p - 1e-12, (p, refine)
            try:
                plan = materialize_variant(p, refine, topo, model,
                                           global_batch=gb, seq=256)
                sim = simulate_training_step(plan, model, topo,
                                             global_batch=gb, seq=256)
            except (ValueError, ZeroDivisionError):
                continue
            rel = 1e-9 * max(1.0, sim.step_time)
            assert lb3 <= sim.step_time + rel, (p, refine)


@settings(max_examples=15, deadline=None)
@given(st.floats(0.05, 1.0))
def test_slowdown_never_speeds_up_schedule(factor):
    g = OpGraph()
    g.add(OpNode("a", "mm", flops=1e12, out_bytes=1e6))
    g.add(OpNode("b", "mm", flops=1e12))
    g.connect("a", "b")
    spec = DeviceSpec("d", 1e14, 1e12, 64e9)
    topo = ClusterTopology([DeviceInstance(0, spec), DeviceInstance(1, spec)])
    topo.add_link(0, 1, Edge(1e10, 1e-6, "l"))
    base = simulate_schedule(g, {"a": 0, "b": 1}, topo).makespan
    topo.devices[1].perf_factor = factor
    slowed = simulate_schedule(g, {"a": 0, "b": 1}, topo).makespan
    assert slowed >= base - 1e-12
