"""Tiered search pipeline (ISSUE 4 tentpole): cascade soundness, per-tier
telemetry, process-parallel determinism, and cross-process cache merge."""

import math

import pytest

from repro.core import (ClusterTopology, DEVICE_PROFILES, DeviceInstance,
                        Edge, ModelDesc, SearchExecutor, StrategyCache,
                        coarse_lower_bound, enumerate_strategies,
                        hetero_cluster, homogeneous_cluster,
                        materialize_variant, multi_pod_tpu, plan_hybrid,
                        point_feasible, point_lower_bound, score_candidates,
                        simulate_training_step)
from repro.core.planner import SearchStats


def line_cluster(n=4, spec="V100", bw=50e9):
    """Chain topology: device i linked only to i+1 — every non-adjacent
    pair is multi-hop routed."""
    devs = [DeviceInstance(i, DEVICE_PROFILES[spec]) for i in range(n)]
    topo = ClusterTopology(devs)
    for i in range(n - 1):
        topo.add_link(i, i + 1, Edge(bw, 1e-6, "link"))
    return topo

DESC = ModelDesc(name="m", n_layers=12, d_model=1024, n_heads=16,
                 n_kv_heads=16, d_ff=4096, vocab=32000)

CLUSTERS = [
    ("hetero", lambda: hetero_cluster({"RTX4090D": 4, "V100": 4},
                                      gpus_per_node=4)),
    ("homo", lambda: homogeneous_cluster(8, "V100", gpus_per_node=8)),
    ("slowlink", lambda: hetero_cluster({"V100": 8}, inter_bw=5e9,
                                        gpus_per_node=4)),
    # sparse link graphs: missing-link pairs are multi-hop routed, and the
    # bound keeps its incident/connectivity ring caps (ISSUE 5)
    ("torus", lambda: multi_pod_tpu(pods=2, chips_per_pod=16)),
    ("line", lambda: line_cluster(4)),
    # unique fastest pair: a 2-member ring crosses only ONE pair, so the
    # g-th-largest pair cap must not apply at g=2 (review regression)
    ("unique-fast-pair", lambda: hetero_cluster({"H100": 2, "RTX4090D": 2},
                                                gpus_per_node=2)),
]


# ---------------------------------------------------------------------------
# Soundness: the cascade never discards the true argmin
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,make", CLUSTERS)
def test_cascade_matches_exhaustive(name, make):
    topo = make()
    exh = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                      with_baseline=False, prune=False)
    cas = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                      with_baseline=False)
    assert cas.plan.to_json() == exh.plan.to_json(), name
    assert cas.predicted.step_time == exh.predicted.step_time
    # the cascade did strictly less simulation work
    assert cas.search_stats.simulated <= exh.search_stats.simulated


def test_cascade_top_k_matches_exhaustive_top_k():
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    exh = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                      with_baseline=False, prune=False, top_k=3)
    cas = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                      with_baseline=False, top_k=3)
    assert len(cas.top_plans) == len(exh.top_plans) == 3
    for (pa, sa), (pb, sb) in zip(cas.top_plans, exh.top_plans):
        assert pa.to_json() == pb.to_json()
        assert sa.step_time == sb.step_time


def test_coarse_bound_admissible_for_every_candidate():
    """Tier-1/2 bounds undershoot the simulator for BOTH materializations
    of every enumerated point (the invariant pruning soundness rests on)."""
    for name, make in CLUSTERS:
        topo = make()
        pts, _ = enumerate_strategies(topo, DESC, global_batch=32)
        variants = (True, False) if topo.is_heterogeneous() else (False,)
        for p in pts:
            lb1 = point_lower_bound(p, topo, DESC, global_batch=32, seq=1024)
            lb2 = coarse_lower_bound(p, topo, DESC, global_batch=32,
                                     seq=1024)
            assert lb2 >= lb1 - 1e-12
            for refine in variants:
                try:
                    plan = materialize_variant(p, refine, topo, DESC,
                                               global_batch=32, seq=1024)
                    sim = simulate_training_step(plan, DESC, topo,
                                                 global_batch=32, seq=1024)
                except (ValueError, ZeroDivisionError):
                    continue
                assert lb2 <= sim.step_time + 1e-12, (name, p, refine)


def test_point_feasible_accepts_every_enumerated_point():
    for name, make in CLUSTERS:
        topo = make()
        pts, _ = enumerate_strategies(topo, DESC, global_batch=32)
        assert pts
        assert all(point_feasible(p, topo, DESC, global_batch=32)
                   for p in pts), name


def test_point_feasible_rejects_structural_mismatch():
    from repro.core import StrategyPoint
    topo = homogeneous_cluster(8, "V100", gpus_per_node=8)
    # wrong world size / batch non-divisible / memory blow-up
    assert not point_feasible(StrategyPoint(2, 2, 1, 1, 2, "rs_ag"),
                              topo, DESC, global_batch=32)
    assert not point_feasible(StrategyPoint(8, 1, 1, 1, 1, "rs_ag"),
                              topo, DESC, global_batch=3)
    big = ModelDesc(name="big", n_layers=96, d_model=12288, n_heads=96,
                    n_kv_heads=96, d_ff=49152, vocab=50000)
    assert not point_feasible(StrategyPoint(1, 8, 1, 1, 1, "rs_ag"),
                              topo, big, global_batch=32)


# ---------------------------------------------------------------------------
# Per-tier telemetry
# ---------------------------------------------------------------------------


def test_tier_telemetry_accounts_for_every_candidate():
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    pts, _ = enumerate_strategies(topo, DESC, global_batch=32)
    stats = SearchStats()
    scored = score_candidates(topo, DESC, global_batch=32, seq=1024,
                              points=pts, stats=stats)
    n_variants = len(pts) * 2            # hetero: refined + uniform
    assert stats.cascade_candidates == n_variants
    assert stats.simulated == len(scored)
    assert 0.0 <= stats.prune_rate < 1.0
    # head of the scored list is the argmin with canonical tie-break
    best = min(scored, key=lambda o: (o.sim.step_time, o.index))
    assert scored[0] is best


def test_incumbent_bound_prunes_through_tiers():
    """An externally supplied achievable bound (the re-planning engine's
    incumbent score) cuts candidates at the analytic tiers."""
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    pts, _ = enumerate_strategies(topo, DESC, global_batch=32)
    base = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                       with_baseline=False)
    stats = SearchStats()
    scored = score_candidates(topo, DESC, global_batch=32, seq=1024,
                              points=pts, stats=stats,
                              incumbent_bound=base.predicted.step_time * 1.01)
    assert stats.pruned_bound + stats.pruned_coarse > 0
    # the bound is achievable, so the argmin survives
    assert scored[0].plan.to_json() == base.plan.to_json()


# ---------------------------------------------------------------------------
# Process-parallel scoring: determinism + cache-delta merge
# ---------------------------------------------------------------------------


def test_parallel_search_equals_serial_plan_for_plan():
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    ser = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                      with_baseline=False, top_k=3)
    with SearchExecutor(n_procs=2) as ex:
        par = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                          with_baseline=False, top_k=3, executor=ex)
    assert par.plan.to_json() == ser.plan.to_json()
    assert par.predicted.step_time == ser.predicted.step_time
    for (pa, _), (pb, _) in zip(par.top_plans, ser.top_plans):
        assert pa.to_json() == pb.to_json()


def test_parallel_search_merges_cache_deltas():
    """Worker-produced plans/scores land in the session StrategyCache: a
    follow-up serial search on the same fingerprint is a pure cache hit."""
    topo = homogeneous_cluster(8, "V100", gpus_per_node=8)
    cache = StrategyCache()
    with SearchExecutor(n_procs=2) as ex:
        r1 = plan_hybrid(topo, DESC, global_batch=32, seq=512,
                         with_baseline=False, executor=ex, cache=cache)
    r2 = plan_hybrid(topo, DESC, global_batch=32, seq=512,
                     with_baseline=False, cache=cache)
    assert r2.search_stats.cache_misses == 0
    assert r2.search_stats.cache_hits > 0
    assert r2.plan.to_json() == r1.plan.to_json()
    assert r2.predicted.step_time == r1.predicted.step_time


def test_cache_context_merge_entries_visible():
    """Unit view of the merge: after a parallel search, the cache context
    holds a materialized plan + score for every simulated candidate."""
    topo = homogeneous_cluster(8, "V100", gpus_per_node=8)
    cache = StrategyCache()
    with SearchExecutor(n_procs=2) as ex:
        res = plan_hybrid(topo, DESC, global_batch=32, seq=512,
                          with_baseline=False, executor=ex, cache=cache)
    ctx = cache.context(topo, DESC, global_batch=32, seq=512)
    entries = ctx.materialized()
    assert len(entries) >= res.search_stats.simulated
    assert sum(1 for _, _, sim in entries if sim is not None) \
        >= res.search_stats.simulated


# ---------------------------------------------------------------------------
# Hypothesis: the cascade never prunes the true argmin (randomized)
# ---------------------------------------------------------------------------


try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    @st.composite
    def model_and_cluster(draw):
        heads = draw(st.sampled_from([2, 4, 8]))
        model = ModelDesc(name="h", n_layers=draw(st.integers(2, 8)),
                          d_model=128 * heads, n_heads=heads,
                          n_kv_heads=heads,
                          d_ff=draw(st.sampled_from([512, 1024, 2048])),
                          vocab=1000)
        kinds = draw(st.sampled_from([{"V100": 4}, {"RTX4090D": 4},
                                      {"RTX4090D": 2, "V100": 2},
                                      {"RTX4090D": 4, "V100": 4},
                                      {"V100": 8}]))
        inter = draw(st.sampled_from([5e9, 25e9, 100e9]))
        topo = hetero_cluster(kinds, inter_bw=inter,
                              gpus_per_node=draw(st.sampled_from([2, 4])))
        # ISSUE 5: randomized sparse / partitioned link graphs.  Dropping an
        # arbitrary link subset leaves multi-hop-routed pairs (and possibly
        # disconnected partitions); the cascade must stay exact — or reject
        # planning entirely, matching exhaustive — under routed pricing.
        keys = sorted(topo.links)
        if len(keys) > 1 and draw(st.booleans()):
            for k in draw(st.sets(st.sampled_from(keys),
                                  max_size=len(keys) - 1)):
                del topo.links[k]
            # direct dict mutation is not tracked by the state signature —
            # the topology contract requires an explicit invalidation
            topo.invalidate_snapshots()
        gb = draw(st.sampled_from([4, 8, 16]))
        return model, topo, gb

    @pytest.mark.slow
    @settings(max_examples=20, deadline=None)
    @given(model_and_cluster())
    def test_cascade_never_prunes_true_argmin(mc):
        model, topo, gb = mc
        try:
            exh = plan_hybrid(topo, model, global_batch=gb, seq=256,
                              with_baseline=False, prune=False)
        except RuntimeError:
            # no feasible plan at all: the cascade must agree
            with pytest.raises(RuntimeError):
                plan_hybrid(topo, model, global_batch=gb, seq=256,
                            with_baseline=False)
            return
        cas = plan_hybrid(topo, model, global_batch=gb, seq=256,
                          with_baseline=False)
        assert cas.plan.to_json() == exh.plan.to_json()
        assert cas.predicted.step_time == exh.predicted.step_time


# ---------------------------------------------------------------------------
# Tier 2.5: LP-relaxation bound (ISSUE 9)
# ---------------------------------------------------------------------------


def test_lp_tier_keeps_argmin_and_attributes_prunes():
    """The LP tier is admissible: toggling it changes only how many
    candidates reach the simulator, never the argmin or the portfolio —
    and it must not steal cuts from the coarse tier's tally."""
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    on = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                     with_baseline=False, top_k=3)
    off = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                      with_baseline=False, top_k=3, lp_prune=False)
    assert on.plan.to_json() == off.plan.to_json()
    assert on.predicted.step_time == off.predicted.step_time
    for (pa, _), (pb, _) in zip(on.top_plans, off.top_plans):
        assert pa.to_json() == pb.to_json()
    s_on, s_off = on.search_stats, off.search_stats
    assert s_off.pruned_lp == 0 and s_off.lp_wall_time == 0.0
    assert s_on.pruned_lp > 0
    assert s_on.simulated < s_off.simulated
    assert s_on.prune_rate > s_off.prune_rate
    assert s_on.lp_wall_time > 0.0
    # attribution: a cut only lands in pruned_lp when the coarse bound
    # alone would NOT have made it — coarse's tally is invariant
    assert s_on.pruned_coarse == s_off.pruned_coarse
    assert s_on.pruned_bound == s_off.pruned_bound
    assert s_on.cascade_candidates == s_off.cascade_candidates


def test_lp_tier_debug_asserts_monotonicity(monkeypatch):
    """REPRO_SEARCH_DEBUG=1 checks point <= coarse <= lp <= simulated on
    every simulated candidate; a clean search must sail through with the
    same result as the untraced run."""
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    base = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                       with_baseline=False)
    monkeypatch.setenv("REPRO_SEARCH_DEBUG", "1")
    dbg = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                      with_baseline=False)
    assert dbg.plan.to_json() == base.plan.to_json()
    assert dbg.search_stats.pruned_lp > 0


def test_prune_counter_drift_check_fires(monkeypatch):
    """A tally site that bumps ``stats.pruned`` without going through
    ``_note_pruned`` must fail loudly (the ISSUE 7 drift invariant now
    covers ``pruned_lp`` too)."""
    from repro.core import search as search_mod

    def bypassing_note(stats, obs, tier, n):
        if n > 0:
            stats.pruned += n        # skips the per-tier counter + registry

    monkeypatch.setattr(search_mod, "_note_pruned", bypassing_note)
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    pts, _ = enumerate_strategies(topo, DESC, global_batch=32)
    with pytest.raises(RuntimeError, match="drift"):
        score_candidates(topo, DESC, global_batch=32, seq=1024,
                         points=pts, stats=SearchStats(),
                         incumbent_bound=1e-9)


# ---------------------------------------------------------------------------
# Worker context blob: snapshot rides along, token hashes it (ISSUE 9)
# ---------------------------------------------------------------------------


def test_worker_context_blob_hashes_snapshot(monkeypatch):
    """The cache's materialization snapshot is part of the pickled worker
    context, and the context token is the blob hash — so a snapshot that
    grew since the last search forces a worker-side reload instead of
    serving stale plans."""
    import hashlib
    import pickle

    from repro.core import search as search_mod
    from repro.core.fabric import default_fabric

    monkeypatch.setattr(search_mod, "_CTX_TOKEN", None)
    monkeypatch.setattr(search_mod, "_CTX_STATE", None)
    monkeypatch.setattr(search_mod, "_CTX_MEMO", {})
    monkeypatch.setattr(search_mod, "_CTX_SNAPSHOT", {})

    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    pts, _ = enumerate_strategies(topo, DESC, global_batch=32)
    p = pts[0]
    plan = materialize_variant(p, True, topo, DESC, global_batch=32,
                               seq=1024)

    def pack(snapshot):
        blob = pickle.dumps((topo, DESC, 32, 1024, default_fabric(),
                             snapshot), protocol=pickle.HIGHEST_PROTOCOL)
        return hashlib.sha1(blob).hexdigest(), blob

    t_empty, b_empty = pack({})
    t_snap, b_snap = pack({(p, True): plan})
    assert t_empty != t_snap             # the token covers the snapshot

    search_mod._load_search_ctx(t_empty, b_empty)
    assert search_mod._CTX_SNAPSHOT == {}
    search_mod._CTX_MEMO["sentinel"] = 1
    search_mod._load_search_ctx(t_empty, b_empty)
    assert search_mod._CTX_MEMO.get("sentinel") == 1   # same token: no reload
    search_mod._load_search_ctx(t_snap, b_snap)
    assert (p, True) in search_mod._CTX_SNAPSHOT       # new token: reload
    assert "sentinel" not in search_mod._CTX_MEMO


def test_worker_chunk_consumes_snapshot_plan(monkeypatch):
    """In-process run of the worker chunk entry point: a plan shipped in
    the read-only snapshot is reused (not rebuilt) and scores identically
    to simulating it directly."""
    import hashlib
    import pickle

    from repro.core import search as search_mod
    from repro.core.fabric import default_fabric

    monkeypatch.setattr(search_mod, "_CTX_TOKEN", None)
    monkeypatch.setattr(search_mod, "_CTX_STATE", None)
    monkeypatch.setattr(search_mod, "_CTX_MEMO", {})
    monkeypatch.setattr(search_mod, "_CTX_SNAPSHOT", {})

    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    pts, _ = enumerate_strategies(topo, DESC, global_batch=32)
    p = pts[0]
    plan = materialize_variant(p, True, topo, DESC, global_batch=32,
                               seq=1024)
    blob = pickle.dumps((topo, DESC, 32, 1024, default_fabric(),
                         {(p, True): plan}),
                        protocol=pickle.HIGHEST_PROTOCOL)
    token = hashlib.sha1(blob).hexdigest()
    out, rejected, pruned, delta = search_mod._score_chunk(
        token, blob, [(0.0, 0, p, True)], math.inf, False)
    assert rejected == 0 and pruned == 0 and delta is None
    [(index, point, refine, oplan, sim)] = out
    assert (index, point, refine) == (0, p, True)
    assert oplan.to_json() == plan.to_json()
    direct = simulate_training_step(plan, DESC, topo, global_batch=32,
                                    seq=1024)
    assert sim.step_time == direct.step_time
