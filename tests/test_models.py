"""Per-architecture smoke tests (reduced configs) + model-level invariants.

Every assigned arch: instantiate the reduced same-family config, run one
forward + one train step on CPU, assert output shapes and no NaNs.  Plus
prefill/decode consistency and a learns-something check on a tiny dense
model.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import LM
from repro.optim.adamw import AdamWConfig
from repro.parallel.trainstep import init_train_state, make_train_step


def _mods(cfg, B, key):
    mods = {}
    if cfg.encoder_layers:
        mods["audio_embed"] = jax.random.normal(
            key, (B, cfg.audio_seq, cfg.d_model), cfg.jnp_dtype) * 0.02
    if cfg.cross_attn_every:
        mods["vision_embed"] = jax.random.normal(
            key, (B, cfg.vision_seq, cfg.d_model), cfg.jnp_dtype) * 0.02
    return mods


# one representative arch stays in the tier-1 gate; the full sweep (a jit
# compile per arch, ~1 min total) runs in the slow suite
_FAST_ARCHS = ("qwen2_7b",)


def _arch_params(archs, fast=_FAST_ARCHS):
    return [a if a in fast else pytest.param(a, marks=pytest.mark.slow)
            for a in archs]


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = LM(cfg)
    key = jax.random.PRNGKey(0)
    B, S = 2, 32
    params = model.init(key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    mods = _mods(cfg, B, key)

    x = model.forward(params, tokens, **mods)
    assert x.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(x).all())

    # one full train step (loss + grads + adamw)
    step = make_train_step(model, AdamWConfig(peak_lr=1e-3, warmup_steps=1,
                                              total_steps=10))
    state = init_train_state(model, key)
    batch = {"tokens": tokens,
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab), **mods}
    state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    before = jax.tree.leaves(init_train_state(model, key)["params"])[0]
    after = jax.tree.leaves(state["params"])[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", _arch_params(["gemma_7b",
                                               "qwen3_moe_30b_a3b",
                                               "zamba2_2p7b", "xlstm_125m",
                                               "whisper_medium"],
                                              fast=("gemma_7b",)))
def test_prefill_matches_forward_last_position(arch):
    """prefill's last-token logits == logits computed from full forward."""
    cfg = get_config(arch).reduced()
    model = LM(cfg)
    key = jax.random.PRNGKey(1)
    B, S = 2, 16
    params = model.init(key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    mods = _mods(cfg, B, key)
    logits, cache = model.prefill(params, tokens, **mods)
    from repro.models import layers as L
    x = model.forward(params, tokens, **mods)
    ref = L.logits_chunked(x[:, -1:], params["embed"]["tok"], cfg)[:, 0]
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", _arch_params(["qwen2_7b", "zamba2_2p7b",
                                               "xlstm_125m"]))
def test_decode_consistent_with_forward(arch):
    """Teacher-forced decode over a fresh cache reproduces forward logits."""
    cfg = get_config(arch).reduced()
    model = LM(cfg)
    key = jax.random.PRNGKey(2)
    B, S = 2, 8
    params = model.init(key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    mods = _mods(cfg, B, key)
    # reference: logits at every position from full forward
    from repro.models import layers as L
    x = model.forward(params, tokens, **mods)
    ref_last = L.logits_chunked(x[:, -1:], params["embed"]["tok"], cfg)[:, 0]
    # decode token by token
    cache = model.init_cache(B, S + 1)
    step = jax.jit(model.decode_step)
    for t in range(S):
        logits, cache = model.decode_step(
            params, cache, tokens[:, t:t + 1],
            jnp.full((B,), t, jnp.int32), **mods)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_last),
                               atol=2e-3, rtol=2e-3)


def test_unroll_matches_scan():
    cfg = get_config("gemma_7b").reduced()
    key = jax.random.PRNGKey(3)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    m_scan, m_unroll = LM(cfg), LM(cfg, unroll=True)
    params = m_scan.init(key)
    np.testing.assert_allclose(
        np.asarray(m_scan.forward(params, tokens)),
        np.asarray(m_unroll.forward(params, tokens)), atol=5e-5)


def test_tiny_dense_model_learns():
    """A few dozen steps on structured synthetic data must cut the loss."""
    from repro.data.pipeline import DataConfig, SyntheticLM
    cfg = get_config("qwen2_7b").reduced(n_layers=2, d_model=64, vocab=64,
                                         d_ff=128)
    model = LM(cfg)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=8, seed=7))
    step = jax.jit(make_train_step(
        model, AdamWConfig(peak_lr=5e-3, warmup_steps=5, total_steps=60,
                           weight_decay=0.0)))
    state = init_train_state(model, jax.random.PRNGKey(0))
    losses = []
    for i in range(60):
        b = data.batch(i)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:5]) - 0.5, losses[::10]


def test_moe_capacity_drops_but_routes():
    """MoE block: outputs differ per token (routing) and are finite."""
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    model = LM(cfg)
    key = jax.random.PRNGKey(4)
    params = model.init(key)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    x = model.forward(params, tokens)
    assert bool(jnp.isfinite(x).all())
    assert float(jnp.std(x)) > 0


def test_n_params_matches_materialized():
    for arch in ("gemma_7b", "dbrx_132b"):
        cfg = get_config(arch).reduced()
        model = LM(cfg)
        n_def = model.n_params()
        n_real = sum(x.size for x in jax.tree.leaves(
            model.init(jax.random.PRNGKey(0))))
        assert n_def == n_real
