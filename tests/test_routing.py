"""Multi-hop routing (ISSUE 5 tentpole): widest-path selection, routed
pricing soundness, relay contention, mid-trace re-routing, cache
invalidation, and the executor-batched warm rescore.  Routed pricing goes
through the fabric layer (ISSUE 8): cut-through pipelining by default,
store-and-forward via ``use_fabric(FabricModel(pipelining=False))``."""

import math

import pytest

from repro.core import (DEVICE_PROFILES, ClusterTopology, DeviceInstance,
                        Edge, FabricModel, ModelDesc, NetworkEvent, OpGraph,
                        OpNode, ReplanEngine, RoutingTable, SearchExecutor,
                        StrategyCache, allreduce_time, default_fabric,
                        hetero_cluster, multi_pod_tpu, plan_hybrid,
                        simulate_schedule, transfer_time, use_fabric)
from repro.core.routing import Route

DESC = ModelDesc(name="m", n_layers=8, d_model=1024, n_heads=16,
                 n_kv_heads=16, d_ff=4096, vocab=32000)

V100 = DEVICE_PROFILES["V100"]


def _topo(n, links):
    """links: (a, b, bw_GBps) triples."""
    topo = ClusterTopology([DeviceInstance(i, V100) for i in range(n)])
    for a, b, bw in links:
        topo.add_link(a, b, Edge(bw * 1e9, 1e-6, "link"))
    return topo


# ---------------------------------------------------------------------------
# Route selection
# ---------------------------------------------------------------------------


def test_widest_path_prefers_fat_route():
    # diamond: 0-1-3 over 100 GB/s links, 0-2-3 over 10 GB/s links
    topo = _topo(4, [(0, 1, 100), (1, 3, 100), (0, 2, 10), (2, 3, 10)])
    r = topo.routing().route(0, 3)
    assert r.path == (0, 1, 3)
    assert r.bottleneck_bw == pytest.approx(100e9)
    # effective (store-and-forward) bandwidth: two equal hops halve it
    assert r.effective_bandwidth == pytest.approx(50e9)


def test_widest_path_tie_breaks_by_hops():
    # two 100 GB/s routes 0->3: direct-ish 2 hops vs 3 hops
    topo = _topo(5, [(0, 1, 100), (1, 3, 100),
                     (0, 2, 100), (2, 4, 100), (4, 3, 100)])
    r = topo.routing().route(0, 3)
    assert r.hops == 2


def test_route_reverse_is_exact_mirror():
    topo = multi_pod_tpu(pods=2, chips_per_pod=16)
    rt = topo.routing()
    fwd = rt.route(3, 21)
    rev = rt.route(21, 3)
    assert rev.path == tuple(reversed(fwd.path))
    assert rev.bottleneck_bw == fwd.bottleneck_bw
    assert rev.transfer_time(1e9) == fwd.transfer_time(1e9)


def test_dead_edges_and_devices_not_routable():
    topo = _topo(3, [(0, 1, 100), (1, 2, 100)])
    assert topo.routing().route(0, 2) is not None
    # link death (bandwidth -> 0) removes the hop from the live graph
    topo.apply_event(NetworkEvent(0.0, "bandwidth", factor=0.0))
    assert topo.routing().route(0, 2) is None
    topo.apply_event(NetworkEvent(0.0, "bandwidth", factor=1.0))
    assert topo.routing().route(0, 2) is not None
    # a dead relay device is not routable either
    topo.apply_event(NetworkEvent(0.0, "fail", device_id=1))
    assert topo.routing().route(0, 2) is None


# ---------------------------------------------------------------------------
# Routed pricing
# ---------------------------------------------------------------------------


def test_routed_price_never_below_any_hop():
    """A routed transfer costs at least every single hop's own
    serialization-aware time, and (pipelined) at most the store-and-forward
    sum of hops — which the un-pipelined fabric mode reproduces exactly."""
    topo = _topo(4, [(0, 1, 100), (1, 2, 25), (2, 3, 50)])
    size = 1e9
    routed = transfer_time(topo, 0, 3, size)
    assert math.isfinite(routed)
    hops = [transfer_time(topo, a, b, size) for a, b in ((0, 1), (1, 2),
                                                         (2, 3))]
    assert routed <= sum(hops) + 1e-12
    for h in hops:
        assert routed >= h
    with use_fabric(FabricModel(pipelining=False)):
        assert transfer_time(topo, 0, 3, size) == pytest.approx(sum(hops))


def test_direct_link_wins_over_route():
    # the route selection rule: a live direct link is always taken, routing
    # applies only where none exists
    topo = _topo(3, [(0, 1, 100), (1, 2, 100), (0, 2, 10)])
    t = transfer_time(topo, 0, 2, 1e9)
    assert t == pytest.approx(1e-6 + 1e9 / 10e9)


def test_disconnected_pair_prices_inf_and_planning_rejects():
    topo = _topo(4, [(0, 1, 100), (2, 3, 100)])   # two islands
    assert transfer_time(topo, 0, 2, 1e9) == math.inf
    with pytest.raises(RuntimeError):
        plan_hybrid(topo, DESC, global_batch=8, seq=256,
                    with_baseline=False)
    # and the exhaustive reference agrees (no silent optimistic plans)
    with pytest.raises(RuntimeError):
        plan_hybrid(topo, DESC, global_batch=8, seq=256,
                    with_baseline=False, prune=False)


def test_routed_ring_collective_slower_than_direct():
    """A ring whose pairs relay over shared links must price above the
    same ring on a complete graph of equal link speed."""
    chain = _topo(3, [(0, 1, 100), (1, 2, 100)])
    full = _topo(3, [(0, 1, 100), (1, 2, 100), (0, 2, 100)])
    ranks = [0, 1, 2]
    assert allreduce_time(chain, 1e9, ranks) > allreduce_time(full, 1e9, ranks)


# ---------------------------------------------------------------------------
# Relay contention in the discrete-event simulator
# ---------------------------------------------------------------------------


def test_relay_hops_contend_with_direct_traffic():
    """A relayed transfer claims every physical edge on its route, so it
    serializes with direct traffic on the same link (Fig. 5b generalized)."""
    topo = _topo(3, [(0, 1, 100), (1, 2, 100)])
    g = OpGraph()
    g.add(OpNode("a", "mm", flops=1e9, out_bytes=100e9))   # 0 -> 1 direct
    g.add(OpNode("b", "mm", flops=1e9, out_bytes=100e9))   # 0 -> 2 relayed
    g.add(OpNode("c", "mm", flops=1e9))
    g.add(OpNode("d", "mm", flops=1e9))
    g.connect("a", "c")
    g.connect("b", "d")
    assign = {"a": 0, "b": 0, "c": 1, "d": 2}
    res = simulate_schedule(g, assign, topo)
    # both 1s transfers need edge (0,1): the relayed one queues behind (or
    # ahead of) the direct one, then streams its second hop — cut-through
    # chunks overlap the hops, but the (0,1) serialization is irreducible
    assert res.makespan >= 2.0 - 1e-6
    assert res.makespan < 3.0
    # store-and-forward mode: the relay fully receives before forwarding,
    # so the second hop's full second is paid on top
    with use_fabric(FabricModel(pipelining=False)):
        snf = simulate_schedule(g, assign, topo)
    assert snf.makespan >= 3.0 - 1e-6


def test_dead_relay_forces_reroute_mid_trace():
    """Events re-route: the fast relay dies mid-trace and traffic falls
    back to the slow path — via the same snapshot/version invalidation the
    rest of the temporal machinery uses."""
    topo = _topo(4, [(0, 1, 100), (1, 3, 100), (0, 2, 10), (2, 3, 10)])
    topo.events = [NetworkEvent(5.0, "fail", device_id=1)]
    before = topo.snapshot(4.0)
    after = topo.snapshot(6.0)
    assert before.routing().route(0, 3).path == (0, 1, 3)
    assert after.routing().route(0, 3).path == (0, 2, 3)
    assert transfer_time(after, 0, 3, 1e9) > transfer_time(before, 0, 3, 1e9)


# ---------------------------------------------------------------------------
# Cache invalidation
# ---------------------------------------------------------------------------


def test_route_cache_invalidation_matches_rebuild():
    """After any event, topo.routing() equals a from-scratch RoutingTable
    on every pair (the cached table never serves stale routes)."""
    topo = _topo(5, [(0, 1, 100), (1, 2, 50), (2, 3, 100), (3, 4, 25),
                     (0, 4, 10)])
    events = [NetworkEvent(0.0, "bandwidth", factor=0.2),
              NetworkEvent(0.0, "fail", device_id=2),
              NetworkEvent(0.0, "join", device_id=2),
              NetworkEvent(0.0, "bandwidth", factor=4.0, mode="scale")]
    ids = range(5)
    for ev in events:
        topo.apply_event(ev)
        cached = topo.routing()
        fresh = RoutingTable(topo)
        for a in ids:
            for b in ids:
                rc, rf = cached.route(a, b), fresh.route(a, b)
                if rf is None:
                    assert rc is None, (ev, a, b)
                else:
                    assert rc == rf, (ev, a, b)


def test_routing_table_identity_is_cached():
    topo = _topo(3, [(0, 1, 100), (1, 2, 100)])
    assert topo.routing() is topo.routing()
    topo.apply_event(NetworkEvent(0.0, "bandwidth", factor=0.5))
    t2 = topo.routing()
    assert t2 is topo.routing()
    topo.invalidate_snapshots()
    assert topo.routing() is not t2


# ---------------------------------------------------------------------------
# Executor-batched warm rescore (ISSUE 5 satellite)
# ---------------------------------------------------------------------------


def test_warm_rescore_executor_matches_serial():
    """The bandwidth-rescore path batched through simulate_many on the
    shared SearchExecutor picks the exact plan the serial walk does."""
    topo = hetero_cluster({"V100": 8}, intra_bw_map={"V100": 25e9},
                          inter_bw=12.5e9, gpus_per_node=4)
    ev = NetworkEvent(1.0, "bandwidth", factor=0.2)

    def replay(executor):
        t = topo.copy()
        engine = ReplanEngine(DESC, global_batch=32, seq=1024,
                              cache=StrategyCache(), executor=executor)
        engine.plan(t)
        t.apply_event(ev)
        return engine.replan(t, ev)

    serial = replay(None)
    with SearchExecutor(n_procs=2) as ex:
        par = replay(ex)
    assert par.path == serial.path == "bandwidth-rescore"
    assert par.plan.to_json() == serial.plan.to_json()
    assert par.predicted.step_time == serial.predicted.step_time
    assert par.stats.explored == serial.stats.explored


def test_route_dataclass_basics():
    r = Route(path=(0, 1, 2), bottleneck_bw=100e9, latency=2e-6,
              resistance=2 / 100e9)
    assert r.hops == 2
    assert r.effective_bandwidth == pytest.approx(50e9)
    # transfer_time is a thin delegate onto the default fabric: pipelined
    # price sits between the bottleneck drain and the store-and-forward sum
    snf = 2e-6 + 2e9 / 100e9
    assert r.transfer_time(1e9) == default_fabric().route_time(r, 1e9)
    assert 2e-6 + 1e9 / 100e9 <= r.transfer_time(1e9) <= snf
    with use_fabric(FabricModel(pipelining=False)):
        assert r.transfer_time(1e9) == pytest.approx(snf)
