"""Branch-and-bound planner (paper §3.3 Alg. 1) + strategy pruning (§3.4)."""

import math

import pytest

from repro.core import (ClusterTopology, DeviceInstance, DeviceSpec, Edge,
                        ModelDesc, OpGraph, OpNode, branch_and_bound_assign,
                        bnb_layer_split, enumerate_strategies,
                        exhaustive_assign, greedy_assign, hetero_cluster,
                        homogeneous_cluster, megatron_default_plan,
                        plan_hybrid, simulate_schedule,
                        simulate_training_step)

DESC = ModelDesc(name="m", n_layers=12, d_model=1024, n_heads=16,
                 n_kv_heads=16, d_ff=4096, vocab=32000)


def small_graph(widths=(2, 1, 2)) -> OpGraph:
    g = OpGraph()
    g.add(OpNode("src", "mm", flops=5e11, bytes_accessed=1e8,
                 out_bytes=5e7))
    prev = ["src"]
    for li, w in enumerate(widths):
        cur = []
        for j in range(w):
            n = g.add(OpNode(f"l{li}_{j}", "mm",
                             flops=(1 + li + j) * 3e11,
                             bytes_accessed=1e8, out_bytes=5e7))
            for p in prev:
                g.connect(p, n.name)
            cur.append(n.name)
        prev = cur
    n = g.add(OpNode("sink", "mm", flops=5e11, bytes_accessed=1e8))
    for p in prev:
        g.connect(p, "sink")
    return g


def two_speed_cluster() -> ClusterTopology:
    fast = DeviceSpec("fast", 100e12, 1e12, 32e9)
    slow = DeviceSpec("slow", 25e12, 1e12, 32e9)
    topo = ClusterTopology([DeviceInstance(0, fast), DeviceInstance(1, slow)])
    topo.add_link(0, 1, Edge(25e9, 1e-6, "pcie"))
    return topo


def test_bnb_matches_exhaustive_optimum():
    """Alg. 1 returns the simulator-optimal assignment on small instances."""
    g = small_graph()
    topo = two_speed_cluster()
    a_opt, c_opt = exhaustive_assign(g, topo)
    a_bnb, c_bnb, stats = branch_and_bound_assign(g, topo)
    assert c_bnb == pytest.approx(c_opt, rel=1e-9)
    assert stats.pruned > 0          # pruning actually fired


def test_bnb_never_worse_than_greedy():
    g = small_graph((3, 2))
    topo = two_speed_cluster()
    greedy = greedy_assign(g, topo)
    c_greedy = simulate_schedule(g, greedy, topo).makespan
    _, c_bnb, _ = branch_and_bound_assign(g, topo)
    assert c_bnb <= c_greedy + 1e-12


def test_bnb_layer_split_balances_hetero_stages():
    topo = hetero_cluster({"RTX4090D": 2, "V100": 2}, gpus_per_node=2)
    groups = [[0, 1], [2, 3]]        # stage0 = fast pair, stage1 = slow pair
    sizes, stats = bnb_layer_split(DESC, topo, groups, tp=2,
                                   batch=8, seq=512)
    assert sum(sizes) == DESC.n_layers
    assert sizes[0] > sizes[1]       # fast stage takes more layers
    # optimality vs brute force over all splits
    from repro.core.opgraph import layer_flops
    costs = [layer_flops(DESC, i, 8, 512) * 3 for i in range(DESC.n_layers)]
    from repro.core.planner import _stage_rate
    rates = [_stage_rate(topo, gr, 2) for gr in groups]
    best = math.inf
    for k in range(1, DESC.n_layers):
        t = max(sum(costs[:k]) / rates[0], sum(costs[k:]) / rates[1])
        best = min(best, t)
    got = max(sum(costs[:sizes[0]]) / rates[0],
              sum(costs[sizes[0]:]) / rates[1])
    assert got == pytest.approx(best, rel=1e-9)


def test_enumerate_strategies_prunes_infeasible():
    topo = homogeneous_cluster(8, "V100", gpus_per_node=8)
    big = ModelDesc(name="big", n_layers=96, d_model=12288, n_heads=96,
                    n_kv_heads=96, d_ff=49152, vocab=50000)   # ~175B
    pts, stats = enumerate_strategies(topo, big, global_batch=64)
    # 175B on 8 V100s: every strategy must be memory-pruned (Eq. 6)
    assert not pts and stats.pruned > 0
    pts_small, _ = enumerate_strategies(topo, DESC, global_batch=64)
    assert pts_small
    assert all(p.dp * p.tp * p.pp == 8 for p in pts_small)
    assert all(DESC.n_heads % p.tp == 0 for p in pts_small)


def test_plan_hybrid_hetero_beats_megatron_default():
    """Paper Fig. 6b: disparate devices -> large speedup over Megatron."""
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    res = plan_hybrid(topo, DESC, global_batch=32, seq=1024)
    assert res.speedup_vs_baseline > 1.2
    # and it never loses to the baseline on a homogeneous cluster
    topo_h = homogeneous_cluster(8, "V100", gpus_per_node=8)
    res_h = plan_hybrid(topo_h, DESC, global_batch=32, seq=1024)
    assert res_h.speedup_vs_baseline >= 0.99


def test_planner_prefers_decomposed_sync_on_slow_links():
    topo = hetero_cluster({"V100": 8}, inter_bw=5e9, gpus_per_node=4)
    res = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                      with_baseline=False)
    assert res.plan.grad_sync == "rs_ag"
