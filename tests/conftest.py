"""Shared test fixtures.

Keeps the planner's default search spaces small during tests so the tier-1
(``-m "not slow"``) subset stays within its CI budget.  Tests that pass
``max_candidates`` explicitly are unaffected, as is
production code (the defaults are only shrunk for the test session).
"""

import pytest


@pytest.fixture(autouse=True)
def _small_search_spaces(monkeypatch):
    from repro.core import planner

    monkeypatch.setattr(planner, "DEFAULT_MAX_CANDIDATES", 96)
