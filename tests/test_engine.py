"""Incremental re-planning engine: fingerprints, strategy cache, and
warm-vs-cold plan equivalence (ISSUE 1 tentpole)."""

import math

import pytest

from repro.core import (ModelDesc, NetworkEvent, ReplanEngine, StrategyCache,
                        fingerprint_topology, hetero_cluster, plan_hybrid)
from repro.core import planner as planner_mod

DESC = ModelDesc(name="m", n_layers=12, d_model=1024, n_heads=16,
                 n_kv_heads=16, d_ff=4096, vocab=32000)


def v100_fabric(n=8, factor=1.0):
    """fig6c-style V100-32G-PCIe cluster whose whole fabric scales (S1)."""
    return hetero_cluster({"V100": n}, intra_bw_map={"V100": 25e9 * factor},
                          inter_bw=12.5e9 * factor, gpus_per_node=4)


# ---------------------------------------------------------------------------
# TopologyFingerprint
# ---------------------------------------------------------------------------


def test_fingerprint_stable_for_identical_topologies():
    a, b = v100_fabric(), v100_fabric()
    assert fingerprint_topology(a).key == fingerprint_topology(b).key


def test_fingerprint_ignores_sub_bucket_bandwidth_wobble():
    # ~1% wobble stays inside one log2/0.25 bucket
    a, b = v100_fabric(factor=1.0), v100_fabric(factor=1.01)
    assert fingerprint_topology(a).key == fingerprint_topology(b).key


def test_fingerprint_changes_when_bandwidth_bucket_changes():
    a, b = v100_fabric(factor=1.0), v100_fabric(factor=0.2)
    fa, fb = fingerprint_topology(a), fingerprint_topology(b)
    assert fa.key != fb.key
    # a links-only change keeps the device identity
    assert fa.device_key == fb.device_key


def test_fingerprint_changes_on_perf_factor_and_death():
    base = v100_fabric()
    slowed = v100_fabric()
    slowed.apply_event(NetworkEvent(0.0, "slowdown", device_id=0, factor=0.5))
    assert fingerprint_topology(base).key != fingerprint_topology(slowed).key
    # perf change is not a device-set change
    assert fingerprint_topology(base).device_key \
        == fingerprint_topology(slowed).device_key
    dead = v100_fabric()
    dead.apply_event(NetworkEvent(0.0, "fail", device_id=7))
    assert fingerprint_topology(base).device_key \
        != fingerprint_topology(dead).device_key


# ---------------------------------------------------------------------------
# StrategyCache
# ---------------------------------------------------------------------------


def test_repeated_plan_hits_cache():
    topo = v100_fabric()
    engine = ReplanEngine(DESC, global_batch=32, seq=512,
                          cache=StrategyCache())
    r1 = engine.plan(topo)
    assert r1.stats.cache_misses > 0
    hits_before = engine.cache.stats.hits
    r2 = engine.plan(topo)          # identical topology: everything memoized
    assert engine.cache.stats.hits > hits_before
    assert r2.stats.cache_misses == 0
    assert r2.predicted.step_time == pytest.approx(r1.predicted.step_time)
    assert r2.wall_time < r1.wall_time


def test_repeated_replan_hits_cache():
    topo = v100_fabric()
    engine = ReplanEngine(DESC, global_batch=32, seq=512,
                          cache=StrategyCache())
    engine.plan(topo)
    ev = NetworkEvent(1.0, "bandwidth", factor=0.2)
    low = v100_fabric(factor=0.2)
    r1 = engine.replan(low, ev)
    assert r1.path == "bandwidth-rescore"
    # the same event again: scores for the low-bw fingerprint are all cached
    r2 = engine.replan(low, ev)
    assert r2.path == "bandwidth-rescore"
    assert r2.stats.cache_hits > 0
    assert r2.predicted.step_time == pytest.approx(r1.predicted.step_time)


def test_cache_lru_eviction_bound():
    cache = StrategyCache(max_entries=2)
    for f in (1.0, 2.0, 4.0, 8.0):
        cache.context(v100_fabric(factor=f), DESC, global_batch=32, seq=512)
    assert len(cache) == 2
    assert cache.stats.evictions == 2


# ---------------------------------------------------------------------------
# Warm replan vs cold plan equivalence
# ---------------------------------------------------------------------------


def test_warm_bandwidth_replan_matches_cold_plan_quality():
    """The acceptance gate's equivalence half: warm re-plan lands within 5%
    of a from-scratch plan_hybrid on the same post-event topology."""
    engine = ReplanEngine(DESC, global_batch=32, seq=512,
                          cache=StrategyCache())
    engine.plan(v100_fabric())
    for factor in (0.2, 4.0):
        post = v100_fabric(factor=factor)
        warm = engine.replan(post, NetworkEvent(1.0, "bandwidth",
                                                factor=factor))
        cold = plan_hybrid(post, DESC, global_batch=32, seq=512,
                           with_baseline=False)
        assert warm.path == "bandwidth-rescore"
        assert warm.predicted.step_time \
            <= cold.predicted.step_time * 1.05, factor
        # bandwidth path never re-enumerates: far fewer sims than cold
        assert warm.stats.explored < cold.candidates_evaluated


def test_fail_replan_returns_feasible_plan_on_survivors():
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    engine = ReplanEngine(DESC, global_batch=32, seq=512,
                          cache=StrategyCache())
    engine.plan(topo)
    topo.apply_event(NetworkEvent(1.0, "fail", device_id=7))
    res = engine.replan(topo, NetworkEvent(1.0, "fail", device_id=7))
    assert res.path in ("neighborhood", "full-replan")
    alive = set(topo.alive_ids())
    used = {d for st in res.plan.stages for d in st.device_ids}
    assert used <= alive
    assert math.isfinite(res.predicted.step_time)


def test_fail_replan_never_returns_plan_naming_dead_device():
    """Regression: the simulator silently drops dead members from TP groups,
    so a stale incumbent can look optimistic on the post-failure topology —
    the engine must not hand it back."""
    from repro.core import materialize_plan, StrategyPoint
    topo = v100_fabric(8)
    engine = ReplanEngine(DESC, global_batch=32, seq=512,
                          cache=StrategyCache())
    engine.plan(topo)
    # force an incumbent whose TP group spans device 7
    inc = materialize_plan(StrategyPoint(2, 2, 2, 1, 2, "rs_ag"), topo, DESC,
                           global_batch=32, seq=512)
    from repro.core import simulate_training_step
    engine.incumbent = (inc, simulate_training_step(
        inc, DESC, topo, global_batch=32, seq=512))
    topo.apply_event(NetworkEvent(1.0, "fail", device_id=7))
    res = engine.replan(topo, NetworkEvent(1.0, "fail", device_id=7))
    alive = set(topo.alive_ids())
    used = {d for st in res.plan.stages for d in st.device_ids}
    assert used <= alive, (used, alive)


def test_straggler_replan_rebalances_and_does_not_regress():
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    engine = ReplanEngine(DESC, global_batch=32, seq=512,
                          cache=StrategyCache())
    r0 = engine.plan(topo)
    topo.apply_event(NetworkEvent(1.0, "slowdown", device_id=0, factor=0.25))
    res = engine.replan(topo, NetworkEvent(1.0, "slowdown", device_id=0,
                                           factor=0.25))
    # the local rebalance may escalate to the dp/tp/pp neighborhood when the
    # rebalanced step time stays above the configured gap (ISSUE 3)
    assert res.path in ("straggler-rebalance", "straggler-neighborhood")
    # incumbent re-scored on the new topology is always a candidate, so the
    # chosen plan can only be at least as good
    from repro.core import simulate_training_step
    inc = simulate_training_step(r0.plan, DESC, topo, global_batch=32,
                                 seq=512)
    assert res.predicted.step_time <= inc.step_time * (1 + 1e-9)


# ---------------------------------------------------------------------------
# Search statistics: silent rejections are now counted (ISSUE 1 small fix)
# ---------------------------------------------------------------------------


def test_plan_hybrid_counts_scoring_rejections(monkeypatch):
    from repro.core import search as search_mod
    topo = v100_fabric()
    real = search_mod.simulate_many

    def flaky(plans, model, topo_, **kw):
        return [None if p.grad_sync == "allreduce" else s
                for p, s in zip(plans, real(plans, model, topo_, **kw))]

    monkeypatch.setattr(search_mod, "simulate_many", flaky)
    res = plan_hybrid(topo, DESC, global_batch=32, seq=512,
                      with_baseline=False)
    assert res.candidates_rejected > 0
    assert res.search_stats is not None
    assert res.search_stats.rejected == res.candidates_rejected
    assert res.plan.grad_sync == "rs_ag"
