"""Multi-device distribution tests (8 emulated host devices, subprocess).

The main pytest process must keep seeing ONE device (smoke tests), so every
case here launches a fresh interpreter with
XLA_FLAGS=--xla_force_host_platform_device_count=8 and asserts inside it.
Covers: sharded-vs-single-device train-step equivalence, the shard_map
pipeline, explicit collective schedules, and a small-mesh dry-run lowering.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

# every case spawns a fresh interpreter and recompiles under an 8-device
# host mesh — minutes of wall time, excluded from the tier-1 CI gate
pytestmark = pytest.mark.slow

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_devices(body: str, n: int = 8) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        assert len(jax.devices()) == {n}
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    run_devices("""
        from repro.configs import get_config
        from repro.models.lm import LM
        from repro.optim.adamw import AdamWConfig
        from repro.parallel import sharding as shd
        from repro.parallel.axes import use_rules
        from repro.parallel.trainstep import init_train_state, make_train_step
        cfg = get_config("qwen2_7b").reduced(n_layers=2, d_model=64,
                                             vocab=128, d_ff=128,
                                             n_heads=4, n_kv_heads=2,
                                             head_dim=16)
        model = LM(cfg)
        key = jax.random.PRNGKey(0)
        step = make_train_step(model, AdamWConfig(peak_lr=1e-3,
                                                  warmup_steps=1,
                                                  total_steps=10))
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab),
                 "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab)}
        # single device
        s0 = init_train_state(model, key)
        s0, m0 = jax.jit(step)(s0, batch)
        # sharded 4x2 (data x model)
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        prof = shd.profile_for(cfg, mesh, zero3=True)
        st_sh = {"params": shd.param_shardings(model, mesh, prof.rules),
                 "opt": shd.opt_state_shardings(model, mesh,
                                                prof.opt_rules)}
        def wrapped(state, b):
            with use_rules(mesh, prof.rules):
                return step(state, b)
        s1 = jax.device_put(init_train_state(model, key), st_sh)
        with mesh:
            s1, m1 = jax.jit(wrapped, in_shardings=(st_sh, None),
                             out_shardings=(st_sh, None))(s1, batch)
        assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-4, \\
            (float(m0["loss"]), float(m1["loss"]))
        for a, b in zip(jax.tree.leaves(s0["params"]),
                        jax.tree.leaves(s1["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=2e-3)
        print("sharded == single OK")
    """)


def test_pipeline_uneven_stages_fwd_bwd():
    run_devices("""
        from repro.parallel.pipeline import pad_stages, pipeline_forward
        mesh = Mesh(np.array(jax.devices()[:4]).reshape(4), ("pipe",))
        L, d, M, mb = 7, 16, 6, 3
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (L, d, d)) * 0.3
        sizes = [2, 2, 2, 1]                      # planner's uneven split
        sp, mask = pad_stages({"w": Ws}, sizes)
        x = jax.random.normal(key, (M, mb, d))
        fn = lambda p, h: jnp.tanh(h @ p["w"])
        out = pipeline_forward(fn, sp, mask, x, mesh=mesh)
        ref = x
        for i in range(L): ref = jnp.tanh(ref @ Ws[i])
        np.testing.assert_allclose(out, ref, atol=1e-5)
        def loss(W):
            s, m = pad_stages({"w": W}, sizes)
            return jnp.sum(pipeline_forward(fn, s, m, x, mesh=mesh) ** 2)
        g = jax.grad(loss)(Ws)
        def loss_ref(W):
            r = x
            for i in range(L): r = jnp.tanh(r @ W[i])
            return jnp.sum(r ** 2)
        gr = jax.grad(loss_ref)(Ws)
        np.testing.assert_allclose(g, gr, atol=1e-4)
        print("pipeline OK")
    """, n=4)


def test_collective_schedules_equivalent():
    run_devices("""
        from repro.parallel.collectives import sync_grads
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        g = {"a": jnp.arange(24.0).reshape(4, 6), "b": jnp.ones((7,))}
        ar, _ = sync_grads(g, mesh, "data", schedule="allreduce")
        rs, _ = sync_grads(g, mesh, "data", schedule="rs_ag")
        for x, y in zip(jax.tree.leaves(ar), jax.tree.leaves(rs)):
            np.testing.assert_allclose(x, y, atol=1e-6)
        # int8: bounded per-step error, error-feedback residual carried
        q, err = sync_grads(g, mesh, "data", schedule="int8")
        scale = float(jnp.max(jnp.abs(g["a"]))) / 127
        assert float(jnp.max(jnp.abs(q["a"] - g["a"]))) <= scale + 1e-6
        assert err is not None
        print("collectives OK")
    """)


def test_small_mesh_dryrun_lowers_and_compiles():
    """End-to-end dry-run machinery on a 2x4 mesh (fast miniature of the
    production 16x16 path, exercising identical code)."""
    run_devices("""
        from repro.configs import get_config
        from repro.launch.dryrun import build_lowered
        from repro.launch.mesh import make_mesh
        from repro.models.config import SHAPES_BY_NAME, ShapeSpec
        from repro.parallel import sharding as shd
        import dataclasses
        cfg = get_config("gemma_7b").reduced()
        shape = ShapeSpec("mini_train", 64, 8, "train")
        mesh = make_mesh((2, 4), ("data", "model"))
        prof = shd.profile_for(cfg, mesh, zero3=True)
        lowered = build_lowered(cfg, shape, mesh, prof, microbatches=2,
                                donate=True)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):   # jax<=0.4.x returns [dict]
            ca = ca[0]
        assert ca["flops"] > 0
        txt = compiled.as_text()
        assert any(k in txt for k in ("all-reduce", "all-gather",
                                      "reduce-scatter"))
        print("mini dryrun OK")
    """)
