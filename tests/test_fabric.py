"""Unified fabric layer (ISSUE 8): one routed-transfer pricing
implementation behind :func:`repro.core.costmodel.transfer_time`, the
simulator's relay, reconfig's reshard pricing and
:meth:`repro.core.routing.Route.transfer_time`.

Covers the cross-path pricing-consistency regression (all four former
implementations must return the *same number* on the same topology), the
cut-through invariants over deterministic randomized sparse graphs (the
hypothesis twin lives in ``test_property_planner.py``), the closed-form ==
relay-recursion identity, ring-capacity semantics, and mid-flight
re-routing inside :func:`repro.core.simulator.simulate_epoch` — including
the catalog-trace outcome it changes.
"""

import math
import random

import pytest

from repro.core import (DEVICE_PROFILES, ClusterTopology, DeviceInstance,
                        Edge, FabricModel, ModelDesc, NetworkEvent, OpGraph,
                        OpNode, allreduce_time, calibrated, default_fabric,
                        megatron_default_plan, set_default_fabric,
                        simulate_epoch, simulate_schedule,
                        simulate_training_step, transfer_time, use_fabric)
from repro.core.costmodel import _bottleneck_bw
from repro.core.reconfig import ReconfigCostModel
from repro.core.routing import Route
from repro.obs import Obs
from repro.scenarios.catalog import build

DESC = ModelDesc(name="m", n_layers=8, d_model=1024, n_heads=16,
                 n_kv_heads=16, d_ff=4096, vocab=32000)

V100 = DEVICE_PROFILES["V100"]


def _topo(n, links):
    """links: (a, b, bw_GBps) triples."""
    topo = ClusterTopology([DeviceInstance(i, V100) for i in range(n)])
    for a, b, bw in links:
        topo.add_link(a, b, Edge(bw * 1e9, 1e-6, "link"))
    return topo


def _random_route(rng):
    """A Route over 1-5 hops with random per-hop bandwidth/latency,
    returning (route, per-hop (bw, lat) list)."""
    hops = rng.randint(1, 5)
    bws = [rng.uniform(1e9, 400e9) for _ in range(hops)]
    lats = [rng.uniform(1e-7, 1e-4) for _ in range(hops)]
    route = Route(path=tuple(range(hops + 1)), bottleneck_bw=min(bws),
                  latency=sum(lats), resistance=sum(1.0 / b for b in bws))
    return route, list(zip(bws, lats))


# ---------------------------------------------------------------------------
# Cut-through closed form: the three pricing invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_pipelined_invariants_random_routes(seed):
    """For any route and size: pipelined <= store-and-forward, == the
    direct-link price on single hops, >= every hop's own price."""
    rng = random.Random(seed)
    fab = FabricModel(alpha=rng.uniform(0.5, 2.0), beta=rng.uniform(0.3, 1.0))
    for _ in range(40):
        route, hops = _random_route(rng)
        size = rng.uniform(1.0, 2e10)
        pip = fab.route_time(route, size)
        snf = fab.store_and_forward_time(route, size)
        assert pip <= snf * (1 + 1e-12)
        for bw, lat in hops:
            assert pip >= fab.hop_time(size, bw, lat) * (1 - 1e-12)
        if route.hops == 1:
            bw, lat = hops[0]
            assert pip == pytest.approx(fab.hop_time(size, bw, lat))
        # un-pipelined mode is exactly the store-and-forward sum
        snf_mode = FabricModel(alpha=fab.alpha, beta=fab.beta,
                               pipelining=False)
        assert snf_mode.route_time(route, size) == pytest.approx(snf)


@pytest.mark.parametrize("seed", range(4))
def test_closed_form_matches_relay_recursion(seed):
    """The simulator's per-hop relay recursion lands on route_time's
    closed form on an uncontended fabric — the identity that makes the
    analytic cost model and the discrete-event simulator price relayed
    transfers identically."""
    rng = random.Random(100 + seed)
    fab = FabricModel(alpha=rng.uniform(0.5, 2.0), beta=rng.uniform(0.3, 1.0))
    for _ in range(40):
        route, hops = _random_route(rng)
        size = rng.uniform(1.0, 2e10)
        first_chunk_at = 0.0
        prev_end = None
        for bw, lat in hops:
            # uncontended: every hop starts the moment its first chunk is in
            prev_end, first_chunk_at = fab.relay_step(
                size, bw, lat, first_chunk_at, first_chunk_at, prev_end)
        assert prev_end == pytest.approx(fab.route_time(route, size),
                                         rel=1e-9)


def test_chunking_and_degenerate_sizes():
    fab = default_fabric()
    assert fab.chunks(0.0) == 1
    assert fab.chunks(1.0) == 1
    assert fab.chunks(fab.chunk_bytes) == 1
    assert fab.chunks(fab.chunk_bytes + 1) == 2
    assert fab.chunks(10.5 * fab.chunk_bytes) == 11
    assert FabricModel(pipelining=False).chunks(1e12) == 1
    route, _ = _random_route(random.Random(0))
    zero = Route(path=(3,), bottleneck_bw=math.inf, latency=0.0,
                 resistance=0.0)
    assert fab.route_time(zero, 1e9) == 0.0
    dead = Route(path=(0, 1), bottleneck_bw=0.0, latency=1e-6,
                 resistance=math.inf)
    assert fab.route_time(dead, 1e9) == math.inf
    assert fab.hop_time(1e9, 0.0, 1e-6) == math.inf


# ---------------------------------------------------------------------------
# Cross-path pricing consistency (the regression the refactor exists for)
# ---------------------------------------------------------------------------


def test_all_pricing_paths_agree_on_routed_pair():
    """costmodel.transfer_time, Route.transfer_time, reconfig's
    _path_time and the discrete-event relay all price the same routed
    transfer to the same number."""
    topo = _topo(4, [(0, 1, 100), (1, 2, 25), (2, 3, 50)])
    size = 1e9

    analytic = transfer_time(topo, 0, 3, size)
    route = topo.routing().route(0, 3)
    via_route = route.transfer_time(size)
    via_reconfig, bw = ReconfigCostModel._path_time(topo, 0, 3, size)

    g = OpGraph()
    g.add(OpNode("a", "mm", flops=0.0, out_bytes=size))
    g.add(OpNode("b", "mm", flops=0.0))
    g.connect("a", "b")
    res = simulate_schedule(g, {"a": 0, "b": 3}, topo)
    via_sim = res.comm_time           # single uncontended transfer

    assert analytic == via_route
    assert analytic == via_reconfig
    assert via_sim == pytest.approx(analytic, rel=1e-9)
    # sustained routed bandwidth is the bottleneck hop's (pipelined)
    assert bw == pytest.approx(default_fabric().beta * 25e9)


def test_all_pricing_paths_agree_on_direct_pair():
    topo = _topo(2, [(0, 1, 100)])
    size = 1e9
    expect = 1e-6 + size / 100e9
    assert transfer_time(topo, 0, 1, size) == pytest.approx(expect)
    t, bw = ReconfigCostModel._path_time(topo, 0, 1, size)
    assert t == pytest.approx(expect)
    assert bw == pytest.approx(100e9)
    g = OpGraph()
    g.add(OpNode("a", "mm", flops=0.0, out_bytes=size))
    g.add(OpNode("b", "mm", flops=0.0))
    g.connect("a", "b")
    res = simulate_schedule(g, {"a": 0, "b": 1}, topo)
    assert res.comm_time == pytest.approx(expect, rel=1e-9)


def test_transfer_dispatch_corner_cases():
    topo = _topo(4, [(0, 1, 100), (2, 3, 100)])      # two islands
    fab = default_fabric()
    assert fab.transfer_time(topo, 1, 1, 1e9) == 0.0
    assert fab.transfer_time(topo, 0, 2, 1e9) == math.inf
    assert fab.path_time(topo, 0, 2, 1e9) == (math.inf, 0.0)
    # explicit edge overrides dispatch entirely
    e = Edge(10e9, 5e-6, "x")
    assert fab.transfer_time(topo, 0, 3, 1e9, edge=e) == \
        pytest.approx(5e-6 + 1e9 / 10e9)


# ---------------------------------------------------------------------------
# Ring capacity (collective pricing)
# ---------------------------------------------------------------------------


def test_ring_capacity_complete_graph_matches_direct_links():
    """On a complete graph the fabric's ring pricing is the plain
    slowest-direct-link rule — identical with and without pipelining."""
    topo = _topo(3, [(0, 1, 100), (1, 2, 100), (0, 2, 100)])
    bw, lat = _bottleneck_bw(topo, [0, 1, 2])
    assert bw == pytest.approx(100e9)
    assert lat == pytest.approx(1e-6)
    with use_fabric(FabricModel(pipelining=False)):
        assert _bottleneck_bw(topo, [0, 1, 2]) == (bw, lat)


def test_ring_capacity_routed_pair_streams_at_bottleneck():
    """A chain ring's wrap pair relays, but its directed hops are unshared
    (full duplex), so pipelining sustains the full link rate; the
    store-and-forward mode halves it (resistance sum)."""
    topo = _topo(3, [(0, 1, 100), (1, 2, 100)])
    bw, lat = _bottleneck_bw(topo, [0, 1, 2])
    assert bw == pytest.approx(100e9)
    assert lat == pytest.approx(2e-6)     # the 2-hop wrap path dominates
    with use_fabric(FabricModel(pipelining=False)):
        snf_bw, snf_lat = _bottleneck_bw(topo, [0, 1, 2])
    assert snf_bw == pytest.approx(50e9)
    assert snf_lat == pytest.approx(2e-6)


def test_ring_capacity_divides_shared_directed_links():
    """Ring order [0, 2, 1, 3] on a 4-chain makes two pair-routes cross
    the same directed link — the sustained rate halves."""
    topo = _topo(4, [(0, 1, 100), (1, 2, 100), (2, 3, 100)])
    bw, _ = _bottleneck_bw(topo, [0, 2, 1, 3])
    assert bw == pytest.approx(50e9)
    # the natural ring order shares nothing and keeps the full rate
    nat, _ = _bottleneck_bw(topo, [0, 1, 2, 3])
    assert nat == pytest.approx(100e9)


def test_ring_capacity_partition_and_small_rings():
    fab = default_fabric()
    topo = _topo(4, [(0, 1, 100), (2, 3, 100)])
    assert fab.ring_capacity(topo, [0, 1, 2]) == (0.0, 0.0)
    assert fab.ring_capacity(topo, [0]) == (math.inf, 0.0)
    assert allreduce_time(topo, 1e9, [0, 2]) == math.inf


# ---------------------------------------------------------------------------
# Default-fabric plumbing (scoped override, calibration)
# ---------------------------------------------------------------------------


def test_use_fabric_scopes_and_restores():
    base = default_fabric()
    custom = FabricModel(alpha=2.0, beta=0.5)
    with use_fabric(custom) as f:
        assert f is custom
        assert default_fabric() is custom
    assert default_fabric() is base
    with pytest.raises(RuntimeError):
        with use_fabric(custom):
            raise RuntimeError("boom")
    assert default_fabric() is base


def test_set_default_fabric_returns_previous():
    base = default_fabric()
    try:
        prev = set_default_fabric(FabricModel(beta=0.7))
        assert prev is base
        assert default_fabric().beta == 0.7
    finally:
        set_default_fabric(base)


def test_calibrated_builds_on_current_default():
    fab = calibrated(1.5, 0.8)
    assert (fab.alpha, fab.beta) == (1.5, 0.8)
    assert fab.chunk_bytes == default_fabric().chunk_bytes
    base = FabricModel(chunk_bytes=4096.0, pipelining=False)
    fab2 = calibrated(2.0, 0.9, base=base)
    assert fab2.chunk_bytes == 4096.0 and not fab2.pipelining


def test_calibration_scales_prices():
    """alpha scales the latency term, beta divides the bandwidth term —
    end to end through the public transfer_time."""
    topo = _topo(3, [(0, 1, 100), (1, 2, 100)])
    size = 1e9
    base = transfer_time(topo, 0, 2, size)
    with use_fabric(calibrated(2.0, 0.5)):
        scaled = transfer_time(topo, 0, 2, size)
    route = topo.routing().route(0, 2)
    fab = calibrated(2.0, 0.5)
    assert scaled == pytest.approx(fab.route_time(route, size))
    assert scaled > base


# ---------------------------------------------------------------------------
# Mid-flight re-routing in simulate_epoch
# ---------------------------------------------------------------------------


def test_midstep_event_splits_and_reprices_the_step():
    """A bandwidth collapse landing inside a step re-prices the remaining
    work fraction immediately; boundary-only mode charges the whole step
    at the pre-event rate."""
    topo = _topo(2, [(0, 1, 100)])
    topo_probe = topo.copy()
    plan = megatron_default_plan(topo_probe, DESC, microbatches=4)
    s0 = simulate_training_step(plan, DESC, topo_probe, global_batch=64,
                                seq=1024).step_time
    tau = 0.4 * s0
    topo.events = [NetworkEvent(tau, "bandwidth", factor=0.1)]
    s1 = simulate_training_step(plan, DESC, topo, global_batch=64,
                                seq=1024, at_time=tau + 1e-9).step_time
    assert s1 > s0

    obs = Obs()
    on = simulate_epoch(plan, DESC, topo, global_batch=64, seq=1024,
                        steps=1, obs=obs)
    off = simulate_epoch(plan, DESC, topo, global_batch=64, seq=1024,
                         steps=1, reroute_in_flight=False)
    # boundary-only: the event is invisible to the single step
    assert off.step_times[0] == pytest.approx(s0)
    # mid-flight: 40% of the work at the old rate, 60% at the degraded one
    assert on.step_times[0] == pytest.approx(tau + 0.6 * s1, rel=1e-9)
    assert on.total_time > off.total_time
    assert obs.metrics.counter_value("sim.reroute.events") == 1
    assert obs.metrics.counter_value("sim.reroute.steps") == 1


def test_midstep_recovery_speeds_up_the_remainder():
    """Re-routing is symmetric: a recovered link speeds the in-flight
    step up, so mid-flight pricing comes in *under* boundary-only."""
    topo = _topo(2, [(0, 1, 100)])
    plan = megatron_default_plan(topo.copy(), DESC, microbatches=4)
    degraded = topo.copy()
    degraded.apply_event(NetworkEvent(0.0, "bandwidth", factor=0.1))
    s_slow = simulate_training_step(plan, DESC, degraded, global_batch=64,
                                    seq=1024).step_time
    tau = 0.3 * s_slow
    topo.events = [NetworkEvent(0.0, "bandwidth", factor=0.1),
                   NetworkEvent(tau, "bandwidth", factor=1.0)]
    on = simulate_epoch(plan, DESC, topo, global_batch=64, seq=1024, steps=1)
    off = simulate_epoch(plan, DESC, topo, global_batch=64, seq=1024,
                         steps=1, reroute_in_flight=False)
    assert on.total_time < off.total_time
    assert off.step_times[0] == pytest.approx(s_slow)


def test_midstep_event_still_triggers_replan_at_next_boundary():
    """An event consumed mid-step must not be lost to the replan hook: the
    next boundary still sees it."""
    topo = _topo(2, [(0, 1, 100)])
    plan = megatron_default_plan(topo.copy(), DESC, microbatches=4)
    s0 = simulate_training_step(plan, DESC, topo, global_batch=64,
                                seq=1024).step_time
    topo.events = [NetworkEvent(0.5 * s0, "bandwidth", factor=0.5)]
    seen = []
    sim = simulate_epoch(plan, DESC, topo, global_batch=64, seq=1024,
                         steps=3, replan_fn=lambda t, at: seen.append(at)
                         or plan)
    assert sim.replans == 1
    assert seen and seen[0] >= 0.5 * s0


def test_reroute_changes_catalog_trace_outcome():
    """Acceptance: mid-flight re-routing changes a catalog-trace outcome.
    diurnal_wan_crossover's 40 s WAN trough lands inside a step at this
    replay scale; the split step re-prices its remainder on the trough
    bandwidth and the epoch total moves (deterministic seed)."""
    topo, trace = build("diurnal_wan_crossover", seed=0)
    plan = megatron_default_plan(topo.copy(), DESC, microbatches=4)
    obs = Obs()
    on = simulate_epoch(plan, DESC, topo, global_batch=512, seq=2048,
                        steps=8, obs=obs)
    off = simulate_epoch(plan, DESC, topo, global_batch=512, seq=2048,
                         steps=8, reroute_in_flight=False)
    assert on.total_time != off.total_time
    assert obs.metrics.counter_value("sim.reroute.events") >= 1
    assert obs.metrics.counter_value("sim.reroute.steps") >= 1
    # determinism: same trace, same outcome
    again = simulate_epoch(plan, DESC, topo, global_batch=512, seq=2048,
                           steps=8)
    assert again.total_time == on.total_time
    assert again.step_times == on.step_times


# ---------------------------------------------------------------------------
# Simulator fabric counters
# ---------------------------------------------------------------------------


def test_simulate_schedule_records_fabric_counters():
    topo = _topo(3, [(0, 1, 100), (1, 2, 100)])
    g = OpGraph()
    g.add(OpNode("a", "mm", flops=0.0, out_bytes=4 * float(1 << 20)))
    g.add(OpNode("b", "mm", flops=0.0))
    g.connect("a", "b")
    obs = Obs()
    simulate_schedule(g, {"a": 0, "b": 2}, topo, obs=obs)
    assert obs.metrics.counter_value("fabric.relays") == 1
    assert obs.metrics.counter_value("fabric.relay_hops") == 2
    assert obs.metrics.counter_value("fabric.chunks") == 4
