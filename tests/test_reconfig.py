"""Physically-modeled reconfiguration cost (ISSUE 3 tentpole): zero-cost
identity, checkpoint-byte monotonicity, bandwidth inverse-monotonicity,
DP-oracle dominance over the greedy oracle, and switch hysteresis
boundary cases."""

import math

import pytest

from repro.core import (ModelDesc, NetworkEvent, ReconfigCostModel,
                        ReplanEngine, StrategyCache, hetero_cluster,
                        megatron_default_plan, plan_hybrid, plan_sequence_dp,
                        simulate_training_step)
from repro.scenarios import ScenarioHarness, list_scenarios

TINY = ModelDesc("tiny", n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
                 d_ff=2048, vocab=32000)
BIG = ModelDesc("big", n_layers=16, d_model=1024, n_heads=16, n_kv_heads=16,
                d_ff=4096, vocab=32000)


def tight_fabric(factor: float = 1.0):
    return hetero_cluster({"V100": 8}, intra_bw_map={"V100": 25e9 * factor},
                          inter_bw=12.5e9 * factor, gpus_per_node=4)


def _plan_pair(model, topo):
    a = plan_hybrid(topo, model, global_batch=32, seq=512,
                    with_baseline=False, max_candidates=24).plan
    b = megatron_default_plan(topo, model)
    assert a.structural_key() != b.structural_key()
    return a, b


# ---------------------------------------------------------------------------
# Cost model invariants
# ---------------------------------------------------------------------------


def test_zero_cost_for_structurally_identical_plans():
    topo = tight_fabric()
    m = ReconfigCostModel(TINY)
    a, b = _plan_pair(TINY, topo)
    for p in (a, b):
        c = m.cost(p, p, topo)
        assert c.total_s == 0.0 and c.reshard_bytes == 0.0
    # a switch that actually changes layout costs something
    assert m.cost(a, b, topo).total_s > 0.0


def test_cost_monotone_in_checkpoint_bytes():
    """A strictly bigger model moves strictly more state for the same plan
    shapes on the same topology."""
    topo = tight_fabric()
    small, big = ReconfigCostModel(TINY), ReconfigCostModel(BIG)
    assert big.checkpoint_bytes() > small.checkpoint_bytes()
    a_s, b_s = _plan_pair(TINY, topo)
    # evaluate the *same structural* switch shapes under both models by
    # pricing each model's own megatron-default vs planner pair
    a_b, b_b = _plan_pair(BIG, topo)
    cs = small.cost(a_s, b_s, topo)
    cb = big.cost(a_b, b_b, topo)
    assert cb.reshard_bytes > cs.reshard_bytes
    assert cb.total_s > cs.total_s


def test_cost_inverse_monotone_in_bandwidth():
    m = ReconfigCostModel(TINY)
    a, b = _plan_pair(TINY, tight_fabric())
    nominal = m.cost(a, b, tight_fabric()).total_s
    degraded_topo = tight_fabric()
    degraded_topo.apply_event(NetworkEvent(0.0, "bandwidth", factor=0.25))
    degraded = m.cost(a, b, degraded_topo).total_s
    boosted_topo = tight_fabric()
    boosted_topo.apply_event(NetworkEvent(0.0, "bandwidth", factor=4.0))
    boosted = m.cost(a, b, boosted_topo).total_s
    assert degraded > nominal > boosted


def test_batch_share_rebalance_is_fabric_free():
    """A plan differing only in batch shares reshards nothing — the
    physically-modeled replacement for the old flat 2 s charge."""
    from dataclasses import replace
    topo = tight_fabric()
    a, _ = _plan_pair(TINY, topo)
    if a.dp < 2:
        pytest.skip("needs dp >= 2 for uneven shares")
    shares = [1.0 / a.dp] * a.dp
    shares[0] += 0.1
    shares[1] -= 0.1
    b = replace(a, batch_shares=tuple(shares))
    c = ReconfigCostModel(TINY).cost(a, b, topo)
    assert c.reshard_bytes == 0.0 and c.store_bytes == 0.0
    assert c.total_s == pytest.approx(c.base_s)


def test_dead_sources_fall_back_to_store_io():
    """After a failure, shards whose *only* owner died have no alive peer
    source: they are charged against the host checkpoint store, and a
    calibrated (slower) store raises the price."""
    from repro.core import ParallelPlan, split_devices, uniform_stages
    topo = tight_fabric()
    m = ReconfigCostModel(TINY)
    # dp=1, pp=8: every layer has exactly one owner
    a = ParallelPlan(dp=1, tp=1, pp=8, microbatches=8,
                     stages=uniform_stages(8, 8,
                                           split_devices(topo, 1, 1, 8)),
                     batch_shares=(1.0,))
    topo.apply_event(NetworkEvent(0.0, "fail", device_id=7))
    b = ParallelPlan(dp=1, tp=1, pp=7, microbatches=7,
                     stages=uniform_stages(8, 7,
                                           split_devices(topo, 1, 1, 7)),
                     batch_shares=(1.0,))
    c = m.cost(a, b, topo)
    assert c.store_bytes > 0.0 and c.io_s > 0.0
    m.calibrate_io(measured_s=10.0, nbytes=1e9)     # 0.1 GB/s store
    assert m.io_bw == pytest.approx(1e8)
    assert m.cost(a, b, topo).io_s > c.io_s


def test_stageless_old_plan_infeasible_after_failure_prices_store():
    """Regression: a stage-less old plan whose default layout needs more
    devices than survive a failure must price as a full store restore, not
    raise ValueError out of split_devices (simulate_epoch replay path)."""
    from repro.core import ParallelPlan
    topo = tight_fabric()
    m = ReconfigCostModel(TINY)
    old = ParallelPlan(dp=2, tp=2, pp=2, microbatches=2)   # world=8, no stages
    topo.apply_event(NetworkEvent(0.0, "fail", device_id=7))
    new = plan_hybrid(topo, TINY, global_batch=32, seq=512,
                      with_baseline=False, max_candidates=24).plan
    c = m.cost(old, new, topo)                             # must not raise
    assert c.total_s > 0.0 and c.store_bytes > 0.0


# ---------------------------------------------------------------------------
# Cross-interval DP schedule
# ---------------------------------------------------------------------------


def test_plan_sequence_dp_prefers_staying_when_switch_is_dear():
    # plan 1 loses interval 0 but wins interval 1; with a dear switch the
    # gain cannot amortize -> stay on plan 0 throughout
    steps, choices = plan_sequence_dp(
        [100.0, 100.0], [[1.0, 1.2], [1.0, 0.9]], lambda i, q, c: 50.0)
    assert choices == [0, 0]
    # make the switch cheap -> move to the better plan for interval 1
    steps2, choices2 = plan_sequence_dp(
        [100.0, 100.0], [[1.0, 1.2], [1.0, 0.9]], lambda i, q, c: 1.0)
    assert choices2 == [0, 1]
    assert steps2 > steps


def test_plan_sequence_dp_routes_around_infeasibility():
    # plan 0 dies in interval 1; DP must switch despite the cost
    _, choices = plan_sequence_dp(
        [10.0, 10.0, 10.0],
        [[1.0, 2.0], [math.inf, 2.0], [1.0, 2.0]],
        lambda i, q, c: 1.0)
    assert choices[1] == 1


@pytest.mark.parametrize("name", list_scenarios())
def test_dp_oracle_never_worse_than_greedy_on_catalog(name):
    h = ScenarioHarness(TINY, global_batch=32, seq=512,
                        max_candidates=16)
    rep = h.run(name, seed=0)
    assert rep.oracle is not None and rep.oracle_dp is not None
    assert rep.oracle_dp.avg_step <= rep.oracle.avg_step * (1 + 1e-9), \
        rep.to_row()
    # total modeled switch charge is finite and visible
    assert math.isfinite(rep.switch_cost_s) and rep.switch_cost_s >= 0.0


# ---------------------------------------------------------------------------
# Engine keep/switch hysteresis
# ---------------------------------------------------------------------------


def _hysteresis_engine(horizon):
    engine = ReplanEngine(TINY, global_batch=32, seq=512,
                          cache=StrategyCache(), max_candidates=24,
                          switch_horizon_s=horizon)
    engine.plan(tight_fabric())
    return engine


def test_hysteresis_boundary_keep_vs_switch():
    """The same event keeps the incumbent just below the amortization
    boundary H * (1 - new/old) = cost and switches just above it."""
    probe = _hysteresis_engine(None)
    post = tight_fabric(0.2)
    ev = NetworkEvent(1.0, "bandwidth", factor=0.2)
    res = probe.replan(post, ev)
    inc_plan = probe.history[0].plan          # the cold incumbent
    old = simulate_training_step(inc_plan, TINY, post,
                                 global_batch=32, seq=512).step_time
    if res.plan.structural_key() == inc_plan.structural_key():
        pytest.skip("no better plan on the degraded fabric at this scale")
    new = res.predicted.step_time
    cost = probe.reconfig.cost(inc_plan, res.plan, post).total_s
    assert cost > 0.0 and new < old
    boundary = cost / (1.0 - new / old)
    for horizon, expect_kept in ((boundary * 0.9, True),
                                 (boundary * 1.1, False)):
        engine = _hysteresis_engine(horizon)
        r = engine.replan(tight_fabric(0.2), ev)
        assert r.kept is expect_kept, (horizon, boundary, r.path)
        if expect_kept:
            assert r.plan.structural_key() == inc_plan.structural_key()
            assert engine.incumbent[0].structural_key() \
                == inc_plan.structural_key()
        else:
            assert r.switch_cost == pytest.approx(cost)


def test_hysteresis_never_keeps_infeasible_incumbent():
    topo = tight_fabric()
    engine = ReplanEngine(TINY, global_batch=32, seq=512,
                          cache=StrategyCache(), max_candidates=24,
                          switch_horizon_s=1e-6)   # hostile to switching
    engine.plan(topo)
    topo.apply_event(NetworkEvent(1.0, "fail", device_id=7))
    res = engine.replan(topo, NetworkEvent(1.0, "fail", device_id=7))
    used = {d for st in res.plan.stages for d in st.device_ids}
    assert used <= set(topo.alive_ids())
    assert math.isfinite(res.predicted.step_time)


def test_unbounded_horizon_keeps_equal_plans():
    """switch_horizon_s=None: a candidate that is not strictly better than
    the incumbent never triggers a switch (no thrash on ties)."""
    engine = _hysteresis_engine(None)
    inc = engine.incumbent[0]
    # replay the *same* fabric: the best candidate ties the incumbent
    res = engine.replan(tight_fabric(),
                        NetworkEvent(1.0, "bandwidth", factor=1.0))
    assert res.plan.structural_key() == inc.structural_key()


# ---------------------------------------------------------------------------
# Partial-overlap reshard credit (ISSUE 4 satellite)
# ---------------------------------------------------------------------------


def test_sig_interval_and_missing_fraction():
    f = ReconfigCostModel._missing_fraction
    # identical slices move nothing
    assert f((2, 0), (2, 0)) == 0.0
    # nested tp reshape: new quarter inside the old half is fully covered
    assert f((4, 0), (2, 0)) == 0.0
    assert f((4, 1), (2, 0)) == 0.0
    # new quarter outside the old half is a full pull of the new slice
    assert f((4, 2), (2, 0)) == pytest.approx(0.25)
    # widening 4 -> 2: the old quarter covers half of the new half
    assert f((2, 0), (4, 0)) == pytest.approx(0.25)
    # disjoint same-width slices pull everything
    assert f((2, 0), (2, 1)) == pytest.approx(0.5)
    # zero1 optimizer sub-slices nest inside their tp slice
    assert f((2, 0, 2, 0), (2, 0)) == 0.0
    assert f((2, 0), (2, 0, 2, 0)) == pytest.approx(0.25)


def test_nested_tp_reshape_cheaper_than_disjoint_switch():
    """Widening tp with slice overlap (nested reshape) must price below the
    whole-shard pulls the pre-credit model charged."""
    from repro.core import ParallelPlan, split_devices, uniform_stages
    topo = tight_fabric()

    def tp_plan(tp):
        groups = split_devices(topo, 1, tp, 8 // tp)
        return ParallelPlan(dp=1, tp=tp, pp=8 // tp, microbatches=8 // tp,
                            stages=uniform_stages(TINY.n_layers, 8 // tp,
                                                  groups),
                            batch_shares=(1.0,), grad_sync="rs_ag",
                            zero1=False)

    m = ReconfigCostModel(TINY)
    narrow, wide = tp_plan(2), tp_plan(4)
    pair_bytes, store = m.reshard_traffic(narrow, wide, topo)
    moved = sum(pair_bytes.values()) + store
    # every device's new slice is either nested in its old slice (overlap
    # credit: free) or lands on a new owner; the pre-credit model charged
    # the full new layout for every signature change
    full_pull = sum(
        m._unit_bytes(u)[0] * pf + m._unit_bytes(u)[1] * of
        for dev, units in m._layout(wide, topo).items()
        for u, (pf, of, psig, osig) in units.items()
        if m._layout(narrow, topo).get(dev, {}).get(u, (None,) * 4)[2]
        != psig)
    assert moved < full_pull
    # and the overlap credit never makes a real switch free
    assert m.cost(narrow, wide, topo).total_s > 0.0


# ---------------------------------------------------------------------------
# Per-term calibration (ISSUE 4 satellite)
# ---------------------------------------------------------------------------


def _store_heavy_switch(model):
    """A switch whose old layout has no alive peers (stage-less old plan on
    a degraded topology) — everything restores from the host store."""
    from repro.core import ParallelPlan
    topo = tight_fabric()
    topo.apply_event(NetworkEvent(0.0, "fail", device_id=7))
    old = ParallelPlan(dp=1, tp=8, pp=1, microbatches=1, grad_sync="rs_ag")
    new = plan_hybrid(topo, model, global_batch=32, seq=512,
                      with_baseline=False, max_candidates=24).plan
    return old, new, topo


def test_calibrate_terms_recovers_per_term_scales():
    topo = tight_fabric()
    a, b = _plan_pair(TINY, topo)
    old_s, new_s, topo_s = _store_heavy_switch(TINY)
    truth = ReconfigCostModel(TINY, fabric_scale=2.0, store_scale=0.5)
    measurements = [
        (truth.cost(a, b, topo).total_s, a, b, topo),
        (truth.cost(b, a, topo).total_s, b, a, topo),
        (truth.cost(old_s, new_s, topo_s).total_s, old_s, new_s, topo_s),
    ]
    fit = ReconfigCostModel(TINY)
    fabric, store = fit.calibrate_terms(measurements)
    assert fabric == pytest.approx(2.0, rel=1e-6)
    assert store == pytest.approx(0.5, rel=1e-6)
    # the fitted model reproduces every measurement
    for measured, old, new, t in measurements:
        assert fit.cost(old, new, t).total_s == pytest.approx(measured,
                                                              rel=1e-6)


def test_calibrate_terms_without_store_signal_keeps_store_scale():
    topo = tight_fabric()
    a, b = _plan_pair(TINY, topo)
    truth = ReconfigCostModel(TINY, fabric_scale=3.0)
    fit = ReconfigCostModel(TINY, store_scale=7.0)
    fabric, store = fit.calibrate_terms(
        [(truth.cost(a, b, topo).total_s, a, b, topo)])
    assert fabric == pytest.approx(3.0, rel=1e-6)
    assert store == 7.0                  # no store bytes observed: untouched


# -- cross-job contention charging (ISSUE 10) --------------------------------


def test_contended_cost_exceeds_solo_on_shared_links():
    topo = tight_fabric()
    a, b = _plan_pair(TINY, topo)
    m = ReconfigCostModel(TINY)
    traffic = m.edge_traffic(a, b, topo)
    assert traffic, "switch moves no bytes — test premise broken"
    solo = m.cost(a, b, topo).total_s
    # a foreign job pushing the same byte volume over the same links
    contended = m.cost(a, b, topo, edge_load=dict(traffic)).total_s
    assert contended > solo
    # the queueing term scales with the foreign load
    heavier = m.cost(a, b, topo,
                     edge_load={k: 4 * v for k, v in traffic.items()}).total_s
    assert heavier > contended


def test_contended_cost_ignores_disjoint_links():
    topo = tight_fabric()
    a, b = _plan_pair(TINY, topo)
    m = ReconfigCostModel(TINY)
    used = set(m.edge_traffic(a, b, topo))
    # load on links this switch never touches prices exactly solo
    foreign = {key: 1e12 for key in
               ((min(u, v), max(u, v)) for u in topo.alive_ids()
                for v in topo.alive_ids() if u < v)
               if key not in used}
    solo = m.cost(a, b, topo).total_s
    assert m.cost(a, b, topo, edge_load=foreign).total_s == solo


def test_concurrent_costs_disjoint_switches_price_solo():
    topo = tight_fabric()
    ids = sorted(topo.alive_ids())
    left, right = topo.subtopology(ids[:4]), topo.subtopology(ids[4:])
    la, lb = _plan_pair(TINY, left)
    ra, rb = _plan_pair(TINY, right)
    m = ReconfigCostModel(TINY)
    joint = m.concurrent_costs([(la, lb, left), (ra, rb, right)])
    assert joint[0].total_s == m.cost(la, lb, left).total_s
    assert joint[1].total_s == m.cost(ra, rb, right).total_s


def test_concurrent_costs_shared_fabric_charges_both():
    topo = tight_fabric()
    a, b = _plan_pair(TINY, topo)
    m = ReconfigCostModel(TINY)
    solo = m.cost(a, b, topo).total_s
    back = m.cost(b, a, topo).total_s
    joint = m.concurrent_costs([(a, b, topo), (b, a, topo)])
    assert joint[0].total_s > solo
    assert joint[1].total_s > back
