"""Discrete-event simulator: the paper's constraint system Eq. 4-7."""

import math

import pytest

from repro.core import (ClusterTopology, CommOp, DeviceInstance, Edge,
                        ModelDesc, NetworkEvent, OpGraph, OpNode,
                        ParallelPlan, build_llm_graph, check_memory,
                        hetero_cluster, homogeneous_cluster, memory_feasible,
                        simulate_schedule, simulate_training_step,
                        megatron_default_plan, simulate_epoch)

DESC = ModelDesc(name="tiny", n_layers=8, d_model=512, n_heads=8,
                 n_kv_heads=8, d_ff=2048, vocab=32000)


def chain_graph(n=4, flops=1e12, out_bytes=1e8) -> OpGraph:
    g = OpGraph()
    prev = None
    for i in range(n):
        g.add(OpNode(f"op{i}", "mm", flops=flops, bytes_accessed=1e9,
                     mem_required=1e9, out_bytes=out_bytes))
        if prev:
            g.connect(prev, f"op{i}")
        prev = f"op{i}"
    return g


def test_dependencies_respected_eq4_eq5():
    topo = homogeneous_cluster(2, "V100", gpus_per_node=1, inter_bw=10e9)
    g = chain_graph(4)
    assignment = {"op0": 0, "op1": 1, "op2": 0, "op3": 1}
    res = simulate_schedule(g, assignment, topo)
    for (u, v) in g.edges:
        assert res.op_start[v] >= res.op_end[u] - 1e-12   # Eq. 4/5
    # cross-device hops pay transfer time
    assert res.comm_bytes == pytest.approx(3e8)
    assert res.makespan > 4 * 1e12 / (112e12 * 0.65)


def test_same_device_chain_no_comm():
    topo = homogeneous_cluster(2, "V100", gpus_per_node=2)
    g = chain_graph(4)
    res = simulate_schedule(g, {f"op{i}": 0 for i in range(4)}, topo)
    assert res.comm_bytes == 0


def test_memory_constraint_eq6():
    topo = homogeneous_cluster(1, "V100", gpus_per_node=1)
    g = chain_graph(2, flops=1e9)
    g.nodes["op0"].params_bytes = 40e9       # > 32 GB V100
    assert not memory_feasible(g, {"op0": 0, "op1": 0}, topo)
    g.nodes["op0"].params_bytes = 1e9
    assert memory_feasible(g, {"op0": 0, "op1": 0}, topo)


def test_bandwidth_event_slows_transfers_eq7():
    def run(factor):
        topo = homogeneous_cluster(2, "V100", gpus_per_node=1,
                                   inter_bw=10e9)
        topo.events = [NetworkEvent(0.0, "bandwidth", factor=factor,
                                    selector="ib")]
        g = chain_graph(2, flops=1e9, out_bytes=1e9)
        return simulate_schedule(g, {"op0": 0, "op1": 1}, topo,
                                 start_time=0.0).makespan
    assert run(0.1) > run(1.0)


def test_conflicting_edges_serialize():
    """Fig. 5b: NVLink and PCIe on one pair cannot be used concurrently."""
    devs = [DeviceInstance(i, homogeneous_cluster(1, "V100")
                           .device(0).spec) for i in range(3)]
    topo = ClusterTopology(devs)
    topo.add_link(0, 1, Edge(100e9, 0.0, "nvlink", ("pcie",)),
                  Edge(100e9, 0.0, "pcie", ("nvlink",)))
    g = OpGraph()
    g.add(OpNode("a", "mm", flops=1e9, out_bytes=100e9))
    g.add(OpNode("b", "mm", flops=1e9, out_bytes=100e9))
    g.add(OpNode("c", "mm", flops=1e9))
    g.add(OpNode("d", "mm", flops=1e9))
    g.connect("a", "c")
    g.connect("b", "d")
    res = simulate_schedule(g, {"a": 0, "b": 0, "c": 1, "d": 1}, topo)
    # two 1s transfers over conflicting 100GB/s edges must serialize: ~2s
    assert res.makespan >= 2.0


def test_training_step_tp_reduces_compute_increases_comm():
    topo = homogeneous_cluster(8, "V100", gpus_per_node=8)
    p1 = megatron_default_plan(topo, DESC, microbatches=4)
    s_tp = simulate_training_step(p1, DESC, topo, global_batch=32, seq=1024)
    assert s_tp.step_time > 0 and math.isfinite(s_tp.step_time)
    assert s_tp.tp_comm_time > 0 if p1.tp > 1 else True


def test_1f1b_bubble_shrinks_with_microbatches():
    from repro.core.simulator import _simulate_1f1b
    fwd, bwd, p2p = [1.0] * 4, [2.0] * 4, [0.0] * 3
    t_small = _simulate_1f1b(fwd, bwd, p2p, 4)
    t_big = _simulate_1f1b(fwd, bwd, p2p, 16)
    # per-microbatch cost improves as the pipeline fills
    assert t_big / 16 < t_small / 4
    # lower bound: work of one stage
    assert t_big >= 16 * 3.0


def test_epoch_with_replan_counts():
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    topo.events = [NetworkEvent(0.05, "slowdown", device_id=0, factor=0.5)]
    plan = megatron_default_plan(topo, DESC, microbatches=4)
    sim = simulate_epoch(plan, DESC, topo, global_batch=32, seq=512,
                         steps=3, replan_fn=lambda t, at: plan)
    assert sim.steps == 3 and sim.replans >= 1
