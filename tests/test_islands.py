"""Hierarchical island search (ISSUE 6): partition, symmetry dedup,
composition, flat fallback, event-routed hierarchical replanning, and the
cascade's anytime simulation budget."""

import math

import pytest

from repro.core import (HierarchicalReplanEngine, ModelDesc, NetworkEvent,
                        hetero_cluster, homogeneous_cluster, multi_pod_tpu,
                        partition_islands, plan_hierarchical, plan_hybrid,
                        remap_plan)
from repro.core.islands import _quantize_shares

DESC = ModelDesc(name="m", n_layers=12, d_model=1024, n_heads=16,
                 n_kv_heads=16, d_ff=4096, vocab=32000)


# ---------------------------------------------------------------------------
# Partition
# ---------------------------------------------------------------------------


def test_partition_multi_pod_one_island_per_pod():
    topo = multi_pod_tpu(pods=2, chips_per_pod=16)
    islands = partition_islands(topo)
    assert len(islands) == 2
    assert [isl.device_ids for isl in islands] == \
        [tuple(range(16)), tuple(range(16, 32))]
    # isomorphic pods: identical canonical signatures
    assert islands[0].signature == islands[1].signature


def test_partition_never_mixes_device_classes():
    topo = hetero_cluster({"RTX4090D": 8, "V100": 8}, gpus_per_node=4)
    islands = partition_islands(topo)
    seen: list[int] = []
    for isl in islands:
        classes = {topo.device(i).spec.name for i in isl.device_ids}
        assert len(classes) == 1, isl
        seen.extend(isl.device_ids)
    assert sorted(seen) == topo.alive_ids()


def test_single_device_island_plans_end_to_end():
    # one lone RTX: no same-class peer, so it forms a singleton island
    topo = hetero_cluster({"RTX4090D": 1, "V100": 4}, gpus_per_node=4)
    islands = partition_islands(topo)
    assert any(isl.n == 1 for isl in islands)
    res = plan_hierarchical(topo, DESC, global_batch=40, seq=512,
                            flat_limit=0)
    assert res.path == "hierarchical"
    assert math.isfinite(res.predicted_step)
    assert sum(ip.batch for ip in res.composed.islands) == 40


def test_signature_distinguishes_degraded_twin():
    topo = multi_pod_tpu(pods=2, chips_per_pod=16)
    sig0 = topo.island_signature(range(16))
    topo.apply_event(NetworkEvent(time=0.0, kind="slowdown", device_id=3,
                                  factor=0.5))
    assert topo.island_signature(range(16)) != sig0
    assert topo.island_signature(range(16, 32)) == sig0


# ---------------------------------------------------------------------------
# Flat fallback + failure modes
# ---------------------------------------------------------------------------


def test_homogeneous_cluster_falls_back_to_flat_identically():
    topo = homogeneous_cluster(8, "V100")
    res = plan_hierarchical(topo, DESC, global_batch=32, seq=1024)
    ref = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                      with_baseline=False)
    assert res.path == "flat"
    assert res.islands_deduped == 0
    assert res.flat.plan.to_json() == ref.plan.to_json()
    assert res.predicted_step == ref.predicted.step_time


def test_partitioned_cluster_raises_runtime_error():
    topo = multi_pod_tpu(pods=2, chips_per_pod=16)
    topo.apply_event(NetworkEvent(time=0.0, kind="bandwidth",
                                  selector="dci", factor=0.0))
    with pytest.raises(RuntimeError, match="partitioned"):
        plan_hierarchical(topo, DESC, global_batch=64, seq=512,
                          flat_limit=0)


def test_batch_smaller_than_island_count_raises():
    topo = multi_pod_tpu(pods=2, chips_per_pod=16)
    with pytest.raises(RuntimeError, match="batch"):
        plan_hierarchical(topo, DESC, global_batch=1, seq=512,
                          flat_limit=0)


# ---------------------------------------------------------------------------
# Symmetry dedup + composition
# ---------------------------------------------------------------------------


def test_isomorphic_islands_searched_exactly_once():
    topo = multi_pod_tpu(pods=2, chips_per_pod=16)
    res = plan_hierarchical(topo, DESC, global_batch=64, seq=512,
                            flat_limit=0)
    assert res.path == "hierarchical"
    assert res.n_islands == 2
    assert res.n_signatures == 1
    assert res.islands_deduped == 1
    searched = [ip for ip in res.composed.islands if ip.searched]
    reused = [ip for ip in res.composed.islands if not ip.searched]
    assert len(searched) == 1 and len(reused) == 1
    # the twin reuses the representative's structure on its own devices
    assert reused[0].plan.meta.get("island_remapped") is True
    assert set(d for st in reused[0].plan.stages for d in st.device_ids) \
        <= set(reused[0].island.device_ids)
    # equal shares for equal pods, and the composed estimate adds a
    # strictly positive inter-island sync term
    assert searched[0].batch == reused[0].batch == 32
    assert res.composed.inter_sync_s > 0.0
    assert res.composed.step_time == pytest.approx(
        max(ip.predicted.step_time for ip in res.composed.islands)
        + res.composed.inter_sync_s)


def test_remap_plan_rewrites_ids_and_marks_meta():
    topo = multi_pod_tpu(pods=2, chips_per_pod=16)
    res = plan_hierarchical(topo, DESC, global_batch=64, seq=512,
                            flat_limit=0)
    rep = next(ip for ip in res.composed.islands if ip.searched)
    mapping = {i: i + 16 for i in range(16)}
    remapped = remap_plan(rep.plan, mapping)
    assert remapped.meta["island_remapped"] is True
    for st_old, st_new in zip(rep.plan.stages, remapped.stages):
        assert st_new.layers == st_old.layers
        assert st_new.device_ids == tuple(d + 16 for d in st_old.device_ids)


def test_quantize_shares_properties():
    # equal weights, even division -> equal shares
    shares, unit = _quantize_shares([1.0, 1.0], 64)
    assert shares == [32, 32] and 64 % unit == 0
    # proportionality with exact sum and a floor of one unit each
    shares, unit = _quantize_shares([3.0, 1.0, 0.0001], 256)
    assert sum(shares) == 256
    assert all(s >= unit for s in shares)
    assert shares[0] > shares[1] > 0
    with pytest.raises(RuntimeError):
        _quantize_shares([1.0, 1.0, 1.0], 2)


# ---------------------------------------------------------------------------
# Hierarchical replanning (event routing)
# ---------------------------------------------------------------------------


def _fleet_engine():
    topo = multi_pod_tpu(pods=2, chips_per_pod=16)
    eng = HierarchicalReplanEngine(DESC, global_batch=64, seq=512,
                                   flat_limit=0)
    cold = eng.plan(topo)
    assert cold.path == "hierarchical:cold"
    return topo, eng, cold


def test_slowdown_replans_only_containing_island():
    topo, eng, _ = _fleet_engine()
    ev = NetworkEvent(time=1.0, kind="slowdown", device_id=3, factor=0.5)
    topo.apply_event(ev)
    res = eng.replan(topo, ev)
    assert res.path.startswith("hierarchical:")
    assert res.islands_replanned == (0,)
    assert set(res.island_results) == {0}


def test_inter_island_bandwidth_event_recomposes_without_search():
    topo, eng, cold = _fleet_engine()
    ev = NetworkEvent(time=1.0, kind="bandwidth", selector="dci",
                      factor=0.5)
    topo.apply_event(ev)
    res = eng.replan(topo, ev)
    # "dci" never appears inside an island, so no sub-search runs: only
    # the inter-island sync bound is recomputed (halved bw -> doubled)
    assert res.islands_replanned == ()
    assert res.path == "hierarchical:recompose"
    assert res.inter_sync_s == pytest.approx(2 * cold.inter_sync_s,
                                             rel=1e-6)


def test_fail_event_triggers_full_repartition():
    topo, eng, _ = _fleet_engine()
    ev = NetworkEvent(time=1.0, kind="fail", device_id=31)
    topo.apply_event(ev)
    res = eng.replan(topo, ev)
    assert res.path == "hierarchical:cold"
    assert 31 not in {d for key in eng._plans for d in key}


def test_small_cluster_delegates_to_flat_engine():
    topo = homogeneous_cluster(8, "V100")
    eng = HierarchicalReplanEngine(DESC, global_batch=32, seq=512)
    res = eng.plan(topo)
    assert res.path.startswith("flat:")
    assert res.flat_result is not None and res.inter_sync_s == 0.0


# ---------------------------------------------------------------------------
# Cascade budget + deprecation (satellites)
# ---------------------------------------------------------------------------


def test_max_sims_budget_bounds_simulations():
    topo = homogeneous_cluster(16, "V100")
    res = plan_hybrid(topo, DESC, global_batch=64, seq=512,
                      with_baseline=False, max_sims=4)
    st = res.search_stats
    assert st.simulated <= 4
    assert st.budget_skipped > 0
    assert math.isfinite(res.predicted.step_time)


def test_plan_hybrid_n_workers_shim_removed():
    # the n_workers= compatibility shim (DeprecationWarning since PR 6)
    # is gone; callers must pass executor=
    topo = homogeneous_cluster(4, "V100")
    with pytest.raises(TypeError, match="n_workers"):
        plan_hybrid(topo, DESC, global_batch=16, seq=512,
                    with_baseline=False, n_workers=2)
