"""Unified tracing + metrics (ISSUE 7): tracer/metrics units, worker span
shipping, exporter round-trips, the counter/stat drift invariant, and the
benchmark provenance header."""

import json
import pickle
import statistics

import pytest

from benchmarks.common import bench_meta, write_json
from benchmarks.compare import compare_rows
from repro.core import (ModelDesc, NetworkEvent, ReplanEngine, SearchExecutor,
                        StrategyCache, hetero_cluster, plan_hybrid)
from repro.obs import (METRICS_KEY, NULL_OBS, Histogram, Obs, Tracer,
                       chrome_trace, resolve_obs, write_trace)
from repro.obs.tracer import NULL_HANDLE
from tools.trace_report import phase_table, render

DESC = ModelDesc(name="m", n_layers=12, d_model=1024, n_heads=16,
                 n_kv_heads=16, d_ff=4096, vocab=32000)


def small_topo():
    return hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)


# ---------------------------------------------------------------------------
# Metrics: histogram percentiles, counters
# ---------------------------------------------------------------------------


def test_histogram_percentiles_match_statistics_quantiles():
    samples = [0.001, 0.004, 0.0041, 0.02, 0.05, 0.3, 0.31, 0.9, 2.0, 7.5,
               0.011, 0.012, 0.6, 1.4, 0.0007]
    h = Histogram("replan.latency_s")
    for v in samples:
        h.observe(v)
    cuts = statistics.quantiles(samples, n=100, method="inclusive")
    for q in (50, 95, 99):
        assert h.percentile(q) == pytest.approx(cuts[q - 1])
    assert h.count == len(samples)
    assert h.mean == pytest.approx(statistics.mean(samples))
    assert sum(h.bucket_counts) == len(samples)


def test_histogram_snapshot_merge_preserves_percentiles():
    a, b = Histogram("h"), Histogram("h")
    for i in range(10):
        (a if i % 2 else b).observe(i * 0.01)
    merged = Histogram("h")
    merged.merge_dict(a.to_dict())
    merged.merge_dict(b.to_dict())
    all_samples = [i * 0.01 for i in range(10)]
    cuts = statistics.quantiles(all_samples, n=100, method="inclusive")
    assert merged.count == 10
    assert merged.percentile(50) == pytest.approx(cuts[49])


# ---------------------------------------------------------------------------
# Disabled path: shared no-ops, nothing allocated or recorded
# ---------------------------------------------------------------------------


def test_disabled_obs_is_shared_noop(monkeypatch):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    assert NULL_OBS.enabled is False
    assert NULL_OBS.tracer is None and NULL_OBS.metrics is None
    # every span() call returns the one shared handle — no allocation
    h1 = NULL_OBS.span("search.cascade", n_points=10)
    h2 = NULL_OBS.span("plan.hybrid")
    assert h1 is NULL_HANDLE and h2 is NULL_HANDLE
    with h1 as h:
        h.set(simulated=5)           # all no-ops
    NULL_OBS.inc("cache.hit")
    NULL_OBS.observe("replan.latency_s", 0.1)
    assert NULL_OBS.current_span_id() is None
    assert NULL_OBS.export_delta() is None
    # an explicit bundle always wins over the env-driven default
    mine = Obs()
    assert resolve_obs(mine) is mine
    assert resolve_obs(None).enabled is False


# ---------------------------------------------------------------------------
# Tracer: nesting, adoption/re-parenting, pickling
# ---------------------------------------------------------------------------


def test_span_nesting_and_attrs():
    obs = Obs()
    with obs.span("outer", kind="test") as outer:
        with obs.span("inner") as inner:
            inner.set(n=3)
        outer.set(done=True)
    spans = {s.name: s for s in obs.tracer.spans}
    assert spans["inner"].parent_id == spans["outer"].span_id
    assert spans["outer"].parent_id is None
    assert spans["inner"].attrs == {"n": 3}
    assert spans["outer"].attrs == {"kind": "test", "done": True}
    assert spans["inner"].duration >= 0.0
    assert spans["outer"].duration >= spans["inner"].duration


def test_adopt_remaps_ids_and_preserves_worker_pid():
    worker = [  # two spans shipped from a fictitious worker, pid 99999
        {"name": "search.worker.chunk", "t0": 1.0, "t1": 2.0, "span_id": 1,
         "parent_id": None, "pid": 99999, "tid": 7, "attrs": {"chunk": 0}},
        {"name": "sim.batch", "t0": 1.2, "t1": 1.8, "span_id": 2,
         "parent_id": 1, "pid": 99999, "tid": 7, "attrs": {}},
    ]
    parent = Tracer()
    with parent.span("search.tier3") as tier3:
        parent.adopt(worker, tier3.span_id)
    by_name = {s.name: s for s in parent.spans}
    root = by_name["search.worker.chunk"]
    child = by_name["sim.batch"]
    assert root.parent_id == by_name["search.tier3"].span_id
    assert child.parent_id == root.span_id
    # ids were remapped out of the worker's private space
    assert root.span_id != 1 and child.span_id != 2
    assert root.pid == 99999 and child.pid == 99999
    assert root.attrs == {"chunk": 0}


def test_obs_pickle_round_trip_keeps_spans_and_metrics():
    obs = Obs()
    with obs.span("a"):
        obs.inc("cache.hit", 3)
        obs.observe("replan.latency_s", 0.25)
    clone = pickle.loads(pickle.dumps(obs))
    assert [s.name for s in clone.tracer.spans] == ["a"]
    assert clone.metrics.counter_value("cache.hit") == 3
    with clone.span("b"):                       # still records after thaw
        clone.inc("cache.hit")
    assert clone.metrics.counter_value("cache.hit") == 4
    assert {s.name for s in clone.tracer.spans} == {"a", "b"}


# ---------------------------------------------------------------------------
# Exporters: Perfetto JSON round-trip, trace_report rendering
# ---------------------------------------------------------------------------


def _traced_plan(executor=None, **kw):
    obs = Obs()
    res = plan_hybrid(small_topo(), DESC, global_batch=32, seq=1024,
                      with_baseline=False, executor=executor, obs=obs, **kw)
    return obs, res


def test_chrome_trace_round_trips_json(tmp_path):
    obs, _ = _traced_plan()
    path = write_trace(obs, tmp_path / "trace.json")
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert events and all(ev["ph"] == "X" for ev in events)
    names = {ev["name"] for ev in events}
    assert {"plan.hybrid", "plan.enumerate", "search.cascade",
            "search.tiers012", "search.tier_lp", "search.tier3",
            "sim.batch"} <= names
    ids = {ev["args"]["span_id"] for ev in events}
    assert len(ids) == len(events)              # unique span ids
    for ev in events:
        pid = ev["args"]["parent_id"]
        assert pid is None or pid in ids        # every parent link resolves
        assert ev["dur"] >= 0.0
    snap = doc[METRICS_KEY]
    assert snap["search.simulated"] > 0
    assert snap["sim.plans"] > 0


def test_trace_report_renders_phases_and_counters():
    obs, _ = _traced_plan()
    obs.inc("cache.hit", 3)
    obs.inc("cache.miss", 1)
    obs.observe("replan.latency_s", 0.02)
    doc = chrome_trace(obs)
    out = render(doc)
    assert "self time per phase" in out
    assert "plan.hybrid" in out and "search.tier3" in out
    assert "replan.latency_s" in out and "p95=" in out
    assert "cache hit rate" in out and "75.0%" in out
    # self-time accounting: a parent's self excludes its children
    rows = {r["phase"]: r for r in phase_table(doc["traceEvents"])}
    hybrid = rows["plan.hybrid"]
    assert hybrid["total_s"] >= hybrid["self_s"] >= 0.0


def test_trace_report_renders_fabric_fidelity_line():
    obs, _ = _traced_plan()
    obs.inc("fabric.relays", 2)
    obs.inc("fabric.relay_hops", 5)
    obs.inc("fabric.chunks", 12)
    obs.inc("sim.reroute.events", 3)
    obs.inc("sim.reroute.steps", 2)
    out = render(chrome_trace(obs))
    assert "fabric fidelity" in out
    assert "2 relayed transfer(s), 2.5 hops avg, 12 chunk(s)" in out
    assert "3 mid-flight reroute event(s) across 2 split step(s)" in out


# ---------------------------------------------------------------------------
# Instrumentation: counters agree with SearchStats (the drift invariant)
# ---------------------------------------------------------------------------


def test_search_counters_match_search_stats():
    obs, res = _traced_plan(prune="cascade")
    snap = obs.metrics.snapshot()
    stats = res.search_stats
    pruned = sum(v for k, v in snap.items()
                 if isinstance(v, int) and k.startswith("search.pruned."))
    assert pruned == stats.pruned
    assert snap.get("search.pruned.coarse", 0) == stats.pruned_coarse
    assert snap.get("search.pruned.bound", 0) == stats.pruned_bound
    assert snap.get("search.pruned.feasibility", 0) == stats.pruned_feasibility
    assert snap.get("search.pruned.lp", 0) == stats.pruned_lp
    assert stats.pruned_lp > 0           # hetero cluster: the LP tier bites
    assert snap["search.simulated"] == stats.simulated


def test_replan_paths_and_latency_flow_through_registry():
    obs = Obs()
    engine = ReplanEngine(DESC, global_batch=32, seq=512,
                          cache=StrategyCache(obs=obs), obs=obs)
    topo = hetero_cluster({"V100": 8}, intra_bw_map={"V100": 25e9},
                          inter_bw=12.5e9, gpus_per_node=4)
    engine.plan(topo)
    low = hetero_cluster({"V100": 8}, intra_bw_map={"V100": 25e9 * 0.2},
                         inter_bw=12.5e9 * 0.2, gpus_per_node=4)
    res = engine.replan(low, NetworkEvent(1.0, "bandwidth", factor=0.2))
    snap = obs.metrics.snapshot()
    assert snap["replan.path.cold-plan"] == 1
    assert snap[f"replan.path.{res.path}"] == 1
    hist = snap["replan.latency_s"]
    assert hist["type"] == "histogram" and hist["count"] == 2
    # the backdated replan.<path> spans cover the whole call
    by_name = {s.name: s for s in obs.tracer.spans}
    assert by_name["replan.cold-plan"].duration == pytest.approx(
        engine.history[0].wall_time, rel=0.5)
    assert f"replan.{res.path}" in by_name
    # cache hit/miss counters are the same funnel as CacheStats
    assert snap.get("cache.hit", 0) == engine.cache.stats.hits
    assert snap.get("cache.miss", 0) == engine.cache.stats.misses


# ---------------------------------------------------------------------------
# Executor workers: spans ship back, tree shape is deterministic
# ---------------------------------------------------------------------------


def _span_shape(obs):
    """(name, parent-name, n_tasks-attr) multiset — the run's tree shape,
    independent of timings, span ids, and which worker ran which chunk."""
    by_id = {s.span_id: s.name for s in obs.tracer.spans}
    return sorted((s.name, by_id.get(s.parent_id),
                   s.attrs.get("n_tasks")) for s in obs.tracer.spans)


def test_worker_spans_ship_back_and_tree_is_deterministic():
    shapes, counters = [], []
    for _ in range(2):
        obs = Obs()
        with SearchExecutor(n_procs=2) as ex:
            plan_hybrid(small_topo(), DESC, global_batch=32, seq=1024,
                        with_baseline=False, executor=ex, obs=obs)
        spans = obs.tracer.spans
        chunks = [s for s in spans if s.name == "search.worker.chunk"]
        assert chunks, "no worker spans were shipped back"
        tier3 = next(s for s in spans if s.name == "search.tier3")
        assert all(c.parent_id == tier3.span_id for c in chunks)
        assert {c.pid for c in chunks} - {tier3.pid}, \
            "worker spans should carry worker pids"
        ids = {s.span_id for s in spans}
        assert all(s.parent_id in ids for s in spans
                   if s.parent_id is not None)
        shapes.append(_span_shape(obs))
        counters.append(obs.metrics.counter_value("search.worker.chunks"))
    assert shapes[0] == shapes[1]
    assert counters[0] == counters[1] == len(
        [s for s in shapes[0] if s[0] == "search.worker.chunk"])


# ---------------------------------------------------------------------------
# Benchmark provenance header (satellite: meta rows)
# ---------------------------------------------------------------------------


def test_bench_meta_header_written_and_ignored_by_compare(tmp_path):
    meta = bench_meta(quick=True)
    assert meta["kind"] == "meta"
    for key in ("git_sha", "timestamp_utc", "python", "jax", "quick"):
        assert key in meta
    path = tmp_path / "bench.json"
    write_json([{"topology": "hetero", "gpus": 16, "prune_rate": 0.5}],
               path, quick=True)
    rows = json.loads(path.read_text())
    assert rows[0]["kind"] == "meta" and rows[0]["quick"] is True
    assert rows[1]["gpus"] == 16
    # compare treats meta rows as absent on either side
    ps = {"topology": "hetero", "gpus": 16,
          "argmin_matches_exhaustive": True,
          "parallel_matches_serial": True, "prune_rate": 0.5,
          "pruned_coarse": 40}
    assert compare_rows("planner_search", [ps], [bench_meta(quick=True), ps]) \
        == []
    assert compare_rows("planner_search", [bench_meta(), ps], [ps]) == []


def test_chrome_trace_lane_attr_groups_onto_named_rows():
    """Spans with a `lane` attr (the planner service's per-job spans) get
    one synthetic named row per distinct lane value, labeled by a
    thread_name metadata event; laneless spans keep their OS tid."""
    obs = Obs()
    with obs.span("service.admit", lane="job-0"):
        pass
    with obs.span("service.replan", lane="job-1"):
        pass
    with obs.span("service.replan", lane="job-0"):
        pass
    with obs.span("plain"):
        pass
    doc = chrome_trace(obs)
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert [m["args"]["name"] for m in meta] == ["job-0", "job-1"]
    by_lane = {}
    for e in doc["traceEvents"]:
        if e["ph"] == "X" and "lane" in e["args"]:
            by_lane.setdefault(e["args"]["lane"], set()).add(e["tid"])
    assert len(by_lane["job-0"]) == 1 and len(by_lane["job-1"]) == 1
    assert by_lane["job-0"] != by_lane["job-1"]
    lane_tids = by_lane["job-0"] | by_lane["job-1"]
    assert {m["tid"] for m in meta} == lane_tids   # rows are labeled
    plain = [e for e in doc["traceEvents"]
             if e["ph"] == "X" and e["name"] == "plain"]
    assert plain[0]["tid"] not in lane_tids
