"""Checkpoint store: roundtrip, async publish, dtype restore, elastic API."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import AsyncSaver, latest_step, restore, save


def _state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((5,), jnp.bfloat16)},
            "opt": (jnp.zeros((3, 4)), jnp.int32(7))}


def test_roundtrip(tmp_path):
    st = _state()
    save(tmp_path / "step_3", st, step=3, plan_json='{"dp": 2}')
    like = jax.tree.map(jnp.zeros_like, st)
    got, manifest = restore(tmp_path / "step_3", like)
    assert manifest["step"] == 3
    assert json.loads(manifest["plan"]) == {"dp": 2}
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_async_saver_and_latest(tmp_path):
    saver = AsyncSaver()
    for s in (10, 20, 30):
        saver.submit(tmp_path / f"step_{s}", _state(), step=s)
    saver.wait()
    assert latest_step(tmp_path) == 30


def test_restore_onto_shardings(tmp_path):
    """Elastic reshard: restore places arrays under the *new* sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P, Mesh
    st = _state()
    save(tmp_path / "step_1", st, step=1)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    got, _ = restore(tmp_path / "step_1", jax.tree.map(jnp.zeros_like, st),
                     shardings=sh)
    assert got["params"]["w"].sharding == NamedSharding(mesh, P())
