"""Fault-tolerant runtime: S1/S2/S3 events -> re-plan -> elastic resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (NetworkEvent, ParallelPlan, hetero_cluster,
                        plan_hybrid)
from repro.core.dynamic import DynamicOrchestrator, PlanTemplates
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    return get_config("qwen2_7b").reduced(n_layers=2, d_model=64, vocab=128,
                                          d_ff=128)


def _tcfg(tmp_path, steps=12):
    return TrainerConfig(arch=_tiny_cfg(), steps=steps, global_batch=4,
                         seq_len=32, ckpt_dir=str(tmp_path), ckpt_every=5,
                         log_every=100,
                         opt=AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                                         total_steps=20))


def test_trainer_runs_and_checkpoints(tmp_path):
    tr = Trainer(_tcfg(tmp_path))
    state, hist = tr.run()
    assert hist and np.isfinite(hist[-1]["loss"])
    from repro.checkpoint.store import latest_step
    assert latest_step(tmp_path) is not None


def test_failure_event_triggers_template_failover_and_resume(tmp_path):
    """S3: node failure -> Oobleck-style template plan -> elastic resume.

    Loss continuity: the post-failover loss stays close to pre-failure (it
    restored the checkpointed state rather than reinitializing)."""
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    ev = NetworkEvent(0.0, "fail", device_id=7)
    cfg = _tcfg(tmp_path, steps=14)
    cfg.log_every = 1
    tr = Trainer(cfg, topo=topo, events=[(7, ev)],
                 plan=ParallelPlan(dp=2, tp=2, pp=2, microbatches=2))
    state, hist = tr.run()
    assert tr.replans == 1
    rec = tr._orch.history[-1]
    # engine-driven trainer: device-set change takes a neighborhood / full /
    # cold path; engine-less orchestrators keep the template lookup
    assert rec.action in ("template-failover", "full-replan",
                          "neighborhood", "cold-plan")
    losses = {h["step"]: h["loss"] for h in hist}
    # resumed loss (step 7, restored from the step-7 snapshot) close to the
    # trajectory before the event
    assert abs(losses[7] - losses[6]) < 1.0
    assert np.isfinite(hist[-1]["loss"])


def test_slowdown_event_reassigns_without_topology_change(tmp_path):
    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    desc = _tiny_cfg().to_model_desc()
    plan = plan_hybrid(topo, desc, global_batch=8, seq=32,
                       with_baseline=False).plan
    orch = DynamicOrchestrator(model=desc, global_batch=8, seq=32)
    ev = NetworkEvent(1.0, "slowdown", device_id=0, factor=0.25)
    topo.apply_event(ev)
    new = orch.adapt(plan, topo, ev)
    assert orch.history[-1].action == "straggler-reassign"
    assert (new.dp, new.tp, new.pp) == (plan.dp, plan.tp, plan.pp)
    # the slowed device's stage lost layers or its rank lost batch share
    assert new.stages != plan.stages or new.batch_shares != plan.batch_shares


def test_bandwidth_event_replans_only_when_worth_it():
    topo = hetero_cluster({"V100": 8}, gpus_per_node=8)
    desc = _tiny_cfg().to_model_desc()
    plan = plan_hybrid(topo, desc, global_batch=8, seq=32,
                       with_baseline=False).plan
    orch = DynamicOrchestrator(model=desc, global_batch=8, seq=32,
                               replan_threshold=1.10)
    ev = NetworkEvent(1.0, "bandwidth", factor=1.0, selector="ib")
    new = orch.adapt(plan, topo, ev)   # nothing changed -> keep
    assert orch.history[-1].action == "keep"
    assert new == plan


def test_trainer_accepts_scenario_trace(tmp_path):
    """A Trace drives the trainer: event times map onto steps, adaptation
    records surface through the public ``adaptations`` property."""
    from repro.scenarios import Trace

    topo = hetero_cluster({"RTX4090D": 4, "V100": 4}, gpus_per_node=4)
    trace = Trace.from_events(
        "unit", [NetworkEvent(5.0, "slowdown", device_id=2, factor=0.4)],
        horizon=10.0)
    cfg = _tcfg(tmp_path, steps=10)
    tr = Trainer(cfg, topo=topo, scenario=trace,
                 plan=ParallelPlan(dp=2, tp=2, pp=2, microbatches=2))
    assert tr.trace is trace
    assert [s for s, _ in tr.events] == [5]        # t=5 of 10 -> step 5
    state, hist = tr.run()
    assert tr.replans == 1 and len(tr.adaptations) == 1
    assert tr.adaptations[0].event.kind == "slowdown"
    assert tr.engine is not None and tr.engine.history
    assert np.isfinite(hist[-1]["loss"])


def test_plan_templates_failover_lookup():
    topo = hetero_cluster({"V100": 8}, gpus_per_node=8)
    desc = _tiny_cfg().to_model_desc()
    tpl = PlanTemplates.precompute(topo, desc, global_batch=8, seq=32,
                                   failure_budget=2)
    assert 8 in tpl.templates and 7 in tpl.templates
    assert tpl.plan_for(7).world <= 7
    with pytest.raises(KeyError):
        tpl.plan_for(0)
