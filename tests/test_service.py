"""Planner-as-a-service (ISSUE 10): admission queue semantics, shared
cross-job cache with exact invalidation + single-flight twin dedup,
tenancy arrival generation, and the serial == threaded replay
determinism contract."""

import random
import threading

import pytest

from repro.core import (ModelDesc, NetworkEvent, ReplanEngine,
                        homogeneous_cluster)
from repro.scenarios import build_tenant, job_arrivals, to_job_specs
from repro.scenarios.tenancy import get_tenant_scenario
from repro.service import (AdmissionQueue, JobSpec, PlannerService,
                           SharedStrategyCache, model_signature)

TINY = ModelDesc("tiny", n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
                 d_ff=2048, vocab=32000)
TINY_RENAMED = ModelDesc("other-name", n_layers=8, d_model=512, n_heads=8,
                         n_kv_heads=8, d_ff=2048, vocab=32000)


def _spec(name, *, n_devices=4, priority=0, model=TINY, global_batch=32,
          arrival_s=0.0, duration_s=0.0):
    return JobSpec(name=name, model=model, global_batch=global_batch,
                   seq=512, n_devices=n_devices, priority=priority,
                   arrival_s=arrival_s, duration_s=duration_s,
                   gpus_per_node=4)


# -- jobs / signatures -------------------------------------------------------


def test_model_signature_is_name_free():
    assert model_signature(TINY) == model_signature(TINY_RENAMED)
    assert _spec("a").signature() == _spec("b", model=TINY_RENAMED).signature()
    assert _spec("a").signature() != _spec("b", global_batch=64).signature()


# -- admission queue ---------------------------------------------------------


def test_queue_priority_then_fifo():
    q = AdmissionQueue(capacity=8)
    for s in (_spec("lo-0"), _spec("hi", priority=2), _spec("lo-1")):
        assert q.offer(s)
    assert q.pop().name == "hi"
    assert q.pop().name == "lo-0"          # FIFO among equal priorities
    assert q.pop().name == "lo-1"


def test_queue_backpressure_rejects_when_full():
    q = AdmissionQueue(capacity=2)
    assert q.offer(_spec("a")) and q.offer(_spec("b"))
    assert not q.offer(_spec("c"))
    assert q.rejected == 1
    assert len(q) == 2


def test_pop_bucket_drains_isomorphic_twins_only():
    q = AdmissionQueue(capacity=8)
    for s in (_spec("t0"), _spec("other", global_batch=64),
              _spec("t1"), _spec("t2", model=TINY_RENAMED)):
        q.offer(s)
    head, twins = q.pop_bucket()
    assert head.name == "t0"
    assert [t.name for t in twins] == ["t1", "t2"]   # renamed model buckets
    assert q.pop().name == "other"


# -- shared cache ------------------------------------------------------------


def _fake_entry(cache, key, ids, tags):
    # a plan object is irrelevant to invalidation matching — store opaque
    # sentinels through the public API
    status, _ = cache.acquire(key, ids)
    assert status == "cold"
    cache.complete(key, plan=("plan", key), sim=("sim", key),
                   device_ids=ids, tags=tags)


def test_invalidate_drops_exactly_affected_entries():
    cache = SharedStrategyCache(max_entries=16)
    _fake_entry(cache, ("a",), (0, 1, 2, 3), {"nvlink", "ib"})
    _fake_entry(cache, ("b",), (4, 5, 6, 7), {"nvlink"})
    _fake_entry(cache, ("c",), (8, 9), {"pcie"})
    # device event: only the slice containing device 1
    assert cache.invalidate(NetworkEvent(1.0, "fail", device_id=1)) == [("a",)]
    assert len(cache) == 2
    # tagged bandwidth event: only slices crossing that fabric
    ev = NetworkEvent(2.0, "bandwidth", selector="pcie", factor=0.5)
    assert cache.invalidate(ev) == [("c",)]
    assert len(cache) == 1                    # ("b",) untouched twice
    assert cache.version == 2


def test_invalidate_unselective_bandwidth_drops_all_edged_entries():
    cache = SharedStrategyCache(max_entries=16)
    _fake_entry(cache, ("a",), (0, 1), {"nvlink"})
    _fake_entry(cache, ("b",), (2, 3), {"ib"})
    ev = NetworkEvent(1.0, "bandwidth", factor=0.5)
    assert sorted(cache.invalidate(ev)) == [("a",), ("b",)]


def test_acquire_single_flight_under_concurrency():
    cache = SharedStrategyCache(max_entries=16)
    statuses, lock = [], threading.Lock()

    def worker():
        status, served = cache.acquire(("k",), (0, 1, 2, 3))
        if status == "cold":
            cache.complete(("k",), plan="P", sim="S",
                           device_ids=(0, 1, 2, 3), tags=("nvlink",))
        with lock:
            statuses.append(status)

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert statuses.count("cold") == 1
    assert statuses.count("hit") == 7
    assert cache.counters()["misses"] == 1


# -- tenancy arrival generation ----------------------------------------------


def test_job_arrivals_deterministic_and_twin_rich():
    mk = lambda: job_arrivals(random.Random(7), 600.0, rate=96 / 600.0,
                              twin_prob=0.65, max_jobs=32)
    a, b = mk(), mk()
    assert a == b
    assert len(a) == 32
    assert all(x.time <= y.time for x, y in zip(a, a[1:]))
    # twin_prob=0.65 must yield real shape reuse for the cache to bite on
    shapes = {(x.model.name, x.global_batch, x.seq, x.n_devices) for x in a}
    assert len(shapes) < len(a) / 2


def test_build_tenant_registry_round_trip():
    topo, arrivals, trace = build_tenant("multi_tenant_small", seed=0)
    spec = get_tenant_scenario("multi_tenant_small")
    assert len(topo.alive_ids()) == 16
    assert arrivals and trace.events
    assert spec.gpus_per_node == 4
    with pytest.raises(KeyError):
        get_tenant_scenario("nope")


# -- service end-to-end ------------------------------------------------------


def test_twins_share_one_cold_search_byte_identically():
    topo = homogeneous_cluster(8, "V100", gpus_per_node=4)
    svc = PlannerService(topo, max_candidates=48)
    rep = svc.replay([_spec("a"), _spec("b")])
    assert rep.admitted == 2
    assert rep.cold_searches == 1
    assert rep.cache_hits == 1
    a, b = svc.jobs["a"], svc.jobs["b"]
    assert a.device_ids == (0, 1, 2, 3) and b.device_ids == (4, 5, 6, 7)
    # the remapped hit is byte-identical to a direct cold search on b's
    # own (isomorphic) slice
    engine = ReplanEngine(TINY, global_batch=32, seq=512, max_candidates=48,
                          gpus_per_node=4)
    direct = engine.plan(svc.topo.subtopology(b.device_ids))
    assert repr(b.plan) == repr(direct.plan)


def test_big_job_blocks_head_of_line_until_devices_free():
    topo = homogeneous_cluster(8, "V100", gpus_per_node=4)
    svc = PlannerService(topo, max_candidates=48)
    # big high-priority job arrives when only 4 devices remain free: the
    # small low-priority job behind it must NOT jump the queue
    specs = [_spec("first", arrival_s=0.0, duration_s=5.0),
             _spec("big", n_devices=8, priority=2, arrival_s=1.0,
                   duration_s=2.0),
             _spec("small", priority=0, arrival_s=1.0)]
    rep = svc.replay(specs)
    assert rep.admitted == 3
    big, small = svc.jobs["big"], svc.jobs["small"]
    assert big.admitted_s == 5.0           # waited for "first" to finish
    assert small.admitted_s == 7.0         # and for "big", despite fitting
    # at t=1 — head-of-line priority is starvation-free for big jobs


def test_replay_serial_equals_threaded():
    def run(workers):
        topo, arrivals, trace = build_tenant("multi_tenant_small", seed=0)
        svc = PlannerService(topo, workers=workers, max_candidates=48)
        return svc.replay(to_job_specs(arrivals, gpus_per_node=4),
                          list(trace.to_events()))

    serial, threaded = run(1), run(4)
    assert serial.plan_digests == threaded.plan_digests
    assert (serial.admitted, serial.cold_searches, serial.cache_hits,
            serial.replans, serial.invalidated) \
        == (threaded.admitted, threaded.cold_searches, threaded.cache_hits,
            threaded.replans, threaded.invalidated)
    assert serial.replans > 0              # the contract was exercised


def test_events_replan_only_affected_jobs():
    svc = PlannerService(homogeneous_cluster(8, "V100", gpus_per_node=4),
                         max_candidates=48)
    svc.replay([_spec("a"), _spec("b")])
    # single-node 4-device slices have no ib edges: an ib-tagged event
    # must replan nobody and invalidate nothing
    out = svc.handle_event(NetworkEvent(1.0, "bandwidth", selector="ib",
                                        factor=0.5))
    assert out == []
    # a device slowdown replans exactly the owning job
    out = svc.handle_event(NetworkEvent(2.0, "slowdown", device_id=5,
                                        factor=0.5))
    assert [name for name, _ in out] == ["b"]
    assert svc.jobs["a"].replans == 0 and svc.jobs["b"].replans == 1
