"""Equivalence tests for the optimized execution paths (§Perf changes).

Every beyond-paper optimization must match its reference implementation:
group-local MoE dispatch, chunkwise-parallel SSD, chunked time scans,
sharding-rule fallbacks.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models.lm import LM

# reference-vs-optimized numerical equivalence sweeps (several jit compiles
# each) — covered by the slow suite, not the tier-1 CI gate
pytestmark = pytest.mark.slow


def test_moe_grouped_dispatch_matches_single_group():
    """With ample capacity (no drops) group-local dispatch == global."""
    cfg = get_config("qwen3_moe_30b_a3b").reduced()
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)  # dropless
    key = jax.random.PRNGKey(0)
    p = L.materialize(L.moe_defs(cfg), key, jnp.float32)
    x = jax.random.normal(key, (4, 16, cfg.d_model)) * 0.5
    y1 = L.moe_block(p, dataclasses.replace(cfg, moe_groups=1), x)
    y4 = L.moe_block(p, dataclasses.replace(cfg, moe_groups=4), x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               atol=1e-5, rtol=1e-5)


def test_moe_group_fallback_when_not_divisible():
    cfg = get_config("dbrx_132b").reduced()
    cfg = dataclasses.replace(cfg, moe_groups=7)   # 2*16 % 7 != 0 -> G=1
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    out = model.forward(params, tokens)
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("chunk,h0", [(64, False), (128, True), (32, True)])
def test_chunkwise_ssd_matches_sequential(chunk, h0):
    key = jax.random.PRNGKey(1)
    B, S, nh, hd, N = 2, 256, 4, 16, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd))
    B_in = jax.random.normal(ks[1], (B, S, N)) * 0.5
    C_in = jax.random.normal(ks[2], (B, S, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, nh)))
    A_log = jax.random.normal(ks[4], (nh,)) * 0.3
    D = jnp.ones((nh,))
    state = jax.random.normal(key, (B, nh, hd, N)) if h0 else None
    y1, h1 = L._mamba_scan_seq(x, B_in, C_in, dt, A_log, D, hd, h0=state)
    y2, h2 = L._mamba_scan(x, B_in, C_in, dt, A_log, D, hd, h0=state,
                           chunk=chunk)
    np.testing.assert_allclose(y1, y2, atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(h1, h2, atol=5e-4, rtol=5e-3)


def test_chunkwise_ssd_gradients_match():
    key = jax.random.PRNGKey(2)
    B, S, nh, hd, N = 1, 128, 2, 8, 4
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, nh, hd))
    B_in = jax.random.normal(ks[1], (B, S, N)) * 0.5
    C_in = jax.random.normal(ks[2], (B, S, N)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, nh)))
    A_log = jnp.zeros((nh,))
    D = jnp.ones((nh,))

    def f_seq(x):
        return jnp.sum(L._mamba_scan_seq(x, B_in, C_in, dt, A_log, D,
                                         hd)[0] ** 2)

    def f_chk(x):
        return jnp.sum(L._mamba_scan(x, B_in, C_in, dt, A_log, D, hd,
                                     chunk=32)[0] ** 2)

    np.testing.assert_allclose(jax.grad(f_seq)(x), jax.grad(f_chk)(x),
                               atol=2e-3, rtol=2e-2)


def test_chunked_time_scan_matches_plain():
    def step(c, x):
        c = 0.9 * c + x
        return c, jnp.tanh(c)

    xs = jax.random.normal(jax.random.PRNGKey(3), (512, 8))
    c0 = jnp.zeros((8,))
    c1, y1 = jax.lax.scan(step, c0, xs)
    c2, y2 = L.chunked_time_scan(step, c0, xs, chunk=128)
    np.testing.assert_allclose(c1, c2, atol=1e-6)
    np.testing.assert_allclose(y1, y2, atol=1e-6)
    # gradient path (the whole point: per-chunk remat)
    g1 = jax.grad(lambda xs: jnp.sum(jax.lax.scan(step, c0, xs)[1]))(xs)
    g2 = jax.grad(lambda xs: jnp.sum(
        L.chunked_time_scan(step, c0, xs, chunk=128)[1]))(xs)
    np.testing.assert_allclose(g1, g2, atol=1e-5)


def test_axis_rules_divisibility_fallback():
    import os
    from repro.parallel.axes import AxisRules
    from jax.sharding import PartitionSpec as P
    rules = AxisRules()
    # no mesh: raw specs
    assert rules.spec(("batch", None, "heads")) == \
        P(("pod", "data"), None, ("model",))
    # pseudo-mesh via shape checks happens in sharding tests (multidev)


def test_pad_heads_exactness():
    """Padded q heads with zero wo rows leave the function unchanged."""
    import dataclasses as dc
    cfg = get_config("qwen2_7b").reduced(n_layers=2, d_model=64, vocab=128,
                                         d_ff=128, n_heads=3, n_kv_heads=1,
                                         head_dim=16)
    model = LM(cfg)
    key = jax.random.PRNGKey(4)
    params = model.init(key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    ref = model.forward(params, tokens)
    # pad 3 -> 4 heads; extra head rows: wq random junk, wo rows ZERO
    cfg_p = dc.replace(cfg, n_heads=4)
    model_p = LM(cfg_p)
    params_p = model_p.init(jax.random.PRNGKey(99))

    def pad_tree(src, dst):
        for pos in ("pos0",):
            for name in ("wq",):
                dst[pos]["attn"][name] = dst[pos]["attn"][name].at[
                    :, :, :3].set(src[pos]["attn"][name])
        return dst

    import copy
    pp = jax.tree.map(lambda x: x, params_p)
    pp["embed"] = params["embed"]
    pp["final_norm"] = params["final_norm"]
    a_src, a_dst = params["pos0"]["attn"], pp["pos0"]["attn"]
    a_dst["ln"] = a_src["ln"]
    a_dst["wk"], a_dst["wv"] = a_src["wk"], a_src["wv"]
    a_dst["bk"], a_dst["bv"] = a_src["bk"], a_src["bv"]
    a_dst["wq"] = a_dst["wq"].at[:, :, :3].set(a_src["wq"])
    a_dst["bq"] = a_dst["bq"].at[:, :3].set(a_src["bq"])
    a_dst["wo"] = jnp.zeros_like(a_dst["wo"]).at[:, :3].set(a_src["wo"])
    pp["pos0"]["ffn"] = params["pos0"]["ffn"]
    out = model_p.forward(pp, tokens)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               atol=2e-5, rtol=2e-5)


def test_chunkwise_mlstm_matches_sequential():
    import math
    key = jax.random.PRNGKey(7)
    B, S, H, hd, chunk = 2, 192, 3, 16, 64
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd)) / math.sqrt(hd)
    v = jax.random.normal(ks[2], (B, S, H, hd))
    it = (jax.random.normal(ks[3], (B, S, H)) * 2).astype(jnp.float32)
    ft = (jax.random.normal(ks[4], (B, S, H)) * 2 + 1).astype(jnp.float32)

    C = jnp.zeros((B, H, hd, hd))
    n = jnp.zeros((B, H, hd))
    m = jnp.full((B, H), -1e30)
    ys = []
    for t in range(S):
        qt, kt, vt = q[:, t], k[:, t], v[:, t]
        logf = -jax.nn.softplus(-ft[:, t])
        m_new = jnp.maximum(logf + m, it[:, t])
        fg = jnp.exp(logf + m - m_new)[..., None]
        ig = jnp.exp(it[:, t] - m_new)[..., None]
        C = C * fg[..., None] + ig[..., None] * (kt[..., :, None]
                                                 * vt[..., None, :])
        n = n * fg + ig * kt
        num = jnp.einsum("bhkv,bhk->bhv", C, qt)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt))
        ys.append(num / jnp.maximum(den, 1.0)[..., None])
        m = m_new
    y_ref = jnp.stack(ys, 1)

    state0 = (jnp.zeros((B, H, hd, hd)), jnp.zeros((B, H, hd)),
              jnp.full((B, H), -1e30))
    y_chk, (C_c, n_c, m_c) = L._mlstm_chunkwise(q, k, v, it, ft, state0,
                                                chunk=chunk)
    np.testing.assert_allclose(y_ref, y_chk, atol=3e-4, rtol=3e-3)
    np.testing.assert_allclose(m, m_c, atol=1e-5)
    np.testing.assert_allclose(C, C_c, atol=3e-4, rtol=3e-3)
