"""End-to-end system behaviour: plan -> train -> checkpoint -> resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import hetero_cluster, plan_hybrid
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def _cfg():
    return get_config("qwen2_7b").reduced(n_layers=2, d_model=64, vocab=128,
                                          d_ff=128)


def test_public_api_imports():
    import repro.core as core
    import repro.kernels.ops as ops
    import repro.models as models
    import repro.parallel.sharding as sharding
    from repro.launch.mesh import make_production_mesh
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.n_layers > 0 and cfg.vocab > 0
        assert cfg.shapes(), a


def test_plan_train_checkpoint_resume(tmp_path):
    """The full loop: auto-plan on an analytic cluster, train, checkpoint,
    build a NEW trainer, restore, and continue with matching loss."""
    topo = hetero_cluster({"RTX4090D": 2, "V100": 2}, gpus_per_node=2)
    plan = plan_hybrid(topo, _cfg().to_model_desc(), global_batch=4,
                       seq=32, with_baseline=False).plan
    tcfg = TrainerConfig(arch=_cfg(), steps=9, global_batch=4, seq_len=32,
                         ckpt_dir=str(tmp_path), ckpt_every=4, log_every=1,
                         opt=AdamWConfig(peak_lr=1e-3, warmup_steps=2,
                                         total_steps=20))
    tr = Trainer(tcfg, plan=plan)
    state, hist = tr.run()
    losses = {h["step"]: h["loss"] for h in hist}

    from repro.checkpoint.store import latest_step, restore
    from repro.parallel.trainstep import init_train_state
    step = latest_step(tmp_path)
    assert step == 8
    import dataclasses
    tcfg2 = dataclasses.replace(tcfg, steps=12)
    tr2 = Trainer(tcfg2, plan=plan)
    like = init_train_state(tr2.model, jax.random.PRNGKey(tcfg.seed))
    restored, manifest = restore(tmp_path / f"step_{step}", like,
                                 shardings=tr2.state_sh)
    state2, hist2 = tr2.run(state=restored, start_step=step + 1)
    # resumed losses continue the trajectory (same data stream)
    assert abs(hist2[0]["loss"] - losses[8]) < 0.6


def test_planner_to_trainer_knobs_flow():
    topo = hetero_cluster({"V100": 4}, gpus_per_node=4)
    res = plan_hybrid(topo, _cfg().to_model_desc(), global_batch=8, seq=32,
                      with_baseline=False)
    assert res.plan.world <= 4
    assert res.plan.microbatches >= 1
    tcfg = TrainerConfig(arch=_cfg(), steps=3, global_batch=8, seq_len=32,
                         ckpt_every=0, microbatches=2)
    tr = Trainer(tcfg, plan=res.plan)
    _, hist = tr.run()
    assert np.isfinite(hist[-1]["loss"])
