"""Scenario subsystem: trace determinism, JSONL round-trip, generator
invariants, and harness replay (sequential == parallel)."""

import math
import random

import pytest

from repro.core import ModelDesc, NetworkEvent
from repro.scenarios import (ScenarioHarness, Trace, build, build_trace,
                             congestion_bursts, get_scenario, list_scenarios,
                             spot_preemptions)

TINY = ModelDesc("tiny", n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
                 d_ff=2048, vocab=32000)

STOCHASTIC = [n for n in list_scenarios()
              if not get_scenario(n).deterministic]


# ---------------------------------------------------------------------------
# Trace format
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list_scenarios())
def test_trace_determinism_byte_identical(name):
    """Identical seeds produce byte-identical traces (the determinism
    gate), and the JSONL round-trip is the identity."""
    a, b = build_trace(name, seed=7), build_trace(name, seed=7)
    assert a.dumps() == b.dumps()
    assert Trace.loads(a.dumps()).dumps() == a.dumps()


def test_trace_seed_sensitivity():
    assert any(build_trace(n, seed=0).dumps() != build_trace(n, seed=1).dumps()
               for n in STOCHASTIC)


def test_trace_record_load_roundtrip(tmp_path):
    tr = build_trace("congested_multitenant", seed=3)
    p = tr.record(tmp_path / "t.jsonl")
    back = Trace.load(p)
    assert back == tr
    assert back.events == tr.events and back.seed == 3


def test_trace_version_and_format_checks():
    tr = build_trace("straggler_churn", seed=0)
    lines = tr.dumps().splitlines()
    with pytest.raises(ValueError, match="not a scenario trace"):
        Trace.loads(lines[0].replace("repro-scenario-trace", "x") + "\n")
    with pytest.raises(ValueError, match="unsupported trace version"):
        Trace.loads(lines[0].replace('"version": 1', '"version": 99') + "\n")
    with pytest.raises(ValueError, match="empty"):
        Trace.loads("")


def test_trace_to_step_events_mapping():
    tr = Trace.from_events(
        "m", [NetworkEvent(6.0, "fail", device_id=0),
              NetworkEvent(12.0, "join", device_id=0),
              NetworkEvent(999.0, "fail", device_id=1)], horizon=24.0)
    stepped = tr.to_step_events(24)
    assert [s for s, _ in stepped] == [6, 12, 23]   # clamped to last step
    assert all(isinstance(e, NetworkEvent) for _, e in stepped)


# ---------------------------------------------------------------------------
# Generator invariants
# ---------------------------------------------------------------------------


def test_spot_preemptions_keep_quorum_and_pair_join_after_fail():
    rng = random.Random(11)
    evs = spot_preemptions(rng, list(range(8)), 1000.0,
                           preempt_rate=0.05, restore_mean=50.0,
                           min_alive_frac=0.5)
    alive = set(range(8))
    last_fail: dict[int, float] = {}
    for ev in evs:
        if ev.kind == "fail":
            alive.discard(ev.device_id)
            last_fail[ev.device_id] = ev.time
        else:
            assert ev.kind == "join"
            assert ev.time > last_fail[ev.device_id]  # join follows its fail
            alive.add(ev.device_id)
        assert len(alive) >= 4                        # quorum held
    assert any(e.kind == "join" for e in evs)


def test_congestion_bursts_are_scale_mode_and_restore():
    rng = random.Random(5)
    evs = congestion_bursts(rng, 10_000.0, burst_rate=0.002, selector="ib",
                            decay_steps=3)
    assert evs and all(e.mode == "scale" and e.kind == "bandwidth"
                       for e in evs)
    prod = 1.0
    for e in evs:
        prod *= e.factor
    # every burst that completed within the horizon restores exactly (scale
    # factors are emitted at full precision); with a huge horizon all bursts
    # complete, so the product returns to 1 up to float rounding
    assert prod == pytest.approx(1.0, rel=1e-9)


# ---------------------------------------------------------------------------
# Harness replay
# ---------------------------------------------------------------------------


def _harness():
    return ScenarioHarness(TINY, global_batch=32, seq=512,
                           max_candidates=24)


def test_harness_replay_and_replay_determinism():
    h = _harness()
    rep1 = h.run("straggler_churn", seed=1)
    rep2 = h.run("straggler_churn", seed=1)
    assert rep1.n_events > 0 and rep1.adaptations == rep1.n_events
    assert rep1.replans >= 1
    assert len(rep1.adapted.timeline) == len(rep1.static.timeline)
    assert math.isfinite(rep1.adapted.avg_step)
    assert rep1.adapted_over_oracle >= 0.95
    # identical seeds -> identical simulated replay
    assert rep1.adapted.timeline == rep2.adapted.timeline
    assert rep1.static.timeline == rep2.static.timeline
    assert rep1.oracle.timeline == rep2.oracle.timeline
    assert rep1.actions == rep2.actions


def test_harness_trace_load_replay_matches_catalog_replay(tmp_path):
    """serialize -> load -> replay == direct catalog replay (the trace file
    is a faithful representation of the scenario)."""
    h = _harness()
    tr = build_trace("straggler_churn", seed=2)
    loaded = Trace.load(tr.record(tmp_path / "s.jsonl"))
    topo, _ = build("straggler_churn", seed=2)
    via_trace = h.run(loaded, topo=topo)
    via_name = h.run("straggler_churn", seed=2)
    assert via_trace.adapted.timeline == via_name.adapted.timeline
    assert via_trace.replans == via_name.replans


def test_harness_parallel_matches_sequential():
    h = _harness()
    items = [("straggler_churn", 1), ("fig6c_dynamic_bw", 0)]
    seq = h.run_many(items, parallel=False)
    par = h.run_many(items, parallel=True)
    assert [r.scenario for r in par] == [r.scenario for r in seq]
    for a, b in zip(seq, par):
        assert a.adapted.timeline == b.adapted.timeline
        assert a.static.timeline == b.static.timeline
        assert a.replans == b.replans


def test_harness_delivers_event_at_horizon():
    """from_events defaults the horizon to the last event's time; that event
    must still reach the orchestrator (as it does via the Trainer path)."""
    from repro.scenarios import build

    topo, _ = build("straggler_churn", seed=0)
    tr = Trace.from_events(
        "edge", [NetworkEvent(50.0, "slowdown", device_id=1, factor=0.5),
                 NetworkEvent(100.0, "fail", device_id=0)])
    assert tr.horizon == 100.0
    rep = _harness().run(tr, topo=topo)
    assert rep.adaptations == 2                 # the t==horizon fail counted
    assert len(rep.adapted.timeline) == 3       # t=0, t=50, t=100 intervals


def test_harness_explicit_trace_requires_topo():
    with pytest.raises(ValueError, match="explicit topology"):
        _harness().run(build_trace("straggler_churn", seed=0))


# ---------------------------------------------------------------------------
# Composed timelines (ISSUE 4 satellite)
# ---------------------------------------------------------------------------


def test_compose_traces_merges_sorted_with_max_horizon():
    from repro.scenarios import compose_traces

    a = Trace.from_events("a", [NetworkEvent(10.0, "bandwidth", factor=0.5,
                                             mode="scale"),
                                NetworkEvent(30.0, "bandwidth", factor=2.0,
                                             mode="scale")], horizon=40.0)
    b = Trace.from_events("b", [NetworkEvent(20.0, "fail", device_id=3)],
                          horizon=100.0)
    c = compose_traces([a, b])
    assert c.name == "a+b"
    assert c.horizon == 100.0
    assert [e.time for e in c.events] == [10.0, 20.0, 30.0]
    assert dict(c.meta)["components"] == "a|b"
    # explicit horizon clips later events
    clipped = compose_traces([a, b], name="clip", horizon=15.0)
    assert [e.time for e in clipped.events] == [10.0]
    with pytest.raises(ValueError):
        compose_traces([])


def test_composed_catalog_entries_mix_their_families():
    storm = build_trace("diurnal_spot_storm", seed=1)
    kinds = {e.kind for e in storm.events}
    assert "bandwidth" in kinds and "fail" in kinds      # S1 + S3 composed
    assert dict(storm.meta)["family"] == "diurnal_spot_storm"
    flaky = build_trace("congested_flaky", seed=1)
    assert all(e.kind == "bandwidth" and e.mode == "scale"
               for e in flaky.events)
    # flaps + bursts interleave: more events than either family alone would
    # produce at these rates, and net level returns to ~1.0 when every
    # burst/flap pair completes inside the horizon
    assert len(flaky.events) >= 6


def test_composed_scenario_replays_through_harness():
    rep = _harness().run("congested_flaky", seed=0)
    assert rep.n_events == len(build_trace("congested_flaky", seed=0))
    assert rep.adaptations == rep.n_events
    assert math.isfinite(rep.adapted.avg_step)


@pytest.mark.slow
def test_harness_search_procs_matches_serial_scoring():
    """A replay whose searches score in worker processes (one executor
    reused across all intervals) is plan-for-plan identical to the serial
    replay — step timelines, switch counts, and charges all match."""
    from dataclasses import replace as dc_replace

    h = _harness()
    base = h.run("fig6c_dynamic_bw", seed=0)
    h.cfg = dc_replace(h.cfg, search_procs=2)
    par = h.run("fig6c_dynamic_bw", seed=0)
    assert par.adapted.timeline == base.adapted.timeline
    assert par.static.timeline == base.static.timeline
    assert par.replans == base.replans
    assert par.switch_cost_s == base.switch_cost_s
    if base.oracle_dp is not None:
        assert par.oracle_dp.timeline == base.oracle_dp.timeline
