"""LP bound tier + exact-MIP oracle (ISSUE 9): dense two-phase simplex
edge cases, admissibility of :func:`lp_lower_bound` against the simulator,
and cascade-argmin == :func:`mip_optimum` certification on the fixed test
topologies."""

import math

import pytest

from repro.core import (coarse_lower_bound, enumerate_strategies,
                        lp_bound_context, lp_lower_bound, materialize_variant,
                        mip_optimum, plan_hybrid, point_lower_bound,
                        simplex_solve, simulate_training_step)
from test_search import CLUSTERS, DESC

FAST_CLUSTERS = [c for c in CLUSTERS
                 if c[0] in ("hetero", "homo", "slowlink", "line")]


# ---------------------------------------------------------------------------
# Simplex: solved-by-hand programs covering every status path
# ---------------------------------------------------------------------------


def test_simplex_basic_optimal():
    # max x1 + x2 s.t. x1 + 2 x2 <= 4, 3 x1 + x2 <= 6: optimum at the
    # intersection (8/5, 6/5), objective 14/5
    res = simplex_solve([-1.0, -1.0], A_ub=[[1, 2], [3, 1]], b_ub=[4, 6])
    assert res.status == "optimal"
    assert res.objective == pytest.approx(-2.8)
    assert res.x == pytest.approx((1.6, 1.2))


def test_simplex_infeasible_prices_plus_inf():
    # x <= -1 contradicts x >= 0; bound code consumes +inf directly
    res = simplex_solve([1.0], A_ub=[[1.0]], b_ub=[-1.0])
    assert res.status == "infeasible"
    assert res.objective == math.inf
    assert res.x is None


def test_simplex_unbounded_guard():
    # x1 unconstrained below in cost, no row touches it
    res = simplex_solve([-1.0, 0.0], A_ub=[[0.0, 1.0]], b_ub=[1.0])
    assert res.status == "unbounded"
    assert res.objective == -math.inf


def test_simplex_degenerate_basis_terminates():
    # duplicated tight rows create a degenerate vertex; Bland's rule must
    # still terminate at the optimum
    res = simplex_solve([-1.0, -1.0],
                        A_ub=[[1, 0], [1, 0], [1, 1]], b_ub=[1, 1, 1])
    assert res.status == "optimal"
    assert res.objective == pytest.approx(-1.0)


def test_simplex_equality_rows():
    # min x1 + 2 x2 on the segment x1 + x2 = 3: all mass on the cheap var
    res = simplex_solve([1.0, 2.0], A_eq=[[1.0, 1.0]], b_eq=[3.0])
    assert res.status == "optimal"
    assert res.objective == pytest.approx(3.0)
    assert res.x == pytest.approx((3.0, 0.0))


def test_simplex_negative_rhs_sign_flip():
    # x1 - x2 = -2 exercises the b < 0 row normalization + artificials
    res = simplex_solve([1.0, 1.0], A_eq=[[1.0, -1.0]], b_eq=[-2.0])
    assert res.status == "optimal"
    assert res.objective == pytest.approx(2.0)
    assert res.x == pytest.approx((0.0, 2.0))


def test_simplex_empty_program():
    assert simplex_solve([1.0, 2.0]).objective == 0.0
    assert simplex_solve([-1.0]).status == "unbounded"


# ---------------------------------------------------------------------------
# Admissibility: point <= coarse <= lp <= simulated, for every candidate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,make", CLUSTERS)
def test_lp_bound_admissible_for_every_candidate(name, make):
    """The tier-2.5 bound undershoots the simulator for BOTH
    materializations of every enumerated point while dominating the
    coarse tier (the invariant LP pruning soundness rests on)."""
    topo = make()
    pts, _ = enumerate_strategies(topo, DESC, global_batch=32)
    variants = (True, False) if topo.is_heterogeneous() else (False,)
    ctx = lp_bound_context(topo, DESC, global_batch=32, seq=1024)
    for p in pts:
        lb2 = coarse_lower_bound(p, topo, DESC, global_batch=32, seq=1024)
        lb3_point = lp_lower_bound(p, topo, DESC, global_batch=32,
                                   seq=1024, ctx=ctx)
        assert lb3_point >= lb2 - 1e-12, (name, p)
        for refine in variants:
            lb3 = lp_lower_bound(p, topo, DESC, global_batch=32, seq=1024,
                                 refine=refine, ctx=ctx)
            assert lb3 >= lb3_point - 1e-12, (name, p, refine)
            try:
                plan = materialize_variant(p, refine, topo, DESC,
                                           global_batch=32, seq=1024)
                sim = simulate_training_step(plan, DESC, topo,
                                             global_batch=32, seq=1024)
            except (ValueError, ZeroDivisionError):
                continue
            rel = 1e-9 * max(1.0, sim.step_time)
            assert lb3 <= sim.step_time + rel, (name, p, refine)


def test_lp_context_memoizes_solves():
    topo = dict(CLUSTERS)["hetero"]()
    pts, _ = enumerate_strategies(topo, DESC, global_batch=32)
    ctx = lp_bound_context(topo, DESC, global_batch=32, seq=1024)
    p = pts[0]
    assert ctx.would_solve(p.tp)
    first = lp_lower_bound(p, topo, DESC, global_batch=32, seq=1024,
                           refine=True, ctx=ctx)
    assert not ctx.would_solve(p.tp)
    solves = ctx.lp_solves
    again = lp_lower_bound(p, topo, DESC, global_batch=32, seq=1024,
                           refine=True, ctx=ctx)
    assert again == first
    assert ctx.lp_solves == solves          # memo hit: no fresh solve
    assert ctx.solve_wall_estimate() > 0.0


# ---------------------------------------------------------------------------
# Certification: cascade argmin == exact MIP optimum
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,make", FAST_CLUSTERS)
def test_cascade_argmin_matches_mip_optimum(name, make):
    topo = make()
    res = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                      with_baseline=False)
    mip = mip_optimum(topo, DESC, global_batch=32, seq=1024,
                      wall_budget_s=120.0)
    assert mip.completed, name
    assert mip.step_time == res.predicted.step_time, name
    assert mip.plan.to_json() == res.plan.to_json(), name
    assert mip.nodes > 0 and mip.sims > 0


@pytest.mark.slow
@pytest.mark.parametrize("name,make", CLUSTERS)
def test_cascade_argmin_matches_mip_optimum_full_sweep(name, make):
    topo = make()
    res = plan_hybrid(topo, DESC, global_batch=32, seq=1024,
                      with_baseline=False)
    mip = mip_optimum(topo, DESC, global_batch=32, seq=1024,
                      wall_budget_s=300.0)
    if not mip.completed:              # budget exhausted: skip, never fail
        pytest.skip(f"oracle budget exhausted on {name}")
    assert mip.step_time == res.predicted.step_time, name
    assert mip.plan.to_json() == res.plan.to_json(), name


def test_mip_budget_exhaustion_is_incomplete_not_wrong():
    topo = dict(CLUSTERS)["hetero"]()
    mip = mip_optimum(topo, DESC, global_batch=32, seq=1024, node_budget=1)
    assert not mip.completed
    # with best-first order an exhausted run either has no incumbent yet
    # (inf sentinel) or a feasible one — never a fabricated optimum claim
    if mip.plan is None:
        assert mip.step_time == math.inf and mip.index == -1
    else:
        full = mip_optimum(topo, DESC, global_batch=32, seq=1024)
        assert mip.step_time >= full.step_time


def test_mip_infeasible_lattice_raises():
    topo = dict(CLUSTERS)["homo"]()
    big = type(DESC)(name="big", n_layers=96, d_model=12288, n_heads=96,
                     n_kv_heads=96, d_ff=49152, vocab=50000)
    with pytest.raises(RuntimeError):
        mip_optimum(topo, big, global_batch=32, seq=4096)
