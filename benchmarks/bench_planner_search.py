"""Planner search efficiency (paper §3.4 + §4 parallel simulation).

Exercises the tiered search pipeline end to end, per (topology, cluster
size):

  * EXHAUSTIVE: every candidate fully simulated (``prune=False``) — the
    soundness reference and the cost floor the cascade is judged against,
  * SERIAL CASCADE: the staged pruning pipeline (feasibility → analytic
    bound → coarse estimate → simulation) in one process,
  * PARALLEL CASCADE: the same pipeline with the final simulation tier
    scored across worker processes (``SearchExecutor``).

Topologies cover both a dense hetero fabric and the sparse TPU torus: with
multi-hop routed transfer pricing (ISSUE 5) the coarse tier keeps its
incident/connectivity ring caps on sparse link graphs, so the torus rows
gate on a nonzero coarse-tier prune count.

Gates: the cascade's argmin must equal the exhaustive argmin byte-for-byte,
the parallel plan must equal the serial plan byte-for-byte, the cascade
must prune a nonzero fraction of candidates before full simulation, the
sparse-topology rows must show coarse-tier pruning, and — where a CPU-bound
calibration probe shows this host can physically deliver >= 2.5x process
scaling — the parallel search must reach >= 2x over serial.  On shared-
hyperthread / 2-vCPU containers the speedup is reported, not asserted
(same policy as the PR 2 scenario-sweep gate).

PYTHONPATH=src python -m benchmarks.bench_planner_search [--quick] [--json P]
"""

from __future__ import annotations

import os
import time

from repro.core import (SearchExecutor, enumerate_strategies, hetero_cluster,
                        multi_pod_tpu, plan_hybrid)
from benchmarks.common import (PAPER_MODELS, calibrate_process_ceiling, emit,
                               write_json)


def _configs(quick: bool):
    """(topology, gpus, builder) rows.  The torus stays at 32 chips in both
    modes: it is the sparse-graph routing + coarse-cap coverage, not the
    scaling story."""
    sizes = (16,) if quick else (16, 64)
    cfgs = [("hetero", n,
             lambda n=n: hetero_cluster({"RTX4090D": n // 2, "V100": n // 2},
                                        gpus_per_node=8))
            for n in sizes]
    cfgs.append(("tpu-torus", 32,
                 lambda: multi_pod_tpu(pods=2, chips_per_pod=16)))
    return cfgs


def run(quick: bool = False, json_path: str | None = None) -> list[dict]:
    rows = []
    desc = PAPER_MODELS["LLaMA_7B"]
    procs = min(os.cpu_count() or 1, 8)
    ceiling = calibrate_process_ceiling(procs)
    executor = SearchExecutor(n_procs=procs)
    executor.warm()          # pool spin-up stays out of the timed region
    try:
        for topology, n, make in _configs(quick):
            topo = make()
            pts, enum_stats = enumerate_strategies(topo, desc,
                                                   global_batch=4 * n)
            kw = dict(global_batch=4 * n, seq=2048, with_baseline=False,
                      max_candidates=128)
            t0 = time.perf_counter()
            exh = plan_hybrid(topo, desc, prune=False, **kw)
            t_exh = time.perf_counter() - t0
            t0 = time.perf_counter()
            ser = plan_hybrid(topo, desc, **kw)
            t_ser = time.perf_counter() - t0
            t0 = time.perf_counter()
            par = plan_hybrid(topo, desc, executor=executor, **kw)
            t_par = time.perf_counter() - t0

            st = ser.search_stats
            speedup = t_ser / max(t_par, 1e-9)
            rows.append({
                "topology": topology,
                "gpus": n, "candidates": len(pts),
                "argmin_matches_exhaustive":
                    ser.plan.to_json() == exh.plan.to_json(),
                "parallel_matches_serial":
                    par.plan.to_json() == ser.plan.to_json(),
                "enum_pruned": enum_stats.pruned + enum_stats.infeasible,
                "cascade_candidates": st.cascade_candidates,
                "pruned_feasibility": st.pruned_feasibility,
                "pruned_bound": st.pruned_bound,
                "pruned_coarse": st.pruned_coarse,
                "simulated": st.simulated,
                "rejected": st.rejected,
                "prune_rate": round(st.prune_rate, 3),
                "search_exhaustive_s": round(t_exh, 2),
                "search_serial_s": round(t_ser, 2),
                "search_parallel_s": round(t_par, 2),
                "parallel_speedup": round(speedup, 2),
                "parallel_ceiling": round(ceiling, 2),
                "workers": procs,
            })
    finally:
        executor.close()
    # persist the telemetry BEFORE any gate can fire: a failed assertion
    # must not discard the rows that diagnose it (same policy as the
    # bench_scenarios gates)
    emit(rows, f"planner_search (tiered cascade + process-parallel "
               f"simulation; calibrated ceiling {ceiling:.2f}x on "
               f"{os.cpu_count()} cores)")
    if json_path:
        write_json(rows, json_path)
    # soundness + determinism gates (acceptance criteria)
    for r in rows:
        assert r["argmin_matches_exhaustive"], \
            ("cascade pruned the true argmin", r)
        assert r["parallel_matches_serial"], \
            ("process-parallel search diverged from serial", r)
        assert r["prune_rate"] > 0.0, \
            ("cascade pruned nothing before full simulation", r)
    # ISSUE 5 acceptance: the coarse tier's ring/connectivity caps are
    # active on the sparse TPU-torus link graph (routed transfer pricing
    # makes them sound there) and actually cut candidates
    sparse = [r for r in rows if r["topology"] == "tpu-torus"]
    assert sparse, rows
    for r in sparse:
        assert r["pruned_coarse"] > 0, \
            ("sparse-graph coarse caps pruned nothing", r)
    # parallel gate: asserted only where the calibrated ceiling shows real
    # multicore headroom (same policy as the bench_scenarios gate)
    if ceiling >= 2.5:
        best = max(r["parallel_speedup"] for r in rows)
        assert best >= 2.0, (
            f"process-parallel search speedup {best:.2f}x < 2x "
            f"(workers={procs}, calibrated ceiling {ceiling:.2f}x)")
    else:
        print(f"[bench] parallel gate skipped: calibrated ceiling "
              f"{ceiling:.2f}x < 2.5x on this host (measured "
              f"{max(r['parallel_speedup'] for r in rows):.2f}x)")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write rows as JSON")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json)
