"""Planner search efficiency (paper §3.4 + §4 parallel simulation) and
fleet-scale hierarchical island search (ISSUE 6).

Two row families, per (topology, cluster size):

**Flat-tractable rows** (<= 64 devices) exercise the tiered cascade end to
end:

  * EXHAUSTIVE: every candidate fully simulated (``prune=False``) — the
    soundness reference and the cost floor the cascade is judged against,
  * SERIAL CASCADE: the staged pruning pipeline (feasibility → analytic
    bound → coarse estimate → simulation) in one process,
  * PARALLEL CASCADE: the same pipeline with the final simulation tier
    scored across worker processes (``SearchExecutor``),
  * HIERARCHICAL ENTRY POINT: ``plan_hierarchical`` at its default
    ``flat_limit`` — on these sizes it must take the flat-fallback path and
    return the serial cascade's plan byte-for-byte (identity gate).

Topologies cover both a dense hetero fabric and the sparse TPU torus: with
multi-hop routed transfer pricing (ISSUE 5) the coarse tier keeps its
incident/connectivity ring caps on sparse link graphs, so the torus rows
gate on a nonzero coarse-tier prune count.

**Fleet rows** (1024 and 4096 devices, multi-pod TPU) exercise the
hierarchical island tier: partition into per-pod islands, one budgeted
sub-search per distinct (signature, batch-share) group — isomorphic pods
are planned once and remapped — composed under the admissible inter-island
sync bound.  These rows run the serial cascade inside each sub-search
(process scaling is the flat rows' story; the fleet lever is symmetry
dedup + the ``max_sims`` anytime budget) and gate on the partition shape,
the dedup count, and an absolute end-to-end wall budget (< 30 s at 4096
devices, the ISSUE 6 acceptance bar).

**Sim-fidelity rows** (ISSUE 8) pin the fabric layer's observable
behaviour: the sparse 2-pod torus training-step estimate under the default
cut-through pipelining vs the store-and-forward reference
(``use_fabric(FabricModel(pipelining=False))``) — pipelined must be
strictly faster on a fabric with relayed pairs — and the deterministic
mid-flight re-routing counters from replaying the ``diurnal_wan_crossover``
catalog trace through ``simulate_epoch``.  ``benchmarks.compare`` gates
the pipelined<=S&F boolean, the pipelined/S&F delta and the exact reroute
counts against the committed baseline.

**LP tier + MIP certification (ISSUE 9)**: every flat row re-runs the
serial cascade with the tier-2.5 LP-relaxation bound disabled
(``lp_prune=False``) and gates byte-identity of the argmin — the LP tier is
admissible, so it may only change how many candidates reach the simulator
(``pruned_lp`` / ``lp_wall_s`` columns; the dense-hetero rows gate a
ratio-min on ``pruned_lp`` via ``benchmarks.compare`` and the ISSUE 9
acceptance floor ``prune_rate >= 0.40`` here).  Each flat row also runs the
exact branch-and-bound oracle (``repro.core.mip.mip_optimum``) under a wall
budget: wherever the oracle completes, the cascade argmin must equal the
certified optimum byte-for-byte (``mip_certified``; budget exhaustion
skips, never fails).

The hetero/16 row additionally measures **tracing overhead** (ISSUE 7):
the serial cascade runs again untraced and twice traced into a live
:class:`repro.obs.Obs` bundle; ``trace_overhead`` is the min-of-2 traced
wall over the min-of-2 untraced wall, gated at <= 1.10x by
``benchmarks.compare``.  ``--trace PATH`` writes the traced run's combined
Perfetto trace (+ a standalone metrics snapshot next to it) for the CI
artifact.

Gates: the cascade's argmin must equal the exhaustive argmin byte-for-byte,
the parallel plan must equal the serial plan byte-for-byte, the
hierarchical entry point must match the serial plan on every flat row, the
cascade must prune a nonzero fraction of candidates, sparse-topology rows
must show coarse-tier pruning, fleet rows must partition into one island
per pod with all but one pod deduped, and — where a CPU-bound calibration
probe shows this host can physically deliver >= 2.5x process scaling — the
parallel search must reach >= 2x over serial.  On shared-hyperthread /
2-vCPU containers the speedup is reported, not asserted (same policy as
the PR 2 scenario-sweep gate).

PYTHONPATH=src python -m benchmarks.bench_planner_search \\
    [--quick] [--json P] [--trace P]
"""

from __future__ import annotations

import os
import time

from repro.core import (FabricModel, SearchExecutor, enumerate_strategies,
                        hetero_cluster, megatron_default_plan, mip_optimum,
                        multi_pod_tpu, plan_hierarchical, plan_hybrid,
                        simulate_epoch, simulate_training_step, use_fabric)
from repro.obs import Obs, write_metrics, write_trace
from benchmarks.common import (PAPER_MODELS, calibrate_process_ceiling, emit,
                               write_json)

# Anytime simulation budget per island sub-search on the fleet rows.  The
# 256-chip sub-search's bound-sorted order reaches the argmin within the
# first dozen simulations (measured; docs/benchmarks.md), and each skipped
# tail simulation costs ~1 s of single-core wall — 12 keeps the 4096-device
# row comfortably inside its 30 s acceptance budget.
FLEET_MAX_SIMS = 12
FLEET_WALL_BUDGET_S = 30.0


def _configs(quick: bool):
    """Flat-tractable (topology, gpus, builder) rows.  The torus stays at
    32 chips in both modes: it is the sparse-graph routing + coarse-cap
    coverage, not the scaling story."""
    sizes = (16,) if quick else (16, 64)
    cfgs = [("hetero", n,
             lambda n=n: hetero_cluster({"RTX4090D": n // 2, "V100": n // 2},
                                        gpus_per_node=8))
            for n in sizes]
    cfgs.append(("tpu-torus", 32,
                 lambda: multi_pod_tpu(pods=2, chips_per_pod=16)))
    return cfgs


def _fleet_configs(quick: bool):
    """Fleet-scale (topology, gpus, pods, chips_per_pod) rows.  Both sizes
    run in --quick too: the 4096-device wall budget is the ISSUE 6
    acceptance criterion and symmetry dedup makes the second row nearly
    free (16 isomorphic pods collapse to one sub-search)."""
    return [("multi-pod", 1024, 4, 256),
            ("multi-pod", 4096, 16, 256)]


def _sim_fidelity_rows(desc) -> list[dict]:
    """ISSUE 8 fabric rows: pipelined-vs-store-and-forward step estimate
    on the sparse 2-pod torus, and the deterministic mid-flight re-routing
    counters from the ``diurnal_wan_crossover`` catalog trace."""
    from repro.scenarios.catalog import build

    topo = multi_pod_tpu(pods=2, chips_per_pod=16)
    plan = megatron_default_plan(topo, desc, microbatches=4)
    kw = dict(global_batch=128, seq=2048)
    step_pip = simulate_training_step(plan, desc, topo, **kw).step_time
    with use_fabric(FabricModel(pipelining=False)):
        step_snf = simulate_training_step(plan, desc, topo, **kw).step_time

    ctopo, _ = build("diurnal_wan_crossover", seed=0)
    cplan = megatron_default_plan(ctopo.copy(), desc, microbatches=4)
    ckw = dict(global_batch=512, seq=2048, steps=8)
    obs = Obs()
    on = simulate_epoch(cplan, desc, ctopo, obs=obs, **ckw)
    off = simulate_epoch(cplan, desc, ctopo, reroute_in_flight=False, **ckw)
    return [{
        "topology": "sim-fidelity",
        "gpus": 32,
        "kind": "sim_fidelity",
        "step_pipelined": round(step_pip, 5),
        "step_snf": round(step_snf, 5),
        # acceptance: cut-through multi-hop estimates are strictly below
        # store-and-forward on a fabric with relayed pairs
        "pipelined_le_snf": step_pip < step_snf,
        "pipeline_delta": round(step_snf / max(step_pip, 1e-12), 4),
        "reroute_events": obs.metrics.counter_value("sim.reroute.events"),
        "reroute_steps": obs.metrics.counter_value("sim.reroute.steps"),
        "reroute_moves_epoch": on.total_time != off.total_time,
        "epoch_reroute_s": round(on.total_time, 4),
        "epoch_boundary_s": round(off.total_time, 4),
    }]


def run(quick: bool = False, json_path: str | None = None,
        trace_path: str | None = None) -> list[dict]:
    """Run every row family, emit CSV/JSON, then enforce the gates
    described in the module docstring.  Returns the rows.  With
    ``trace_path`` the hetero/16 traced run's Perfetto trace (and a
    ``*_metrics.json`` snapshot next to it) are written there."""
    rows = []
    trace_obs: Obs | None = None
    desc = PAPER_MODELS["LLaMA_7B"]
    procs = min(os.cpu_count() or 1, 8)
    ceiling = calibrate_process_ceiling(procs)
    executor = SearchExecutor(n_procs=procs)
    executor.warm()          # pool spin-up stays out of the timed region
    try:
        for topology, n, make in _configs(quick):
            topo = make()
            pts, enum_stats = enumerate_strategies(topo, desc,
                                                   global_batch=4 * n)
            kw = dict(global_batch=4 * n, seq=2048, with_baseline=False,
                      max_candidates=128)
            t0 = time.perf_counter()
            exh = plan_hybrid(topo, desc, prune=False, **kw)
            t_exh = time.perf_counter() - t0
            t0 = time.perf_counter()
            ser = plan_hybrid(topo, desc, **kw)
            t_ser = time.perf_counter() - t0
            # ISSUE 9: the same cascade with the LP tier off — admissibility
            # means the argmin is byte-identical, only the simulated count
            # (and wall) moves
            t0 = time.perf_counter()
            nolp = plan_hybrid(topo, desc, lp_prune=False, **kw)
            t_nolp = time.perf_counter() - t0
            t0 = time.perf_counter()
            par = plan_hybrid(topo, desc, executor=executor, **kw)
            t_par = time.perf_counter() - t0
            # exact-MIP certification oracle: budgeted at the exhaustive
            # wall (the oracle's LP bounds make it far cheaper in practice);
            # an exhausted budget skips certification, never fails it
            mip = mip_optimum(topo, desc, global_batch=4 * n, seq=2048,
                              max_candidates=128,
                              wall_budget_s=max(30.0, 2.0 * t_exh))
            mip_certified = (not mip.completed) or (
                mip.step_time == ser.predicted.step_time
                and mip.plan.to_json() == ser.plan.to_json())
            # hierarchical entry point at its default flat_limit: these
            # sizes must take the flat-fallback path and reproduce the
            # serial cascade's plan exactly
            t0 = time.perf_counter()
            hier = plan_hierarchical(topo, desc, global_batch=4 * n,
                                     seq=2048, max_candidates=128)
            t_hier = time.perf_counter() - t0

            # tracing-overhead measurement (ISSUE 7), on the gated
            # hetero/16 row only: min-of-2 walls on both sides keep
            # shared-runner scheduling noise out of the gated ratio
            trace_overhead = None
            if topology == "hetero" and n == 16:
                t0 = time.perf_counter()
                plan_hybrid(topo, desc, **kw)
                untraced = min(t_ser, time.perf_counter() - t0)
                traced = float("inf")
                for _ in range(2):
                    tobs = Obs()
                    t0 = time.perf_counter()
                    plan_hybrid(topo, desc, obs=tobs, **kw)
                    traced = min(traced, time.perf_counter() - t0)
                    trace_obs = tobs
                trace_overhead = round(traced / max(untraced, 1e-9), 3)

            st = ser.search_stats
            speedup = t_ser / max(t_par, 1e-9)
            rows.append({
                "topology": topology,
                "gpus": n, "candidates": len(pts),
                "argmin_matches_exhaustive":
                    ser.plan.to_json() == exh.plan.to_json(),
                "argmin_matches_nolp":
                    ser.plan.to_json() == nolp.plan.to_json()
                    and ser.predicted.step_time == nolp.predicted.step_time,
                "parallel_matches_serial":
                    par.plan.to_json() == ser.plan.to_json(),
                "mip_certified": mip_certified,
                "mip_completed": mip.completed,
                "mip_wall_s": round(mip.wall_s, 2),
                "hierarchical_matches_flat":
                    hier.path == "flat" and hier.flat is not None
                    and hier.flat.plan.to_json() == ser.plan.to_json(),
                "enum_pruned": enum_stats.pruned + enum_stats.infeasible,
                "cascade_candidates": st.cascade_candidates,
                "pruned_feasibility": st.pruned_feasibility,
                "pruned_bound": st.pruned_bound,
                "pruned_coarse": st.pruned_coarse,
                "pruned_lp": st.pruned_lp,
                "lp_wall_s": round(st.lp_wall_time, 4),
                "simulated": st.simulated,
                "rejected": st.rejected,
                "prune_rate": round(st.prune_rate, 3),
                "search_exhaustive_s": round(t_exh, 2),
                "search_serial_s": round(t_ser, 2),
                "search_serial_nolp_s": round(t_nolp, 2),
                "search_parallel_s": round(t_par, 2),
                "hier_wall_s": round(t_hier, 2),
                "parallel_speedup": round(speedup, 2),
                "parallel_ceiling": round(ceiling, 2),
                "workers": procs,
            })
            if trace_overhead is not None:
                rows[-1]["trace_overhead"] = trace_overhead

        for topology, n, pods, chips in _fleet_configs(quick):
            topo = multi_pod_tpu(pods=pods, chips_per_pod=chips)
            t0 = time.perf_counter()
            res = plan_hierarchical(topo, desc, global_batch=4 * n,
                                    seq=2048, max_candidates=128,
                                    max_sims=FLEET_MAX_SIMS)
            t_hier = time.perf_counter() - t0
            st = res.stats
            comp = res.composed
            rows.append({
                "topology": topology,
                "gpus": n, "pods": pods,
                "path": res.path,
                "n_islands": res.n_islands,
                "n_signatures": res.n_signatures,
                "islands_deduped": res.islands_deduped,
                "islands_dropped": res.islands_dropped,
                "max_sims": FLEET_MAX_SIMS,
                "simulated": st.simulated,
                "budget_skipped": st.budget_skipped,
                "step_est": round(res.predicted_step, 4),
                "inter_sync_s":
                    round(comp.inter_sync_s, 4) if comp else 0.0,
                "hier_wall_s": round(t_hier, 2),
            })

        rows.extend(_sim_fidelity_rows(desc))
    finally:
        executor.close()
    # persist the telemetry BEFORE any gate can fire: a failed assertion
    # must not discard the rows that diagnose it (same policy as the
    # bench_scenarios gates)
    emit(rows, f"planner_search (tiered cascade + process-parallel "
               f"simulation + hierarchical islands; calibrated ceiling "
               f"{ceiling:.2f}x on {os.cpu_count()} cores)")
    if json_path:
        write_json(rows, json_path, quick=quick)
    if trace_path and trace_obs is not None:
        from pathlib import Path
        p = write_trace(trace_obs, trace_path)
        m = write_metrics(trace_obs,
                          Path(trace_path).with_name(
                              Path(trace_path).stem + "_metrics.json"))
        print(f"[bench] wrote trace -> {p}, metrics -> {m}")
    # soundness + determinism gates (acceptance criteria)
    flat_rows = [r for r in rows if r["topology"] != "multi-pod"
                 and r.get("kind") != "sim_fidelity"]
    for r in flat_rows:
        assert r["argmin_matches_exhaustive"], \
            ("cascade pruned the true argmin", r)
        assert r["argmin_matches_nolp"], \
            ("LP tier changed the argmin — the bound is not admissible", r)
        assert r["parallel_matches_serial"], \
            ("process-parallel search diverged from serial", r)
        assert r["hierarchical_matches_flat"], \
            ("hierarchical fallback diverged from the flat cascade", r)
        assert r["prune_rate"] > 0.0, \
            ("cascade pruned nothing before full simulation", r)
        assert r["mip_certified"], \
            ("cascade argmin != completed MIP-oracle optimum", r)
    # ISSUE 9 acceptance: on the dense-hetero rows the LP tier must cut
    # candidates and lift the end-to-end prune rate past 40%
    for r in flat_rows:
        if r["topology"] == "hetero":
            assert r["pruned_lp"] > 0, \
                ("LP tier pruned nothing on a dense-hetero row", r)
            assert r["prune_rate"] >= 0.40, \
                ("dense-hetero prune rate below the ISSUE 9 floor", r)
    # ISSUE 5 acceptance: the coarse tier's ring/connectivity caps are
    # active on the sparse TPU-torus link graph (routed transfer pricing
    # makes them sound there) and actually cut candidates
    sparse = [r for r in rows if r["topology"] == "tpu-torus"]
    assert sparse, rows
    for r in sparse:
        assert r["pruned_coarse"] > 0, \
            ("sparse-graph coarse caps pruned nothing", r)
    # ISSUE 6 acceptance: fleet rows partition into one island per pod,
    # plan all-but-one pod by symmetry reuse, respect the simulation
    # budget, and land the 4096-device end-to-end plan under 30 s wall
    fleet = [r for r in rows if r["topology"] == "multi-pod"]
    assert fleet, rows
    for r in fleet:
        assert r["path"] == "hierarchical", \
            ("fleet row did not take the hierarchical path", r)
        assert r["n_islands"] == r["pods"], \
            ("island partition does not match the pod structure", r)
        assert r["islands_deduped"] == r["pods"] - 1, \
            ("isomorphic pods were not deduplicated", r)
        searched = r["n_islands"] - r["islands_deduped"] \
            - r["islands_dropped"]
        assert r["simulated"] <= r["max_sims"] * max(1, searched), \
            ("anytime budget was not respected", r)
        if r["gpus"] >= 4096:
            assert r["hier_wall_s"] < FLEET_WALL_BUDGET_S, \
                (f"4096-device hierarchical plan exceeded the "
                 f"{FLEET_WALL_BUDGET_S:.0f}s budget", r)
    # ISSUE 8 acceptance: cut-through pipelining strictly beats
    # store-and-forward on the sparse torus, and mid-flight re-routing is
    # live (the catalog trace splits at least one step) and deterministic
    fid = [r for r in rows if r.get("kind") == "sim_fidelity"]
    assert fid, rows
    for r in fid:
        assert r["pipelined_le_snf"], \
            ("pipelined step estimate not below store-and-forward", r)
        assert r["reroute_events"] >= 1 and r["reroute_steps"] >= 1, \
            ("catalog trace produced no mid-flight re-routes", r)
        assert r["reroute_moves_epoch"], \
            ("mid-flight re-routing did not change the epoch outcome", r)
    # parallel gate: asserted only where the calibrated ceiling shows real
    # multicore headroom (same policy as the bench_scenarios gate)
    if ceiling >= 2.5:
        best = max(r["parallel_speedup"] for r in flat_rows)
        assert best >= 2.0, (
            f"process-parallel search speedup {best:.2f}x < 2x "
            f"(workers={procs}, calibrated ceiling {ceiling:.2f}x)")
    else:
        print(f"[bench] parallel gate skipped: calibrated ceiling "
              f"{ceiling:.2f}x < 2.5x on this host (measured "
              f"{max(r['parallel_speedup'] for r in flat_rows):.2f}x)")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write rows as JSON")
    ap.add_argument("--trace", default=None,
                    help="write the hetero/16 traced run's Perfetto trace "
                         "(+ *_metrics.json snapshot) to this path")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json, trace_path=args.trace)
