"""Planner search efficiency (paper §3.4 + Alg. 1 parallelization).

Reports: candidate counts before/after pruning, wall time with 1 vs 8
simulator threads (the paper accelerates search with concurrent simulation),
and the incumbent-quality trace of the branch-and-bound layer split.
"""

from __future__ import annotations

import time

from repro.core import (enumerate_strategies, hetero_cluster, plan_hybrid)
from benchmarks.common import PAPER_MODELS, emit, write_json


def run(quick: bool = False, json_path: str | None = None) -> list[dict]:
    rows = []
    desc = PAPER_MODELS["LLaMA_7B"]
    for n in (16, 64) if not quick else (16,):
        topo = hetero_cluster({"RTX4090D": n // 2, "V100": n // 2},
                              gpus_per_node=8)
        pts, stats = enumerate_strategies(topo, desc, global_batch=4 * n)
        t1 = time.perf_counter()
        plan_hybrid(topo, desc, global_batch=4 * n, seq=2048,
                    n_workers=1, with_baseline=False, max_candidates=128)
        t_serial = time.perf_counter() - t1
        t2 = time.perf_counter()
        res = plan_hybrid(topo, desc, global_batch=4 * n, seq=2048,
                          n_workers=8, with_baseline=False,
                          max_candidates=128)
        t_par = time.perf_counter() - t2
        rows.append({"gpus": n, "candidates": len(pts),
                     "pruned": stats.pruned + stats.infeasible,
                     "rejected": res.candidates_rejected,
                     "search_1thread_s": round(t_serial, 2),
                     "search_8threads_s": round(t_par, 2),
                     "parallel_speedup": round(t_serial / max(t_par, 1e-9),
                                               2)})
    emit(rows, "planner_search (pruning + parallel simulation, Alg. 1)")
    if json_path:
        write_json(rows, json_path)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write rows as JSON")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json)
