"""Benchmark driver: one entry per paper table/figure + planner extras.

PYTHONPATH=src python -m benchmarks.run [--quick] [--trace PATH]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    """Run every registered benchmark module in sequence."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small cluster sizes only")
    ap.add_argument("--trace", default=None,
                    help="write the planner_search traced run's Perfetto "
                         "trace (+ metrics snapshot) to this path")
    args = ap.parse_args()

    from benchmarks import (bench_planner_search, bench_replan,
                            bench_scenarios, bench_service, fig2_roofline,
                            fig3_allreduce_decomp, fig6a_hetero_similar,
                            fig6b_hetero_disparate, fig6c_dynamic_bw)
    suites = [
        ("fig2_roofline", lambda: fig2_roofline.run()),
        ("fig3_allreduce_decomp", lambda: fig3_allreduce_decomp.run()),
        ("fig6a_hetero_similar",
         lambda: fig6a_hetero_similar.run(quick=args.quick)),
        ("fig6b_hetero_disparate",
         lambda: fig6b_hetero_disparate.run(quick=args.quick)),
        ("fig6c_dynamic_bw", lambda: fig6c_dynamic_bw.run(quick=args.quick)),
        ("planner_search",
         lambda: bench_planner_search.run(quick=args.quick,
                                          trace_path=args.trace)),
        ("bench_replan", lambda: bench_replan.run(quick=args.quick)),
        ("bench_scenarios", lambda: bench_scenarios.run(quick=args.quick)),
        ("bench_service", lambda: bench_service.run(quick=args.quick)),
    ]
    failures = []
    for name, fn in suites:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.perf_counter()
        try:
            fn()
            print(f"[{name}] PASS ({time.perf_counter() - t0:.1f}s)")
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"[{name}] FAIL: {e!r}")
    print("\n===== summary =====")
    print(f"{len(suites) - len(failures)}/{len(suites)} benchmark suites "
          f"passed" + (f"; FAILED: {failures}" if failures else ""))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
