"""Fig. 6c reproduction: TP size x network bandwidth (V100-32G-PCIe).

Paper claims on one-epoch execution time:
  * low bandwidth makes the HIGH-TP plan 25-52% slower than the LOW-TP
    plan for the smaller models,
  * with unconstrained bandwidth the high-TP plan is only ~2-8% slower,
  * for the largest model high TP is absorbed by PP's non-overlapped
    communication (gap shrinks or reverses).

Setup mirrors the paper: 8/16/64/256 V100-32G-PCIe GPUs, TP pairs
(7B: 2v4), (13B: 4v8), (22B: 8v16), (175B: 16v32).
"""

from __future__ import annotations

import math

from repro.core import (ParallelPlan, ReplanEngine, hetero_cluster,
                        split_devices, uniform_stages)
from benchmarks.common import PAPER_MODELS, emit

TP_PAIRS = {"LLaMA_7B": (2, 4, 8), "GPT_13B": (4, 8, 16),
            "GPT_22B": (8, 16, 64), "GPT_175B": (16, 32, 256)}


def tp_plans(desc, topo, n, tp, gb):
    """Candidate TP-degree plans the dynamic-bandwidth sweep switches
    between."""
    plans = []
    for pp in (1, 2, 4, 8):
        dp, rem = divmod(n, tp * pp)
        if rem or dp < 1 or pp > desc.n_layers or gb % max(dp, 1):
            continue
        for mb in (pp, 2 * pp, 4 * pp):
            if (gb // dp) % mb:
                continue
            groups = split_devices(topo, dp, tp, pp)
            plans.append(ParallelPlan(
                dp=dp, tp=tp, pp=pp, microbatches=mb,
                stages=uniform_stages(desc.n_layers, pp, groups),
                batch_shares=tuple([1 / dp] * dp), grad_sync="rs_ag"))
    return plans


def step_time(engine, plans, topo):
    """Best step time for a fixed-TP plan family under one network
    condition; one cache context (topology fingerprint) per family, and
    re-scored conditions are free on repeat runs."""
    sims = engine.score_plans(plans, topo)
    candidates = [s.step_time for s in sims if s is not None]
    return min(candidates) if candidates else math.inf


def run(quick: bool = False) -> list[dict]:
    """Reproduce the Fig. 6c dynamic-bandwidth adaptation sweep;
    returns the rows."""
    rows = []
    items = list(TP_PAIRS.items())[:2] if quick else list(TP_PAIRS.items())
    for name, (tp_lo, tp_hi, n) in items:
        desc = PAPER_MODELS[name]
        gb = max(n * 2, 64)
        engine = ReplanEngine(desc, global_batch=gb, seq=2048)
        # dynamic network conditions scale the whole PCIe/IB fabric (S1):
        # nominal = V100-32G-PCIe 25 GB/s intra + 12.5 GB/s inter
        for bw_label, factor in (("low_bw_0.2x", 0.2),
                                 ("unconstrained_4x", 4.0)):
            topo = hetero_cluster({"V100": n},
                                  intra_bw_map={"V100": 25e9 * factor},
                                  inter_bw=12.5e9 * factor,
                                  gpus_per_node=8)
            t_lo = step_time(engine, tp_plans(desc, topo, n, tp_lo, gb),
                             topo)
            t_hi = step_time(engine, tp_plans(desc, topo, n, tp_hi, gb),
                             topo)
            if math.isinf(t_lo) or math.isinf(t_hi):
                continue
            rows.append({"model": name, "gpus": n, "bw": bw_label,
                         "tp_low": tp_lo, "tp_high": tp_hi,
                         "t_lowTP_s": round(t_lo, 3),
                         "t_highTP_s": round(t_hi, 3),
                         "highTP_penalty_pct":
                             round((t_hi / t_lo - 1) * 100, 1)})
    assert rows
    small = [r for r in rows if r["model"] in ("LLaMA_7B", "GPT_13B")]
    lo_pen = [r["highTP_penalty_pct"] for r in small
              if r["bw"] == "low_bw_0.2x"]
    hi_pen = [r["highTP_penalty_pct"] for r in small
              if r["bw"] == "unconstrained_4x"]
    # low bandwidth punishes high TP much harder (paper: +25-52% vs +2-8%)
    assert min(lo_pen) >= 15, rows
    assert max(hi_pen) <= 12, rows
    assert sum(lo_pen) / len(lo_pen) > sum(hi_pen) / len(hi_pen) + 10, rows
    emit(rows, "fig6c_dynamic_bw (TP size x bandwidth; paper: +25-52% "
               "low-bw small models, +2-8% unconstrained)")
    return rows


if __name__ == "__main__":
    run()
