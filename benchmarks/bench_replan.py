"""Cold plan vs warm re-plan latency (the re-planning engine's raison d'être).

The paper's dynamic-network claim only pays off if re-planning is cheap
enough to run during training.  This benchmark measures, per model config
and per event kind, the latency of

  * COLD: from-scratch ``plan_hybrid`` on the post-event topology (what the
    seed code did on every event), vs
  * WARM: ``ReplanEngine.replan`` after one cold plan warmed the strategy
    cache (bandwidth events re-score cached plans, stragglers get a local
    rebalance, device-set changes a neighborhood-seeded search),

and checks plan quality: the warm plan's simulated step time must stay close
to the cold plan's on the same post-event topology.

Acceptance gate (ISSUE 1): on the fig6c dynamic-bandwidth scenario the warm
re-plan must be >= 5x faster than cold with step time within 5%.

PYTHONPATH=src python -m benchmarks.bench_replan [--quick] [--json PATH]
"""

from __future__ import annotations

import time

from repro.core import (NetworkEvent, ReplanEngine, StrategyCache,
                        hetero_cluster, plan_hybrid)
from benchmarks.common import PAPER_MODELS, emit, write_json

# fig6c setting: V100-32G-PCIe fabric whose whole interconnect scales (S1).
FIG6C_INTRA, FIG6C_INTER = 25e9, 12.5e9


def _fig6c_topo(n: int, factor: float = 1.0):
    return hetero_cluster({"V100": n},
                          intra_bw_map={"V100": FIG6C_INTRA * factor},
                          inter_bw=FIG6C_INTER * factor, gpus_per_node=8)


def _hetero_topo(n: int):
    return hetero_cluster({"RTX4090D": n // 2, "V100": n // 2},
                          gpus_per_node=max(2, n // 4))


SCENARIOS = ("bandwidth", "slowdown", "fail")


def _event_and_topo(scenario: str, n: int):
    """Post-event topology + the event, per scenario."""
    if scenario == "bandwidth":
        # fig6c low-bandwidth condition: fabric drops to 0.2x nominal
        ev = NetworkEvent(1.0, "bandwidth", factor=0.2)
        topo = _fig6c_topo(n, factor=0.2)
        pre = _fig6c_topo(n, factor=1.0)
    elif scenario == "slowdown":
        ev = NetworkEvent(1.0, "slowdown", device_id=0, factor=0.4)
        pre = _hetero_topo(n)
        topo = _hetero_topo(n)
        topo.apply_event(ev)
    else:
        # node failure on the 32 GB V100 fabric (the 24 GB-min hetero
        # cluster cannot host the 13B/22B optimizer state once degraded)
        ev = NetworkEvent(1.0, "fail", device_id=n - 1)
        pre = _fig6c_topo(n)
        topo = _fig6c_topo(n)
        topo.apply_event(ev)
    return pre, topo, ev


def run(quick: bool = False, json_path: str | None = None) -> list[dict]:
    """Run the warm-vs-cold replan rows, emit CSV/JSON, enforce the
    path/quality gates.  Returns the rows."""
    configs = [("LLaMA_7B", 32, 128), ("GPT_13B", 16, 64),
               ("GPT_22B", 16, 64)]
    if quick:
        configs = [("LLaMA_7B", 16, 64), ("GPT_13B", 16, 64),
                   ("GPT_22B", 16, 64)]
    rows = []
    for name, n, gb in configs:
        desc = PAPER_MODELS[name]
        for scenario in SCENARIOS:
            pre, post, ev = _event_and_topo(scenario, n)
            engine = ReplanEngine(desc, global_batch=gb, seq=2048,
                                  cache=StrategyCache())
            engine.plan(pre)                     # warm the cache
            t0 = time.perf_counter()
            warm = engine.replan(post, ev)
            warm_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            cold = plan_hybrid(post, desc, global_batch=gb, seq=2048,
                               with_baseline=False)
            cold_s = time.perf_counter() - t0
            delta_pct = (warm.predicted.step_time
                         / cold.predicted.step_time - 1) * 100
            rows.append({
                "model": name, "gpus": n, "scenario": scenario,
                "path": warm.path,
                "cold_plan_ms": round(cold_s * 1e3, 2),
                "warm_replan_ms": round(warm_s * 1e3, 2),
                "speedup": round(cold_s / max(warm_s, 1e-9), 2),
                "cold_step_s": round(cold.predicted.step_time, 4),
                "warm_step_s": round(warm.predicted.step_time, 4),
                "step_delta_pct": round(delta_pct, 2),
                "cache_hits": warm.stats.cache_hits,
                "cache_misses": warm.stats.cache_misses,
                # cold-path cascade telemetry: fraction of candidates the
                # tiered pipeline cut before full simulation
                "cold_prune_rate": round(
                    cold.search_stats.prune_rate, 3)
                if cold.search_stats else None,
                "cold_simulated": cold.search_stats.simulated
                if cold.search_stats else None,
                # mirrors the warm-quality internal gate (bandwidth rows:
                # warm step within 5% of cold) as a row field, so the CI
                # bench-regression compare blocks on it even though this
                # bench's asserts run under continue-on-error in CI
                # computed from the same rounded value the internal gate
                # asserts on, so the two verdicts cannot diverge at the
                # 5.0-boundary
                "quality_ok": scenario != "bandwidth"
                or abs(round(delta_pct, 2)) <= 5.0,
            })
    # persist the telemetry BEFORE any gate can fire (same policy as the
    # other benches): the CI bench-regression compare needs the JSON even
    # when a gate trips, and a failed assertion must not discard the rows
    # that diagnose it
    emit(rows, "bench_replan (cold plan_hybrid vs warm ReplanEngine.replan; "
               "gate: fig6c bandwidth scenario >=5x, step within 5%)")
    if json_path:
        write_json(rows, json_path)
    # acceptance gates.  (1) On the fig6c reference scenario (LLaMA_7B, the
    # paper's fig6c small-model case) warm bandwidth re-planning is >=5x
    # faster than a cold plan.  Models whose memory constraints leave only a
    # handful of feasible candidates (22B on 16 GPUs) make cold search
    # trivially cheap, so the latency gate is tied to the reference scenario
    # while (2) plan quality — warm step time within 5% of cold — must hold
    # for EVERY bandwidth row.
    bw = [r for r in rows if r["scenario"] == "bandwidth"]
    gate = [r for r in bw if r["model"] == "LLaMA_7B"]
    assert gate, rows
    for r in gate:
        assert r["speedup"] >= 5.0, r
    for r in bw:
        assert abs(r["step_delta_pct"]) <= 5.0, r
        assert r["speedup"] > 1.0, r
    # warm paths never enumerate from scratch on parameter-only events
    # (straggler-neighborhood is the ISSUE-3 escalation: a *bounded*
    # dp/tp/pp-neighborhood search taken when the local rebalance cannot
    # recover — it trades warm latency for closing the straggler-vs-oracle
    # gap, and is still seeded, not from-scratch)
    assert all(r["path"] in ("bandwidth-rescore", "straggler-rebalance",
                             "straggler-neighborhood", "neighborhood",
                             "full-replan")
               for r in rows), rows
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write rows as JSON")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json)
