"""Benchmark-regression gate: fresh bench JSON vs committed baselines.

CI has uploaded bench JSON as artifacts since PR 1, but nothing ever
compared runs — a planner-speed or adaptability regression would merge
silently.  This module diffs a fresh ``bench_out/`` run against the
baselines committed under ``benchmarks/baselines/`` (produced by the same
``--quick`` invocations) with two gate classes:

  * **structural gates** — plan-identity booleans (cascade == exhaustive
    argmin, parallel == serial), DP <= greedy, warm-path identity, replan
    counts — hard-fail on any violation.  These are host-independent model
    invariants: the simulator, cascade and engine are deterministic pure
    float math, so they must reproduce exactly on any machine.
  * **ratio gates** — prune rate, warm-replan speedup, adapted-over-static
    — fail only beyond a calibrated per-metric relative tolerance.  Prune
    rates are deterministic (tight tolerance guards against silent
    candidate-set drift); wall-clock ratios carry real scheduler noise and
    cross-host variance (the committed baseline ran on a different
    machine), so their tolerances come from the observed cross-run spread:
    warm speedups vary by several x run-to-run on shared runners while a
    real regression (warm path falling back to cold search) collapses them
    to ~1x, and adapted_over_static only moves with measured re-plan
    latency, which is tiny against the scenario horizon.

Rows are matched on per-bench key fields; a baseline row missing from the
fresh run is a violation (the bench crashed or silently dropped coverage),
extra fresh rows are reported but allowed (new coverage must not require a
lock-step baseline bump to land).

Usage (exit code 1 on any violation):

  PYTHONPATH=src python -m benchmarks.compare \
      [--baseline-dir benchmarks/baselines] [--fresh-dir bench_out]
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from dataclasses import dataclass, field
from pathlib import Path

BASELINE_DIR = Path(__file__).resolve().parent / "baselines"


@dataclass(frozen=True)
class Violation:
    """One gate failure: which bench, row, metric, and why."""

    bench: str
    row_key: tuple
    metric: str
    detail: str

    def __str__(self) -> str:
        key = "/".join(str(k) for k in self.row_key) or "-"
        return f"[{self.bench}] {key} :: {self.metric}: {self.detail}"


@dataclass(frozen=True)
class Gate:
    """One gated metric.

    kinds:
      * ``bool-true``  — structural: fresh must be truthy.
      * ``equal``      — structural: fresh must equal the baseline exactly.
      * ``min``        — structural floor: fresh >= ``floor``.
      * ``max``        — absolute ceiling: fresh <= ``ceil`` (wall-time
                         budgets; the ceiling is host-independent slack,
                         not a ratio against the committed baseline).
      * ``ratio-min``  — fresh >= baseline * (1 - tol): regressions that
                         shrink the metric fail; improvements always pass.
      * ``ratio-max``  — fresh <= baseline * (1 + tol): the mirror image.

    Non-finite values (NaN static baselines on failure scenarios) pass a
    ratio gate only when baseline and fresh agree on non-finiteness.
    """

    metric: str
    kind: str
    tol: float = 0.0
    floor: float = 0.0
    ceil: float = math.inf

    def check(self, base, fresh) -> str | None:
        """Violation detail string, or None when the gate passes."""
        if self.kind == "bool-true":
            return None if fresh else f"expected true, got {fresh!r}"
        if self.kind == "equal":
            return None if fresh == base \
                else f"expected {base!r}, got {fresh!r}"
        bf = _as_float(base)
        ff = _as_float(fresh)
        if self.kind == "min":
            # same NaN-agreement semantics as the ratio gates: a baseline
            # that legitimately recorded a non-finite value (the bench's own
            # gate tolerates those) must not turn the CI gate permanently red
            if ff is not None and bf is not None \
                    and not math.isfinite(bf) and not math.isfinite(ff):
                return None
            if ff is None or not math.isfinite(ff) or ff < self.floor:
                return f"{fresh!r} < floor {self.floor}"
            return None
        if self.kind == "max":
            if ff is not None and bf is not None \
                    and not math.isfinite(bf) and not math.isfinite(ff):
                return None
            if ff is None or not math.isfinite(ff) or ff > self.ceil:
                return f"{fresh!r} > ceiling {self.ceil}"
            return None
        if ff is None or bf is None:
            return f"non-numeric ({base!r} vs {fresh!r})"
        if math.isfinite(bf) != math.isfinite(ff):
            return f"finiteness changed ({base!r} -> {fresh!r})"
        if not math.isfinite(bf):
            return None                      # both non-finite: agree
        if self.kind == "ratio-min":
            limit = bf * (1.0 - self.tol)
            return None if ff >= limit \
                else f"{ff} < {limit:.4g} (baseline {bf}, tol {self.tol})"
        if self.kind == "ratio-max":
            limit = bf * (1.0 + self.tol)
            return None if ff <= limit \
                else f"{ff} > {limit:.4g} (baseline {bf}, tol {self.tol})"
        raise ValueError(f"unknown gate kind {self.kind}")


def _as_float(x) -> float | None:
    if isinstance(x, bool) or x is None:
        return None
    try:
        return float(x)
    except (TypeError, ValueError):
        return None


@dataclass(frozen=True)
class BenchSpec:
    """Row keying + gates for one benchmark's JSON."""

    baseline_file: str
    fresh_file: str
    key: tuple[str, ...]
    gates: tuple[Gate, ...]
    # rows this spec does not gate (e.g. family_summary aggregate rows —
    # their per-seed constituents are gated individually)
    skip_kinds: tuple[str, ...] = field(default=())

    def rows(self, raw: list[dict]) -> dict[tuple, dict]:
        out: dict[tuple, dict] = {}
        for r in raw:
            # "meta" is the provenance header (git sha, timestamp, versions
            # — see benchmarks.common.bench_meta): never a measurement, so
            # never gated, regardless of the per-spec skip list
            if r.get("kind") == "meta" or r.get("kind") in self.skip_kinds:
                continue
            out[tuple(r.get(k) for k in self.key)] = r
        return out


SPECS: dict[str, BenchSpec] = {
    "planner_search": BenchSpec(
        baseline_file="BENCH_planner_search.json",
        fresh_file="planner_search.json",
        key=("topology", "gpus"),
        gates=(
            # structural: pruning soundness + process determinism
            Gate("argmin_matches_exhaustive", "bool-true"),
            Gate("parallel_matches_serial", "bool-true"),
            # deterministic counters: tight tolerance catches candidate-set
            # or tier drift without demanding bit-equality across refactors
            Gate("prune_rate", "ratio-min", tol=0.10),
            Gate("pruned_coarse", "ratio-min", tol=0.50),
            # LP-relaxation tier + exact-MIP oracle (ISSUE 9): toggling the
            # admissible LP bound must never move the argmin, a completed
            # oracle run must certify the cascade argmin, and the LP tier's
            # cut count on the committed (dense-hetero) baseline rows must
            # not silently collapse
            Gate("argmin_matches_nolp", "bool-true"),
            Gate("mip_certified", "bool-true"),
            Gate("pruned_lp", "ratio-min", tol=0.25),
            # hierarchical island tier (ISSUE 6): on every flat-tractable
            # row the hierarchical entry point must fall back to the flat
            # cascade and return the identical plan byte-for-byte
            Gate("hierarchical_matches_flat", "bool-true"),
            # fleet rows: the partition and its symmetry structure are
            # deterministic; the planning wall-time carries an absolute
            # budget (acceptance: 4096 devices end-to-end < 30 s — the 60 s
            # ceiling is 2x slack for slower CI hosts)
            Gate("path", "equal"),
            Gate("n_islands", "equal"),
            Gate("islands_deduped", "equal"),
            Gate("hier_wall_s", "max", ceil=60.0),
            # observability (ISSUE 7): tracing the serial search must stay
            # within 10% of the untraced wall (min-of-2 timings both sides
            # keep shared-runner noise out of the ratio)
            Gate("trace_overhead", "max", ceil=1.10),
            # fabric sim-fidelity (ISSUE 8): cut-through pipelining must
            # stay strictly below store-and-forward on the sparse torus,
            # the pipelined/S&F delta must not silently collapse, and the
            # catalog-trace mid-flight re-route counters are deterministic
            # pure float math — exact across hosts
            Gate("pipelined_le_snf", "bool-true"),
            Gate("pipeline_delta", "ratio-min", tol=0.05),
            Gate("reroute_events", "equal"),
            Gate("reroute_steps", "equal"),
            Gate("reroute_moves_epoch", "bool-true"),
        ),
    ),
    "bench_replan": BenchSpec(
        baseline_file="BENCH_replan.json",
        fresh_file="bench_replan.json",
        key=("model", "gpus", "scenario"),
        gates=(
            # structural: the engine's path decision is deterministic, and
            # warm plan quality (step within 5% of cold on bandwidth rows)
            # is a model invariant mirrored into the rows
            Gate("path", "equal"),
            Gate("quality_ok", "bool-true"),
            # timing ratio, cross-host: a real regression (warm path doing
            # cold work) collapses the speedup to ~1x; honest scheduler
            # noise stays well inside 80% of the committed baseline
            Gate("speedup", "ratio-min", tol=0.80),
        ),
    ),
    "bench_scenarios": BenchSpec(
        baseline_file="BENCH_scenarios.json",
        fresh_file="bench_scenarios.json",
        key=("scenario", "seed"),
        skip_kinds=("family_summary",),
        gates=(
            # structural: the DP oracle is never worse than greedy, the
            # engine's switch decisions are deterministic, and parallel
            # replays reproduce the sequential timelines exactly
            Gate("greedy_over_dp", "min", floor=1.0 - 1e-9),
            Gate("replans", "equal"),
            Gate("parallel_matches_sequential", "bool-true"),
            # adaptability ratios: deterministic except for the measured
            # re-plan latency charged against throughput (tiny vs horizon)
            Gate("adapted_over_static", "ratio-max", tol=0.08),
            Gate("adapted_over_oracle", "ratio-max", tol=0.08),
        ),
    ),
    "bench_service": BenchSpec(
        baseline_file="BENCH_service.json",
        fresh_file="bench_service.json",
        key=("family",),
        gates=(
            # structural: the service's frozen-round replay is deterministic
            # — a threaded replay must be byte-identical to serial, and the
            # admission / cache / replan counters are pure bookkeeping over
            # deterministic inputs, so they must reproduce exactly
            Gate("serial_matches_threaded", "bool-true"),
            Gate("admitted", "equal"),
            Gate("rejected", "equal"),
            Gate("cold_searches", "equal"),
            Gate("replans", "equal"),
            Gate("invalidated", "equal"),
            # acceptance (ISSUE 10): bucketed twins in the 32-job storm
            # reuse one search — cross-job hit rate holds the 50% floor and
            # must not drift down vs the committed baseline
            Gate("cache_hit_rate", "min", floor=0.5),
            Gate("cache_hit_rate", "ratio-min", tol=0.10),
            # p99 replan latency: absolute wall budget (measured ~0.03 s on
            # a shared 2-vCPU container; a warm path regressing to cold
            # search lands well above 0.75 s)
            Gate("p99_replan_s", "max", ceil=0.75),
        ),
    ),
}


def compare_rows(bench: str, baseline: list[dict],
                 fresh: list[dict]) -> list[Violation]:
    """All gate violations of ``fresh`` against ``baseline`` for one
    bench (the pure core — the unit tests drive this directly)."""
    spec = SPECS[bench]
    base_rows = spec.rows(baseline)
    fresh_rows = spec.rows(fresh)
    out: list[Violation] = []
    for key, brow in base_rows.items():
        frow = fresh_rows.get(key)
        if frow is None:
            out.append(Violation(bench, key, "<row>",
                                 "baseline row missing from fresh run"))
            continue
        for gate in spec.gates:
            if gate.metric not in brow:
                # metric not in this baseline row: either the row kind does
                # not carry it (fleet rows vs flat rows share one spec) or
                # the baseline predates the metric — in both cases gating
                # fresh-only values would force lock-step baseline bumps
                continue
            detail = gate.check(brow.get(gate.metric), frow.get(gate.metric))
            if detail is not None:
                out.append(Violation(bench, key, gate.metric, detail))
    for key in fresh_rows.keys() - base_rows.keys():
        print(f"[compare] note: {bench} row {key} has no baseline "
              f"(new coverage, not gated)")
    return out


def compare_dirs(baseline_dir: Path | str = BASELINE_DIR,
                 fresh_dir: Path | str = "bench_out") -> list[Violation]:
    """All violations across every spec'd bench; missing baseline or
    fresh JSON files are violations themselves."""
    baseline_dir, fresh_dir = Path(baseline_dir), Path(fresh_dir)
    out: list[Violation] = []
    for bench, spec in SPECS.items():
        bpath = baseline_dir / spec.baseline_file
        fpath = fresh_dir / spec.fresh_file
        if not bpath.exists():
            out.append(Violation(bench, (), "<baseline>",
                                 f"missing committed baseline {bpath}"))
            continue
        if not fpath.exists():
            out.append(Violation(bench, (), "<fresh>",
                                 f"missing fresh JSON {fpath} — did the "
                                 f"bench crash before writing it?"))
            continue
        out.extend(compare_rows(bench,
                                json.loads(bpath.read_text()),
                                json.loads(fpath.read_text())))
    return out


def main(argv: list[str] | None = None) -> int:
    """CLI entry point: exit 1 on any violation."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", default=str(BASELINE_DIR))
    ap.add_argument("--fresh-dir", default="bench_out")
    args = ap.parse_args(argv)
    violations = compare_dirs(args.baseline_dir, args.fresh_dir)
    n_gates = sum(len(s.gates) for s in SPECS.values())
    if violations:
        print(f"[compare] FAIL: {len(violations)} violation(s)")
        for v in violations:
            print(f"  {v}")
        return 1
    print(f"[compare] PASS: {len(SPECS)} benches, {n_gates} gated metrics, "
          f"no regressions vs committed baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
