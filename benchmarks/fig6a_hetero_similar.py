"""Fig. 6a reproduction: RTX4090D + L20 (similar perf) vs Megatron default.

Paper claim: layer-level task assignment yields ~1.01-1.03x over the
general-purpose Megatron configuration when device performance is similar.
We sweep 8/16/32/256-GPU mixed clusters x the paper's four models and
report the planner's speedup over (a) the literal Megatron default and
(b) a tuned uniform baseline (stronger, heterogeneity-blind).
"""

from __future__ import annotations

from repro.core import hetero_cluster, plan_hybrid
from benchmarks.common import PAPER_MODELS, emit

SIZES = (8, 16, 32, 256)


def run(quick: bool = False) -> list[dict]:
    """Reproduce the Fig. 6a similar-devices hetero rows; returns
    the rows."""
    rows = []
    sizes = SIZES[:2] if quick else SIZES
    models = list(PAPER_MODELS.items())[:2] if quick else PAPER_MODELS.items()
    for name, desc in models:
        for n in sizes:
            topo = hetero_cluster({"RTX4090D": n // 2, "L20": n // 2},
                                  gpus_per_node=8 if n >= 16 else n // 2)
            gb = max(n * 4, 64)
            try:
                res = plan_hybrid(topo, desc, global_batch=gb, seq=2048,
                                  max_candidates=160 if n < 64 else 512)
            except (RuntimeError, AssertionError):
                continue
            rows.append({
                "model": name, "gpus": n,
                "plan": res.plan.describe(),
                "speedup_vs_megatron_default":
                    round(res.speedup_vs_baseline, 3),
                "speedup_vs_tuned_uniform": round(res.speedup_vs_tuned, 3),
            })
    assert rows, "no feasible configurations"
    sp = [r["speedup_vs_tuned_uniform"] for r in rows]
    # similar-perf devices: modest but consistent gains (paper: 1.01-1.03x).
    # (>=0.97: at 256 nodes the capped candidate list can trail the
    # exhaustive uniform grid by a few percent.)
    assert all(s >= 0.97 for s in sp), sp
    assert any(s >= 1.005 for s in sp), sp
    emit(rows, "fig6a_hetero_similar (RTX4090D+L20; expect ~1.01-1.03x "
               "vs tuned uniform)")
    return rows


if __name__ == "__main__":
    run()
