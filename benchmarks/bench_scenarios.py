"""Multi-scenario adaptability sweep over the cloud-scenario catalog.

Replays every catalog family end-to-end through the simulator +
``DynamicOrchestrator``/``ReplanEngine`` (repro.scenarios harness) and
reports, per (family, seed):

  * adapted-vs-static step-time ratio   (< 1: adaptation pays; a static
    plan that dies with a failed device contributes zero throughput),
  * adapted-vs-DP-oracle ratio          (>= 1: distance to the clairvoyant
    cross-interval DP schedule, modeled switch costs included),
  * greedy-vs-DP oracle ratio           (>= 1: the DP schedule is the
    tighter bound; the per-interval greedy oracle over-switches),
  * modeled switch cost charged, re-plan counts / path histogram / latency,

plus per-family mean / 95% CI aggregates across seeds.  Every switch charge
flows through :class:`repro.core.ReconfigCostModel` (checkpoint/reshard
traffic priced on the post-event topology) — there are no hard-coded
reconfiguration constants anywhere in the replay.

The bandwidth-crossover families (``*_crossover``) replay at a comm-heavy
scale (small global batch): that is the regime where the fig6c
TP-vs-bandwidth crossover actually flips the plan mid-trace, and the sweep
gates on at least one such family switching plans *and* beating static.

The sweep then runs twice — sequentially and process-parallel (the paper's
parallel-simulation strategy applied across scenarios) — and gates on the
parallel speedup.  The gate is hardware-calibrated: a pure-CPU busy-loop
probe measures what process-level scaling this host can physically deliver.
When the calibrated ceiling shows real multicore headroom (>= 2.5x — any
unshared >= 3-core machine, including the CI runners) the sweep must reach
>= 2x.  On shared-hyperthread / throttled 2-vCPU containers the ceiling
itself is noise-dominated (observed 0.9x-1.7x across identical runs), so
the speedup is reported but not asserted.

PYTHONPATH=src python -m benchmarks.bench_scenarios [--quick] [--json PATH]
"""

from __future__ import annotations

import math
import os
import time

from repro.scenarios import (HarnessConfig, get_scenario, list_scenarios,
                             run_payloads, summarize_reports)
from benchmarks.common import (PAPER_MODELS, calibrate_process_ceiling, emit,
                               write_json)

# longest families first: ex.map dispatches in order, so fronting the
# expensive fail/join + composed families keeps the parallel schedule
# balanced
_ORDER = ("diurnal_spot_storm", "cloud_spot", "diurnal_wan",
          "straggler_churn", "congested_multitenant", "congested_flaky",
          "cross_region", "fig6c_dynamic_bw")
_SEEDS = (0, 1)


def _is_crossover(name: str) -> bool:
    return "crossover" in get_scenario(name).tags


def _payloads(quick: bool) -> list[tuple[HarnessConfig, str, int]]:
    # two seeds per family keeps every task well under half the sweep, so
    # the longest-task bound cannot cap the parallel speedup below 2x
    max_candidates = 48 if quick else 96
    base = HarnessConfig(PAPER_MODELS["LLaMA_7B"], global_batch=64, seq=2048,
                         max_candidates=max_candidates)
    # comm-heavy scale for the crossover families: at global_batch=64 the
    # LLaMA-7B step is compute-bound and no bandwidth level flips the plan;
    # at 8 the cross-fabric gradient sync dominates and the fig6c crossover
    # sits inside the scenario's bandwidth swing
    tight = HarnessConfig(PAPER_MODELS["LLaMA_7B"], global_batch=8, seq=2048,
                          max_candidates=max_candidates)
    names = [n for n in _ORDER if n in list_scenarios()]
    names += [n for n in list_scenarios()
              if n not in names and not _is_crossover(n)]
    cross = [n for n in list_scenarios() if _is_crossover(n)]
    return [(base, n, s) for s in _SEEDS for n in names] \
        + [(tight, n, s) for s in _SEEDS for n in cross]


def run(quick: bool = False, json_path: str | None = None) -> list[dict]:
    """Replay every scenario family, emit CSV/JSON, enforce the
    adaptability + determinism gates.  Returns the rows."""
    payloads = _payloads(quick)

    t0 = time.perf_counter()
    seq_reports = run_payloads(payloads, parallel=False)
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    par_reports = run_payloads(payloads, parallel=True)
    t_par = time.perf_counter() - t0
    speedup = t_seq / max(t_par, 1e-9)

    # calibrate + persist the telemetry BEFORE any gate can fire: a failed
    # assertion must not discard the rows that diagnose it
    workers = min(os.cpu_count() or 1, len(payloads))
    ceiling = calibrate_process_ceiling(workers)
    rows = [r.to_row() for r in seq_reports]
    for row, a, b in zip(rows, seq_reports, par_reports):
        row["parallel_speedup"] = round(speedup, 2)
        row["parallel_ceiling"] = round(ceiling, 2)
        # structural determinism as a row field, so the CI bench-regression
        # compare (benchmarks/compare.py) gates it even though this bench's
        # own asserts run under continue-on-error in CI
        row["parallel_matches_sequential"] = (
            a.scenario == b.scenario
            and a.adapted.timeline == b.adapted.timeline
            and a.replans == b.replans
            and a.switch_cost_s == b.switch_cost_s)
    emit(rows, f"bench_scenarios (catalog replay through ReplanEngine, "
               f"ReconfigCostModel switch charges; parallel sweep "
               f"{speedup:.2f}x over sequential, calibrated ceiling "
               f"{ceiling:.2f}x on {os.cpu_count()} cores)")
    # multi-seed aggregation: mean / 95% CI per family
    fam_rows = [f.to_row() for f in summarize_reports(seq_reports)]
    emit(fam_rows, "bench_scenarios family aggregates (mean/CI over seeds)")
    if json_path:
        write_json(rows + [{"kind": "family_summary", **fr}
                           for fr in fam_rows], json_path)

    # -- gates ---------------------------------------------------------------
    families = {r.scenario for r in seq_reports}
    assert len(families) >= 8, f"only {sorted(families)} replayed"
    # the composed timelines (ROADMAP open item) actually replay
    assert {"diurnal_spot_storm", "congested_flaky"} <= families, families
    # every replay actually went through the engine (path histogram is the
    # orchestrator's record of ReplanEngine decisions)
    assert all(r.actions for r in seq_reports if r.n_events), rows
    for r in seq_reports:
        ovs, ovd = r.adapted_over_static, r.adapted_over_oracle_dp
        ovg = r.adapted_over_oracle
        # adaptation never costs more than ~6% vs standing still...
        assert not math.isfinite(ovs) or ovs <= 1.06, r.to_row()
        # ...and tracks the clairvoyant greedy oracle (cost-model hysteresis
        # allows some drift, plus the local-rebalance vs full-search gap)
        assert not math.isfinite(ovg) or 0.95 <= ovg <= 1.30, r.to_row()
        # the DP oracle's top-K-widened candidate set (ISSUE 4) makes it up
        # to ~1.33x tighter than greedy on switch-heavy fail/join traces, so
        # its tracking band is correspondingly wider
        assert not math.isfinite(ovd) or 0.95 <= ovd <= 1.40, r.to_row()
        # the DP oracle is never worse than the per-interval greedy oracle
        god = r.greedy_over_dp
        assert not math.isfinite(god) or god >= 1.0 - 1e-9, r.to_row()
    # at least one family must show a real adaptation win
    wins = [r.adapted_over_static for r in seq_reports
            if math.isfinite(r.adapted_over_static)]
    assert min(wins) <= 0.90, rows
    # ...and at least one *bandwidth* family must actually switch plans
    # mid-trace and beat static (the fig6c crossover, modeled switch cost
    # included) — the S1 win the constant-overhead harness never showed
    bw_wins = [r for r in seq_reports
               if "bandwidth" in get_scenario(r.scenario).tags
               and r.replans >= 1 and math.isfinite(r.adapted_over_static)
               and r.adapted_over_static < 1.0]
    assert bw_wins, rows
    # deterministic across processes: the simulated step-time timelines of a
    # parallel replay match the sequential one exactly (avg_step also charges
    # *measured* re-plan latency, which legitimately varies with load)
    for a, b in zip(seq_reports, par_reports):
        assert a.scenario == b.scenario
        assert a.adapted.timeline == b.adapted.timeline, (a.to_row(),
                                                          b.to_row())
        assert a.replans == b.replans
        assert a.switch_cost_s == b.switch_cost_s
    # parallel execution gate: asserted only where the calibrated ceiling
    # shows real multicore headroom; on 2-vCPU/hyperthread-shared containers
    # every wall-clock measurement (probe included) is noise-dominated
    if ceiling >= 2.5:
        assert speedup >= 2.0, (
            f"parallel sweep speedup {speedup:.2f}x < 2x "
            f"(seq {t_seq:.1f}s, par {t_par:.1f}s, {workers} workers, "
            f"calibrated ceiling {ceiling:.2f}x)")
    else:
        print(f"[bench] parallel gate skipped: calibrated ceiling "
              f"{ceiling:.2f}x < 2.5x on this host "
              f"(measured sweep speedup {speedup:.2f}x)")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write rows as JSON")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json)
