"""Fig. 6b reproduction: RTX4090D + V100 (disparate perf) vs Megatron.

Paper claim: 1.74-4.69x speedups when integrating latest-gen with older
GPUs.  Disparity here is compounded: compute ratio (~2.4x raw, more with
fused-attention support) times the PCIe-vs-NVLink interconnect asymmetry
that the multi-edge model captures.
"""

from __future__ import annotations

from repro.core import hetero_cluster, plan_hybrid
from benchmarks.common import PAPER_MODELS, emit

SIZES = (8, 16, 32, 256)


def run(quick: bool = False) -> list[dict]:
    """Reproduce the Fig. 6b disparate-devices hetero rows; returns
    the rows."""
    rows = []
    sizes = SIZES[:2] if quick else SIZES
    models = list(PAPER_MODELS.items())[:2] if quick else PAPER_MODELS.items()
    for name, desc in models:
        for n in sizes:
            topo = hetero_cluster({"RTX4090D": n // 2, "V100": n // 2},
                                  gpus_per_node=8 if n >= 16 else n // 2)
            gb = max(n * 4, 64)
            try:
                res = plan_hybrid(topo, desc, global_batch=gb, seq=2048,
                                  max_candidates=160 if n < 64 else 512)
            except (RuntimeError, AssertionError):
                continue
            rows.append({
                "model": name, "gpus": n,
                "plan": res.plan.describe(),
                "speedup_vs_megatron_default":
                    round(res.speedup_vs_baseline, 3),
                "speedup_vs_tuned_uniform": round(res.speedup_vs_tuned, 3),
            })
    assert rows, "no feasible configurations"
    sp = [r["speedup_vs_megatron_default"] for r in rows]
    # paper band: 1.74-4.69x vs Megatron default
    assert max(sp) >= 1.74, sp
    assert all(s >= 1.2 for s in sp), sp
    emit(rows, "fig6b_hetero_disparate (RTX4090D+V100; paper band "
               "1.74-4.69x vs Megatron default)")
    return rows


if __name__ == "__main__":
    run()
