"""Fig. 2 reproduction: attention-kernel throughput, H100 vs V100.

The paper plots attention throughput saturating at a device-specific
ceiling once the workload passes the roofline knee (Eq. 1-2).  We sweep the
same kernel sizes through the cost model's per-device roofline and report
attained TFLOP/s, expecting (a) both curves to saturate and (b) the H100
ceiling ≈ 6-9x the V100 one (fused attention + higher peak).
"""

from __future__ import annotations

from repro.core import DEVICE_PROFILES
from benchmarks.common import emit


def attention_op(batch: int, seq: int, heads: int = 32, hd: int = 128,
                 *, fused: bool) -> tuple[float, float]:
    """(flops, bytes) of one attention forward at bf16."""
    d = heads * hd
    proj = 2 * batch * seq * d * (3 * d) + 2 * batch * seq * d * d
    scores = 4 * batch * heads * seq * seq * hd * 0.5
    flops = proj + scores
    io_qkv = 3 * batch * seq * d * 2 + 4 * d * d * 2 + batch * seq * d * 2
    io_scores = 0.0 if fused else 3 * 4 * batch * heads * seq * seq * 0.5
    return flops, io_qkv + io_scores


def run() -> list[dict]:
    """Reproduce the Fig. 2 roofline table; returns the rows."""
    rows = []
    for dev_name in ("H100", "V100"):
        spec = DEVICE_PROFILES[dev_name]
        for seq in (128, 256, 512, 1024, 2048, 4096, 8192):
            flops, byts = attention_op(8, seq, fused=spec.supports_fusion)
            t = spec.roofline_time(flops, byts)
            rows.append({"device": dev_name, "seq": seq,
                         "tflops_attained": round(flops / t / 1e12, 1)})
    # saturation + ceiling-gap checks (Fig. 2's qualitative claims)
    for dev_name in ("H100", "V100"):
        r = [x["tflops_attained"] for x in rows if x["device"] == dev_name]
        assert r[-1] >= r[0]                       # rises to the knee
        assert abs(r[-1] - r[-2]) / r[-1] < 0.15   # saturates
    h = max(x["tflops_attained"] for x in rows if x["device"] == "H100")
    v = max(x["tflops_attained"] for x in rows if x["device"] == "V100")
    assert 4 <= h / v <= 14, (h, v)
    emit(rows, "fig2_attention_roofline (H100 vs V100, saturating)")
    return rows


if __name__ == "__main__":
    run()
