"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import csv
import io
from dataclasses import dataclass

from repro.core import ModelDesc

# The paper's evaluation models (§4): LLaMA-7B and GPT-3-style 13/22/175B.
PAPER_MODELS: dict[str, ModelDesc] = {
    "LLaMA_7B": ModelDesc("LLaMA_7B", n_layers=32, d_model=4096, n_heads=32,
                          n_kv_heads=32, d_ff=11008, vocab=32000),
    "GPT_13B": ModelDesc("GPT_13B", n_layers=40, d_model=5120, n_heads=40,
                         n_kv_heads=40, d_ff=20480, vocab=50257,
                         ffn_kind="gelu"),
    "GPT_22B": ModelDesc("GPT_22B", n_layers=48, d_model=6144, n_heads=48,
                         n_kv_heads=48, d_ff=24576, vocab=50257,
                         ffn_kind="gelu"),
    "GPT_175B": ModelDesc("GPT_175B", n_layers=96, d_model=12288, n_heads=96,
                          n_kv_heads=96, d_ff=49152, vocab=50257,
                          ffn_kind="gelu"),
}


def _burn(n: int) -> int:
    s = 0
    for i in range(n):
        s += i * i
    return s


def calibrate_process_ceiling(workers: int, n: int = 8_000_000) -> float:
    """Measured process-scaling ceiling of this host: ``workers`` identical
    CPU-bound tasks, sequential vs one-per-process.  Parallel-speedup gates
    assert only when this shows real multicore headroom — on shared-
    hyperthread / throttled 2-vCPU containers every wall-clock measurement
    (probe included) is noise-dominated."""
    import multiprocessing
    import time
    from concurrent.futures import ProcessPoolExecutor

    if workers <= 1:
        return 1.0
    t0 = time.perf_counter()
    for _ in range(workers):
        _burn(n)
    seq = time.perf_counter() - t0
    # spawn for the same reason the harness uses it: the parent may have run
    # planner thread pools, and forking a threaded process risks deadlock
    ctx = multiprocessing.get_context("spawn")
    with ProcessPoolExecutor(max_workers=workers, mp_context=ctx) as ex:
        list(ex.map(_burn, [1] * workers))      # absorb worker start-up
        t0 = time.perf_counter()
        list(ex.map(_burn, [n] * workers))
        par = time.perf_counter() - t0
    return seq / max(par, 1e-9)


def bench_meta(*, quick: bool | None = None) -> dict:
    """Provenance header row prepended to every benchmark JSON artifact:
    git sha, UTC timestamp, python/jax versions, and the quick-vs-full
    flag.  ``kind == "meta"`` marks it; :mod:`benchmarks.compare` skips it
    when gating, so two runs with different shas still compare on the
    measurement rows alone."""
    import datetime
    import platform
    import subprocess

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    try:
        import jax
        jax_version: str | None = jax.__version__
    except Exception:
        jax_version = None
    return {
        "kind": "meta",
        "git_sha": sha,
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "python": platform.python_version(),
        "jax": jax_version,
        "quick": quick,
    }


def write_json(rows: list[dict], path: str, *,
               quick: bool | None = None) -> None:
    """Persist benchmark rows as JSON (CI uploads these as artifacts so the
    BENCH_* trajectory accumulates across commits).  A :func:`bench_meta`
    provenance header is prepended unless the rows already carry one."""
    import json
    from pathlib import Path

    if not any(r.get("kind") == "meta" for r in rows):
        rows = [bench_meta(quick=quick), *rows]
    p = Path(path)
    if p.parent != Path("."):
        p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(rows, indent=2, sort_keys=True))
    print(f"[bench] wrote {len(rows)} rows -> {p}")


def emit(rows: list[dict], title: str) -> str:
    """Print a small CSV block (one per paper table/figure).  Rows may be
    heterogeneous (a bench mixing row families, e.g. flat vs fleet rows):
    the header is the union of keys in encounter order, absent cells
    render empty.  ``kind == "meta"`` provenance rows print as a comment
    line instead of polluting the CSV header."""
    meta = [r for r in rows if r.get("kind") == "meta"]
    rows = [r for r in rows if r.get("kind") != "meta"]
    buf = io.StringIO()
    if rows:
        fields = list(dict.fromkeys(k for r in rows for k in r))
        w = csv.DictWriter(buf, fieldnames=fields, restval="")
        w.writeheader()
        w.writerows(rows)
    header = f"# {title}\n"
    for m in meta:
        header += "# meta: " + " ".join(
            f"{k}={v}" for k, v in m.items() if k != "kind") + "\n"
    out = header + buf.getvalue()
    print(out)
    return out
