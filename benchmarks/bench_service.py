"""Planner-service arrival storm: multi-tenant replay through
``repro.service.PlannerService``.

Replays every registered ``multi_tenant`` scenario family (seeded job
arrivals + network-event timeline, ``repro.scenarios.tenancy``) through
one shared-cluster :class:`~repro.service.PlannerService` and reports,
per family:

  * admission outcomes (admitted / rejected / finished, peak queue depth),
  * cross-job cache effectiveness — cold searches vs plan-store hits on
    isomorphic twins (``cache_hit_rate``),
  * replan volume + latency (mean / p99 over every per-job replan),
  * exact-invalidation volume (entries dropped by network events),
  * ``serial_matches_threaded`` — a second replay with a 4-worker pool
    must produce byte-identical per-job plan sequences and identical
    admission/cache counters (the service's frozen-round determinism
    contract).

Gates (the ISSUE 10 acceptance criteria): the 32-job storm family must
sustain a cross-job cache hit rate >= 50% on its bucketed twins, p99
replan latency must stay under an absolute wall budget, and every family
must replay deterministically serial == threaded.  The JSON rows are
written *before* the gates run so a failed assertion never discards the
telemetry that diagnoses it; ``benchmarks/compare.py`` re-checks the same
invariants against the committed baseline in CI.

PYTHONPATH=src python -m benchmarks.bench_service [--quick] [--json PATH]
"""

from __future__ import annotations

import time

from repro.scenarios import build_tenant, list_tenant_scenarios, to_job_specs
from repro.scenarios.tenancy import get_tenant_scenario
from repro.service import PlannerService
from benchmarks.common import emit, write_json

# p99 budget for one warm replan under the storm (absolute, host-independent
# slack: measured ~0.03 s on a shared 2-vCPU container at max_candidates=96;
# a warm path regressing to cold search lands well above this)
P99_BUDGET_S = 0.75
_SEED = 0
_THREAD_WORKERS = 4


def _replay(family: str, workers: int, max_candidates: int):
    topo, arrivals, trace = build_tenant(family, seed=_SEED)
    gpn = get_tenant_scenario(family).gpus_per_node
    specs = to_job_specs(arrivals, gpus_per_node=gpn)
    svc = PlannerService(topo, workers=workers, max_candidates=max_candidates)
    t0 = time.perf_counter()
    report = svc.replay(specs, list(trace.to_events()))
    return report, time.perf_counter() - t0


def run(quick: bool = False, json_path: str | None = None) -> list[dict]:
    """Replay every multi_tenant family serial + threaded, emit CSV/JSON,
    enforce the hit-rate / latency / determinism gates.  Returns rows."""
    max_candidates = 48 if quick else 96
    rows: list[dict] = []
    for family in list_tenant_scenarios():
        serial, wall_s = _replay(family, 1, max_candidates)
        threaded, wall_t = _replay(family, _THREAD_WORKERS, max_candidates)
        matches = (
            serial.plan_digests == threaded.plan_digests
            and (serial.admitted, serial.rejected, serial.finished,
                 serial.cold_searches, serial.cache_hits, serial.replans,
                 serial.invalidated)
            == (threaded.admitted, threaded.rejected, threaded.finished,
                threaded.cold_searches, threaded.cache_hits, threaded.replans,
                threaded.invalidated))
        walls = serial.replan_walls
        rows.append({
            "family": family,
            "jobs": serial.arrivals,
            "events": serial.events,
            "admitted": serial.admitted,
            "rejected": serial.rejected,
            "finished": serial.finished,
            "max_queue_depth": serial.max_queue_depth,
            "cold_searches": serial.cold_searches,
            "cache_hits": serial.cache_hits,
            "cache_hit_rate": round(serial.cache_hit_rate, 4),
            "replans": serial.replans,
            "invalidated": serial.invalidated,
            "mean_replan_s": round(sum(walls) / len(walls), 5) if walls
            else 0.0,
            "p99_replan_s": round(serial.percentile(99), 5),
            "events_per_s": round(serial.events / wall_s, 1),
            "wall_s": round(wall_s, 2),
            "threaded_wall_s": round(wall_t, 2),
            "serial_matches_threaded": matches,
        })
    emit(rows, "bench_service (multi-tenant arrival storms through "
               "PlannerService: shared cross-job cache, admission queue, "
               "contention-charged replans; serial vs 4-worker replay)")
    if json_path:
        write_json(rows, json_path, quick=quick)

    # -- gates ---------------------------------------------------------------
    by_family = {r["family"]: r for r in rows}
    storm = by_family["multi_tenant_storm"]
    # acceptance: the 32-job storm's bucketed twins reuse searches
    assert storm["jobs"] >= 32, storm
    assert storm["cache_hit_rate"] >= 0.5, storm
    # every family replays deterministically, serial == threaded
    for r in rows:
        assert r["serial_matches_threaded"], r
    # every admitted job actually ran to completion inside the horizon
    for r in rows:
        assert r["finished"] == r["admitted"], r
    # warm replans stay warm: p99 under the absolute budget
    for r in rows:
        assert r["p99_replan_s"] <= P99_BUDGET_S, r
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, help="write rows as JSON")
    args = ap.parse_args()
    run(quick=args.quick, json_path=args.json)
