"""Fig. 3 reproduction: naive all-reduce vs reduce-scatter + all-gather.

Two views:
  (a) the analytic multi-edge cost model (what the planner optimizes): the
      naive schedule funnels (n-1)x the tensor through the root's link,
      the decomposition moves 2(n-1)/n per device — speedup ≈ n/1,
  (b) the real JAX lowering: grads synced via explicit shard_map schedules
      on emulated devices, asserting both produce identical numerics
      (correctness of the decomposition, §2.3).
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

from repro.core import allreduce_time, homogeneous_cluster, hetero_cluster
from benchmarks.common import emit

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run() -> list[dict]:
    """Reproduce the Fig. 3 allreduce-decomposition comparison;
    returns the rows."""
    rows = []
    for n, label in ((8, "nvlink-node"), (16, "two-nodes-ib")):
        topo = homogeneous_cluster(n, "V100", gpus_per_node=8)
        ranks = topo.alive_ids()
        for size_mb in (16, 128, 1024):
            size = size_mb * 1e6
            naive = allreduce_time(topo, size, ranks, decomposed=False)
            dec = allreduce_time(topo, size, ranks, decomposed=True)
            rows.append({"cluster": label, "n": n, "size_mb": size_mb,
                         "naive_ms": round(naive * 1e3, 3),
                         "decomposed_ms": round(dec * 1e3, 3),
                         "speedup": round(naive / dec, 2)})
            assert dec < naive
    emit(rows, "fig3_allreduce_decomposition (analytic, multi-edge model)")

    # (b) numerics of the real collective schedules on 8 emulated devices
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
import sys; sys.path.insert(0, {SRC!r})
from repro.parallel.collectives import sync_grads
mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
g = {{"w": jnp.arange(64.0).reshape(8, 8)}}
ar, _ = sync_grads(g, mesh, "data", schedule="allreduce")
rs, _ = sync_grads(g, mesh, "data", schedule="rs_ag")
np.testing.assert_allclose(ar["w"], rs["w"], atol=1e-6)
print("rs_ag == allreduce numerics: OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr
    print(r.stdout.strip())
    return rows


if __name__ == "__main__":
    run()
