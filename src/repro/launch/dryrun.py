import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each of the 40 assigned cells on the single-pod 16×16 mesh AND the
2×16×16 multi-pod mesh:

  * build the model + sharding profile,
  * ``jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)``
    with ShapeDtypeStruct stand-ins (no allocation),
  * ``.compile()`` — GSPMD must partition every collective,
  * print ``memory_analysis()`` (proves the 16 GB/v5e-chip fit) and
    ``cost_analysis()`` (FLOPs/bytes for §Roofline),
  * probe-lower the same step at 1 and 2 layer-cycles to recover true
    per-step FLOPs/bytes (XLA cost_analysis counts scan bodies once — see
    repro.launch.roofline), and write a JSON report to reports/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma_7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi     # pod axis
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build_report, combine_probe_costs
from repro.models.config import SHAPES_BY_NAME, ArchConfig, ShapeSpec
from repro.models.lm import LM
from repro.optim.adamw import AdamWConfig
from repro.parallel import sharding as shd
from repro.parallel.axes import use_rules
from repro.parallel.trainstep import (abstract_train_state, make_prefill_step,
                                      make_serve_step, make_train_step)

REPORTS = Path(__file__).resolve().parents[3] / "reports" / "dryrun"


# ---------------------------------------------------------------------------
# Per-cell policy (the planner's memory model, Eq. 6, applied to the mesh)
# ---------------------------------------------------------------------------


def needs_zero3(cfg: ArchConfig, shape: ShapeSpec, model_extent: int) -> bool:
    n = LM(cfg).n_params()
    if shape.kind == "train":
        resident = 4.0 * n / model_extent          # bf16 p+g, moments zero1'd
    else:
        resident = 2.0 * n / model_extent
    return resident > 6e9


def choose_microbatches(cfg: ArchConfig, shape: ShapeSpec,
                        dp_extent: int) -> int:
    """Smallest grad-accumulation factor whose activation estimate fits."""
    if shape.kind != "train":
        return 1
    per_dev_batch = max(shape.global_batch // dp_extent, 1)
    # MoE working set: top_k routed copies + dispatch/combine buffers
    # (~K·(1+cf)·d_ff per token), much larger than the expert d_ff alone.
    d_ff_eff = cfg.top_k * cfg.d_ff * (1 + cfg.moe_capacity_factor) \
        if cfg.n_experts else cfg.d_ff
    for M in (1, 2, 4, 8, 16, 32):
        if M > per_dev_batch:
            return per_dev_batch
        mb_tokens = per_dev_batch // M * shape.seq_len
        stored = cfg.n_layers * mb_tokens * cfg.d_model * 2      # remat=full
        work = mb_tokens * max(d_ff_eff, 4 * cfg.d_model) * 2 * 4
        if stored + work < 6e9:
            return M
    return per_dev_batch


# ---------------------------------------------------------------------------
# Lowering builder (shared by the full cell and the cost probes)
# ---------------------------------------------------------------------------


def build_lowered(cfg: ArchConfig, shape: ShapeSpec, mesh, prof, *,
                  microbatches: int, donate: bool, remat: str = "full",
                  unroll: bool = False):
    """Lower one step for ``cfg`` on ``mesh``; returns the jax Lowered."""
    if cfg.n_experts:
        # group-local MoE dispatch aligned with the data shards (the global
        # argsort would all-gather every token — see layers.moe_block)
        mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_ext = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
        t_mb = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1) // microbatches
        if t_mb % dp_ext == 0:
            cfg = dataclasses.replace(cfg, moe_groups=dp_ext)
    model = LM(cfg, unroll=unroll)
    specs = cfg.input_specs(shape)
    batch_sh = shd.batch_shardings(mesh, specs, prof.rules)
    with mesh:
        if shape.kind == "train":
            step = make_train_step(model, AdamWConfig(),
                                   microbatches=microbatches, remat=remat)
            state_sh = {
                "params": shd.param_shardings(model, mesh, prof.rules),
                "opt": shd.opt_state_shardings(model, mesh, prof.opt_rules),
            }
            state_abs = abstract_train_state(model)
            metrics_sh = jax.tree.map(
                lambda _: NamedSharding(mesh, P()),
                {"loss": 0, "grad_norm": 0, "lr": 0, "tokens": 0})

            def wrapped(state, batch):
                with use_rules(mesh, prof.rules):
                    return step(state, batch)

            return jax.jit(wrapped,
                           in_shardings=(state_sh, batch_sh),
                           out_shardings=(state_sh, metrics_sh),
                           donate_argnums=(0,) if donate else ()
                           ).lower(state_abs, specs)
        if shape.kind == "prefill":
            step = make_prefill_step(model)
            p_sh = shd.param_shardings(model, mesh, prof.rules)

            def wrapped(params, batch):
                with use_rules(mesh, prof.rules):
                    return step(params, batch)

            # pin output shardings: last-token logits + the stacked prefill
            # cache (otherwise GSPMD under-shards the 32k cache output)
            out_abs = jax.eval_shape(wrapped, model.abstract_params(), specs)
            logits_sh = prof.rules.sharding(
                ("batch", "vocab"), out_abs[0].shape, mesh)
            cache_sh = shd._tree_shardings(model.stacked_cache_axes(),
                                           out_abs[1], mesh, prof.rules)
            return jax.jit(wrapped, in_shardings=(p_sh, batch_sh),
                           out_shardings=(logits_sh, cache_sh)
                           ).lower(model.abstract_params(), specs)
        # decode
        step = make_serve_step(model)
        p_sh = shd.param_shardings(model, mesh, prof.rules)
        cache_abs = model.init_cache(shape.global_batch, shape.seq_len,
                                     abstract=True)
        cache_sh = shd.cache_shardings(model, mesh, prof.rules,
                                       shape.global_batch, shape.seq_len)
        logits_sh = prof.rules.sharding(
            ("batch", "vocab"), (shape.global_batch, cfg.vocab), mesh)

        def wrapped(params, cache, batch):
            with use_rules(mesh, prof.rules):
                return step(params, cache, batch)

        return jax.jit(wrapped,
                       in_shardings=(p_sh, cache_sh, batch_sh),
                       out_shardings=(logits_sh, cache_sh),
                       donate_argnums=(1,) if donate else ()
                       ).lower(model.abstract_params(), cache_abs, specs)


def probe_costs(cfg: ArchConfig, shape: ShapeSpec, mesh, prof, *,
                remat: str = "full") -> dict:
    """1-/2-cycle probe lowerings -> true per-device per-step flops/bytes."""
    cyc = cfg.cycle_len

    def cost_of(n_layers: int, enc: int) -> dict[str, float]:
        sub = dataclasses.replace(cfg, n_layers=n_layers,
                                  encoder_layers=enc)
        lowered = build_lowered(sub, shape, mesh, prof, microbatches=1,
                                donate=False, unroll=True, remat=remat)
        ca = lowered.compile().cost_analysis()
        if isinstance(ca, (list, tuple)):   # jax<=0.4.x returns [dict]
            ca = ca[0] if ca else {}
        return {"flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0))}

    # Probes run at microbatches=1 with the FULL global batch, so their
    # flops/bytes already cover every token of the step — no M scaling.
    enc1 = min(cfg.encoder_layers, 1)
    f1 = cost_of(cyc, enc1)
    f2 = cost_of(2 * cyc, enc1)
    f_enc = cost_of(cyc, 2) if cfg.encoder_layers else None
    return combine_probe_costs(
        f1=f1, f2=f2, n_cycles=cfg.n_cycles, microbatches=1,
        f_enc1=f_enc, n_enc=cfg.encoder_layers)


# ---------------------------------------------------------------------------
# Cell driver
# ---------------------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, mesh_kind: str, *,
               verbose: bool = True, zero3: bool | None = None,
               donate: bool = True, with_probe: bool = True,
               microbatches: int | None = None, remat: str = "full",
               attn_fused: bool = False, pad_q_heads: bool = False):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if shape not in cfg.shapes():
        reason = dict(cfg.skipped_shapes()).get(shape, "not applicable")
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skip", "reason": str(reason)}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    if pad_q_heads:
        cfg = shd.pad_heads(cfg, mesh)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_extent = mesh_shape.get("model", 1)
    dp_extent = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    if zero3 is None:
        zero3 = needs_zero3(cfg, shape, model_extent)
    M = microbatches or choose_microbatches(cfg, shape, dp_extent)
    prof = shd.profile_for(cfg, mesh, zero3=zero3)

    t0 = time.perf_counter()
    per_dev_batch = max(shape.global_batch // dp_extent, 1)
    while True:
        lowered = build_lowered(cfg, shape, mesh, prof, microbatches=M,
                                donate=donate, remat=remat)
        compiled = lowered.compile()
        ma0 = compiled.memory_analysis()
        used = (ma0.argument_size_in_bytes + ma0.temp_size_in_bytes
                + ma0.output_size_in_bytes - ma0.alias_size_in_bytes)
        # memory-driven microbatch escalation (Eq. 6 applied post-compile)
        if used <= 16e9 or shape.kind != "train" or M >= per_dev_batch:
            break
        M = min(M * 2, per_dev_batch)
    t_lower = 0.0
    t_compile = time.perf_counter() - t0

    probe = None
    if with_probe:
        probe = probe_costs(cfg, shape, mesh, prof, remat=remat)

    hlo_text = compiled.as_text()
    rep = build_report(arch=arch, shape=shape, mesh_name=mesh_kind,
                       mesh_shape=mesh_shape, cfg=cfg, compiled=compiled,
                       hlo_text=hlo_text, zero3=zero3, zero1=True,
                       microbatches=M, probe=probe, remat_policy=remat,
                       attn_fused=attn_fused)
    out = {"status": "ok", "t_lower_s": round(t_lower, 1),
           "t_compile_s": round(t_compile, 1), "zero3": zero3,
           "microbatches": M, "remat": remat,
           "profile_notes": list(prof.notes),
           **rep.to_dict()}
    if verbose:
        ma = compiled.memory_analysis()
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/1e9:.2f}GB "
              f"temp={ma.temp_size_in_bytes/1e9:.2f}GB "
              f"out={ma.output_size_in_bytes/1e9:.2f}GB "
              f"alias={ma.alias_size_in_bytes/1e9:.2f}GB "
              f"-> fits16GB={out['fits']}")
        print(f"  cost_analysis(static): flops/dev={rep.hlo_flops_static:.3e}"
              f" bytes/dev={rep.hlo_bytes_static:.3e}")
        print(f"  probe-scaled: flops/dev={rep.flops:.3e} "
              f"bytes/dev={rep.bytes:.3e}")
        print(f"  roofline: compute={rep.t_compute*1e3:.1f}ms "
              f"memory={rep.t_memory*1e3:.1f}ms "
              f"collective={rep.t_collective*1e3:.1f}ms "
              f"-> {rep.bottleneck}-bound  useful={rep.useful_ratio:.2f}")
        print(f"  hlo collectives (static): {rep.hlo_coll_counts}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES_BY_NAME)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    REPORTS.mkdir(parents=True, exist_ok=True)

    results = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                tag = f"{arch} × {shape} × {mk}"
                print(f"[dryrun] {tag}", flush=True)
                t0 = time.perf_counter()
                try:
                    r = lower_cell(arch, shape, mk,
                                   donate=not args.no_donate,
                                   with_probe=not args.no_probe)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    r = {"arch": arch, "shape": shape, "mesh": mk,
                         "status": "fail", "error": repr(e),
                         "trace": traceback.format_exc()[-2000:]}
                    print(f"  FAIL: {e!r}")
                r["wall_s"] = round(time.perf_counter() - t0, 1)
                results.append(r)
                path = REPORTS / f"{arch}.{shape}.{mk}.json"
                path.write_text(json.dumps(r, indent=1, default=str))
                print(f"  -> {r['status']} ({r['wall_s']}s)", flush=True)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"\n[dryrun] done: {n_ok} ok, {n_skip} skip, {n_fail} FAIL "
          f"of {len(results)} cells")
    if n_fail:
        for r in results:
            if r["status"] == "fail":
                print(f"  FAILED {r['arch']} {r['shape']} {r['mesh']}: "
                      f"{r['error']}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
