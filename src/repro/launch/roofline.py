"""Roofline-term extraction from the compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds (assignment §Roofline):

  compute    = per-device HLO FLOPs / peak_FLOP/s        (cost_analysis)
  memory     = per-device HLO bytes / HBM bandwidth       (cost_analysis)
  collective = per-device collective bytes / ICI link bw  (analytic + HLO)

``cost_analysis()`` reports the *per-device* partitioned module (verified
empirically: a 2×4-sharded matmul reports dense/8 flops), so terms divide by
per-chip peaks directly.

Collective bytes: collectives inside ``lax.scan`` bodies appear once in HLO
text but execute once per trip, so a static text sum undercounts by the
layer count.  We therefore compute the collective term *analytically* from
the sharding profile (the framework knows which collectives its shardings
induce — FSDP all-gathers, ZeRO-1 reduce-scatter+all-gather, TP activation
collectives, EP all-to-alls) and use the HLO text parse (op kinds + per-trip
bytes) as a cross-check recorded alongside.
"""

from __future__ import annotations

import math
import re
from dataclasses import asdict, dataclass, field

import jax

from repro.models.config import ArchConfig, ShapeSpec
from repro.models.lm import LM

# TPU v5e constants (assignment).
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s/link; 2 links/axis direction on a torus
DCI_BW = 12.5e9              # inter-pod


# ---------------------------------------------------------------------------
# HLO text parsing (cross-check)
# ---------------------------------------------------------------------------

_DT_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
             "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
             "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8}
_COLL_RE = re.compile(
    r"=\s*(?P<sig>[^=]*?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _sig_bytes(sig: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


@dataclass
class HloCollectives:
    """Static (per-trip) collective footprint of the compiled module."""

    counts: dict[str, int] = field(default_factory=dict)
    bytes_static: dict[str, float] = field(default_factory=dict)

    @property
    def total_static(self) -> float:
        return sum(self.bytes_static.values())


def parse_collectives(hlo_text: str) -> HloCollectives:
    out = HloCollectives()
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group("kind")
        b = _sig_bytes(m.group("sig"))
        out.counts[kind] = out.counts.get(kind, 0) + 1
        out.bytes_static[kind] = out.bytes_static.get(kind, 0.0) + b
    return out


# ---------------------------------------------------------------------------
# Analytic HBM traffic (fused-TPU view)
#
# The CPU-backend HLO "bytes accessed" counts every op's operands unfused
# (~50-100x what a fused TPU pass touches), so the memory term uses this
# analytic model instead; the HLO number is kept as an upper-bound
# cross-check.  ``attn_fused=False`` charges the S×S score round-trips of
# the unfused jnp attention path — the traffic the Pallas flash kernel
# (repro.kernels.flash_attention) eliminates.
# ---------------------------------------------------------------------------


def analytic_hbm_bytes(cfg: ArchConfig, shape: ShapeSpec,
                       mesh_shape: dict[str, int], *, zero3: bool,
                       microbatches: int, remat: str = "full",
                       attn_fused: bool = False) -> dict[str, float]:
    model = mesh_shape.get("model", 1)
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    db = 2
    B, S = shape.global_batch, shape.seq_len
    Sq = 1 if shape.kind == "decode" else S
    L, d = cfg.n_layers, cfg.d_model
    M = microbatches
    train = shape.kind == "train"

    n = LM(cfg).n_params()
    p_shards = model * (data if zero3 else 1)
    p_loc = n * db / p_shards
    tok_loc = max(B // data, 1) * Sq              # per device per step
    # heads replicated over "model" when not divisible (fallback rule)
    H_loc = cfg.n_heads // model if cfg.n_heads % model == 0 else cfg.n_heads

    out: dict[str, float] = {}
    # parameters: fwd read ×M (+ bwd re-read, + remat re-read), optimizer r/w
    if train:
        reads = M * (2 + (1 if remat == "full" else 0))
        out["params_io"] = p_loc * reads
        n_opt_loc = n / (model * data)            # zero1: moments over data
        out["optimizer_io"] = n_opt_loc * (4 * 4 + 2 * db) + p_loc * 2
    else:
        out["params_io"] = p_loc
    # activations: residual stream + block internals, fwd (+bwd ~2x, remat +1)
    act_mult = (4.0 if remat == "full" else 3.0) if train else 1.0
    d_ff_eff = cfg.top_k * cfg.d_ff if cfg.n_experts else cfg.d_ff
    act_total = 0.0
    for i in range(L):
        kind = cfg.block_kind(i)
        if kind == "mamba":
            inner = 6 * cfg.ssm_expand * d          # z/x/conv/gate streams
        elif kind in ("mlstm", "slstm"):
            inner = 10 * d                          # qkv/gates at e≈2d
        else:
            inner = 4 * max(d_ff_eff, 2 * d)
        act_total += tok_loc * (8 * d + inner) * db
    out["activations_io"] = act_mult * act_total
    # unfused attention scores (the flash-kernel target)
    n_attn = sum(1 for i in range(L) if cfg.block_kind(i) in
                 ("attn", "cross_attn", "shared_attn"))
    if not attn_fused and n_attn:
        kv_avg = min(cfg.attn_window or S, S) if shape.kind != "decode" \
            else min(cfg.attn_window or S, S)
        causal_frac = 0.5 if (shape.kind != "decode"
                              and not cfg.attn_window) else 1.0
        B_loc = max(B // data, 1)
        score_rw = 3 * 4                           # write+read f32, + softmax
        out["attn_scores_io"] = (act_mult if train else 1.0) * n_attn * \
            B_loc * H_loc * Sq * kv_avg * causal_frac * score_rw
    # kv cache / recurrent state io (serving: the cache read dominates)
    if shape.kind == "decode":
        mdl = LM(cfg)
        cache = mdl.init_cache(B, S, abstract=True)
        total = sum(math.prod(x.shape) * x.dtype.itemsize
                    for x in jax.tree.leaves(cache))
        out["cache_io"] = total / (data * model) * 2   # read + write
    # lm head + embed
    V_loc = cfg.vocab / model if cfg.vocab % model == 0 else cfg.vocab
    if train:
        out["lm_head_io"] = tok_loc * V_loc * (db + 4) + \
            M * (cfg.vocab * d * db / p_shards) * 3
    else:
        out["lm_head_io"] = max(B // data, 1) * V_loc * 4
    return out


def _ring_ag_bytes(size_global: float, n: int) -> float:
    """Per-device wire bytes for a ring all-gather of a tensor whose global
    (gathered) size is ``size_global``, over ``n`` participants."""
    if n <= 1:
        return 0.0
    return (n - 1) / n * size_global


def analytic_collectives(cfg: ArchConfig, shape: ShapeSpec, mesh_shape:
                         dict[str, int], *, zero3: bool, zero1: bool,
                         microbatches: int = 1) -> dict[str, float]:
    """Per-device, per-step collective wire bytes by class.

    Classes map to mesh axes (multi-edge: different axes = different physical
    links, so only same-axis traffic serializes — DESIGN.md §3):
      * tp_*:   activation collectives on the "model" axis
      * dp_*:   gradient sync on "data" (+ "pod"): AR, or RS+AG (ZeRO-1),
                plus FSDP param all-gathers when zero3
      * ep_*:   MoE all-to-all on "model"
    """
    model = mesh_shape.get("model", 1)
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    db = 2  # bf16
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        S = 1
    L = cfg.n_layers
    d = cfg.d_model
    act_global = B * S * d * db            # one residual-stream tensor
    m = LM(cfg)
    params_bytes = m.n_params() * db

    out: dict[str, float] = {}
    heads_shardable = cfg.n_heads % model == 0
    # TP activation collectives per layer (fwd; bwd doubles; train = 3x fwd
    # cost in flops but 2 passes of collectives).
    passes = 2.0 if shape.kind == "train" else 1.0
    n_attn = sum(1 for i in range(L)
                 if cfg.block_kind(i) in ("attn", "cross_attn", "shared_attn"))
    n_ffn = sum(1 for i in range(L) if cfg.block_kind(i) == "attn"
                and not cfg.n_experts) \
        + sum(1 for i in range(L) if cfg.block_kind(i) in
              ("cross_attn", "shared_attn"))
    if model > 1 and heads_shardable:
        # Megatron TP: each attn/ffn output row-parallel matmul ends in an
        # all-reduce of the activation (2 per transformer layer).
        n_coll = n_attn + n_ffn
        out["tp_allreduce_model"] = passes * n_coll * 2 * _ring_ag_bytes(
            act_global / max(data, 1), model)
    if cfg.n_experts and model > 1:
        # EP: dispatch+combine all-to-alls of the routed activations.
        moe_layers = sum(1 for i in range(L) if cfg.block_kind(i) == "attn")
        routed = act_global / max(data, 1) * cfg.top_k
        out["ep_alltoall_model"] = passes * moe_layers * 2 * routed / model
    if shape.kind == "train" and data > 1:
        if zero1 or zero3:
            out["dp_reduce_scatter_data"] = _ring_ag_bytes(params_bytes, data)
            out["dp_all_gather_data"] = _ring_ag_bytes(params_bytes, data)
        else:
            out["dp_allreduce_data"] = 2 * _ring_ag_bytes(params_bytes, data)
        if zero3:
            # params re-gathered each microbatch fwd+bwd.  Expert weights
            # use 2-D TP on the data axis instead of FSDP (layers.moe_defs),
            # so only the dense remainder is gathered.
            expert_bytes = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model \
                * cfg.d_ff * db if cfg.n_experts else 0.0
            out["fsdp_all_gather_data"] = 2 * microbatches * _ring_ag_bytes(
                max(params_bytes - expert_bytes, 0.0), data)
    return out


def collective_seconds(vol: dict[str, float],
                       mesh_shape: dict[str, int]) -> float:
    """Serialize same-axis traffic; different axes ride different ICI links
    (multi-edge) — the slower of the two axis queues bounds the term when
    overlap is perfect, their sum when not.  We report the conservative
    no-overlap sum within an axis and max across axes."""
    per_axis: dict[str, float] = {}
    for k, v in vol.items():
        axis = k.rsplit("_", 1)[-1]
        bw = ICI_BW * 2  # bidirectional ring: 2 links per axis
        if axis == "data" and mesh_shape.get("pod", 1) > 1:
            bw = DCI_BW  # gradient ring crosses the pod boundary
        per_axis[axis] = per_axis.get(axis, 0.0) + v / bw
    return max(per_axis.values()) if per_axis else 0.0


# ---------------------------------------------------------------------------
# Probe-based cost scaling
#
# XLA's cost_analysis counts a while-loop body ONCE, not per trip, so the
# full-cell lowering (layers scanned, microbatches scanned) undercounts
# FLOPs/bytes by the trip counts.  We therefore lower the same step with 1
# and 2 layer-cycles (a 1- or 2-trip scan is counted exactly): the delta is
# the true per-cycle cost, and known static trip counts (n_cycles ×
# microbatches) scale it to the full model.  Attention chunk loops and the
# cross-entropy chunk loop are python-unrolled in the model, so probes count
# them exactly.  Recurrent *time* scans (mamba/mlstm/slstm, S trips) get an
# analytic correction below.
# ---------------------------------------------------------------------------


def combine_probe_costs(*, f1: dict[str, float], f2: dict[str, float],
                        n_cycles: int, microbatches: int,
                        f_enc1: dict[str, float] | None = None,
                        n_enc: int = 0) -> dict[str, float]:
    """Extrapolate per-device (flops, bytes) from 1-/2-cycle probes."""
    out = {}
    for k in ("flops", "bytes"):
        d_cyc = max(f2[k] - f1[k], 0.0)
        base = max(f1[k] - d_cyc, 0.0)
        if f_enc1 is not None and n_enc > 0:
            d_enc = max(f_enc1[k] - f1[k], 0.0)   # probe3: one extra enc layer
            base_total = base + d_cyc * n_cycles + d_enc * (n_enc - 1)
        else:
            base_total = base + d_cyc * n_cycles
        out[k] = base_total * microbatches
        out[f"{k}_per_cycle"] = d_cyc
        out[f"{k}_base"] = base
    return out


def recurrent_correction(cfg: ArchConfig, shape: ShapeSpec,
                         mesh_shape: dict[str, int]) -> dict[str, float]:
    """Analytic per-device flops/bytes of the sequential time scans, which
    probes count once instead of S times (decode: S=1, nothing to fix)."""
    if shape.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    model = mesh_shape.get("model", 1)
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    B = shape.global_batch
    S = shape.seq_len
    B_loc = max(B // data, 1)
    mult = 3.0 if shape.kind == "train" else 1.0      # bwd re-runs the scan
    d = cfg.d_model
    flops = byts = 0.0
    for kind in cfg.pattern:          # one occurrence per cycle per position
        n_occ = cfg.n_cycles
        if kind == "mamba":
            # chunkwise-parallel SSD: the big intra-chunk einsums sit
            # OUTSIDE the chunk loop and the boundary step unrolls in the
            # probes, so probe costs are already exact — no correction.
            continue
        elif kind == "mlstm":
            H = cfg.n_heads
            hd = 2 * d // H
            chunked = S % 64 == 0 and S > 64
            if chunked and S // 64 <= 128:
                continue      # probes unroll the chunk loop: counted exactly
            if chunked:
                # chunkwise analytic: intra matmuls + per-chunk state io
                c = 64
                flops += n_occ * B_loc * H * (4 * S * c * hd + 8 * (S // c)
                                              * hd * hd)
                byts += n_occ * (S // c) * B_loc * 2 * H * hd * hd * 4
            else:
                st = H * hd * hd
                flops += n_occ * S * B_loc * 8 * st
                byts += n_occ * S * B_loc * 2 * st * 4
        elif kind == "slstm":
            H = cfg.n_heads
            hd = d // H
            rec = H * hd * 4 * hd
            flops += n_occ * S * B_loc * 2 * rec
            byts += n_occ * S * (rec * 2 + B_loc * 8 * H * hd * 4)
    return {"flops": flops * mult, "bytes": byts * mult}


# ---------------------------------------------------------------------------
# Cell report
# ---------------------------------------------------------------------------


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device.  hlo_* are the raw cost_analysis numbers of the full-cell
    # module (loop bodies counted once); flops/bytes are the probe-scaled
    # true per-step costs used for the terms.
    hlo_flops_static: float
    hlo_bytes_static: float
    flops: float
    bytes: float
    collective_bytes: float
    # terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    # memory fit
    arg_bytes: float
    temp_bytes: float
    fits: bool
    hlo_coll_counts: dict[str, int] = field(default_factory=dict)
    hlo_coll_bytes_static: float = 0.0
    analytic_detail: dict[str, float] = field(default_factory=dict)
    probe_detail: dict[str, float] = field(default_factory=dict)
    note: str = ""

    def to_dict(self) -> dict:
        return asdict(self)


def model_flops_estimate(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (train), 2·N·D (prefill/decode); N = active."""
    m = LM(cfg)
    n = m.n_params()
    if cfg.n_experts:
        dense_ffn = cfg.n_layers * cfg.n_experts * (
            3 * cfg.d_model * cfg.d_ff)
        active_ffn = cfg.n_layers * cfg.top_k * (3 * cfg.d_model * cfg.d_ff)
        n = n - dense_ffn + active_ffn
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def build_report(*, arch: str, shape: ShapeSpec, mesh_name: str,
                 mesh_shape: dict[str, int], cfg: ArchConfig,
                 compiled, hlo_text: str | None, zero3: bool, zero1: bool,
                 microbatches: int, probe: dict[str, float] | None = None,
                 remat_policy: str = "full", attn_fused: bool = False,
                 note: str = "") -> RooflineReport:
    chips = math.prod(mesh_shape.values())
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):       # jax<=0.4.x returns [dict]
        ca = ca[0] if ca else {}
    flops_static = float(ca.get("flops", 0.0))
    bytes_static = float(ca.get("bytes accessed", 0.0))
    if probe is not None:
        corr = recurrent_correction(cfg, shape, mesh_shape)
        flops = probe["flops"] + corr["flops"]
        probe = {**probe, "recurrent_corr_flops": corr["flops"],
                 "recurrent_corr_bytes": corr["bytes"]}
    else:
        corr = recurrent_correction(cfg, shape, mesh_shape)
        flops = flops_static
    # memory term: analytic fused-TPU traffic (HLO bytes kept as the
    # unfused upper bound in hlo_bytes_static)
    hbm = analytic_hbm_bytes(cfg, shape, mesh_shape, zero3=zero3,
                             microbatches=microbatches, remat=remat_policy,
                             attn_fused=attn_fused)
    byts = sum(hbm.values()) + corr["bytes"]
    vol = analytic_collectives(cfg, shape, mesh_shape, zero3=zero3,
                               zero1=zero1, microbatches=microbatches)
    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = collective_seconds(vol, mesh_shape)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_estimate(cfg, shape)
    ma = compiled.memory_analysis()
    arg = float(getattr(ma, "argument_size_in_bytes", 0))
    tmp = float(getattr(ma, "temp_size_in_bytes", 0))
    out_b = float(getattr(ma, "output_size_in_bytes", 0))
    alias = float(getattr(ma, "alias_size_in_bytes", 0))
    hc = parse_collectives(hlo_text) if hlo_text else HloCollectives()
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops_static=flops_static, hlo_bytes_static=bytes_static,
        flops=flops, bytes=byts,
        collective_bytes=sum(vol.values()),
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=bottleneck, model_flops=mf,
        useful_ratio=(mf / (flops * chips)) if flops else 0.0,
        arg_bytes=arg, temp_bytes=tmp,
        fits=(arg + tmp + out_b - alias) <= 16e9,
        hlo_coll_counts=hc.counts, hlo_coll_bytes_static=hc.total_static,
        analytic_detail={**vol, **{f"hbm_{k}": v for k, v in hbm.items()}},
        probe_detail=probe or {}, note=note)
