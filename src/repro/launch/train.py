"""Training launcher CLI.

Plans with the paper's search (over the analytic cluster model), then trains
the selected architecture on the available devices:

  PYTHONPATH=src python -m repro.launch.train --arch xlstm_125m --steps 50 \\
      --global-batch 8 --seq 256 [--reduced] [--plan auto|megatron]

``--reduced`` uses the smoke-scale config (CPU-friendly).  On a real TPU
cluster the same launcher runs under ``jax.distributed`` with the production
mesh from repro.launch.mesh.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core import hetero_cluster, plan_hybrid
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm_125m", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--plan", default="auto", choices=["auto", "megatron"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "selective", "full"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    # Plan against the analytic cluster (the paper's planning step); the
    # host run then uses the plan's execution knobs.
    topo = hetero_cluster({"TPUv5e": max(len(jax.devices()), 4)},
                          gpus_per_node=4)
    plan = None
    if args.plan == "auto":
        res = plan_hybrid(topo, cfg.to_model_desc(),
                          global_batch=args.global_batch, seq=args.seq,
                          with_baseline=False)
        plan = res.plan
        print(f"[plan] {plan.describe()} "
              f"(predicted step {res.predicted.step_time*1e3:.1f} ms)")

    tcfg = TrainerConfig(
        arch=cfg, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq, ckpt_dir=args.ckpt_dir,
        microbatches=args.microbatches, remat=args.remat,
        opt=AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                        total_steps=args.steps))
    trainer = Trainer(tcfg, plan=plan)
    _, hist = trainer.run()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"[train] loss {first:.4f} -> {last:.4f} over {args.steps} steps")


if __name__ == "__main__":
    main()
