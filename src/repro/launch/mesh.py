"""Production mesh builders (assignment §Multi-pod dry-run).

A function, not a module-level constant: importing this module never touches
jax device state.  Shapes: 16×16 = 256 chips per pod (TPU v5e), multi-pod =
2×16×16 = 512 chips with a leading "pod" axis riding the slower DCI links.
"""

from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def _mk(shape, axes) -> Mesh:
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — the "
            "dry-run launcher must set XLA_FLAGS="
            "--xla_force_host_platform_device_count before importing jax")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    return _mk(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1) -> Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    data = data or (n // model)
    return _mk((data, model), ("data", "model"))
