"""Summarize reports/dryrun/*.json into the EXPERIMENTS.md roofline table.

PYTHONPATH=src python -m repro.launch.summarize [--mesh single]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

REPORTS = Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def load(mesh: str | None = None) -> list[dict]:
    rows = []
    for p in sorted(REPORTS.glob("*.json")):
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        rows.append(r)
    return rows


def fmt_ms(x: float) -> str:
    return f"{x*1e3:.1f}" if x < 10 else f"{x*1e3:.0f}"


def table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute ms | memory ms | coll ms | "
           "bound | useful | fits | note |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows = sorted(rows, key=lambda r: (r["arch"],
                                       order.get(r["shape"], 9),
                                       r.get("mesh", "")))
    for r in rows:
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                       f"| — | skip | — | — | {r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                       f"| — | FAIL | — | — | {r.get('error','')[:40]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_ms(r['t_compute'])} | {fmt_ms(r['t_memory'])} "
            f"| {fmt_ms(r['t_collective'])} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.2f} | {'Y' if r['fits'] else 'N'} "
            f"| zero3={r['zero3']} M={r['microbatches']} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = load(args.mesh)
    print(table(rows))
    ok = [r for r in rows if r["status"] == "ok"]
    fit = sum(1 for r in ok if r["fits"])
    print(f"\n{len(ok)} ok cells, {fit} fit in 16GB; "
          f"{sum(1 for r in rows if r['status']=='skip')} skips; "
          f"{sum(1 for r in rows if r['status']=='fail')} failures")


if __name__ == "__main__":
    main()
