"""Deterministic synthetic token pipeline, shardable per DP rank.

Every batch is a pure function of (seed, step), so any rank — or a restarted
replacement rank after a failure — regenerates exactly its shard without
coordination.  Structure in the stream (a repeating Markov-ish walk) gives
the model something learnable so the e2e example's loss visibly drops.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    # modality stubs (whisper/VLM): emit fixed frame/patch embeddings
    audio_seq: int = 0
    vision_seq: int = 0
    d_model: int = 0


class SyntheticLM:
    """Deterministic structured token stream.

    tokens[t+1] = (a * tokens[t] + walk) % vocab with per-sequence (a, walk)
    drawn from (seed, step, row) — learnable short-range structure.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        B, S, V = c.global_batch, c.seq_len, c.vocab
        a = rng.integers(1, 5, size=(B, 1))
        start = rng.integers(0, V, size=(B, 1))
        idx = np.arange(S + 1)[None, :]
        toks = (start + a * idx) % V
        noise = rng.integers(0, V, size=(B, S + 1))
        keep = rng.random((B, S + 1)) < 0.98
        toks = np.where(keep, toks, noise).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if c.audio_seq:
            r = np.random.default_rng((c.seed, 7, step))
            out["audio_embed"] = (r.standard_normal(
                (B, c.audio_seq, c.d_model)) * 0.02).astype(np.float32)
        if c.vision_seq:
            r = np.random.default_rng((c.seed, 9, step))
            out["vision_embed"] = (r.standard_normal(
                (B, c.vision_seq, c.d_model)) * 0.02).astype(np.float32)
        return out

    def shard(self, step: int, rank: int, world: int,
              shares: tuple[float, ...] | None = None) -> dict[str, np.ndarray]:
        """This rank's rows — supports the planner's *uneven* batch shares
        for heterogeneous DP (paper §4.1)."""
        full = self.batch(step)
        B = self.cfg.global_batch
        if shares is None:
            lo = B * rank // world
            hi = B * (rank + 1) // world
        else:
            cuts = np.floor(np.cumsum((0.0,) + shares) * B).astype(int)
            lo, hi = cuts[rank], cuts[rank + 1]
        return {k: v[lo:hi] for k, v in full.items()}
