"""Sharded checkpointing with elastic resharding + async save.

Arrays are gathered to host and written as one npz per *shard group* plus a
JSON manifest holding the step, the serialized ParallelPlan and the pytree
structure.  Restore is mesh-agnostic: arrays are re-placed under whatever
NamedSharding tree the *new* plan/mesh dictates — that is the elastic
resharding used after S3 failover (topology changed → planner re-plans →
restore reshards), cf. Oobleck's template switch.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any
_SEP = "|"


def _flatten(tree: Pytree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":     # npz has no native bf16
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(path: str | Path, state: Pytree, *, step: int,
         plan_json: str = "", extra: dict | None = None) -> None:
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    tmp = path / ".tmp.arrays.npz"
    np.savez(tmp, **flat)
    tmp.rename(path / "arrays.npz")      # atomic-ish publish
    treedef = jax.tree_util.tree_structure(state)
    manifest = {"step": step, "plan": plan_json,
                "treedef": str(treedef), "keys": sorted(flat),
                "time": time.time(), **(extra or {})}
    (path / "manifest.json").write_text(json.dumps(manifest, indent=1))


class AsyncSaver:
    """Fire-and-forget background checkpoint writes (one in flight)."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def submit(self, path, state, *, step: int, plan_json: str = "") -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)
        self._thread = threading.Thread(
            target=save, args=(path, host_state),
            kwargs={"step": step, "plan_json": plan_json}, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def restore(path: str | Path, like: Pytree, *,
            shardings: Pytree | None = None) -> tuple[Pytree, dict]:
    """Restore into the structure of ``like``; place under ``shardings``
    (the *new* mesh's sharding tree — elastic resharding)."""
    path = Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    data = np.load(path / "arrays.npz")
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, leaf in leaves_like:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in p)
        arr = data[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = np.asarray(jnp.asarray(arr).astype(leaf.dtype))
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest


def latest_step(root: str | Path) -> int | None:
    root = Path(root)
    steps = [int(p.name.split("_")[-1]) for p in root.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None
