"""Exporters: Chrome-trace/Perfetto JSON, JSONL event log, metrics files.

The Chrome trace format (``{"traceEvents": [...]}``) loads directly in
Perfetto (https://ui.perfetto.dev) and ``chrome://tracing``: each span
becomes one complete ``"ph": "X"`` event with microsecond timestamps, and
events keep their originating process id, so spans adopted from
:class:`repro.core.search.SearchExecutor` workers render as one lane per
worker process under the parent's timeline.  Extra top-level keys are
allowed by the format, so the metrics snapshot rides along under
``"reproMetrics"`` — one self-contained file per traced run that
:mod:`tools.trace_report` can summarize without a second artifact.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import TYPE_CHECKING

if TYPE_CHECKING:                              # pragma: no cover
    from . import Obs

# Key the metrics snapshot is embedded under in the combined trace file.
METRICS_KEY = "reproMetrics"


# Base tid for named lanes — far above any real thread id so the synthetic
# rows never collide with OS thread lanes in the same process group.
_LANE_TID_BASE = 1_000_000


def chrome_trace(obs: "Obs") -> dict:
    """The combined Chrome-trace/Perfetto document for ``obs``:
    ``traceEvents`` (one ``X`` event per finished span, µs timestamps,
    span/parent ids in ``args``) plus the metrics snapshot under
    :data:`METRICS_KEY`.

    Spans carrying a ``lane`` attribute (e.g. the planner service's
    per-job ``service.replan`` spans, ``lane=<job name>``) are grouped
    onto one synthetic named row per distinct lane value instead of their
    OS thread id — a ``thread_name`` metadata event labels each row, so
    Perfetto shows one timeline per job regardless of which worker thread
    ran the replan."""
    events = []
    lanes: dict[tuple[int, str], int] = {}       # (pid, lane) -> tid
    for s in obs.tracer.spans:
        if s.t1 is None:
            continue
        tid = s.tid
        lane = s.attrs.get("lane")
        if lane is not None:
            key = (s.pid, str(lane))
            tid = lanes.get(key)
            if tid is None:                       # first-seen order, stable
                tid = _LANE_TID_BASE + len(lanes)
                lanes[key] = tid
                events.append({
                    "ph": "M", "name": "thread_name", "pid": s.pid,
                    "tid": tid, "args": {"name": str(lane)},
                })
        events.append({
            "ph": "X", "name": s.name,
            "ts": s.t0 * 1e6, "dur": (s.t1 - s.t0) * 1e6,
            "pid": s.pid, "tid": tid,
            "args": {"span_id": s.span_id, "parent_id": s.parent_id,
                     **s.attrs},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms",
            METRICS_KEY: obs.metrics.snapshot()}


def write_trace(obs: "Obs", path: str | Path) -> Path:
    """Write the combined Perfetto trace + metrics file; returns the path."""
    p = Path(path)
    if p.parent != Path("."):
        p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(chrome_trace(obs), sort_keys=True))
    return p


def write_jsonl(obs: "Obs", path: str | Path) -> Path:
    """Write the structured event log: one JSON object per line — every
    finished span (``{"kind": "span", ...}``) followed by one final
    ``{"kind": "metrics", ...}`` snapshot record."""
    p = Path(path)
    if p.parent != Path("."):
        p.parent.mkdir(parents=True, exist_ok=True)
    lines = [json.dumps({"kind": "span", **s.to_dict()})
             for s in obs.tracer.spans]
    lines.append(json.dumps({"kind": "metrics",
                             "metrics": obs.metrics.snapshot()}))
    p.write_text("\n".join(lines) + "\n")
    return p


def write_metrics(obs: "Obs", path: str | Path) -> Path:
    """Write the metrics snapshot alone (the CI artifact next to the
    trace); returns the path."""
    p = Path(path)
    if p.parent != Path("."):
        p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(obs.metrics.snapshot(), indent=2,
                            sort_keys=True))
    return p
