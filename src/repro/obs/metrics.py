"""Metrics registry: named counters + fixed-bucket histograms (ISSUE 7).

The registry is the single tally point for the repo's scattered hand-rolled
counters: the search cascade's per-tier prune counts, the strategy cache's
hit/miss pair, and the re-planning engine's per-path latency all flow
through one :class:`MetricsRegistry` when observability is enabled (see
:mod:`repro.obs`).  Everything here is stdlib-only and cheap enough to sit
on hot paths — a counter increment is one dict lookup + int add under a
lock, and histograms keep a bounded raw-sample reservoir so percentile
queries stay exact for the sample counts the planner actually produces.

Percentile math matches :func:`statistics.quantiles` with
``method="inclusive"`` (linear interpolation between closest ranks), so the
numbers :mod:`tools.trace_report` prints agree with what a user would
compute from the raw samples.
"""

from __future__ import annotations

import math
import threading
from bisect import bisect_right, insort
from typing import Mapping, Sequence

# Default histogram bucket upper bounds (seconds): spans replan latencies
# from sub-millisecond warm re-scores to multi-minute fleet searches.
DEFAULT_BUCKETS: tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0)

# Raw-sample reservoir cap per histogram.  The planner's per-search sample
# counts (replans per scenario, intervals per trace) sit far below this, so
# percentiles are exact in practice; past the cap the earliest samples are
# kept (deterministic, unlike random reservoir sampling).
RESERVOIR_CAP = 4096


class Counter:
    """A monotonically increasing named counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        """Add ``n`` (default 1) to the counter."""
        self.value += n


class Histogram:
    """Fixed-bucket histogram with an exact bounded sample reservoir.

    ``bucket_counts[i]`` counts observations ``<= bounds[i]``; the final
    slot counts overflows.  ``count``/``total``/``min``/``max`` are exact
    over every observation; percentiles interpolate over the (sorted)
    reservoir, which is exact until :data:`RESERVOIR_CAP` observations.
    """

    __slots__ = ("name", "bounds", "bucket_counts", "count", "total",
                 "min", "max", "_samples")

    def __init__(self, name: str,
                 bounds: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.bounds = tuple(bounds)
        self.bucket_counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: list[float] = []        # kept sorted (insort)

    def observe(self, value: float) -> None:
        """Record one observation."""
        self.bucket_counts[bisect_right(self.bounds, value)] += 1
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        if len(self._samples) < RESERVOIR_CAP:
            insort(self._samples, value)

    @property
    def mean(self) -> float:
        """Arithmetic mean of every observation (NaN when empty)."""
        return self.total / self.count if self.count else math.nan

    def percentile(self, q: float) -> float:
        """The ``q``-th percentile (``0 <= q <= 100``) over the reservoir.

        Uses the same inclusive linear interpolation as
        ``statistics.quantiles(samples, n=100, method="inclusive")``:
        rank ``(n - 1) * q / 100`` between sorted closest samples.
        """
        s = self._samples
        if not s:
            return math.nan
        if len(s) == 1:
            return s[0]
        rank = (len(s) - 1) * (q / 100.0)
        lo = math.floor(rank)
        hi = min(lo + 1, len(s) - 1)
        frac = rank - lo
        return s[lo] * (1.0 - frac) + s[hi] * frac

    def to_dict(self) -> dict:
        """Snapshot as a plain-JSON dict (see ``MetricsRegistry.snapshot``)."""
        return {
            "type": "histogram", "count": self.count,
            "sum": self.total,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "mean": self.mean if self.count else None,
            "p50": self.percentile(50) if self.count else None,
            "p95": self.percentile(95) if self.count else None,
            "p99": self.percentile(99) if self.count else None,
            "bounds": list(self.bounds),
            "bucket_counts": list(self.bucket_counts),
            "samples": list(self._samples),
        }

    def merge_dict(self, d: Mapping) -> None:
        """Fold a ``to_dict`` snapshot (same bounds) into this histogram."""
        if tuple(d.get("bounds", ())) != self.bounds:
            raise ValueError(
                f"histogram {self.name}: bucket bounds mismatch on merge")
        for i, c in enumerate(d.get("bucket_counts", ())):
            self.bucket_counts[i] += c
        self.count += d.get("count", 0)
        self.total += d.get("sum", 0.0)
        if d.get("min") is not None and d["min"] < self.min:
            self.min = d["min"]
        if d.get("max") is not None and d["max"] > self.max:
            self.max = d["max"]
        for v in d.get("samples", ()):
            if len(self._samples) >= RESERVOIR_CAP:
                break
            insort(self._samples, v)


class MetricsRegistry:
    """Named counters + histograms with snapshot/merge for worker shipping.

    Thread-safe; picklable (the lock is dropped and re-created, the same
    treatment :class:`repro.obs.Obs` gets so a harness config holding one
    can ship to spawn workers).
    """

    def __init__(self) -> None:
        self._counters: dict[str, Counter] = {}
        self._histograms: dict[str, Histogram] = {}
        self._lock = threading.Lock()

    # -- pickling (drop the lock) -------------------------------------------

    def __getstate__(self) -> dict:
        state = self.__dict__.copy()
        del state["_lock"]
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._lock = threading.Lock()

    # -- recording -----------------------------------------------------------

    def counter(self, name: str) -> Counter:
        """Get or create the counter ``name``."""
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def histogram(self, name: str,
                  bounds: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        """Get or create the histogram ``name``."""
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name, Histogram(name, bounds))
        return h

    def inc(self, name: str, n: int = 1) -> None:
        """Increment counter ``name`` by ``n``."""
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters.setdefault(name, Counter(name))
            c.value += n

    def observe(self, name: str, value: float) -> None:
        """Record ``value`` into histogram ``name``."""
        self.histogram(name).observe(value)

    # -- reading / shipping ---------------------------------------------------

    def counter_value(self, name: str) -> int:
        """Current value of counter ``name`` (0 when absent)."""
        c = self._counters.get(name)
        return c.value if c is not None else 0

    def counters_with_prefix(self, prefix: str) -> dict[str, int]:
        """``{name: value}`` for every counter whose name starts with
        ``prefix`` — how callers take before/after deltas on a shared
        registry (e.g. the harness's per-scenario replan-path counts)."""
        with self._lock:
            return {n: c.value for n, c in self._counters.items()
                    if n.startswith(prefix)}

    def snapshot(self) -> dict:
        """Plain-JSON view of every metric: counters as ints, histograms
        as their ``to_dict`` summaries.  This is the metrics exporter."""
        with self._lock:
            out: dict = {n: c.value for n, c in self._counters.items()}
            for n, h in self._histograms.items():
                out[n] = h.to_dict()
        return out

    def merge(self, snap: Mapping) -> None:
        """Fold a ``snapshot()`` (e.g. shipped back from a search worker)
        into this registry."""
        for name, val in snap.items():
            if isinstance(val, Mapping) and val.get("type") == "histogram":
                self.histogram(name, val.get("bounds", DEFAULT_BUCKETS)) \
                    .merge_dict(val)
            else:
                self.inc(name, int(val))
