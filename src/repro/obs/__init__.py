"""``repro.obs`` — unified tracing + metrics for the planner stack (ISSUE 7).

A zero-dependency telemetry layer with two halves:

* a **span tracer** (:mod:`repro.obs.tracer`): ``with obs.span("search.tier3",
  n_tasks=40):`` records monotonic-clock nested spans, thread-safe, and
  spawn-worker-safe — :class:`repro.core.search.SearchExecutor` workers
  trace locally, ship span dicts back with their result payload, and the
  parent re-parents them under the enqueuing span;
* a **metrics registry** (:mod:`repro.obs.metrics`): named counters
  (``cache.hit``, ``search.pruned.coarse``, ``replan.path.*``) and
  fixed-bucket histograms (``replan.latency_s``) that absorb the repo's
  previously hand-rolled accounting.

The :class:`Obs` bundle ties the two together and is what every
instrumented entry point accepts (``plan_hybrid(obs=...)``,
``ReplanEngine(obs=...)``, ``HarnessConfig.obs``).  **Off by default** with
near-zero disabled overhead: the module-level :data:`NULL_OBS` singleton
answers every call with shared no-op objects — no span allocation, no
counter writes.  Set ``REPRO_TRACE=/path/trace.json`` to enable the
process-wide default and dump a combined Perfetto trace + metrics file at
exit; see ``docs/observability.md`` for the span/metric taxonomy and
``tools/trace_report.py`` for the CLI summarizer.
"""

from __future__ import annotations

import atexit
import os

from .export import (METRICS_KEY, chrome_trace, write_jsonl,  # noqa: F401
                     write_metrics, write_trace)
from .metrics import (DEFAULT_BUCKETS, Counter, Histogram,  # noqa: F401
                      MetricsRegistry)
from .tracer import NULL_HANDLE, Span, Tracer, _NullHandle  # noqa: F401

__all__ = [
    "Obs", "NULL_OBS", "resolve_obs", "default_obs",
    "Tracer", "Span", "MetricsRegistry", "Counter", "Histogram",
    "chrome_trace", "write_trace", "write_jsonl", "write_metrics",
    "METRICS_KEY", "DEFAULT_BUCKETS",
]


class Obs:
    """Tracer + metrics bundle — the handle instrumented code passes down.

    ``enabled=False`` turns every operation into a no-op that allocates
    nothing (use the shared :data:`NULL_OBS` instead of constructing one).
    Picklable: locks/thread-locals are dropped and re-created, so a frozen
    :class:`repro.scenarios.harness.HarnessConfig` holding one ships to
    spawn workers (each worker records into its own copy).
    """

    __slots__ = ("enabled", "tracer", "metrics")

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.tracer = Tracer() if enabled else None
        self.metrics = MetricsRegistry() if enabled else None

    # -- pickling --------------------------------------------------------------

    def __getstate__(self) -> dict:
        return {"enabled": self.enabled, "tracer": self.tracer,
                "metrics": self.metrics}

    def __setstate__(self, state: dict) -> None:
        self.enabled = state["enabled"]
        self.tracer = state["tracer"]
        self.metrics = state["metrics"]

    # -- recording -------------------------------------------------------------

    def span(self, name: str, **attrs):
        """Open a nested span context manager (shared no-op when
        disabled)."""
        if not self.enabled:
            return NULL_HANDLE
        return self.tracer.span(name, **attrs)

    def inc(self, name: str, n: int = 1) -> None:
        """Increment counter ``name`` by ``n`` (no-op when disabled)."""
        if self.enabled and n:
            self.metrics.inc(name, n)

    def observe(self, name: str, value: float) -> None:
        """Record ``value`` into histogram ``name`` (no-op when
        disabled)."""
        if self.enabled:
            self.metrics.observe(name, value)

    def current_span_id(self):
        """Innermost open span id on this thread (None when disabled or at
        root) — the parent id worker spans are adopted under."""
        if not self.enabled:
            return None
        return self.tracer.current_span_id()

    def adopt(self, span_dicts, parent_id, metrics_snapshot=None) -> None:
        """Fold a worker's shipped telemetry into this bundle: re-parent
        its spans under ``parent_id`` and merge its metrics snapshot."""
        if not self.enabled:
            return
        if span_dicts:
            self.tracer.adopt(span_dicts, parent_id)
        if metrics_snapshot:
            self.metrics.merge(metrics_snapshot)

    def export_delta(self) -> tuple[list[dict], dict] | None:
        """(span dicts, metrics snapshot) for shipping across a process
        boundary; None when disabled (nothing to ship)."""
        if not self.enabled:
            return None
        return self.tracer.span_dicts(), self.metrics.snapshot()


NULL_OBS = Obs(enabled=False)

_DEFAULT: Obs | None = None


def default_obs() -> Obs:
    """The process-wide default bundle: enabled iff the ``REPRO_TRACE``
    environment variable is set (its value is the trace output path,
    written at interpreter exit); :data:`NULL_OBS` otherwise."""
    global _DEFAULT
    if _DEFAULT is None:
        path = os.environ.get("REPRO_TRACE", "")
        if path:
            _DEFAULT = Obs(enabled=True)
            atexit.register(write_trace, _DEFAULT, path)
        else:
            _DEFAULT = NULL_OBS
    return _DEFAULT


def resolve_obs(obs: "Obs | None") -> Obs:
    """The bundle instrumented code should record into: an explicit ``obs``
    wins, otherwise the ``REPRO_TRACE``-driven process default."""
    return obs if obs is not None else default_obs()
