"""Span tracer: nested monotonic-clock spans, thread- and worker-safe.

A :class:`Span` is one timed region (``perf_counter`` start/end) with a
name, free-form attributes, and a parent link; a :class:`Tracer` maintains
a per-thread span stack so ``with tracer.span("search.tier3")`` nests
correctly under whatever span the calling thread currently has open.

Spawn-worker spans cannot share the parent's tracer, so workers trace into
their own local tracer, export with :meth:`Tracer.span_dicts`, ship the
dicts back with their result payload, and the parent **re-parents** them
under the span that enqueued the work (:meth:`Tracer.adopt`) — worker span
ids are remapped into the parent's id space, worker pids are preserved so
exporters can draw one lane per worker process.
"""

from __future__ import annotations

import itertools
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Mapping, Sequence


@dataclass
class Span:
    """One finished (or open) timed region."""

    name: str
    t0: float                                  # perf_counter seconds
    span_id: int
    parent_id: int | None
    pid: int
    tid: int
    t1: float | None = None                    # None while open
    attrs: dict = field(default_factory=dict)

    @property
    def duration(self) -> float:
        """Span length in seconds (0.0 while still open)."""
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def to_dict(self) -> dict:
        """Plain-JSON form (the worker shipping + JSONL event format)."""
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "pid": self.pid, "tid": self.tid, "attrs": self.attrs}


class _SpanHandle:
    """Context manager returned by :meth:`Tracer.span`; exposes the live
    span so callers can attach attributes discovered mid-region
    (``handle.set(simulated=12)``)."""

    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span

    @property
    def span_id(self) -> int:
        """Id of the underlying span (parent for adopted worker spans)."""
        return self.span.span_id

    def set(self, **attrs) -> None:
        """Attach/overwrite attributes on the live span."""
        self.span.attrs.update(attrs)

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, *exc) -> None:
        self._tracer._close(self.span)


class _NullHandle:
    """Shared no-op stand-in for :class:`_SpanHandle` when tracing is off:
    allocates nothing, records nothing."""

    __slots__ = ()
    span_id = None

    def set(self, **attrs) -> None:
        """No-op."""

    def __enter__(self) -> "_NullHandle":
        return self

    def __exit__(self, *exc) -> None:
        return None


NULL_HANDLE = _NullHandle()


class Tracer:
    """Collects finished spans; one per :class:`repro.obs.Obs` bundle."""

    def __init__(self) -> None:
        self._spans: list[Span] = []
        self._ids = itertools.count(1)
        self._local = threading.local()
        self._lock = threading.Lock()

    # -- pickling (drop lock + thread-local; spans survive) -------------------

    def __getstate__(self) -> dict:
        state = self.__dict__.copy()
        del state["_local"], state["_lock"]
        state["_next_id"] = next(self._ids)
        del state["_ids"]
        return state

    def __setstate__(self, state: dict) -> None:
        nxt = state.pop("_next_id")
        self.__dict__.update(state)
        self._ids = itertools.count(nxt)
        self._local = threading.local()
        self._lock = threading.Lock()

    # -- recording -----------------------------------------------------------

    def _stack(self) -> list[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **attrs) -> _SpanHandle:
        """Open a nested span; use as a context manager."""
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        with self._lock:
            sid = next(self._ids)
        sp = Span(name=name, t0=time.perf_counter(), span_id=sid,
                  parent_id=parent, pid=os.getpid(),
                  tid=threading.get_ident(), attrs=dict(attrs))
        stack.append(sp)
        return _SpanHandle(self, sp)

    def _close(self, span: Span) -> None:
        span.t1 = time.perf_counter()
        stack = self._stack()
        if stack and stack[-1] is span:
            stack.pop()
        else:                                   # mis-nested exit: best effort
            try:
                stack.remove(span)
            except ValueError:
                pass
        with self._lock:
            self._spans.append(span)

    def current_span_id(self) -> int | None:
        """Id of the calling thread's innermost open span (None at root)."""
        stack = self._stack()
        return stack[-1].span_id if stack else None

    # -- reading / shipping ---------------------------------------------------

    @property
    def spans(self) -> list[Span]:
        """All finished spans, in close order."""
        with self._lock:
            return list(self._spans)

    def span_dicts(self) -> list[dict]:
        """Finished spans as plain dicts (the worker shipping format)."""
        return [s.to_dict() for s in self.spans]

    def adopt(self, span_dicts: Sequence[Mapping],
              parent_id: int | None) -> None:
        """Re-parent shipped worker spans under ``parent_id``.

        Worker span ids are remapped into this tracer's id space (two
        workers may both have used id 1); spans that were roots in the
        worker get ``parent_id`` as their parent; worker pids/tids are kept
        so the Perfetto export draws one lane per worker process.
        """
        remap: dict[int, int] = {}
        with self._lock:
            for d in span_dicts:
                remap[d["span_id"]] = next(self._ids)
            for d in span_dicts:
                wparent = d.get("parent_id")
                self._spans.append(Span(
                    name=d["name"], t0=d["t0"], t1=d["t1"],
                    span_id=remap[d["span_id"]],
                    parent_id=remap.get(wparent, parent_id)
                    if wparent is not None else parent_id,
                    pid=d["pid"], tid=d["tid"],
                    attrs=dict(d.get("attrs", {}))))

    def clear(self) -> None:
        """Drop every finished span (open spans are unaffected)."""
        with self._lock:
            self._spans.clear()
