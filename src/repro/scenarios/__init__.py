"""Scenario subsystem: cloud-environment trace generation, record/replay,
and parallel multi-scenario evaluation (the substrate for every adaptability
claim — paper §2.2 dynamic scenarios, §4 parallel simulation).

  * :mod:`repro.scenarios.generators` — seeded stochastic event generators
    (spot preemption, diurnal WAN, congestion bursts, straggler churn,
    cross-region degradation),
  * :mod:`repro.scenarios.trace` — the versioned JSONL trace format with
    ``record``/``load`` round-trip,
  * :mod:`repro.scenarios.catalog` — the named scenario registry,
  * :mod:`repro.scenarios.harness` — replay through the simulator +
    ``ReplanEngine`` with static/adapted/greedy-oracle/DP-oracle policies
    (switch costs modeled via ``repro.core.ReconfigCostModel``),
    process-parallel across scenarios, multi-seed mean/CI sweeps,
  * :mod:`repro.scenarios.tenancy` — seeded multi-tenant job-arrival
    streams + the ``multi_tenant`` scenario family driving the
    planner-service benchmarks (ISSUE 10).
"""

from .catalog import (ScenarioSpec, build, build_trace, get_scenario,
                      list_scenarios, register)
from .generators import (congestion_bursts, diurnal_bandwidth,
                         link_degradation, spot_preemptions, straggler_churn)
from .harness import (FamilySummary, HarnessConfig, PolicyResult,
                      ScenarioHarness, ScenarioReport, run_payloads,
                      run_scenario, summarize_reports)
from .tenancy import (DEFAULT_SHAPES, TENANT_MODELS, JobArrival, JobShape,
                      TenantScenarioSpec, build_tenant, get_tenant_scenario,
                      job_arrivals, list_tenant_scenarios, register_tenant,
                      to_job_specs)
from .trace import TRACE_FORMAT, TRACE_VERSION, Trace, compose_traces

__all__ = [k for k in dir() if not k.startswith("_")]
