"""Scenario replay harness: trace -> (static | adapted | oracle) metrics.

Replays a :class:`Trace` against the analytic simulator through the PR-1
:class:`ReplanEngine` (via :class:`DynamicOrchestrator`) and reports
per-scenario adaptation metrics:

  * ``static``  — the cold t=0 plan, never re-planned (what a planner with
    no dynamic awareness delivers; after a failure it may be infeasible,
    contributing zero throughput for that interval),
  * ``adapted`` — every event flows through ``DynamicOrchestrator.adapt``;
    measured re-plan latency plus the *physically modeled* reconfiguration
    cost (checkpoint/reshard traffic priced on the post-event topology via
    :class:`repro.core.ReconfigCostModel`) is charged against the throughput
    budget on every plan switch.  The engine's keep/switch hysteresis sees
    the remaining horizon, so it only switches when the modeled savings
    amortize the modeled cost,
  * ``oracle``  — the clairvoyant *greedy* baseline: a fresh full search on
    every interval's topology, now charged the same modeled switch cost when
    its per-interval winners differ,
  * ``oracle_dp`` — the true clairvoyant bound: a cross-interval dynamic
    program (:func:`repro.core.plan_sequence_dp`) over the candidate plans
    (per-interval winners + the adapted policy's plans), switch costs
    included.  Never worse than the greedy oracle.

Step-time timelines are derived per inter-event interval; throughput is the
time-weighted number of optimizer steps completed inside the horizon.

:meth:`ScenarioHarness.run_many` evaluates several scenarios at once, either
sequentially or **process-parallel**; :meth:`ScenarioHarness.run_sweep` runs
multi-seed sweeps and aggregates mean / 95% CI per scenario family.
"""

from __future__ import annotations

import itertools
import math
import multiprocessing
import os
import statistics
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, replace
from typing import Sequence

from repro.core import (ClusterTopology, DynamicOrchestrator, ModelDesc,
                        NetworkEvent, ParallelPlan, ReconfigCostModel,
                        ReplanEngine, StrategyCache, plan_sequence_dp,
                        simulate_training_step)
from repro.obs import NULL_OBS, Obs, resolve_obs

from . import catalog
from .trace import Trace


# ---------------------------------------------------------------------------
# Configuration / results
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HarnessConfig:
    """Everything a (possibly remote) scenario replay needs — picklable, so
    :meth:`ScenarioHarness.run_many` can ship it to worker processes."""

    model: ModelDesc
    global_batch: int
    seq: int
    max_candidates: int | None = None
    # switch-cost model: checkpoint/reshard traffic priced on the post-event
    # topology (cf. the Oobleck/ReCycle reconfiguration-cost discussion,
    # paper §2.2.2).  None builds the default model from ``model``.
    # (the legacy replan_threshold knob is gone: with a finite
    # switch-horizon the engine's cost-model hysteresis decides keep/switch)
    reconfig: ReconfigCostModel | None = None
    oracle: bool = True
    # DP-oracle candidate widening: each interval contributes its top-K
    # distinct plans (not just the winner) to plan_sequence_dp's candidate
    # set — the cascade makes the extra per-interval scoring affordable
    dp_top_k: int = 4
    # score the search's final simulation tier in this many worker
    # processes; ONE SearchExecutor is created per replay and reused across
    # every interval (None = serial in-process scoring).  Leave None when
    # the replay itself runs under run_many(parallel=True) — nesting pools
    # oversubscribes the host.
    search_procs: int | None = None
    # telemetry bundle (repro.obs.Obs): the replay records scenario.*
    # spans plus the engine/orchestrator replan counters and latency
    # histograms into it.  None falls back to the REPRO_TRACE-driven
    # process default (a no-op unless the env var is set).  Note that
    # run_many(parallel=True) replays in spawn workers — each worker
    # records into its own pickled copy, which is not shipped back; pass
    # an explicit obs only for in-process replays.
    obs: Obs | None = None


@dataclass(frozen=True)
class PolicyResult:
    """One replan policy's outcome over a scenario."""

    name: str
    avg_step: float                         # time-weighted mean step time, s
    steps: float                            # optimizer steps completed
    timeline: tuple[tuple[float, float], ...]  # (interval start, step time)


@dataclass(frozen=True)
class ScenarioReport:
    """One (scenario, seed) replay: static / adapted / oracle policy
    results plus replan accounting (see :meth:`to_row`)."""

    scenario: str
    seed: int
    n_devices: int
    n_events: int
    horizon: float
    static: PolicyResult
    adapted: PolicyResult
    oracle: PolicyResult | None              # greedy clairvoyant (costed)
    oracle_dp: PolicyResult | None           # DP clairvoyant bound (costed)
    adaptations: int                         # events processed
    replans: int                             # actual plan switches
    actions: tuple[tuple[str, int], ...]     # replan-path histogram
    switch_cost_s: float                     # modeled switch cost charged
    replan_latency_mean_ms: float
    replan_latency_max_ms: float
    wall_s: float

    @property
    def adapted_over_static(self) -> float:
        return _ratio(self.adapted.avg_step, self.static.avg_step)

    @property
    def adapted_over_oracle(self) -> float:
        if self.oracle is None:
            return float("nan")
        return _ratio(self.adapted.avg_step, self.oracle.avg_step)

    @property
    def adapted_over_oracle_dp(self) -> float:
        if self.oracle_dp is None:
            return float("nan")
        return _ratio(self.adapted.avg_step, self.oracle_dp.avg_step)

    @property
    def greedy_over_dp(self) -> float:
        """Greedy-oracle avg step over DP-oracle avg step (>= 1: the DP
        schedule is the tighter clairvoyant bound)."""
        if self.oracle is None or self.oracle_dp is None:
            return float("nan")
        return _ratio(self.oracle.avg_step, self.oracle_dp.avg_step)

    def to_row(self) -> dict:
        row = {
            "scenario": self.scenario, "seed": self.seed,
            "devices": self.n_devices, "events": self.n_events,
            "static_step_s": _round(self.static.avg_step),
            "adapted_step_s": _round(self.adapted.avg_step),
            "oracle_step_s": _round(self.oracle.avg_step)
            if self.oracle else None,
            "oracle_dp_step_s": _round(self.oracle_dp.avg_step)
            if self.oracle_dp else None,
            "adapted_over_static": _round(self.adapted_over_static),
            "adapted_over_oracle": _round(self.adapted_over_oracle),
            "adapted_over_oracle_dp": _round(self.adapted_over_oracle_dp),
            "greedy_over_dp": _round(self.greedy_over_dp),
            "replans": self.replans,
            "switch_cost_s": _round(self.switch_cost_s),
            "actions": "|".join(f"{k}:{v}" for k, v in self.actions),
            "replan_ms_mean": round(self.replan_latency_mean_ms, 1),
            "replan_ms_max": round(self.replan_latency_max_ms, 1),
            "wall_s": round(self.wall_s, 2),
        }
        return row


def _round(x: float, nd: int = 4) -> float:
    return round(x, nd) if math.isfinite(x) else x


def _ratio(a: float, b: float) -> float:
    if not math.isfinite(a) or not math.isfinite(b) or b <= 0:
        if math.isinf(b) and math.isfinite(a):
            return 0.0                      # baseline infeasible, policy fine
        return float("nan") if not (math.isinf(a) and math.isfinite(b)) \
            else math.inf
    return a / b


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------


def _step_time(plan: ParallelPlan, cfg: HarnessConfig,
               topo: ClusterTopology, t: float) -> float:
    try:
        return simulate_training_step(
            plan, cfg.model, topo, global_batch=cfg.global_batch,
            seq=cfg.seq, at_time=t).step_time
    except (ValueError, ZeroDivisionError):
        return math.inf


def _aggregate(name: str, segs: Sequence[tuple[float, float, float]],
               horizon: float) -> PolicyResult:
    """segs: (interval start, step time, overhead charged at interval
    start).  Throughput = sum of d_i/s_i over the overhead-trimmed
    intervals; overhead exceeding its interval carries into the next one
    (a reconfiguration does not get cheaper because the next event came
    quickly).  avg step = horizon / steps."""
    steps = 0.0
    carry = 0.0
    starts = [t for t, _, _ in segs]
    for (t0, s, oh), t1 in zip(segs, starts[1:] + [horizon]):
        oh += carry
        d = t1 - t0
        carry = max(0.0, oh - d)
        usable = max(0.0, d - oh)
        if math.isfinite(s) and s > 0:
            steps += usable / s
    avg = horizon / steps if steps > 0 else math.inf
    return PolicyResult(name=name, avg_step=avg, steps=round(steps, 3),
                        timeline=tuple((t, _round(s)) for t, s, _ in segs))


def _oracle_policies(cfg: HarnessConfig, topo: ClusterTopology,
                     boundaries: list[float], horizon: float,
                     reconfig: ReconfigCostModel,
                     extra_plans: Sequence[ParallelPlan],
                     executor=None) -> tuple[PolicyResult, PolicyResult]:
    """(greedy oracle, DP oracle) — both clairvoyant, both charged the
    modeled switch cost.

    Greedy re-plans from scratch per interval and pays whenever consecutive
    winners differ.  The DP oracle chooses the best plan *sequence* over the
    candidate set — each interval's top-``cfg.dp_top_k`` distinct plans
    (the search cascade makes the runner-ups free to report) plus
    ``extra_plans`` — via :func:`plan_sequence_dp`; when the carry-over of a
    switch cost across an interval boundary makes the DP's carry-free
    objective mis-rank, the greedy sequence (a member of the DP's search
    space) is taken instead — so the DP oracle is never worse than the
    greedy one.
    """
    # oracle searches are baseline machinery, not the policy under test:
    # they get NULL_OBS so the replay's replan.*/cache.* metrics reflect
    # only the adapted engine
    engine = ReplanEngine(cfg.model, global_batch=cfg.global_batch,
                          seq=cfg.seq, cache=StrategyCache(obs=NULL_OBS),
                          max_candidates=cfg.max_candidates,
                          reconfig=reconfig, executor=executor,
                          plan_top_k=max(1, cfg.dp_top_k), obs=NULL_OBS)
    snaps = [topo.snapshot(t) for t in boundaries]
    winners: list[ParallelPlan | None] = []
    runners_up: list[ParallelPlan] = []
    for snap in snaps:
        try:
            res = engine.plan(snap)
            winners.append(res.plan)
            runners_up.extend(p for p, _ in res.top_plans)
        except RuntimeError:
            winners.append(None)

    # candidate set: per-interval top-K plans + the adapted policy's plans
    cands: list[ParallelPlan] = []
    cand_idx: dict = {}
    for p in [*winners, *runners_up, *extra_plans]:
        if p is not None and p.structural_key() not in cand_idx:
            cand_idx[p.structural_key()] = len(cands)
            cands.append(p)
    if not cands:                      # every interval infeasible
        segs = [(t, math.inf, 0.0) for t in boundaries]
        return (_aggregate("oracle", segs, horizon),
                _aggregate("oracle_dp", segs, horizon))

    # step-time grid through the engine's score cache: one batched
    # score_plans per boundary; same-fingerprint boundaries hit the cache
    st = []
    for snap in snaps:
        sims = engine.score_plans(cands, snap)
        st.append([s.step_time if s is not None else math.inf
                   for s in sims])

    def seq_segs(idxs: Sequence[int | None]
                 ) -> list[tuple[float, float, float]]:
        segs = []
        prev: int | None = None
        for i, (t, c) in enumerate(zip(boundaries, idxs)):
            if c is None:
                segs.append((t, math.inf, 0.0))
                continue
            oh = switch_cost(i, prev, c) if i and prev is not None \
                and prev != c else 0.0
            segs.append((t, st[i][c], oh))
            prev = c
        return segs

    durations = [t1 - t0 for t0, t1 in
                 zip(boundaries, boundaries[1:] + [horizon])]
    cost_memo: dict[tuple[int, int, int], float] = {}

    def switch_cost(i: int, q: int, c: int) -> float:
        key = (i, q, c)
        if key not in cost_memo:
            cost_memo[key] = reconfig.cost(cands[q], cands[c],
                                           snaps[i]).total_s
        return cost_memo[key]

    winner_idxs = [cand_idx[p.structural_key()] if p is not None else None
                   for p in winners]
    greedy = _aggregate("oracle", seq_segs(winner_idxs), horizon)
    _, choices = plan_sequence_dp(durations, st, switch_cost)
    dp = _aggregate("oracle_dp", seq_segs(choices), horizon)
    # the DP objective is carry-free while _aggregate carries overhead
    # across short intervals; when that mis-ranks, the greedy sequence (a
    # member of the DP search space) is the DP result — compare on the
    # *unrounded* avg_step so the invariant dp <= greedy holds exactly
    if not (dp.avg_step <= greedy.avg_step):
        dp = replace(greedy, name="oracle_dp")
    return greedy, dp


def run_scenario(cfg: HarnessConfig, scenario: str | Trace, seed: int = 0,
                 topo: ClusterTopology | None = None) -> ScenarioReport:
    """Replay one scenario end-to-end; see the module docstring for the
    four policies.  ``scenario`` is a catalog name (the topology comes from
    the spec) or an explicit :class:`Trace` (then ``topo`` is required)."""
    wall0 = time.perf_counter()
    if isinstance(scenario, Trace):
        if topo is None:
            raise ValueError("an explicit Trace needs an explicit topology")
        trace = scenario
    else:
        built_topo, trace = catalog.build(scenario, seed)
        if topo is None:
            topo = built_topo
    # replay on a private copy: attaching the trace must not clobber a
    # caller-provided topology's own event timeline
    topo = topo.copy()
    topo.events = trace.to_events()
    horizon = trace.horizon
    # t == horizon included: the interval it opens has zero width (no
    # throughput effect) but the event still flows through the orchestrator,
    # matching the Trainer's to_step_events behaviour — and from_events()
    # defaults the horizon to the *last* event's time, which must not vanish
    boundaries = [0.0] + [t for t in trace.event_times() if 0.0 < t <= horizon]

    reconfig = cfg.reconfig if cfg.reconfig is not None \
        else ReconfigCostModel(cfg.model)
    # one process pool for the whole replay: every interval's search (the
    # adapted engine's re-plans AND the oracles' per-boundary full searches)
    # reuses it instead of re-spawning workers per event
    executor = None
    if cfg.search_procs and cfg.search_procs > 1:
        from repro.core import SearchExecutor
        executor = SearchExecutor(n_procs=cfg.search_procs)
    try:
        return _run_scenario_inner(cfg, trace, topo, seed, boundaries,
                                   horizon, reconfig, executor, wall0)
    finally:
        if executor is not None:
            executor.close()


_ACTION_PREFIX = "replan.action."


def _action_delta(obs: Obs, before: dict) -> dict[str, int]:
    """Per-action counts this replay added to the registry: the delta of
    the ``replan.action.*`` counters against the entry snapshot (a shared
    registry may carry counts from earlier replays)."""
    after = obs.metrics.counters_with_prefix(_ACTION_PREFIX)
    return {k[len(_ACTION_PREFIX):]: after[k] - before.get(k, 0)
            for k in after if after[k] - before.get(k, 0) > 0}


def _run_scenario_inner(cfg: HarnessConfig, trace: Trace,
                        topo: ClusterTopology, seed: int,
                        boundaries: list[float], horizon: float,
                        reconfig: ReconfigCostModel, executor,
                        wall0: float) -> ScenarioReport:
    obs = resolve_obs(cfg.obs)
    actions0 = obs.metrics.counters_with_prefix(_ACTION_PREFIX) \
        if obs.enabled else {}
    replay_span = obs.span("scenario.replay", scenario=trace.name, seed=seed,
                           n_events=len(trace))
    replay_span.__enter__()
    engine = ReplanEngine(cfg.model, global_batch=cfg.global_batch,
                          seq=cfg.seq, cache=StrategyCache(obs=obs),
                          max_candidates=cfg.max_candidates,
                          reconfig=reconfig,
                          switch_horizon_s=horizon, executor=executor,
                          obs=obs)
    orch = DynamicOrchestrator(model=cfg.model, global_batch=cfg.global_batch,
                               seq=cfg.seq, engine=engine, obs=obs)
    cold = engine.plan(topo.snapshot(0.0))
    plan0 = cold.plan

    # -- static: the t=0 plan, never revisited ------------------------------
    with obs.span("scenario.static"):
        static_segs = [(t, _step_time(plan0, cfg, topo, t), 0.0)
                       for t in boundaries]

    # -- adapted: every event through the orchestrator ----------------------
    plan = plan0
    adapted_segs: list[tuple[float, float, float]] = \
        [(0.0, _step_time(plan0, cfg, topo, 0.0), 0.0)]
    adapted_plans: list[ParallelPlan] = [plan0]
    latencies: list[float] = []
    replans = 0
    switch_cost_total = 0.0
    grouped = [(t, list(evs)) for t, evs in
               itertools.groupby(trace.events, key=lambda e: e.time)
               if 0.0 < t <= horizon]
    for t, evs in grouped:
        interval = obs.span("scenario.interval", t=t, n_events=len(evs))
        interval.__enter__()
        overhead = 0.0
        # the hysteresis amortizes switch cost over what is actually left
        engine.switch_horizon_s = max(horizon - t, 0.0)
        for ev in evs:
            t0 = time.perf_counter()
            new_plan = orch.adapt(plan, topo, ev)
            lat = time.perf_counter() - t0
            latencies.append(lat)
            if new_plan.structural_key() != plan.structural_key():
                replans += 1
                # the engine priced this exact switch inside its hysteresis
                # (same incumbent, same snapshot); a structural switch costs
                # at least the base term, so 0.0 means the engine's cold
                # fallback skipped pricing — compute it here then
                cost = orch.history[-1].switch_cost if orch.history else 0.0
                if cost <= 0.0:
                    cost = reconfig.cost(plan, new_plan,
                                         topo.snapshot(t)).total_s
                switch_cost_total += cost
                overhead += lat + cost
                adapted_plans.append(new_plan)
            else:
                overhead += lat
            plan = new_plan
        adapted_segs.append((t, _step_time(plan, cfg, topo, t), overhead))
        interval.set(switched=plan is not plan0)
        interval.__exit__(None, None, None)

    # -- oracles: clairvoyant greedy + cross-interval DP bound --------------
    oracle_res = oracle_dp_res = None
    if cfg.oracle:
        with obs.span("scenario.oracle"):
            oracle_res, oracle_dp_res = _oracle_policies(
                cfg, topo, boundaries, horizon, reconfig, adapted_plans,
                executor=executor)

    # replan-path histogram: the metrics registry is the source of truth
    # (every action funnels through DynamicOrchestrator._record); the
    # history fallback serves untraced replays only
    if obs.enabled:
        actions = _action_delta(obs, actions0)
    else:
        actions = {}
        for rec in orch.history:
            actions[rec.action] = actions.get(rec.action, 0) + 1
    replay_span.set(replans=replans, adaptations=len(orch.history))
    replay_span.__exit__(None, None, None)
    return ScenarioReport(
        scenario=trace.name, seed=trace.seed if trace.seed is not None
        else seed,
        n_devices=len(topo.devices), n_events=len(trace),
        horizon=horizon,
        static=_aggregate("static", static_segs, horizon),
        adapted=_aggregate("adapted", adapted_segs, horizon),
        oracle=oracle_res, oracle_dp=oracle_dp_res,
        adaptations=len(orch.history), replans=replans,
        actions=tuple(sorted(actions.items())),
        switch_cost_s=switch_cost_total,
        replan_latency_mean_ms=1e3 * (sum(latencies) / len(latencies))
        if latencies else 0.0,
        replan_latency_max_ms=1e3 * max(latencies, default=0.0),
        wall_s=time.perf_counter() - wall0)


def _worker(payload: tuple[HarnessConfig, str, int]) -> ScenarioReport:
    cfg, name, seed = payload
    return run_scenario(cfg, name, seed)


def run_payloads(payloads: Sequence[tuple[HarnessConfig, str, int]], *,
                 parallel: bool = False,
                 max_workers: int | None = None) -> list[ScenarioReport]:
    """Replay explicit (config, scenario, seed) payloads, sequentially or
    process-parallel (results keep input order).  Payloads may mix harness
    configurations — e.g. the bandwidth-crossover families replay at a
    comm-heavy scale while the rest use the default one."""
    if not parallel or len(payloads) <= 1:
        return [_worker(p) for p in payloads]
    workers = max_workers or min(len(payloads), os.cpu_count() or 1)
    # spawn, not fork: the caller may be multi-threaded (planner thread
    # pools, JAX) and fork()ing a threaded parent risks deadlocked
    # children; workers only import dependency-free repro.core, so a
    # fresh interpreter starts in well under a second
    ctx = multiprocessing.get_context("spawn")
    with ProcessPoolExecutor(max_workers=workers, mp_context=ctx) as ex:
        return list(ex.map(_worker, payloads))


# ---------------------------------------------------------------------------
# Multi-seed aggregation
# ---------------------------------------------------------------------------


# two-sided 95% Student-t quantiles by degrees of freedom; the normal 1.96
# would understate the interval ~6.5x at the n=2 sweeps the bench runs
_T95 = {1: 12.706, 2: 4.303, 3: 3.182, 4: 2.776, 5: 2.571, 6: 2.447,
        7: 2.365, 8: 2.306, 9: 2.262, 10: 2.228, 15: 2.131, 20: 2.086,
        30: 2.042}


def _t95(df: int) -> float:
    if df <= 0:
        return float("nan")
    usable = [d for d in _T95 if d <= df]
    return _T95[max(usable)] if usable else 1.96


def _mean_ci(xs: Sequence[float]) -> tuple[float, float]:
    """(mean, Student-t 95% CI half-width) over the finite values; NaNs
    if none."""
    vals = [x for x in xs if math.isfinite(x)]
    if not vals:
        return float("nan"), float("nan")
    mean = sum(vals) / len(vals)
    if len(vals) < 2:
        return mean, 0.0
    return mean, _t95(len(vals) - 1) * statistics.stdev(vals) \
        / math.sqrt(len(vals))


@dataclass(frozen=True)
class FamilySummary:
    """Mean / 95% CI across the seeds of one scenario family."""

    scenario: str
    n: int
    seeds: tuple[int, ...]
    adapted_over_static: tuple[float, float]       # (mean, ci95)
    adapted_over_oracle_dp: tuple[float, float]
    greedy_over_dp: tuple[float, float]
    replans_mean: float
    switch_cost_s_mean: float

    def to_row(self) -> dict:
        aos, aod, god = (self.adapted_over_static,
                         self.adapted_over_oracle_dp, self.greedy_over_dp)
        return {
            "scenario": self.scenario,
            "seeds": "|".join(str(s) for s in self.seeds),
            "n": self.n,
            "adapted_over_static_mean": _round(aos[0]),
            "adapted_over_static_ci95": _round(aos[1]),
            "adapted_over_oracle_dp_mean": _round(aod[0]),
            "adapted_over_oracle_dp_ci95": _round(aod[1]),
            "greedy_over_dp_mean": _round(god[0]),
            "replans_mean": _round(self.replans_mean, 2),
            "switch_cost_s_mean": _round(self.switch_cost_s_mean, 2),
        }


def summarize_reports(reports: Sequence[ScenarioReport]
                      ) -> list[FamilySummary]:
    """Aggregate per-(family, seed) reports into per-family mean/CI rows,
    in first-appearance order."""
    by_family: dict[str, list[ScenarioReport]] = {}
    for r in reports:
        by_family.setdefault(r.scenario, []).append(r)
    out = []
    for name, reps in by_family.items():
        out.append(FamilySummary(
            scenario=name, n=len(reps),
            seeds=tuple(r.seed for r in reps),
            adapted_over_static=_mean_ci(
                [r.adapted_over_static for r in reps]),
            adapted_over_oracle_dp=_mean_ci(
                [r.adapted_over_oracle_dp for r in reps]),
            greedy_over_dp=_mean_ci([r.greedy_over_dp for r in reps]),
            replans_mean=sum(r.replans for r in reps) / len(reps),
            switch_cost_s_mean=sum(r.switch_cost_s for r in reps)
            / len(reps)))
    return out


# ---------------------------------------------------------------------------
# Multi-scenario evaluation
# ---------------------------------------------------------------------------


class ScenarioHarness:
    """Replays catalog scenarios and evaluates adaptation quality.

    >>> h = ScenarioHarness(model, global_batch=64, seq=2048)
    >>> rep = h.run("cloud_spot", seed=1)
    >>> reps = h.run_many([("cloud_spot", 0), ("diurnal_wan", 0)],
    ...                   parallel=True)
    >>> reps, fams = h.run_sweep(["cloud_spot"], seeds=(0, 1, 2))
    """

    def __init__(self, model: ModelDesc, *, global_batch: int, seq: int,
                 max_candidates: int | None = None,
                 reconfig: ReconfigCostModel | None = None,
                 oracle: bool = True, obs: Obs | None = None):
        self.cfg = HarnessConfig(
            model=model, global_batch=global_batch, seq=seq,
            max_candidates=max_candidates,
            reconfig=reconfig, oracle=oracle, obs=obs)

    def run(self, scenario: str | Trace, seed: int = 0,
            topo: ClusterTopology | None = None) -> ScenarioReport:
        return run_scenario(self.cfg, scenario, seed, topo=topo)

    def run_many(self, items: Sequence[tuple[str, int] | str], *,
                 parallel: bool = False,
                 max_workers: int | None = None) -> list[ScenarioReport]:
        """Replay several catalog scenarios; ``items`` are names or
        (name, seed) pairs.  With ``parallel=True`` scenarios run in worker
        processes (results keep input order)."""
        norm: list[tuple[str, int]] = [
            it if isinstance(it, tuple) else (it, 0) for it in items]
        payloads = [(self.cfg, name, seed) for name, seed in norm]
        return run_payloads(payloads, parallel=parallel,
                            max_workers=max_workers)

    def run_sweep(self, families: Sequence[str] | None = None, *,
                  seeds: Sequence[int] = (0, 1, 2),
                  parallel: bool = False, max_workers: int | None = None
                  ) -> tuple[list[ScenarioReport], list[FamilySummary]]:
        """Multi-seed sweep: replay every (family, seed) pair and aggregate
        mean / 95% CI per family."""
        names = list(families) if families is not None \
            else catalog.list_scenarios()
        items = [(n, s) for n in names for s in seeds]
        reports = self.run_many(items, parallel=parallel,
                                max_workers=max_workers)
        return reports, summarize_reports(reports)
