"""Scenario replay harness: trace -> (static | adapted | oracle) metrics.

Replays a :class:`Trace` against the analytic simulator through the PR-1
:class:`ReplanEngine` (via :class:`DynamicOrchestrator`) and reports
per-scenario adaptation metrics:

  * ``static``  — the cold t=0 plan, never re-planned (what a planner with
    no dynamic awareness delivers; after a failure it may be infeasible,
    contributing zero throughput for that interval),
  * ``adapted`` — every event flows through ``DynamicOrchestrator.adapt``;
    measured re-plan latency plus a fixed reconfiguration overhead is
    charged against the throughput budget on every plan switch,
  * ``oracle``  — a clairvoyant baseline: a fresh full search on every
    interval's topology with zero re-plan cost (the adaptability headroom).

Step-time timelines are derived per inter-event interval; throughput is the
time-weighted number of optimizer steps completed inside the horizon.

:meth:`ScenarioHarness.run_many` evaluates several scenarios at once, either
sequentially or **process-parallel** — the paper accelerates its search
"through parallel execution within the simulator"; this applies the same
strategy one level up, across scenarios (the planner's per-candidate
``ThreadPoolExecutor`` stays GIL-bound, so scenario-level parallelism needs
processes).  ``repro.core`` is dependency-free, so worker start-up is cheap.
"""

from __future__ import annotations

import itertools
import math
import multiprocessing
import os
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass, field
from typing import Sequence

from repro.core import (ClusterTopology, DynamicOrchestrator, ModelDesc,
                        NetworkEvent, ParallelPlan, ReplanEngine,
                        StrategyCache, simulate_training_step)

from . import catalog
from .trace import Trace


# ---------------------------------------------------------------------------
# Configuration / results
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HarnessConfig:
    """Everything a (possibly remote) scenario replay needs — picklable, so
    :meth:`ScenarioHarness.run_many` can ship it to worker processes."""

    model: ModelDesc
    global_batch: int
    seq: int
    max_candidates: int | None = None
    n_workers: int | None = None
    # seconds charged per *plan switch*: checkpoint reload + reshard
    # (cf. the Oobleck/ReCycle reconfiguration-cost discussion, paper §2.2.2)
    reconfig_overhead: float = 2.0
    oracle: bool = True
    replan_threshold: float = 1.10


@dataclass(frozen=True)
class PolicyResult:
    """One replan policy's outcome over a scenario."""

    name: str
    avg_step: float                         # time-weighted mean step time, s
    steps: float                            # optimizer steps completed
    timeline: tuple[tuple[float, float], ...]  # (interval start, step time)


@dataclass(frozen=True)
class ScenarioReport:
    scenario: str
    seed: int
    n_devices: int
    n_events: int
    horizon: float
    static: PolicyResult
    adapted: PolicyResult
    oracle: PolicyResult | None
    adaptations: int                         # events processed
    replans: int                             # actual plan switches
    actions: tuple[tuple[str, int], ...]     # replan-path histogram
    replan_latency_mean_ms: float
    replan_latency_max_ms: float
    wall_s: float

    @property
    def adapted_over_static(self) -> float:
        return _ratio(self.adapted.avg_step, self.static.avg_step)

    @property
    def adapted_over_oracle(self) -> float:
        if self.oracle is None:
            return float("nan")
        return _ratio(self.adapted.avg_step, self.oracle.avg_step)

    def to_row(self) -> dict:
        row = {
            "scenario": self.scenario, "seed": self.seed,
            "devices": self.n_devices, "events": self.n_events,
            "static_step_s": _round(self.static.avg_step),
            "adapted_step_s": _round(self.adapted.avg_step),
            "oracle_step_s": _round(self.oracle.avg_step)
            if self.oracle else None,
            "adapted_over_static": _round(self.adapted_over_static),
            "adapted_over_oracle": _round(self.adapted_over_oracle),
            "replans": self.replans,
            "actions": "|".join(f"{k}:{v}" for k, v in self.actions),
            "replan_ms_mean": round(self.replan_latency_mean_ms, 1),
            "replan_ms_max": round(self.replan_latency_max_ms, 1),
            "wall_s": round(self.wall_s, 2),
        }
        return row


def _round(x: float, nd: int = 4) -> float:
    return round(x, nd) if math.isfinite(x) else x


def _ratio(a: float, b: float) -> float:
    if not math.isfinite(a) or not math.isfinite(b) or b <= 0:
        if math.isinf(b) and math.isfinite(a):
            return 0.0                      # baseline infeasible, policy fine
        return float("nan") if not (math.isinf(a) and math.isfinite(b)) \
            else math.inf
    return a / b


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------


def _step_time(plan: ParallelPlan, cfg: HarnessConfig,
               topo: ClusterTopology, t: float) -> float:
    try:
        return simulate_training_step(
            plan, cfg.model, topo, global_batch=cfg.global_batch,
            seq=cfg.seq, at_time=t).step_time
    except (ValueError, ZeroDivisionError):
        return math.inf


def _aggregate(name: str, segs: Sequence[tuple[float, float, float]],
               horizon: float) -> PolicyResult:
    """segs: (interval start, step time, overhead charged at interval
    start).  Throughput = sum of d_i/s_i over the overhead-trimmed
    intervals; overhead exceeding its interval carries into the next one
    (a reconfiguration does not get cheaper because the next event came
    quickly).  avg step = horizon / steps."""
    steps = 0.0
    carry = 0.0
    starts = [t for t, _, _ in segs]
    for (t0, s, oh), t1 in zip(segs, starts[1:] + [horizon]):
        oh += carry
        d = t1 - t0
        carry = max(0.0, oh - d)
        usable = max(0.0, d - oh)
        if math.isfinite(s) and s > 0:
            steps += usable / s
    avg = horizon / steps if steps > 0 else math.inf
    return PolicyResult(name=name, avg_step=avg, steps=round(steps, 3),
                        timeline=tuple((t, _round(s)) for t, s, _ in segs))


def run_scenario(cfg: HarnessConfig, scenario: str | Trace, seed: int = 0,
                 topo: ClusterTopology | None = None) -> ScenarioReport:
    """Replay one scenario end-to-end; see the module docstring for the
    three policies.  ``scenario`` is a catalog name (the topology comes from
    the spec) or an explicit :class:`Trace` (then ``topo`` is required)."""
    wall0 = time.perf_counter()
    if isinstance(scenario, Trace):
        if topo is None:
            raise ValueError("an explicit Trace needs an explicit topology")
        trace = scenario
    else:
        built_topo, trace = catalog.build(scenario, seed)
        if topo is None:
            topo = built_topo
    # replay on a private copy: attaching the trace must not clobber a
    # caller-provided topology's own event timeline
    topo = topo.copy()
    topo.events = trace.to_events()
    horizon = trace.horizon
    # t == horizon included: the interval it opens has zero width (no
    # throughput effect) but the event still flows through the orchestrator,
    # matching the Trainer's to_step_events behaviour — and from_events()
    # defaults the horizon to the *last* event's time, which must not vanish
    boundaries = [0.0] + [t for t in trace.event_times() if 0.0 < t <= horizon]

    engine = ReplanEngine(cfg.model, global_batch=cfg.global_batch,
                          seq=cfg.seq, cache=StrategyCache(),
                          max_candidates=cfg.max_candidates,
                          n_workers=cfg.n_workers)
    orch = DynamicOrchestrator(model=cfg.model, global_batch=cfg.global_batch,
                               seq=cfg.seq, engine=engine,
                               replan_threshold=cfg.replan_threshold)
    cold = engine.plan(topo.snapshot(0.0))
    plan0 = cold.plan

    # -- static: the t=0 plan, never revisited ------------------------------
    static_segs = [(t, _step_time(plan0, cfg, topo, t), 0.0)
                   for t in boundaries]

    # -- adapted: every event through the orchestrator ----------------------
    plan = plan0
    adapted_segs: list[tuple[float, float, float]] = \
        [(0.0, _step_time(plan0, cfg, topo, 0.0), 0.0)]
    latencies: list[float] = []
    replans = 0
    grouped = [(t, list(evs)) for t, evs in
               itertools.groupby(trace.events, key=lambda e: e.time)
               if 0.0 < t <= horizon]
    for t, evs in grouped:
        overhead = 0.0
        for ev in evs:
            t0 = time.perf_counter()
            new_plan = orch.adapt(plan, topo, ev)
            lat = time.perf_counter() - t0
            latencies.append(lat)
            if new_plan.structural_key() != plan.structural_key():
                replans += 1
                overhead += lat + cfg.reconfig_overhead
            else:
                overhead += lat
            plan = new_plan
        adapted_segs.append((t, _step_time(plan, cfg, topo, t), overhead))

    # -- oracle: clairvoyant full re-plan per interval, zero cost -----------
    oracle_res = None
    if cfg.oracle:
        oracle_engine = ReplanEngine(cfg.model, global_batch=cfg.global_batch,
                                     seq=cfg.seq, cache=StrategyCache(),
                                     max_candidates=cfg.max_candidates,
                                     n_workers=cfg.n_workers)
        oracle_segs = []
        for t in boundaries:
            try:
                r = oracle_engine.plan(topo.snapshot(t))
                oracle_segs.append((t, r.predicted.step_time, 0.0))
            except RuntimeError:
                oracle_segs.append((t, math.inf, 0.0))
        oracle_res = _aggregate("oracle", oracle_segs, horizon)

    actions: dict[str, int] = {}
    for rec in orch.history:
        actions[rec.action] = actions.get(rec.action, 0) + 1
    return ScenarioReport(
        scenario=trace.name, seed=trace.seed if trace.seed is not None
        else seed,
        n_devices=len(topo.devices), n_events=len(trace),
        horizon=horizon,
        static=_aggregate("static", static_segs, horizon),
        adapted=_aggregate("adapted", adapted_segs, horizon),
        oracle=oracle_res,
        adaptations=len(orch.history), replans=replans,
        actions=tuple(sorted(actions.items())),
        replan_latency_mean_ms=1e3 * (sum(latencies) / len(latencies))
        if latencies else 0.0,
        replan_latency_max_ms=1e3 * max(latencies, default=0.0),
        wall_s=time.perf_counter() - wall0)


def _worker(payload: tuple[HarnessConfig, str, int]) -> ScenarioReport:
    cfg, name, seed = payload
    return run_scenario(cfg, name, seed)


# ---------------------------------------------------------------------------
# Multi-scenario evaluation
# ---------------------------------------------------------------------------


class ScenarioHarness:
    """Replays catalog scenarios and evaluates adaptation quality.

    >>> h = ScenarioHarness(model, global_batch=64, seq=2048)
    >>> rep = h.run("cloud_spot", seed=1)
    >>> reps = h.run_many([("cloud_spot", 0), ("diurnal_wan", 0)],
    ...                   parallel=True)
    """

    def __init__(self, model: ModelDesc, *, global_batch: int, seq: int,
                 max_candidates: int | None = None,
                 n_workers: int | None = None,
                 reconfig_overhead: float = 2.0, oracle: bool = True,
                 replan_threshold: float = 1.10):
        self.cfg = HarnessConfig(
            model=model, global_batch=global_batch, seq=seq,
            max_candidates=max_candidates, n_workers=n_workers,
            reconfig_overhead=reconfig_overhead, oracle=oracle,
            replan_threshold=replan_threshold)

    def run(self, scenario: str | Trace, seed: int = 0,
            topo: ClusterTopology | None = None) -> ScenarioReport:
        return run_scenario(self.cfg, scenario, seed, topo=topo)

    def run_many(self, items: Sequence[tuple[str, int] | str], *,
                 parallel: bool = False,
                 max_workers: int | None = None) -> list[ScenarioReport]:
        """Replay several catalog scenarios; ``items`` are names or
        (name, seed) pairs.  With ``parallel=True`` scenarios run in worker
        processes (results keep input order)."""
        norm: list[tuple[str, int]] = [
            it if isinstance(it, tuple) else (it, 0) for it in items]
        payloads = [(self.cfg, name, seed) for name, seed in norm]
        if not parallel or len(payloads) <= 1:
            return [_worker(p) for p in payloads]
        workers = max_workers or min(len(payloads), os.cpu_count() or 1)
        # spawn, not fork: the caller may be multi-threaded (planner thread
        # pools, JAX) and fork()ing a threaded parent risks deadlocked
        # children; workers only import dependency-free repro.core, so a
        # fresh interpreter starts in well under a second
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=workers, mp_context=ctx) as ex:
            return list(ex.map(_worker, payloads))
