"""Multi-tenant job-arrival generation + the ``multi_tenant`` catalog
family (ISSUE 10): the workload side of planner-as-a-service.

The network-event generators in :mod:`repro.scenarios.generators` model
what the *cluster* does; this module models what the *tenants* do — a
seeded Poisson stream of :class:`JobArrival`\\ s drawn from a small pool of
job shapes, with a tunable twin probability (a new arrival clones an
earlier arrival's shape) so isomorphic-bucketing and cross-job cache reuse
have something real to bite on.  A :class:`TenantScenarioSpec` bundles a
topology factory, an arrival generator and a network-event generator into
one named, seeded, reproducible multi-tenant scenario — the substrate of
``benchmarks/bench_service.py``'s arrival storm.

Identical seeds produce identical arrival lists and identical event
traces (the same determinism contract as :mod:`repro.scenarios.catalog`).
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, Sequence

from repro.core import (ClusterTopology, ModelDesc, NetworkEvent,
                        hetero_cluster, homogeneous_cluster)

from . import generators as gen
from .generators import _poisson_times, _r
from .trace import Trace

# Small tenant model pool: planner-friendly sizes so a 32-job storm's cold
# searches stay in benchmark budget while still spanning distinct shapes.
TENANT_MODELS: dict[str, ModelDesc] = {
    "tenant_tiny": ModelDesc("tenant_tiny", n_layers=8, d_model=512,
                             n_heads=8, n_kv_heads=8, d_ff=2048, vocab=32000),
    "tenant_small": ModelDesc("tenant_small", n_layers=12, d_model=1024,
                              n_heads=16, n_kv_heads=16, d_ff=4096,
                              vocab=32000),
    "tenant_wide": ModelDesc("tenant_wide", n_layers=8, d_model=2048,
                             n_heads=16, n_kv_heads=16, d_ff=8192,
                             vocab=32000),
}


@dataclass(frozen=True)
class JobShape:
    """One drawable job template: model + batch geometry + slice size."""

    model: ModelDesc
    global_batch: int
    seq: int
    n_devices: int


@dataclass(frozen=True)
class JobArrival:
    """One tenant job arriving at ``time`` (all times in seconds on the
    scenario clock).  ``duration`` is how long the job holds its devices
    once admitted; the service frees them afterwards."""

    time: float
    name: str
    model: ModelDesc
    global_batch: int
    seq: int
    n_devices: int
    priority: int
    duration: float


DEFAULT_SHAPES: tuple[JobShape, ...] = (
    JobShape(TENANT_MODELS["tenant_tiny"], global_batch=32, seq=1024,
             n_devices=4),
    JobShape(TENANT_MODELS["tenant_small"], global_batch=64, seq=1024,
             n_devices=4),
    JobShape(TENANT_MODELS["tenant_wide"], global_batch=64, seq=1024,
             n_devices=8),
)


def job_arrivals(rng: random.Random, horizon: float, *, rate: float,
                 shapes: Sequence[JobShape] = DEFAULT_SHAPES,
                 twin_prob: float = 0.5,
                 priorities: Sequence[int] = (0, 1, 2),
                 duration_mean: float = 240.0,
                 max_jobs: int | None = None,
                 name_prefix: str = "job") -> list[JobArrival]:
    """Seeded Poisson stream of tenant jobs.

    With probability ``twin_prob`` a new arrival clones the *shape* of a
    uniformly-drawn earlier arrival (its own name/priority/duration) —
    the isomorphic twins the service's bucketing and cross-job cache
    dedup; otherwise the shape is drawn uniformly from ``shapes``.
    ``max_jobs`` caps the stream length (the arrival storm benches pin an
    exact job count).  Deterministic per ``rng`` seed.
    """
    out: list[JobArrival] = []
    for i, t in enumerate(_poisson_times(rng, rate, horizon)):
        if max_jobs is not None and len(out) >= max_jobs:
            break
        if out and rng.random() < twin_prob:
            proto = out[rng.randrange(len(out))]
            model, batch = proto.model, proto.global_batch
            seq, n_dev = proto.seq, proto.n_devices
        else:
            shape = shapes[rng.randrange(len(shapes))]
            model, batch = shape.model, shape.global_batch
            seq, n_dev = shape.seq, shape.n_devices
        out.append(JobArrival(
            time=_r(t), name=f"{name_prefix}-{i:03d}", model=model,
            global_batch=batch, seq=seq, n_devices=n_dev,
            priority=priorities[rng.randrange(len(priorities))],
            duration=_r(rng.expovariate(1.0 / duration_mean))))
    return out


def to_job_specs(arrivals: Sequence[JobArrival], *,
                 gpus_per_node: int = 4) -> list:
    """Convert arrivals into the service's
    :class:`repro.service.jobs.JobSpec` list (imported lazily — the
    scenarios layer stays importable without the service package)."""
    from repro.service.jobs import JobSpec
    return [JobSpec(name=a.name, model=a.model, global_batch=a.global_batch,
                    seq=a.seq, n_devices=a.n_devices, priority=a.priority,
                    arrival_s=a.time, duration_s=a.duration,
                    gpus_per_node=gpus_per_node)
            for a in arrivals]


# ---------------------------------------------------------------------------
# Named multi-tenant scenario registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TenantScenarioSpec:
    """One named multi-tenant scenario: topology + seeded arrival stream +
    seeded network-event timeline (the service benchmark's input triple)."""

    name: str
    description: str
    make_topology: Callable[[], ClusterTopology]
    make_arrivals: Callable[[random.Random, float], list[JobArrival]]
    make_events: Callable[[random.Random, float], list[NetworkEvent]]
    horizon: float = 600.0
    gpus_per_node: int = 4
    tags: tuple[str, ...] = ()


_TENANT_REGISTRY: dict[str, TenantScenarioSpec] = {}


def register_tenant(spec: TenantScenarioSpec) -> TenantScenarioSpec:
    """Register a multi-tenant scenario (unique name)."""
    if spec.name in _TENANT_REGISTRY:
        raise ValueError(f"tenant scenario {spec.name!r} already registered")
    _TENANT_REGISTRY[spec.name] = spec
    return spec


def get_tenant_scenario(name: str) -> TenantScenarioSpec:
    """Lookup by name; ``KeyError`` lists what is available."""
    try:
        return _TENANT_REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown tenant scenario {name!r}; available: "
                       f"{sorted(_TENANT_REGISTRY)}") from None


def list_tenant_scenarios() -> list[str]:
    """Sorted registered multi-tenant scenario names."""
    return sorted(_TENANT_REGISTRY)


def build_tenant(name: str, seed: int = 0
                 ) -> tuple[ClusterTopology, list[JobArrival], Trace]:
    """(topology, arrivals, network-event trace) for ``(name, seed)``.

    Arrivals are generated first, events second, from one seeded rng —
    the order is part of the determinism contract (identical seeds give
    byte-identical triples)."""
    spec = get_tenant_scenario(name)
    rng = random.Random(seed)
    arrivals = spec.make_arrivals(rng, spec.horizon)
    events = spec.make_events(rng, spec.horizon)
    trace = Trace(name=spec.name, horizon=spec.horizon,
                  events=tuple(events), seed=seed,
                  meta=(("family", spec.name), ("jobs", len(arrivals))))
    return spec.make_topology(), arrivals, trace


register_tenant(TenantScenarioSpec(
    name="multi_tenant_small",
    description="8 tenant jobs on a 16-GPU cluster, light congestion "
                "(quick smoke config)",
    make_topology=lambda: homogeneous_cluster(16, "V100", gpus_per_node=4),
    make_arrivals=lambda rng, horizon: job_arrivals(
        rng, horizon, rate=24.0 / horizon, twin_prob=0.5, max_jobs=8,
        duration_mean=horizon / 2),
    make_events=lambda rng, horizon: gen.congestion_bursts(
        rng, horizon, burst_rate=4.0 / horizon, selector="ib",
        depth_range=(0.3, 0.6), duration_range=(horizon / 20, horizon / 8),
        decay_steps=2),
    tags=("multi_tenant", "S1"),
))

register_tenant(TenantScenarioSpec(
    name="multi_tenant_storm",
    description="32-job arrival storm with heavy twin reuse on a 64-GPU "
                "cluster + multi-tenant congestion (the bench_service "
                "acceptance config)",
    make_topology=lambda: homogeneous_cluster(64, "V100", gpus_per_node=4),
    make_arrivals=lambda rng, horizon: job_arrivals(
        rng, horizon, rate=96.0 / horizon, twin_prob=0.65, max_jobs=32,
        duration_mean=horizon / 3),
    # congestion on the shared ib fabric + straggler churn across the fleet
    # (device-level events reach single-node jobs the ib selector cannot);
    # sequential generation from one rng keeps the composition seeded
    make_events=lambda rng, horizon: sorted(
        gen.congestion_bursts(
            rng, horizon, burst_rate=6.0 / horizon, selector="ib",
            depth_range=(0.3, 0.6),
            duration_range=(horizon / 20, horizon / 8), decay_steps=2)
        + gen.straggler_churn(
            rng, list(range(64)), horizon, rate=12.0 / horizon,
            slow_range=(0.4, 0.7), recover_mean=horizon / 8),
        key=lambda e: e.time),
    tags=("multi_tenant", "S1", "S2", "storm"),
))

register_tenant(TenantScenarioSpec(
    name="multi_tenant_churn",
    description="16 tenant jobs under straggler churn on a mixed fleet "
                "(device events exercise per-job replan routing)",
    make_topology=lambda: hetero_cluster({"RTX4090D": 16, "V100": 16},
                                         gpus_per_node=4),
    make_arrivals=lambda rng, horizon: job_arrivals(
        rng, horizon, rate=48.0 / horizon, twin_prob=0.5, max_jobs=16,
        duration_mean=horizon / 2),
    make_events=lambda rng, horizon: gen.straggler_churn(
        rng, list(range(32)), horizon, rate=8.0 / horizon,
        slow_range=(0.4, 0.7), recover_mean=horizon / 8),
    tags=("multi_tenant", "S2"),
))
