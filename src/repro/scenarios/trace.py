"""Versioned JSONL trace format for cloud-scenario event timelines.

One trace = one header line + one line per :class:`NetworkEvent`, so
generated and hand-written timelines share a single on-disk representation
that diffs cleanly, streams line-by-line, and round-trips byte-identically
(``loads(dumps(t)).dumps() == t.dumps()`` — the determinism gate in
``tests/test_scenarios.py`` relies on this).

Schema (version 1)::

    {"format": "repro-scenario-trace", "version": 1, "name": ...,
     "seed": ..., "horizon": ..., "meta": {...}}
    {"t": 12.5, "kind": "bandwidth", "device_id": null, "factor": 0.4,
     "selector": "ib", "mode": "scale"}
    ...

All keys are always emitted and serialized with ``sort_keys``, so identical
event timelines produce identical bytes.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Iterable, Mapping, Sequence

from repro.core import NetworkEvent

TRACE_FORMAT = "repro-scenario-trace"
TRACE_VERSION = 1


def _event_to_obj(ev: NetworkEvent) -> dict[str, Any]:
    return {"t": ev.time, "kind": ev.kind, "device_id": ev.device_id,
            "factor": ev.factor, "selector": ev.selector, "mode": ev.mode}


def _event_from_obj(obj: Mapping[str, Any]) -> NetworkEvent:
    return NetworkEvent(time=float(obj["t"]), kind=str(obj["kind"]),
                        device_id=obj.get("device_id"),
                        factor=float(obj.get("factor", 1.0)),
                        selector=obj.get("selector"),
                        mode=str(obj.get("mode", "set")))


@dataclass(frozen=True)
class Trace:
    """An immutable, named event timeline over ``[0, horizon]`` seconds."""

    name: str
    horizon: float
    events: tuple[NetworkEvent, ...]
    seed: int | None = None
    meta: tuple[tuple[str, Any], ...] = ()   # frozen key/value metadata

    def __post_init__(self) -> None:
        object.__setattr__(self, "events", tuple(
            sorted(self.events, key=lambda e: e.time)))

    # -- views -----------------------------------------------------------------

    def __len__(self) -> int:
        return len(self.events)

    def to_events(self) -> list[NetworkEvent]:
        return list(self.events)

    def to_step_events(self, steps: int) -> list[tuple[int, NetworkEvent]]:
        """Map event times onto a ``steps``-long training run: time ``t``
        lands on step ``floor(t / horizon * steps)`` (clamped).  This is how
        the :class:`repro.runtime.trainer.Trainer` consumes a trace."""
        out = []
        for ev in self.events:
            frac = ev.time / self.horizon if self.horizon > 0 else 0.0
            step = min(steps - 1, max(0, int(frac * steps)))
            out.append((step, ev))
        return out

    def event_times(self) -> list[float]:
        """Distinct event times within the horizon, ascending."""
        seen: list[float] = []
        for ev in self.events:
            if ev.time <= self.horizon and \
                    (not seen or ev.time != seen[-1]):
                seen.append(ev.time)
        return seen

    # -- serialization ---------------------------------------------------------

    def dumps(self) -> str:
        header = {"format": TRACE_FORMAT, "version": TRACE_VERSION,
                  "name": self.name, "seed": self.seed,
                  "horizon": self.horizon, "meta": dict(self.meta)}
        lines = [json.dumps(header, sort_keys=True)]
        lines += [json.dumps(_event_to_obj(ev), sort_keys=True)
                  for ev in self.events]
        return "\n".join(lines) + "\n"

    def record(self, path: str | Path) -> Path:
        """Write the trace as JSONL; returns the path."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.dumps())
        return p

    @staticmethod
    def loads(text: str) -> "Trace":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise ValueError("empty trace")
        header = json.loads(lines[0])
        if header.get("format") != TRACE_FORMAT:
            raise ValueError(f"not a scenario trace: "
                             f"format={header.get('format')!r}")
        if header.get("version") != TRACE_VERSION:
            raise ValueError(f"unsupported trace version "
                             f"{header.get('version')!r} "
                             f"(supported: {TRACE_VERSION})")
        events = tuple(_event_from_obj(json.loads(ln)) for ln in lines[1:])
        return Trace(name=str(header["name"]),
                     horizon=float(header["horizon"]),
                     events=events, seed=header.get("seed"),
                     meta=tuple(sorted(dict(header.get("meta") or {})
                                       .items())))

    @staticmethod
    def load(path: str | Path) -> "Trace":
        return Trace.loads(Path(path).read_text())

    @staticmethod
    def from_events(name: str, events: Iterable[NetworkEvent], *,
                    horizon: float | None = None, seed: int | None = None,
                    meta: Mapping[str, Any] | None = None) -> "Trace":
        evs = tuple(sorted(events, key=lambda e: e.time))
        if horizon is None:
            horizon = max((e.time for e in evs), default=0.0)
        return Trace(name=name, horizon=float(horizon), events=evs,
                     seed=seed, meta=tuple(sorted((meta or {}).items())))

    def describe(self) -> str:
        kinds: dict[str, int] = {}
        for ev in self.events:
            kinds[ev.kind] = kinds.get(ev.kind, 0) + 1
        ks = " ".join(f"{k}x{v}" for k, v in sorted(kinds.items()))
        return (f"Trace '{self.name}': {len(self.events)} events over "
                f"{self.horizon:.0f}s ({ks}), seed={self.seed}")


def compose_traces(traces: Sequence[Trace], *, name: str | None = None,
                   horizon: float | None = None,
                   seed: int | None = None) -> Trace:
    """Merge several traces into one timeline.

    Generators return plain event lists, so composition is concatenation:
    events are merged time-sorted (ties keep input order — the sort is
    stable), the horizon defaults to the longest component's, and the
    component names are recorded in ``meta["components"]``.  Scale-mode
    events from different sources compose multiplicatively by construction
    (PR 2's ``NetworkEvent.mode``), which is what makes naive concatenation
    semantically sound."""
    traces = list(traces)
    if not traces:
        raise ValueError("compose_traces needs at least one trace")
    h = horizon if horizon is not None else max(t.horizon for t in traces)
    events = tuple(e for t in traces for e in t.events if e.time <= h)
    return Trace(
        name=name or "+".join(t.name for t in traces),
        horizon=float(h), events=events, seed=seed,
        meta=(("components", "|".join(t.name for t in traces)),
              ("composed", True)))
