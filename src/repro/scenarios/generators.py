"""Seeded stochastic generators for cloud scenario families (paper §2.2).

Each generator turns a ``random.Random`` (stdlib — deterministic across
platforms) plus shape parameters into a sorted list of
:class:`repro.core.NetworkEvent`, composable into one timeline.  Families:

  * :func:`spot_preemptions`       — spot-instance preemption/rejoin churn
                                     via Poisson arrivals (S3 fail/join).
  * :func:`diurnal_bandwidth`      — day/night WAN bandwidth curve, sampled
                                     into absolute ``mode="set"`` levels (S1).
  * :func:`congestion_bursts`      — multi-tenant congestion bursts with
                                     staged decay; overlapping bursts compose
                                     multiplicatively (``mode="scale"``) (S1).
  * :func:`straggler_churn`        — devices degrade and recover; overlapping
                                     slowdowns on one device compose (S2).
  * :func:`link_degradation`       — cross-region (dci/ib) link flaps:
                                     degrade, then repair (S1).

Event *times* are rounded to 6 decimals for readable traces; *scale-mode
factor pairs* are kept at full precision so a burst's reciprocal recovery
restores the previous level exactly (rounding one side of the pair would
make levels drift across long multi-burst traces).
"""

from __future__ import annotations

import math
import random
from typing import Sequence

from repro.core import NetworkEvent


def _poisson_times(rng: random.Random, rate: float,
                   horizon: float) -> list[float]:
    """Poisson arrival times in (0, horizon) at ``rate`` events/second."""
    times: list[float] = []
    t = 0.0
    if rate <= 0:
        return times
    while True:
        t += rng.expovariate(rate)
        if t >= horizon:
            return times
        times.append(t)


def _r(x: float) -> float:
    return round(x, 6)


# ---------------------------------------------------------------------------
# S3: spot-instance preemption / rejoin
# ---------------------------------------------------------------------------


def spot_preemptions(rng: random.Random, device_ids: Sequence[int],
                     horizon: float, *, preempt_rate: float,
                     restore_mean: float,
                     min_alive_frac: float = 0.5) -> list[NetworkEvent]:
    """Poisson preemption arrivals; each preempted device rejoins after an
    exponential restore delay.  Never preempts below ``min_alive_frac`` of
    the fleet (a spot pool retains a reserved core)."""
    ids = list(device_ids)
    min_alive = max(1, math.ceil(len(ids) * min_alive_frac))
    events: list[NetworkEvent] = []
    # (rejoin_time, device) for devices currently out
    out: list[tuple[float, int]] = []
    for t in _poisson_times(rng, preempt_rate, horizon):
        out = [(rt, d) for rt, d in out if rt > t]
        alive = [d for d in ids if d not in {d for _, d in out}]
        if len(alive) <= min_alive:
            continue
        victim = rng.choice(alive)
        events.append(NetworkEvent(_r(t), "fail", device_id=victim))
        back = t + rng.expovariate(1.0 / restore_mean)
        if back < horizon:
            events.append(NetworkEvent(_r(back), "join", device_id=victim,
                                       factor=1.0))
            out.append((back, victim))
        else:
            out.append((math.inf, victim))
    return sorted(events, key=lambda e: e.time)


# ---------------------------------------------------------------------------
# S1: diurnal WAN bandwidth fluctuation
# ---------------------------------------------------------------------------


def diurnal_bandwidth(rng: random.Random, horizon: float, *,
                      period: float, floor: float = 0.3,
                      selector: str | None = "ib",
                      samples_per_period: int = 8,
                      jitter: float = 0.05) -> list[NetworkEvent]:
    """Sampled day/night curve: the link level swings between 1.0 (off-peak)
    and ``floor`` (peak) on a cosine of ``period`` seconds, with
    multiplicative noise.  Each sample is an absolute ``mode="set"`` level —
    a single-source condition, so absolute-set is the documented semantics
    here (composition with *other* sources belongs in scale-mode events)."""
    events: list[NetworkEvent] = []
    n = max(1, int(horizon / period * samples_per_period))
    dt = horizon / (n + 1)
    for i in range(1, n + 1):
        t = i * dt
        phase = 2 * math.pi * t / period
        level = floor + (1.0 - floor) * (0.5 + 0.5 * math.cos(phase))
        level *= 1.0 + jitter * rng.uniform(-1.0, 1.0)
        events.append(NetworkEvent(_r(t), "bandwidth",
                                   factor=_r(max(0.05, level)),
                                   selector=selector, mode="set"))
    return events


# ---------------------------------------------------------------------------
# S1: multi-tenant congestion bursts with overlapping decay
# ---------------------------------------------------------------------------


def congestion_bursts(rng: random.Random, horizon: float, *,
                      burst_rate: float, selector: str | None = "ib",
                      depth_range: tuple[float, float] = (0.3, 0.7),
                      duration_range: tuple[float, float] = (20.0, 90.0),
                      decay_steps: int = 2) -> list[NetworkEvent]:
    """Each burst multiplies the link level by ``1 - depth`` at onset, then
    recovers in ``decay_steps`` equal multiplicative steps spread over its
    duration, so the net effect of a completed burst is exactly 1.0 and
    *overlapping* bursts from different tenants compose — this is the family
    that requires ``mode="scale"`` semantics."""
    events: list[NetworkEvent] = []
    for t in _poisson_times(rng, burst_rate, horizon):
        depth = rng.uniform(*depth_range)
        dur = rng.uniform(*duration_range)
        onset = 1.0 - depth
        events.append(NetworkEvent(_r(t), "bandwidth", factor=onset,
                                   selector=selector, mode="scale"))
        step = (1.0 / onset) ** (1.0 / decay_steps)
        for k in range(1, decay_steps + 1):
            tk = t + dur * k / decay_steps
            if tk >= horizon:
                break
            events.append(NetworkEvent(_r(tk), "bandwidth", factor=step,
                                       selector=selector, mode="scale"))
    return sorted(events, key=lambda e: e.time)


# ---------------------------------------------------------------------------
# S2: straggler churn
# ---------------------------------------------------------------------------


def straggler_churn(rng: random.Random, device_ids: Sequence[int],
                    horizon: float, *, rate: float,
                    slow_range: tuple[float, float] = (0.3, 0.7),
                    recover_mean: float = 60.0) -> list[NetworkEvent]:
    """Poisson straggler onsets: a device's perf is multiplied by a slowdown
    factor, then restored by the reciprocal after an exponential recovery
    delay.  Scale-mode keeps overlapping slowdowns on one device honest."""
    ids = list(device_ids)
    events: list[NetworkEvent] = []
    for t in _poisson_times(rng, rate, horizon):
        dev = rng.choice(ids)
        s = rng.uniform(*slow_range)
        events.append(NetworkEvent(_r(t), "slowdown", device_id=dev,
                                   factor=s, mode="scale"))
        back = t + rng.expovariate(1.0 / recover_mean)
        if back < horizon:
            events.append(NetworkEvent(_r(back), "slowdown", device_id=dev,
                                       factor=1.0 / s, mode="scale"))
    return sorted(events, key=lambda e: e.time)


# ---------------------------------------------------------------------------
# S1: cross-region link degradation (dci / ib flaps)
# ---------------------------------------------------------------------------


def link_degradation(rng: random.Random, horizon: float, *,
                     selector: str = "dci", rate: float,
                     severity_range: tuple[float, float] = (0.1, 0.5),
                     repair_mean: float = 90.0) -> list[NetworkEvent]:
    """Cross-region links flap: degrade to ``severity`` of nominal, repair
    after an exponential delay (scale-mode pair, so concurrent flaps on the
    same selector compose instead of clobbering)."""
    events: list[NetworkEvent] = []
    for t in _poisson_times(rng, rate, horizon):
        sev = rng.uniform(*severity_range)
        events.append(NetworkEvent(_r(t), "bandwidth", factor=sev,
                                   selector=selector, mode="scale"))
        back = t + rng.expovariate(1.0 / repair_mean)
        if back < horizon:
            events.append(NetworkEvent(_r(back), "bandwidth",
                                       factor=1.0 / sev,
                                       selector=selector, mode="scale"))
    return sorted(events, key=lambda e: e.time)
