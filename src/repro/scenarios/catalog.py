"""Named scenario catalog: cloud-environment families ready to replay.

Each :class:`ScenarioSpec` bundles a topology factory with a seeded event
generator; :func:`build_trace` turns (name, seed) into a reproducible
:class:`Trace` and :func:`build` additionally instantiates the topology.
Identical seeds produce byte-identical traces (the determinism gate).

Registered families:

===================== ======================================================
name                  what
===================== ======================================================
cloud_spot            spot-instance preemption/rejoin churn on a mixed
                      RTX4090D + V100 fleet (Poisson arrivals, S3)
diurnal_wan           day/night WAN bandwidth curve on the inter-node "ib"
                      fabric of a 16x V100 cluster (S1, absolute-set)
congested_multitenant overlapping multi-tenant congestion bursts with staged
                      decay on "ib" (S1, scale-mode composition)
straggler_churn       devices degrade and recover on a heterogeneous node
                      pair (S2, scale-mode)
cross_region          cross-region DCI link flaps between two TPU pods (S1)
fig6c_dynamic_bw      the fig6c benchmark timeline re-expressed as a trace:
                      nominal -> 0.2x -> 4x fabric bandwidth (deterministic)
diurnal_wan_crossover deep diurnal trough on the ``ib`` fabric joining two
                      NVLink islands — crosses the fig6c TP-vs-bandwidth
                      boundary, so the plan actually flips mid-trace (S1)
congested_crossover   deep multi-tenant bursts on the same ``ib`` fabric;
                      burst floors cross the DP-across-nodes vs
                      PP-across-nodes boundary (S1)
diurnal_spot_storm    composed timeline: diurnal WAN curve + spot
                      preemption churn on one mixed fleet (S1+S3)
congested_flaky       composed timeline: multi-tenant bursts + link flaps
                      on the same fabric, scale-mode composition (S1)
===================== ======================================================

The ``*_crossover`` variants exist because the original bandwidth families
ended in "keep" on every event: the cold plan stays bandwidth-robust on
their fabrics at any swing the generators produce.  With fast NVLink
islands and only the inter-island ``ib`` link swinging, the crossover is
inside the swing range — at a comm-heavy replay scale (small global batch)
a deep trough flips DP-across-nodes to PP-across-nodes and the adapted
policy has a real S1 win to collect.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Sequence

from repro.core import (ClusterTopology, NetworkEvent, hetero_cluster,
                        homogeneous_cluster, multi_pod_tpu)

from . import generators as gen
from .trace import Trace, compose_traces


@dataclass(frozen=True)
class ScenarioSpec:
    """One catalog entry: topology factory + seeded event generator."""

    name: str
    description: str
    make_topology: Callable[[], ClusterTopology]
    make_events: Callable[[random.Random, float], list[NetworkEvent]]
    horizon: float = 600.0
    deterministic: bool = False        # events independent of the seed
    tags: tuple[str, ...] = ()


_REGISTRY: dict[str, ScenarioSpec] = {}


def register(spec: ScenarioSpec) -> ScenarioSpec:
    """Register a scenario spec under its (unique) name; returns it."""
    if spec.name in _REGISTRY:
        raise ValueError(f"scenario {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_scenario(name: str) -> ScenarioSpec:
    """Lookup by name; ``KeyError`` lists what is available."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; available: "
                       f"{sorted(_REGISTRY)}") from None


def list_scenarios() -> list[str]:
    """Sorted registered scenario names."""
    return sorted(_REGISTRY)


def build_trace(name: str, seed: int = 0) -> Trace:
    """Generate the named scenario's trace for ``seed`` (reproducible)."""
    spec = get_scenario(name)
    rng = random.Random(seed)
    events = spec.make_events(rng, spec.horizon)
    return Trace(name=spec.name, horizon=spec.horizon, events=tuple(events),
                 seed=seed, meta=(("deterministic", spec.deterministic),
                                  ("family", spec.name)))


def build(name: str, seed: int = 0) -> tuple[ClusterTopology, Trace]:
    """Topology + trace for the named scenario; the trace's events are
    attached to the topology's timeline, ready for replay."""
    spec = get_scenario(name)
    trace = build_trace(name, seed)
    topo = spec.make_topology()
    topo.events = trace.to_events()
    return topo, trace


# ---------------------------------------------------------------------------
# Registered families
# ---------------------------------------------------------------------------


register(ScenarioSpec(
    name="cloud_spot",
    description="spot-instance preemption/rejoin churn, mixed fleet (S3)",
    make_topology=lambda: hetero_cluster({"RTX4090D": 8, "V100": 8},
                                         gpus_per_node=4),
    make_events=lambda rng, horizon: gen.spot_preemptions(
        rng, list(range(16)), horizon, preempt_rate=5.0 / horizon,
        restore_mean=horizon / 4),
    tags=("S3", "fail", "join"),
))

register(ScenarioSpec(
    name="diurnal_wan",
    description="day/night WAN bandwidth curve on the ib fabric (S1)",
    make_topology=lambda: homogeneous_cluster(16, "V100", gpus_per_node=8),
    make_events=lambda rng, horizon: gen.diurnal_bandwidth(
        rng, horizon, period=horizon / 2, floor=0.25, selector="ib",
        samples_per_period=7),
    tags=("S1", "bandwidth"),
))

register(ScenarioSpec(
    name="congested_multitenant",
    description="overlapping multi-tenant congestion bursts on ib (S1)",
    make_topology=lambda: homogeneous_cluster(8, "V100", gpus_per_node=4),
    make_events=lambda rng, horizon: gen.congestion_bursts(
        rng, horizon, burst_rate=7.0 / horizon, selector="ib",
        depth_range=(0.3, 0.7), duration_range=(horizon / 20, horizon / 6),
        decay_steps=2),
    tags=("S1", "bandwidth", "scale"),
))

register(ScenarioSpec(
    name="straggler_churn",
    description="devices degrade and recover on a hetero node pair (S2)",
    make_topology=lambda: hetero_cluster({"RTX4090D": 4, "V100": 4},
                                         gpus_per_node=4),
    make_events=lambda rng, horizon: gen.straggler_churn(
        rng, list(range(8)), horizon, rate=6.0 / horizon,
        slow_range=(0.3, 0.7), recover_mean=horizon / 8),
    tags=("S2", "slowdown"),
))

register(ScenarioSpec(
    name="cross_region",
    description="cross-region DCI link flaps between two TPU pods (S1)",
    make_topology=lambda: multi_pod_tpu(pods=2, chips_per_pod=16),
    make_events=lambda rng, horizon: gen.link_degradation(
        rng, horizon, selector="dci", rate=4.0 / horizon,
        severity_range=(0.1, 0.5), repair_mean=horizon / 6),
    tags=("S1", "bandwidth", "dci"),
))


def _crossover_fabric() -> ClusterTopology:
    """Two NVLink-backed 4-GPU V100 boxes joined by a 25 GB/s WAN-class
    ``ib`` fabric.  With the intra-node fabric fast and only ``ib``
    swinging, the fig6c crossover sits inside the swing: at nominal
    bandwidth DP-across-nodes wins, in a deep trough the planner flips to
    pipeline-across-nodes (drops the cross-``ib`` gradient sync).  Replay
    this family at a comm-heavy scale (small global batch) — at large
    batches the step is compute-bound and no bandwidth level flips it."""
    return hetero_cluster({"V100": 8}, inter_bw=25e9, gpus_per_node=4)


register(ScenarioSpec(
    name="diurnal_wan_crossover",
    description="deep diurnal WAN swing across NVLink islands (S1)",
    make_topology=_crossover_fabric,
    make_events=lambda rng, horizon: gen.diurnal_bandwidth(
        rng, horizon, period=horizon / 2, floor=0.10, selector="ib",
        samples_per_period=7),
    tags=("S1", "bandwidth", "crossover"),
))

register(ScenarioSpec(
    name="congested_crossover",
    description="deep multi-tenant bursts across NVLink islands (S1)",
    make_topology=_crossover_fabric,
    make_events=lambda rng, horizon: gen.congestion_bursts(
        rng, horizon, burst_rate=5.0 / horizon, selector="ib",
        depth_range=(0.6, 0.9),
        duration_range=(horizon / 10, horizon / 4), decay_steps=2),
    tags=("S1", "bandwidth", "scale", "crossover"),
))


# ---------------------------------------------------------------------------
# Composed timelines (ROADMAP open item): one scenario, several families
# ---------------------------------------------------------------------------


def _composed_events(rng: random.Random, horizon: float, name: str,
                     parts: Sequence[tuple[str, Callable[
                         [random.Random, float], list[NetworkEvent]]]]
                     ) -> list[NetworkEvent]:
    """Generate each component family with the shared rng (order is part of
    the scenario's determinism contract) and merge via
    :func:`repro.scenarios.trace.compose_traces`."""
    traces = [Trace.from_events(pname, fn(rng, horizon), horizon=horizon)
              for pname, fn in parts]
    return compose_traces(traces, name=name, horizon=horizon).to_events()


register(ScenarioSpec(
    name="diurnal_spot_storm",
    description="diurnal WAN trough + spot preemption churn, one timeline "
                "(S1+S3 composed)",
    make_topology=lambda: hetero_cluster({"RTX4090D": 8, "V100": 8},
                                         gpus_per_node=4),
    make_events=lambda rng, horizon: _composed_events(
        rng, horizon, "diurnal_spot_storm", [
            ("diurnal_wan", lambda r, h: gen.diurnal_bandwidth(
                r, h, period=h / 2, floor=0.3, selector="ib",
                samples_per_period=5)),
            ("spot", lambda r, h: gen.spot_preemptions(
                r, list(range(16)), h, preempt_rate=4.0 / h,
                restore_mean=h / 4)),
        ]),
    tags=("S1", "S3", "bandwidth", "fail", "join", "composed"),
))

register(ScenarioSpec(
    name="congested_flaky",
    description="multi-tenant congestion bursts + link flaps on the same "
                "fabric (S1 composed, scale-mode)",
    make_topology=lambda: homogeneous_cluster(8, "V100", gpus_per_node=4),
    make_events=lambda rng, horizon: _composed_events(
        rng, horizon, "congested_flaky", [
            ("congestion", lambda r, h: gen.congestion_bursts(
                r, h, burst_rate=5.0 / h, selector="ib",
                depth_range=(0.3, 0.6),
                duration_range=(h / 20, h / 6), decay_steps=2)),
            ("flaps", lambda r, h: gen.link_degradation(
                r, h, selector="ib", rate=3.0 / h,
                severity_range=(0.25, 0.6), repair_mean=h / 8)),
        ]),
    tags=("S1", "bandwidth", "scale", "composed"),
))


def _fig6c_events(rng: random.Random,
                  horizon: float) -> list[NetworkEvent]:
    # the fig6c benchmark's two network conditions as one timeline:
    # nominal fabric, then the 0.2x low-bandwidth leg, then 4x unconstrained
    del rng  # deterministic family
    return [
        NetworkEvent(round(horizon / 3, 6), "bandwidth", factor=0.2,
                     mode="set"),
        NetworkEvent(round(2 * horizon / 3, 6), "bandwidth", factor=4.0,
                     mode="set"),
    ]


register(ScenarioSpec(
    name="fig6c_dynamic_bw",
    description="fig6c bandwidth sweep (0.2x / 4x) as a trace (S1)",
    make_topology=lambda: hetero_cluster({"V100": 8},
                                         intra_bw_map={"V100": 25e9},
                                         inter_bw=12.5e9, gpus_per_node=8),
    make_events=_fig6c_events,
    deterministic=True,
    tags=("S1", "bandwidth", "paper"),
))
