"""Public kernel entry points: pick Pallas-TPU or interpret/reference.

``flash_attention`` / ``rmsnorm`` dispatch on the backend: compiled Pallas on
TPU, ``interpret=True`` (Python-executed kernel body) on CPU so the same
call sites validate everywhere.  The model layer can route its attention
through here when ``ArchConfig.use_flash_kernel`` is set (the fused
cost-model entry of paper §2.3).
"""

from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 256,
                    block_kv: int = 256, interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  block_q=block_q, block_kv=block_kv, interpret=interpret)


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return _rmsnorm(x, w, eps=eps, block_rows=block_rows,
                    interpret=interpret)


mha_reference = ref.mha_reference
rmsnorm_reference = ref.rmsnorm_reference
