"""Pure-jnp oracles for the Pallas kernels (the unfused baselines).

These deliberately materialize the full S×S score matrix / intermediate
tensors — they are the "before fusion" cost-model entries (paper §2.3) and
the ground truth for the kernel allclose sweeps in tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd), H % KV == 0 (GQA).

    Returns (B, Sq, H, hd).  Unfused: scores materialized in fp32.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqhgk,bshk->bhgqs", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(hd))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqs,bshk->bqhgk", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, hd)


def rmsnorm_reference(x: jax.Array, w: jax.Array,
                      eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
            ).astype(x.dtype)
