"""Pallas TPU flash attention (fused, online-softmax) with GQA/causal/window.

TPU-native adaptation of the paper's fusion example (§2.3, FlashAttention):
instead of a CUDA warp-level design, tiling follows the TPU memory hierarchy:

  * grid = (batch, q_heads, q_blocks, kv_blocks); the minor-most kv_blocks
    dimension iterates sequentially on a TensorCore, so fp32 running
    (acc, m, l) live in VMEM scratch across kv steps,
  * BlockSpecs stream (block_q × head_dim) / (block_kv × head_dim) tiles
    HBM→VMEM; head_dim rides the 128-lane minor dimension and block sizes
    are MXU-aligned multiples of 128,
  * GQA is free: the kv BlockSpec index_map sends q-head h to kv-head
    h // (H // KV) — no repeated-KV materialization,
  * the S×S score matrix never touches HBM (the whole point).

Numerics follow the standard stable online softmax; the causal/window mask
is applied per tile from block-relative iotas.  Validated on CPU with
``interpret=True`` against ``ref.mha_reference`` (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_kv: int, seq_q: int, seq_kv: int,
                  softcap: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)           # (bq, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bkv, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    # positions: queries offset by (seq_kv - seq_q) (decode-style alignment)
    qpos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0) + (seq_kv - seq_q)
    kpos = ik * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    masked = s
    if causal:
        masked = jnp.where(qpos >= kpos, masked, NEG_INF)
    if window:
        masked = jnp.where(qpos - kpos < window, masked, NEG_INF)
    s = masked

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)   # fully-masked rows stay zero
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_diff(q, k, v, causal, window, softcap, block_q, block_kv,
                interpret):
    return _flash_fwd_kernel_call(q, k, v, causal=causal, window=window,
                                  softcap=softcap, block_q=block_q,
                                  block_kv=block_kv, interpret=interpret)


def _flash_vjp_fwd(q, k, v, causal, window, softcap, block_q, block_kv,
                   interpret):
    o = _flash_fwd_kernel_call(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_kv=block_kv, interpret=interpret)
    return o, (q, k, v)


def _flash_vjp_bwd(causal, window, softcap, block_q, block_kv, interpret,
                   res, g):
    """Backward through the exact attention math (recompute-from-inputs).

    The forward runs the fused Pallas kernel; the backward recomputes with
    the reference formula and lets XLA differentiate it — the standard
    fwd-kernel + analytic-bwd split (a dedicated bwd Pallas kernel is the
    further TPU optimization, EXPERIMENTS.md §Perf)."""
    from repro.kernels.ref import mha_reference
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: mha_reference(
        q, k, v, causal=causal, window=window, softcap=softcap), q, k, v)
    return vjp(g)


_flash_diff.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "block_q",
                              "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0,
                    block_q: int = 256, block_kv: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd).  Returns (B, Sq, H, hd)."""
    return _flash_diff(q, k, v, causal, window, softcap, block_q, block_kv,
                       interpret)


def _flash_fwd_kernel_call(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool, window: int, softcap: float,
                           block_q: int, block_kv: int,
                           interpret: bool) -> jax.Array:
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    assert H % KV == 0, "GQA requires H % KV == 0"
    G = H // KV
    bq = min(block_q, Sq)
    while Sq % bq:
        bq //= 2
    bkv = min(block_kv, Skv)
    while Skv % bkv:
        bkv //= 2
    bq, bkv = max(bq, 1), max(bkv, 1)
    grid = (B, H, Sq // bq, Skv // bkv)
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_kv=bkv, seq_q=Sq, seq_kv=Skv, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bkv, 1, hd),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bkv, 1, hd),
                         lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
