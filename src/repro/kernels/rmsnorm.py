"""Pallas TPU fused RMSNorm: one HBM read, normalize+scale in VMEM.

Grid tiles rows (tokens); the feature dim rides the 128-lane minor axis in
one VMEM block (d_model ≤ a few K fits comfortably).  fp32 accumulation for
the mean-square reduction regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (..., d); w: (d,).  Returns same shape/dtype as x."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br:
        br -= 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(shape)
