"""Fault-tolerant training runtime.

Drives the jitted train step over the synthetic pipeline with:

  * periodic async checkpoints (repro.checkpoint),
  * an *event loop* mirroring the paper's dynamic scenarios: injected
    :class:`NetworkEvent`s (S1 bandwidth / S2 slowdown / S3 failure) are
    applied to the analytic :class:`ClusterTopology`, the
    :class:`DynamicOrchestrator` re-plans (template failover for failures,
    local reassignment for stragglers, threshold re-plan for bandwidth), and
    the trainer rebuilds its mesh/shardings and elastically reshards the
    restored checkpoint onto the new layout,
  * uneven heterogeneous batch shares consumed straight from the plan.

On CPU the mesh spans host devices; on a real cluster the same code runs
under jax.distributed with the production mesh.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import (ClusterTopology, DynamicOrchestrator, ModelDesc,
                        NetworkEvent, ParallelPlan, ReplanEngine,
                        StrategyCache)
from repro.checkpoint.store import AsyncSaver, latest_step, restore
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.config import ArchConfig
from repro.models.lm import LM
from repro.optim.adamw import AdamWConfig
from repro.parallel import sharding as shd
from repro.parallel.axes import use_rules
from repro.parallel.trainstep import init_train_state, make_train_step

Pytree = Any


@dataclass
class TrainerConfig:
    arch: ArchConfig
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    log_every: int = 10
    remat: str = "none"
    microbatches: int = 1
    zero3: bool = False
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    seed: int = 0


class Trainer:
    def __init__(self, cfg: TrainerConfig, *,
                 mesh: Mesh | None = None,
                 plan: ParallelPlan | None = None,
                 topo: ClusterTopology | None = None,
                 events: Sequence[tuple[int, NetworkEvent]] = (),
                 scenario: "str | object | None" = None):
        self.cfg = cfg
        self.model = LM(cfg.arch)
        self.plan = plan
        self.topo = topo
        self.trace = None
        events = list(events)
        if scenario is not None:
            # a catalog name or a repro.scenarios.Trace: event times map
            # onto training steps via Trace.to_step_events, and a catalog
            # name also supplies the topology when none was given
            from repro.scenarios import Trace, build_trace, get_scenario
            if isinstance(scenario, str):
                self.trace = build_trace(scenario, seed=cfg.seed)
                if topo is None:
                    topo = self.topo = get_scenario(scenario).make_topology()
            elif isinstance(scenario, Trace):
                if topo is None:
                    raise ValueError(
                        "an explicit Trace needs an explicit topo=")
                self.trace = scenario
            else:
                raise TypeError(f"scenario must be a catalog name or Trace, "
                                f"got {type(scenario).__name__}")
            events += self.trace.to_step_events(cfg.steps)
        if topo is not None:
            # fail fast on a trace/topology mismatch instead of KeyError-ing
            # mid-run (e.g. a 16-device catalog trace on an 8-device topo)
            missing = sorted({ev.device_id for _, ev in events
                              if ev.device_id is not None}
                             - set(topo.devices))
            if missing:
                raise ValueError(
                    f"events reference device ids {missing} not present "
                    f"in the topology ({sorted(topo.devices)})")
        self.events = sorted(events, key=lambda e: e[0])
        self.saver = AsyncSaver()
        self.history: list[dict] = []
        self.replans = 0
        self._start_step = 0
        self._hist_mark = 0
        self._orch = None
        self._engine = None
        if topo is not None:
            desc = cfg.arch.to_model_desc()
            # the incremental re-planning engine handles every event kind
            # (device-set changes included), so the Oobleck-style
            # PlanTemplates precompute is no longer paid here — it remains
            # available for engine-less DynamicOrchestrator users
            self._engine = ReplanEngine(
                desc, global_batch=cfg.global_batch, seq=cfg.seq_len,
                cache=StrategyCache())
            try:
                # cold plan up front: warms the strategy cache + candidate
                # portfolio so every later event takes a warm path
                self._engine.plan(topo)
            except RuntimeError:
                pass
            self._orch = DynamicOrchestrator(
                model=desc, global_batch=cfg.global_batch, seq=cfg.seq_len,
                engine=self._engine)
        self._build(mesh)

    # -- public adaptation telemetry ------------------------------------------

    @property
    def adaptations(self) -> list:
        """Adaptation records (one per handled event) — the public view of
        the orchestrator history; empty when no topology was attached."""
        return list(self._orch.history) if self._orch is not None else []

    @property
    def engine(self):
        """The incremental ReplanEngine (None when no topology attached)."""
        return self._engine

    # -- (re)build against the current mesh/plan -----------------------------

    def _build(self, mesh: Mesh | None) -> None:
        if mesh is None:
            n = len(jax.devices())
            mesh = Mesh(np.array(jax.devices()).reshape(n, 1),
                        ("data", "model"))
        self.mesh = mesh
        self.prof = shd.profile_for(self.cfg.arch, mesh,
                                    zero3=self.cfg.zero3)
        self.state_sh = {
            "params": shd.param_shardings(self.model, mesh, self.prof.rules),
            "opt": shd.opt_state_shardings(self.model, mesh,
                                           self.prof.opt_rules),
        }
        step_fn = make_train_step(self.model, self.cfg.opt,
                                  microbatches=self.cfg.microbatches,
                                  remat=self.cfg.remat)

        def wrapped(state, batch):
            with use_rules(mesh, self.prof.rules):
                return step_fn(state, batch)

        self._jit = jax.jit(wrapped, in_shardings=(self.state_sh, None),
                            out_shardings=(self.state_sh, None),
                            donate_argnums=(0,))
        a = self.cfg.arch
        self.data = SyntheticLM(DataConfig(
            vocab=a.vocab, seq_len=self.cfg.seq_len,
            global_batch=self.cfg.global_batch, seed=self.cfg.seed,
            audio_seq=a.audio_seq if a.encoder_layers else 0,
            vision_seq=a.vision_seq if a.cross_attn_every else 0,
            d_model=a.d_model))

    def init_state(self) -> Pytree:
        state = init_train_state(self.model, jax.random.PRNGKey(self.cfg.seed))
        return jax.device_put(state, self.state_sh)

    def _place(self, batch: dict) -> dict:
        out = {}
        for k, v in batch.items():
            axes = ["batch"] + [None] * (v.ndim - 1)
            sh = self.prof.rules.sharding(axes, v.shape, self.mesh)
            out[k] = jax.device_put(v, sh)
        return out

    # -- event handling (paper §2.2: S1/S2/S3) --------------------------------

    def _handle_event(self, step: int, ev: NetworkEvent,
                      state: Pytree) -> Pytree:
        assert self.topo is not None and self._orch is not None
        self.saver.wait()
        ck = Path(self.cfg.ckpt_dir) / f"step_{step}"
        self.saver.submit(ck, state, step=step,
                          plan_json=self.plan.to_json() if self.plan else "")
        self.saver.wait()
        self.topo.apply_event(ev)
        if self._engine is not None and len(self.history) > self._hist_mark:
            # remaining-horizon budget for the engine's switch-cost
            # hysteresis: steps left x the measured mean step wall time.
            # Only entries logged by *this* run() invocation qualify: their
            # wall is measured from this run's t0 and covers the steps since
            # start_step (a previous run's entries would mix timebases)
            m = self.history[-1]
            done = max(m["step"] - self._start_step + 1, 1)
            self._engine.switch_horizon_s = \
                (self.cfg.steps - step) * m["wall"] / done
        old_plan = self.plan or ParallelPlan()
        self.plan = self._orch.adapt(old_plan, self.topo, ev)
        self.replans += 1
        # rebuild (the mesh shape may change on a real cluster; on the host
        # mesh we rebuild shardings/jit against the new plan) and reshard
        # the checkpoint elastically onto the new layout.
        self._build(self.mesh)
        like = init_train_state(self.model,
                                jax.random.PRNGKey(self.cfg.seed))
        t0 = time.perf_counter()
        restored, _ = restore(ck, like, shardings=self.state_sh)
        restore_s = time.perf_counter() - t0
        if self._engine is not None:
            # calibration hook: fold the measured checkpoint-restore path
            # into the reconfiguration cost model, so simulated switch
            # charges track what elastic restore costs on this deployment
            nbytes = sum(
                getattr(leaf, "nbytes", 0)
                for leaf in jax.tree_util.tree_leaves(restored))
            self._engine.reconfig.calibrate_io(restore_s, float(nbytes))
        return restored

    # -- main loop -------------------------------------------------------------

    def run(self, state: Pytree | None = None,
            start_step: int = 0) -> tuple[Pytree, list[dict]]:
        cfg = self.cfg
        state = state if state is not None else self.init_state()
        self._start_step = start_step
        self._hist_mark = len(self.history)
        ev_i = 0
        t0 = time.perf_counter()
        for step in range(start_step, cfg.steps):
            while ev_i < len(self.events) and self.events[ev_i][0] == step:
                _, ev = self.events[ev_i]
                state = self._handle_event(step, ev, state)
                ev_i += 1
            batch = self._place(self.data.batch(step))
            state, metrics = self._jit(state, batch)
            if step % cfg.log_every == 0 or step == cfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, wall=time.perf_counter() - t0)
                self.history.append(m)
                tok_s = m["tokens"] * (step - start_step + 1) / m["wall"]
                print(f"  step {step:4d} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.2f} lr {m['lr']:.2e} "
                      f"tok/s {tok_s:,.0f}", flush=True)
            if cfg.ckpt_every and step and step % cfg.ckpt_every == 0:
                self.saver.submit(Path(cfg.ckpt_dir) / f"step_{step}",
                                  state, step=step,
                                  plan_json=self.plan.to_json()
                                  if self.plan else "")
        self.saver.wait()
        return state, self.history
