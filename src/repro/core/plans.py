"""Parallel plans: the planner's output IR (paper §3.2.2) and baselines.

A :class:`ParallelPlan` captures everything the paper's output specification
requires at the model level: device assignment (pipeline stage -> device
group, layer -> stage), data-parallel batch shares (possibly uneven for
heterogeneous devices), the collective/link schedule choice (naive vs
decomposed all-reduce), and execution knobs (microbatches, remat, ZeRO-1).

``megatron_default_plan`` reproduces the paper's baseline: uniform layer
split, TP within a node, DP across nodes, even batch shares.
"""

from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Sequence

from .cluster import ClusterTopology
from .opgraph import ModelDesc


@dataclass(frozen=True)
class StageAssignment:
    """One pipeline stage: which layers it owns and which devices run it."""

    layers: tuple[int, ...]            # global layer indices (contiguous)
    device_ids: tuple[int, ...]        # devices forming this stage's TP x DP block


@dataclass(frozen=True)
class ParallelPlan:
    """Hybrid-parallel execution plan (output spec, paper §3.2.2)."""

    dp: int = 1
    tp: int = 1
    pp: int = 1
    ep: int = 1                         # expert parallel degree (MoE archs)
    sp: bool = True                     # sequence-parallel norm/dropout regions
    microbatches: int = 1
    stages: tuple[StageAssignment, ...] = ()
    # uneven data-parallel batch shares, one per DP rank (sums to 1).
    batch_shares: tuple[float, ...] = ()
    # collective schedule: "allreduce" (naive) or "rs_ag" (decomposed, Fig. 3)
    grad_sync: str = "rs_ag"
    zero1: bool = True                  # shard optimizer states over DP
    remat: str = "selective"            # none | selective | full
    grad_compression: str = "none"      # none | int8 | topk
    meta: dict = field(default_factory=dict)

    # -- derived ---------------------------------------------------------------

    @property
    def world(self) -> int:
        return self.dp * self.tp * self.pp

    def layers_of_stage(self, s: int) -> tuple[int, ...]:
        return self.stages[s].layers if self.stages else ()

    def structural_key(self) -> tuple:
        """Identity of everything the simulator reads; ``meta`` is excluded
        so plans differing only in provenance compare equal (score-cache
        keys, replan-switch detection)."""
        return (self.dp, self.tp, self.pp, self.ep, self.sp,
                self.microbatches, self.stages, self.batch_shares,
                self.grad_sync, self.zero1, self.remat,
                self.grad_compression)

    def validate(self, n_layers: int) -> None:
        if self.stages:
            got = [l for st in self.stages for l in st.layers]
            if sorted(got) != list(range(n_layers)):
                raise ValueError(
                    f"stage layers {got} do not cover 0..{n_layers - 1}")
        if self.batch_shares:
            if len(self.batch_shares) != self.dp:
                raise ValueError("batch_shares length must equal dp")
            if abs(sum(self.batch_shares) - 1.0) > 1e-6:
                raise ValueError("batch_shares must sum to 1")
        if self.microbatches < 1:
            raise ValueError("microbatches must be >= 1")

    # -- serialization (plans are checkpointed for elastic restart) -----------

    def to_json(self) -> str:
        return json.dumps(asdict(self), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "ParallelPlan":
        d = json.loads(s)
        d["stages"] = tuple(StageAssignment(tuple(st["layers"]),
                                            tuple(st["device_ids"]))
                            for st in d["stages"])
        d["batch_shares"] = tuple(d["batch_shares"])
        return ParallelPlan(**d)

    def describe(self) -> str:
        parts = [f"dp={self.dp} tp={self.tp} pp={self.pp}"]
        if self.ep > 1:
            parts.append(f"ep={self.ep}")
        parts.append(f"mb={self.microbatches} sync={self.grad_sync}")
        if self.stages and len({len(s.layers) for s in self.stages}) > 1:
            parts.append("layers=" + "/".join(str(len(s.layers))
                                              for s in self.stages))
        if self.batch_shares and len(set(self.batch_shares)) > 1:
            parts.append("shares=" + ",".join(f"{s:.2f}"
                                              for s in self.batch_shares))
        return " ".join(parts)


# ---------------------------------------------------------------------------
# Uniform helpers
# ---------------------------------------------------------------------------


def uniform_stages(n_layers: int, pp: int,
                   device_groups: Sequence[Sequence[int]]) -> tuple[StageAssignment, ...]:
    """Megatron-style uniform contiguous layer split."""
    base, rem = divmod(n_layers, pp)
    stages = []
    start = 0
    for s in range(pp):
        size = base + (1 if s < rem else 0)
        stages.append(StageAssignment(tuple(range(start, start + size)),
                                      tuple(device_groups[s])))
        start += size
    return tuple(stages)


def stages_from_sizes(sizes: Sequence[int],
                      device_groups: Sequence[Sequence[int]]) -> tuple[StageAssignment, ...]:
    """Build stage assignments from per-stage layer counts: stage ``s``
    holds the next ``sizes[s]`` consecutive layers on
    ``device_groups[s]``."""
    stages = []
    start = 0
    for s, size in enumerate(sizes):
        stages.append(StageAssignment(tuple(range(start, start + size)),
                                      tuple(device_groups[s])))
        start += size
    return tuple(stages)


def split_devices(topo: ClusterTopology, dp: int, tp: int, pp: int,
                  *, sort_by_speed: bool = False) -> list[list[int]]:
    """Group alive devices into pp stage groups of dp*tp devices each.

    With ``sort_by_speed`` the fastest devices land in the first stages —
    the natural layout for heterogeneous pipelines (paper §4.1 layer-level
    task assignment gives early/late stages different work)."""
    ids = topo.alive_ids()
    if sort_by_speed:
        ids = sorted(ids, key=lambda i: -topo.device(i).spec.peak_flops
                     * topo.device(i).perf_factor)
    need = dp * tp * pp
    if len(ids) < need:
        raise ValueError(f"cluster has {len(ids)} devices, plan needs {need}")
    ids = ids[:need]
    per_stage = dp * tp
    return [ids[s * per_stage:(s + 1) * per_stage] for s in range(pp)]


def megatron_default_plan(topo: ClusterTopology, model: ModelDesc, *,
                          gpus_per_node: int = 8,
                          microbatches: int | None = None) -> ParallelPlan:
    """The paper's baseline: Megatron default configuration.

    TP = min(gpus_per_node, heads divisor), PP grows until the model fits
    memory, DP takes the rest; uniform layers, even batch shares, naive
    all-reduce gradient sync, no heterogeneity awareness.
    """
    n = len(topo.alive_ids())
    tp = 1
    for cand in (8, 4, 2, 1):
        if cand <= gpus_per_node and cand <= n and model.n_heads % cand == 0 \
                and n % cand == 0:
            tp = cand
            break
    # memory-driven pp (uniform split): params*9 bytes (p+g+adam) per replica
    mem_per_dev = min(d.spec.mem_bytes for d in topo.alive_devices)
    state_bytes = model.total_params() * (2 + 2 + 8)
    pp = 1
    while pp < n // tp:
        if state_bytes / (tp * pp) * 1.35 < mem_per_dev * 0.9:
            break
        pp *= 2
    pp = max(1, min(pp, n // tp, model.n_layers))
    dp = max(1, n // (tp * pp))
    groups = split_devices(topo, dp, tp, pp)
    mb = microbatches if microbatches is not None else max(1, 4 * pp)
    return ParallelPlan(
        dp=dp, tp=tp, pp=pp,
        microbatches=mb,
        stages=uniform_stages(model.n_layers, pp, groups),
        batch_shares=tuple([1.0 / dp] * dp),
        grad_sync="allreduce", zero1=False,
        meta={"source": "megatron-default"})
