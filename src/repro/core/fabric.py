"""Unified fabric transfer model (ISSUE 8 tentpole).

Transfer pricing used to be quadruplicated — :func:`repro.core.costmodel.
transfer_time`, the simulator's ``hop_ready``/``edge_ready_time`` relay,
:meth:`repro.core.reconfig.ReconfigCostModel._path_time` and
:meth:`repro.core.routing.Route.transfer_time` each re-implemented the
"latency + size / bandwidth, store-and-forward over the widest route"
formula — so any fidelity fix had to land four times or drift.  This module
owns the single implementation; every former call site delegates here.

Pricing model
-------------

A :class:`FabricModel` prices one logical transfer of ``size`` bytes as a
stream of ``K = ceil(size / chunk_bytes)`` cut-through chunks of
``c = size / K`` bytes:

* **direct link** (single hop, bandwidth ``bw``, latency ``l``)::

      T = alpha * l + size / (beta * bw)

  identical to :meth:`repro.core.cluster.Edge.transfer_time` at the default
  calibration ``alpha = beta = 1``;

* **relayed route** (hops ``h`` with latencies ``l_h``, bandwidths ``bw_h``,
  bottleneck ``bneck = min bw_h``, resistance ``R = sum 1/bw_h``), chunks
  pipeline through the relays instead of store-and-forward::

      T = alpha * sum(l_h) + c * R / beta + (K - 1) * c / (beta * bneck)
        =  latency        +  pipeline fill +  size drained at bottleneck rate

  For ``K -> inf`` this approaches ``latency + size / bneck``; for ``K = 1``
  (or a single hop) it degenerates to the store-and-forward sum
  ``latency + size * R``.  Three invariants hold for every route (the
  hypothesis suite in ``tests/test_fabric.py`` locks them in):

  1. pipelined <= store-and-forward (``latency + size * R``),
  2. == the direct-link price on single-hop routes,
  3. >= the slowest single hop's own price ``alpha*l_h + size/(beta*bw_h)``.

  Invariant 3 is what the coarse search tier's per-hop/connectivity caps
  rest on (see ``docs/search.md``): a routed pair's end-to-end bandwidth
  never exceeds its bottleneck hop's bandwidth.

* **ring collectives** (:meth:`FabricModel.ring_capacity`): a collective
  *streams* continuously, so a relayed ring pair sustains its route's
  bottleneck rate — but every physical link it relays over is shared with
  the other ring pairs routed across that link.  The sustained per-pair
  rate is therefore ``min over hops of beta * bw_link / load(link)`` where
  ``load`` counts how many of the ring's pair-routes traverse the link.
  This replaces the old resistance-sum pricing (``1 / R``), which modeled
  relays as store-and-forward; it is never above any hop's bandwidth, so
  the coarse tier's caps stay admissible (``docs/search.md``).

With ``pipelining=False`` the model reproduces the pre-fabric
store-and-forward pricing exactly (at ``alpha = beta = 1``) — benchmarks
use :func:`use_fabric` to measure the pipelined-vs-store-and-forward delta.

Calibration
-----------

``alpha`` scales every latency term and ``beta`` scales every bandwidth
term; ``tools/calibrate_fabric.py`` fits them from measured JAX transfer /
collective microbenchmark sweeps (least squares on ``t = alpha*l +
size/(beta*bw)``) and gates the simulated-vs-measured step error.

The process-wide default instance (:func:`default_fabric`) is what the
cost model, simulator and reconfig pricing consult; ``SearchExecutor``
ships it to worker processes so serial and process-parallel searches price
identically even under a non-default calibration.
"""

from __future__ import annotations

import math
from contextlib import contextmanager
from dataclasses import dataclass, replace
from typing import TYPE_CHECKING, Iterator, Sequence

if TYPE_CHECKING:                                     # pragma: no cover
    from .cluster import ClusterTopology, Edge
    from .routing import Route, RoutingTable


def _has_live_direct(topo: "ClusterTopology", a: int, b: int) -> bool:
    """True iff the pair has a direct link with positive effective
    bandwidth (a fully degraded link routes like a missing one)."""
    link = topo.link(a, b)
    return link is not None and any(e.effective_bandwidth > 0
                                    for e in link.edges)


@dataclass(frozen=True)
class FabricModel:
    """The one routed-transfer pricing implementation (see module doc).

    Frozen/picklable on purpose: search worker processes receive the
    parent's instance verbatim, and :func:`use_fabric` swaps whole
    instances rather than mutating shared state.
    """

    chunk_bytes: float = float(1 << 20)   # cut-through chunk size (1 MiB)
    alpha: float = 1.0                    # latency calibration scale
    beta: float = 1.0                     # bandwidth efficiency scale
    pipelining: bool = True               # False -> store-and-forward

    # -- primitives ------------------------------------------------------------

    def chunks(self, size: float) -> int:
        """Number of cut-through chunks a transfer is split into."""
        if not self.pipelining or size <= 0 or self.chunk_bytes <= 0:
            return 1
        return max(1, math.ceil(size / self.chunk_bytes))

    def hop_time(self, size: float, bw: float, latency: float) -> float:
        """One physical hop: ``alpha * latency + size / (beta * bw)``."""
        if bw <= 0:
            return math.inf
        return self.alpha * latency + size / (self.beta * bw)

    def linear_bw(self, bw: float) -> float:
        """Linearized pricing hook for the admissible search bounds (the
        coarse and LP tiers): the highest sustained rate this fabric can
        deliver over a link of nominal bandwidth ``bw`` — the latency-free,
        chunking-free limit of :meth:`hop_time`.  Clamped at the nominal
        rate so a (non-physical) ``beta > 1`` calibration cannot lift a
        lower bound above the raw-bandwidth caps the admissibility
        arguments are stated for; under the calibrated ``beta <= 1`` this
        *tightens* the bounds to match the scaled simulator."""
        return bw * min(1.0, self.beta)

    def edge_time(self, edge: "Edge", size: float) -> float:
        """Price ``size`` bytes on one physical edge (calibrated)."""
        return self.hop_time(size, edge.effective_bandwidth, edge.latency)

    # -- routed transfers ------------------------------------------------------

    def route_time(self, route: "Route", size: float) -> float:
        """End-to-end time of one transfer along ``route`` (closed form).

        Equals the simulator's per-hop relay recursion on an uncontended
        fabric (``tests/test_fabric.py`` asserts the identity), so every
        pricing path that consults the fabric returns the same number.
        """
        if route.hops <= 0:
            return 0.0
        if route.bottleneck_bw <= 0 or not math.isfinite(route.resistance):
            return math.inf
        if not self.pipelining:
            return self.alpha * route.latency + size * route.resistance / self.beta
        k = self.chunks(size)
        c = size / k
        return (self.alpha * route.latency
                + c * route.resistance / self.beta
                + (k - 1) * c / (self.beta * route.bottleneck_bw))

    def store_and_forward_time(self, route: "Route", size: float) -> float:
        """The un-pipelined reference price (sum of per-hop times)."""
        if route.hops <= 0:
            return 0.0
        return self.alpha * route.latency + size * route.resistance / self.beta

    def pair_bandwidth(self, route: "Route") -> float:
        """Sustained end-to-end bandwidth of a routed pair: the bottleneck
        hop rate under pipelining, the store-and-forward ``1/resistance``
        otherwise (both ``beta``-scaled)."""
        if route.hops <= 0:
            return math.inf
        if self.pipelining:
            return self.beta * route.bottleneck_bw
        if route.resistance <= 0:
            return math.inf
        return self.beta / route.resistance

    # -- the four ported call sites --------------------------------------------

    def transfer_time(self, topo: "ClusterTopology", a: int, b: int,
                      size: float, *, edge: "Edge | None" = None,
                      routing: "RoutingTable | None" = None) -> float:
        """T_comm(size, l_alpha): one logical transfer ``a -> b``.

        Dispatch: explicit ``edge`` > live direct link (best edge) > widest
        multi-hop route (pipelined) > unreachable (``inf``).  Hot loops
        pricing many pairs should fetch ``topo.routing()`` once and pass it
        as ``routing``."""
        if a == b:
            return 0.0
        if edge is not None:
            return self.edge_time(edge, size)
        if _has_live_direct(topo, a, b):
            return self.edge_time(topo.link(a, b).best_edge(size), size)
        table = routing if routing is not None else topo.routing()
        route = table.route(a, b)
        if route is None:
            return math.inf
        return self.route_time(route, size)

    def path_time(self, topo: "ClusterTopology", a: int, b: int, size: float,
                  *, routing: "RoutingTable | None" = None
                  ) -> tuple[float, float]:
        """(seconds, sustained bandwidth) for one transfer — the reconfig
        reshard pricing entry point.  Unreachable pairs return
        ``(inf, 0.0)``; callers fall back to the host checkpoint store."""
        if _has_live_direct(topo, a, b):
            link = topo.link(a, b)
            return (self.edge_time(link.best_edge(size), size),
                    self.beta * max(e.effective_bandwidth
                                    for e in link.edges))
        table = routing if routing is not None else topo.routing()
        route = table.route(a, b)
        if route is None:
            return math.inf, 0.0
        return self.route_time(route, size), self.pair_bandwidth(route)

    def ring_capacity(self, topo: "ClusterTopology", ranks: Sequence[int],
                      *, routing: "RoutingTable | None" = None
                      ) -> tuple[float, float]:
        """(bandwidth, latency) of the slowest pair on the participant ring.

        Every consecutive pair contributes its physical hop path (the
        direct link, or the widest route).  Under pipelining the sustained
        per-pair rate is ``min over hops of beta * bw / load`` with
        ``load`` = number of the ring's pair-paths crossing that physical
        link *in the same direction* (links are full duplex, matching the
        analytic collective model's convention — a 2-rank ring exchanges
        both ways at full link rate) — relayed pairs stream at bottleneck
        rate but share directed link capacity with the pairs they relay
        through.  Without pipelining, routed pairs price at the
        store-and-forward ``beta / resistance`` (the pre-fabric model).
        A ring crossing a partition (no route) returns bandwidth 0 — the
        collective is unpriceable and the candidate infeasible."""
        if len(ranks) < 2:
            return math.inf, 0.0
        n = len(ranks)
        table = None
        # pair -> list of (link_key, bw) hops, plus the pair's latency
        paths: list[tuple[list[tuple[tuple[int, int], float]], float]] = []
        probe = float(1 << 20)
        for i in range(n):
            a, b = ranks[i], ranks[(i + 1) % n]
            if a == b:
                continue
            if _has_live_direct(topo, a, b):
                e = topo.link(a, b).best_edge(probe)
                paths.append(([((a, b), e.effective_bandwidth)], e.latency))
                continue
            if table is None:
                table = (routing if routing is not None else topo.routing())
            route = table.route(a, b)
            if route is None:
                return 0.0, 0.0
            hops: list[tuple[tuple[int, int], float]] = []
            for u, v in zip(route.path, route.path[1:]):
                hop = table.hop_price(u, v)
                hops.append(((u, v), hop[0] if hop is not None else 0.0))
            paths.append((hops, route.latency))
        if not paths:
            return math.inf, 0.0
        lat = self.alpha * max(p[1] for p in paths)
        if self.pipelining:
            load: dict[tuple[int, int], int] = {}
            for hops, _ in paths:
                for key, _bw in hops:
                    load[key] = load.get(key, 0) + 1
            bw = math.inf
            for hops, _ in paths:
                for key, hop_bw in hops:
                    bw = min(bw, self.beta * hop_bw / load[key])
            return bw, lat
        bw = math.inf
        for hops, _ in paths:
            if len(hops) == 1:
                bw = min(bw, self.beta * hops[0][1])
                continue
            res = sum(1.0 / hop_bw if hop_bw > 0 else math.inf
                      for _, hop_bw in hops)
            bw = min(bw, self.beta / res if res > 0 else math.inf)
        return bw, lat

    # -- simulator relay recursion ---------------------------------------------

    def relay_step(self, size: float, bw: float, latency: float,
                   hop_start: float, first_chunk_at: float,
                   prev_end: float | None) -> tuple[float, float]:
        """One hop of the cut-through relay recursion used by
        ``simulate_schedule``: returns ``(hop_end, next_first_chunk_at)``.

        ``hop_start`` is when this hop's edge actually starts forwarding
        (contention included); ``first_chunk_at`` is when the first chunk
        arrived at this hop's sender; ``prev_end`` is when the previous hop
        delivered its *last* chunk (``None`` on the first hop).  The hop
        finishes once it has serialized all chunks (``hop_start +
        hop_time(size)``) and once the last chunk has arrived and crossed
        (``prev_end + alpha*l + c/(beta*bw)``).  On an uncontended fabric
        the last hop's end equals :meth:`route_time`'s closed form —
        ``tests/test_fabric.py`` asserts the identity."""
        if bw <= 0:
            return math.inf, math.inf
        c = size / self.chunks(size)
        chunk_cross = self.alpha * latency + c / (self.beta * bw)
        end = hop_start + self.hop_time(size, bw, latency)
        if prev_end is not None:
            end = max(end, prev_end + chunk_cross)
        return end, hop_start + chunk_cross


# ---------------------------------------------------------------------------
# Process-wide default + scoped override
# ---------------------------------------------------------------------------

_default = FabricModel()


def default_fabric() -> FabricModel:
    """The fabric every pricing path consults unless handed one."""
    return _default


def set_default_fabric(fabric: FabricModel) -> FabricModel:
    """Install ``fabric`` as the process default; returns the previous one
    (e.g. applying a calibration from ``tools/calibrate_fabric.py``)."""
    global _default
    prev = _default
    _default = fabric
    return prev


@contextmanager
def use_fabric(fabric: FabricModel) -> Iterator[FabricModel]:
    """Scoped default-fabric override::

        with use_fabric(FabricModel(pipelining=False)):
            snf = simulate_training_step(...)   # store-and-forward pricing
    """
    prev = set_default_fabric(fabric)
    try:
        yield fabric
    finally:
        set_default_fabric(prev)


def calibrated(alpha: float, beta: float, *,
               base: FabricModel | None = None) -> FabricModel:
    """A copy of ``base`` (default: the current default fabric) with fitted
    calibration terms — what ``tools/calibrate_fabric.py`` installs."""
    return replace(base if base is not None else default_fabric(),
                   alpha=alpha, beta=beta)
