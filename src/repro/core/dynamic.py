"""Dynamic-network adaptation (paper §2.2): re-planning on temporal events.

Three mechanisms, matching the paper's scenarios S1-S3 (Fig. 1):

  * S1 bandwidth variation  — :func:`replan_on_event` re-runs the planner on
    the topology snapshot; the new plan may pick a different TP size or
    collective decomposition (the paper's Fig. 6c finding).
  * S2 stragglers           — :func:`reassign_for_straggler` performs a
    ReCycle-style local re-balance: shrink the slow device's layer share /
    batch share without a full re-plan.
  * S3 failures/joins       — :class:`PlanTemplates` precomputes Oobleck-style
    plans for descending device counts so failover is a table lookup, not a
    search.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

from ..obs import Obs, resolve_obs
from .cluster import ClusterTopology, NetworkEvent
from .opgraph import ModelDesc
from .planner import (PlanResult, bnb_layer_split, hetero_batch_shares,
                      plan_hybrid)
from .plans import ParallelPlan, StageAssignment, stages_from_sizes
from .simulator import simulate_training_step


# ---------------------------------------------------------------------------
# Oobleck-style templates (S3)
# ---------------------------------------------------------------------------


@dataclass
class PlanTemplates:
    """Pre-computed plans keyed by alive-device count.

    ``precompute`` plans for n, n-f1, n-f2, ... devices ahead of time (the
    paper cites Oobleck's pipeline templates); ``plan_for`` returns the best
    template not exceeding the current device count, so recovery needs no
    search in the critical path.
    """

    model: ModelDesc
    global_batch: int
    seq: int
    templates: dict[int, ParallelPlan] = field(default_factory=dict)

    @staticmethod
    def precompute(topo: ClusterTopology, model: ModelDesc, *,
                   global_batch: int, seq: int,
                   failure_budget: int = 2,
                   step: int | None = None) -> "PlanTemplates":
        """Plan for len(devices) - k for k in 0..failure_budget (k*step devs
        removed per template, default one node of 1)."""
        tpl = PlanTemplates(model, global_batch, seq)
        ids = topo.alive_ids()
        step = step or 1
        for k in range(failure_budget + 1):
            n = len(ids) - k * step
            if n < 1:
                break
            snap = topo.snapshot(0.0)
            # remove the k*step slowest devices — the most likely casualties
            # are interchangeable; any subset of size n yields the same shape
            for d in ids[n:]:
                snap.devices[d].alive = False
            try:
                res = plan_hybrid(snap, model, global_batch=global_batch,
                                  seq=seq, with_baseline=False)
                tpl.templates[n] = res.plan
            except RuntimeError:
                continue
        return tpl

    def plan_for(self, n_alive: int) -> ParallelPlan:
        usable = [k for k in self.templates if k <= n_alive]
        if not usable:
            raise KeyError(f"no template for {n_alive} devices")
        return self.templates[max(usable)]


# ---------------------------------------------------------------------------
# Straggler mitigation (S2)
# ---------------------------------------------------------------------------


def reassign_for_straggler(plan: ParallelPlan, model: ModelDesc,
                           topo: ClusterTopology, *,
                           batch: int, seq: int) -> ParallelPlan:
    """Local re-balance after a slowdown event: recompute layer split and
    batch shares against current perf factors, keeping dp/tp/pp fixed
    (ReCycle-style — no topology change, no checkpoint reload)."""
    groups = [list(st.device_ids) for st in plan.stages]
    if not groups:
        # plans built without explicit stages (templates, manual configs)
        # get the default device grouping before re-balancing
        from .plans import split_devices
        groups = split_devices(topo, plan.dp, plan.tp, plan.pp)
    if plan.pp > 1:
        sizes, _ = bnb_layer_split(model, topo, groups, plan.tp,
                                   batch=batch, seq=seq)
        stages = stages_from_sizes(sizes, groups)
    else:
        stages = plan.stages
    if plan.dp > 1:
        rank_devs = [[g[r * plan.tp] for g in groups]
                     for r in range(plan.dp)]
        shares = hetero_batch_shares(topo, rank_devs)
    else:
        shares = plan.batch_shares
    return ParallelPlan(
        dp=plan.dp, tp=plan.tp, pp=plan.pp, ep=plan.ep, sp=plan.sp,
        microbatches=plan.microbatches, stages=stages, batch_shares=shares,
        grad_sync=plan.grad_sync, zero1=plan.zero1, remat=plan.remat,
        grad_compression=plan.grad_compression,
        meta={**plan.meta, "source": "straggler-reassign"})


# ---------------------------------------------------------------------------
# Event-driven orchestration (S1 + S2 + S3)
# ---------------------------------------------------------------------------


@dataclass
class AdaptationRecord:
    """One adaptation taken by the orchestrator: the triggering event, the
    action chosen (keep / switch variant), and the step-time before/after
    plus the modeled plan-switch charge."""

    time: float
    event: NetworkEvent
    action: str
    old_step_time: float
    new_step_time: float
    # modeled reconfiguration charge for this adaptation's plan switch
    # (ReconfigCostModel via the engine; 0.0 when the plan was kept or the
    # engine-less legacy path was taken)
    switch_cost: float = 0.0


@dataclass
class DynamicOrchestrator:
    """Drives plan adaptation over a temporal topology.

    With an incremental :class:`repro.core.engine.ReplanEngine` attached
    (the default path wired by the trainer), every event goes through
    ``engine.replan`` — warm cache re-scoring for bandwidth shifts, local
    rebalance for stragglers, neighborhood-seeded search for device-set
    changes.  Without one, the legacy seed behaviour applies: S2 slowdowns
    get the cheap local reassignment; S3 failures consult the precomputed
    templates; S1 bandwidth changes trigger a full re-plan only if the
    current plan degrades by more than ``replan_threshold``."""

    model: ModelDesc
    global_batch: int
    seq: int
    templates: PlanTemplates | None = None
    engine: "object | None" = None       # ReplanEngine (duck-typed; avoids
    #                                      a core.engine import cycle)
    replan_threshold: float = 1.10
    history: list[AdaptationRecord] = field(default_factory=list)
    obs: Obs | None = None

    def _record(self, rec: AdaptationRecord) -> None:
        """Single funnel for adaptation telemetry: every action taken lands
        in ``history`` AND bumps the ``replan.action.<action>`` counter, so
        the registry and the hand-inspectable history cannot drift."""
        self.history.append(rec)
        resolve_obs(self.obs).inc(f"replan.action.{rec.action}")

    def adapt(self, plan: ParallelPlan, topo: ClusterTopology,
              event: NetworkEvent) -> ParallelPlan:
        snap = topo.snapshot(event.time)
        import math

        class _Inf:
            step_time = math.inf

        try:
            old = simulate_training_step(plan, self.model, topo,
                                         global_batch=self.global_batch,
                                         seq=self.seq, at_time=event.time)
        except (ValueError, ZeroDivisionError):
            old = _Inf()      # old plan infeasible on new topology (dead
            #                   stage after S3) -> any re-plan wins
        if self.engine is not None:
            if not isinstance(old, _Inf) \
                    and self.engine._device_key is not None:
                # the caller's *running* plan becomes the incumbent so warm
                # paths rebalance it (the engine's cached portfolio from its
                # cold plan stays valid for the same device set).  An engine
                # that never cold-planned has no pre-event baseline to
                # classify the delta against — leave incumbent unset and let
                # replan() take its cold path.
                self.engine.incumbent = (plan, old)
            res = self.engine.replan(snap, event)
            new_plan, action = res.plan, res.path
            new_step = res.predicted.step_time     # scored on this snapshot
            if getattr(res, "kept", False):
                # the engine's switch-cost hysteresis priced the move off
                # the incumbent (ReconfigCostModel) and kept it
                action = "keep"
            elif action == "bandwidth-rescore" \
                    and getattr(self.engine, "switch_horizon_s", None) \
                    is None \
                    and old.step_time / max(res.predicted.step_time, 1e-12) \
                    < self.replan_threshold:
                # legacy threshold hysteresis: only applies when no
                # remaining-horizon budget makes the cost model decisive
                new_plan, action, new_step = plan, "keep", old.step_time
            self._record(AdaptationRecord(
                time=event.time, event=event, action=action,
                old_step_time=old.step_time, new_step_time=new_step,
                switch_cost=0.0 if action == "keep"
                else getattr(res, "switch_cost", 0.0)))
            return new_plan
        if event.kind == "fail":
            n_alive = len(snap.alive_ids())
            if self.templates is not None:
                try:
                    new_plan = self.templates.plan_for(n_alive)
                    action = "template-failover"
                except KeyError:
                    new_plan = plan_hybrid(snap, self.model,
                                           global_batch=self.global_batch,
                                           seq=self.seq,
                                           with_baseline=False).plan
                    action = "full-replan"
            else:
                new_plan = plan_hybrid(snap, self.model,
                                       global_batch=self.global_batch,
                                       seq=self.seq,
                                       with_baseline=False).plan
                action = "full-replan"
        elif event.kind == "slowdown":
            new_plan = reassign_for_straggler(
                plan, self.model, snap,
                batch=self.global_batch, seq=self.seq)
            action = "straggler-reassign"
        else:  # bandwidth / join
            res = plan_hybrid(snap, self.model,
                              global_batch=self.global_batch, seq=self.seq,
                              with_baseline=False)
            candidate = res.plan
            cand_sim = res.predicted
            if old.step_time / max(cand_sim.step_time, 1e-12) \
                    >= self.replan_threshold:
                new_plan, action = candidate, "bandwidth-replan"
            else:
                new_plan, action = plan, "keep"
        new = simulate_training_step(new_plan, self.model, topo,
                                     global_batch=self.global_batch,
                                     seq=self.seq, at_time=event.time)
        self._record(AdaptationRecord(
            time=event.time, event=event, action=action,
            old_step_time=old.step_time, new_step_time=new.step_time))
        return new_plan
