"""Discrete-event simulator for heterogeneous, dynamic clusters.

This is our stand-in for SimAI (paper §4): a deterministic performance model
that predicts task execution times under the paper's constraint system:

  Eq. 4  data dependencies   — an op starts after its preds and their transfers,
  Eq. 5  communication       — a transfer starts after its producer finishes,
  Eq. 6  memory              — per-device residency must fit (checked statically),
  Eq. 7  bandwidth           — transfers on one physical edge-class serialize
                               (exclusive use at rate B_alpha).  Pairs without
                               a live direct link relay hop-by-hop along the
                               cached widest route (repro.core.routing); every
                               relay hop claims its physical edge, so relayed
                               traffic contends with direct traffic.

Two levels are provided:

  * :func:`simulate_schedule` — faithful event-driven simulation of an
    arbitrary op DAG with an explicit device assignment, including dynamic
    bandwidth events re-rating in-flight transfers (temporal graph, §2.2).
    This is what the branch-and-bound planner evaluates.
  * :func:`simulate_training_step` / :func:`simulate_epoch` — model-level
    hybrid-parallel (DP/TP/PP/EP) step simulation with 1F1B pipelining,
    uneven heterogeneous batch shares and layer assignments, naive vs
    decomposed gradient sync.  This is the resolution the paper evaluates at
    (its §5 notes SimAI limits it to Megatron-style model-level assignment).
"""

from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

from .cluster import ClusterTopology, DeviceInstance, Edge, NetworkEvent
from .costmodel import _has_live_edge, collective_time, op_time, transfer_time
from .fabric import default_fabric
from .opgraph import CommOp, ModelDesc, OpGraph, layer_flops
from .plans import ParallelPlan

# ---------------------------------------------------------------------------
# Level 1: faithful DAG simulation
# ---------------------------------------------------------------------------


@dataclass
class SimResult:
    """Outcome of one discrete-event schedule simulation: end-to-end
    makespan, per-op start/end times, per-device busy time and aggregate
    communication volume/time."""

    makespan: float
    op_start: dict[str, float]
    op_end: dict[str, float]
    device_busy: dict[int, float]
    comm_bytes: float
    comm_time: float

    def utilization(self, topo: ClusterTopology) -> dict[int, float]:
        if self.makespan <= 0:
            return {d: 0.0 for d in self.device_busy}
        return {d: b / self.makespan for d, b in self.device_busy.items()}


class _EdgeClass:
    """Serialization domain: one physical edge (plus its conflict partners)."""

    __slots__ = ("edge", "free_at")

    def __init__(self, edge: Edge):
        self.edge = edge
        self.free_at = 0.0


def _edge_classes(topo: ClusterTopology) -> dict[tuple[int, int, str], _EdgeClass]:
    out: dict[tuple[int, int, str], _EdgeClass] = {}
    for (a, b), link in topo.links.items():
        for e in link.edges:
            out[(a, b, e.tag)] = _EdgeClass(e)
    return out


def check_memory(graph: OpGraph, assignment: Mapping[str, int],
                 topo: ClusterTopology) -> dict[int, float]:
    """Eq. 6: per-device residency.  Returns bytes per device; raises nothing —
    the planner compares against capacity for pruning."""
    usage: dict[int, float] = {}
    for name, dev in assignment.items():
        op = graph.nodes[name]
        usage[dev] = usage.get(dev, 0.0) + op.params_bytes + op.mem_required
    for (u, v), size in graph.edges.items():
        du, dv = assignment.get(u), assignment.get(v)
        if du is not None and dv is not None and du != dv:
            usage[dv] = usage.get(dv, 0.0) + size
    return usage


def memory_feasible(graph: OpGraph, assignment: Mapping[str, int],
                    topo: ClusterTopology, *, headroom: float = 0.95) -> bool:
    """True when every device's working set under ``assignment`` fits in
    ``headroom`` of its memory (see :func:`check_memory`)."""
    for dev, used in check_memory(graph, assignment, topo).items():
        if used > topo.device(dev).spec.mem_bytes * headroom:
            return False
    return True


def simulate_schedule(graph: OpGraph, assignment: Mapping[str, int],
                      topo: ClusterTopology, *,
                      priority: Sequence[str] | None = None,
                      apply_events: bool = True,
                      start_time: float = 0.0,
                      obs=None) -> SimResult:
    """Event-driven simulation of ``graph`` under ``assignment``.

    Ops on one device run serially in ready order (ties broken by the given
    priority / topological order).  Each cross-device dependency becomes a
    transfer that must win exclusive use of one physical edge; conflicting
    edge tags (paper Fig. 5b) share a serialization domain.  Dynamic
    bandwidth events re-rate in-flight transfers at their event time.

    Relayed transfers pipeline cut-through chunks through the default
    :class:`repro.core.fabric.FabricModel` (every hop still claims its
    physical edge, so relay traffic serializes against direct traffic);
    ``obs`` records ``fabric.relays`` / ``fabric.relay_hops`` /
    ``fabric.chunks`` counters (no-op by default).
    """
    from ..obs import resolve_obs
    obs = resolve_obs(obs)
    fabric = default_fabric()
    topo = topo.snapshot(start_time) if apply_events else topo
    order = priority or graph.topo_order()
    rank = {n: i for i, n in enumerate(order)}
    classes = _edge_classes(topo)
    # hoisted: the sim's topology is immutable for the whole run, so one
    # table serves every relayed transfer (construction is O(links); the
    # per-source widest-path trees stay lazy inside it)
    route_table = topo.routing()
    # conflict partners share the max free_at: map tag -> sibling tags
    dev_free = {d: 0.0 for d in topo.devices}
    op_start: dict[str, float] = {}
    op_end: dict[str, float] = {}
    xfer_end: dict[tuple[str, str], float] = {}
    busy: dict[int, float] = {d: 0.0 for d in topo.devices}
    comm_bytes = 0.0
    comm_time = 0.0

    pending_events = [e for e in topo.events if e.time > start_time] \
        if apply_events else []

    remaining = set(graph.nodes)
    n_preds = {v: len(graph.preds(v)) for v in graph.nodes}
    done_preds = {v: 0 for v in graph.nodes}

    def hop_earliest(link, key: tuple[int, int], e: Edge, cls: _EdgeClass,
                     not_before: float) -> float:
        """Earliest start on one physical edge: queueing behind the edge's
        own traffic plus its conflict partners (they serialize together)."""
        conflict_free = max(
            [classes[(key[0], key[1], o.tag)].free_at
             for o in link.edges
             if o.tag in e.conflicts_with or e.tag in o.conflicts_with],
            default=0.0)
        return max(not_before, cls.free_at, conflict_free)

    def hop_ready(a: int, b: int, size: float,
                  not_before: float) -> tuple[float, float, _EdgeClass]:
        """(start, end, edge_class) for the best physical edge on the
        direct link ``a``-``b``, queueing included."""
        link = topo.link(a, b)
        key = (min(a, b), max(a, b))
        best = None
        for e in link.edges:
            cls = classes[(key[0], key[1], e.tag)]
            st = hop_earliest(link, key, e, cls, not_before)
            en = st + fabric.edge_time(e, size)
            if best is None or en < best[1]:
                best = (st, en, cls)
        return best  # type: ignore[return-value]

    def edge_ready_time(a: int, b: int, size: float, not_before: float
                        ) -> tuple[float, float, list[tuple[_EdgeClass, float]]]:
        """(start, end, claims) for one logical transfer.

        Direct pairs pick the best physical edge on their link.  Pairs
        without a live direct link relay cut-through chunks hop-by-hop
        along the cached widest route (:mod:`repro.core.routing`) via the
        fabric's relay recursion — hop ``h`` finishes once it has
        serialized all chunks *and* the last chunk has arrived from hop
        ``h-1``, so on an uncontended fabric the final hop's end equals
        :meth:`repro.core.fabric.FabricModel.route_time`'s closed form.
        Every hop still claims its physical edge's serialization domain,
        so relay traffic contends with direct traffic on the same links
        (paper Fig. 5b generalized).  ``claims`` are (edge_class,
        busy_until) pairs the caller commits once the transfer is
        scheduled.  Unroutable pairs (partitioned cluster) finish at
        ``inf``."""
        if a == b:
            return not_before, not_before, []
        if _has_live_edge(topo, a, b):
            st, en, cls = hop_ready(a, b, size, not_before)
            return st, en, [(cls, en)]
        route = route_table.route(a, b)
        if route is None:
            return not_before, math.inf, []
        first_chunk_at = not_before
        prev_end: float | None = None
        st0 = not_before
        claims: list[tuple[_EdgeClass, float]] = []
        for hi, (u, v) in enumerate(zip(route.path, route.path[1:])):
            link = topo.link(u, v)
            key = (min(u, v), max(u, v))
            best = None
            for e in link.edges:
                cls = classes[(key[0], key[1], e.tag)]
                st = hop_earliest(link, key, e, cls, first_chunk_at)
                en, nxt = fabric.relay_step(
                    size, e.effective_bandwidth, e.latency,
                    st, first_chunk_at, prev_end)
                if best is None or en < best[0]:
                    best = (en, st, nxt, cls)
            en, st, nxt, cls = best  # type: ignore[misc]
            if hi == 0:
                st0 = st
            claims.append((cls, en))
            prev_end = en
            first_chunk_at = nxt
        obs.inc("fabric.relays")
        obs.inc("fabric.relay_hops", len(claims))
        obs.inc("fabric.chunks", fabric.chunks(size))
        return st0, prev_end, claims  # type: ignore[return-value]

    # Kahn-style scheduling loop: repeatedly place the ready op whose device
    # is available earliest; deterministic by (ready-rank) priority.
    ready = [v for v in order if n_preds[v] == 0]
    while remaining:
        if not ready:
            raise RuntimeError("deadlock: no ready ops but graph not done")
        # choose the ready op with the smallest priority rank
        v = min(ready, key=lambda n: rank[n])
        ready.remove(v)
        dev = assignment[v]
        # data-arrival time: all incoming transfers must complete (Eq. 4)
        arrive = 0.0
        for u in graph.preds(v):
            du = assignment[u]
            size = graph.edges[(u, v)]
            if du == dev:
                arrive = max(arrive, op_end[u])
            else:
                st, en, claims = edge_ready_time(du, dev, size,
                                                 not_before=op_end[u])  # Eq. 5
                for cls, busy_until in claims:
                    cls.free_at = busy_until
                xfer_end[(u, v)] = en
                comm_bytes += size
                comm_time += en - st
                arrive = max(arrive, en)
        st = max(arrive, dev_free[dev], start_time)
        dur = op_time(graph.nodes[v], topo.device(dev))
        # dynamic bandwidth events don't change compute; device slowdown
        # events between start_time and st are visible via snapshot+replay:
        for ev in pending_events:
            if ev.kind == "slowdown" and ev.device_id == dev and ev.time <= st:
                dur = op_time(graph.nodes[v], DeviceInstance(
                    dev, topo.device(dev).spec, perf_factor=ev.factor))
        en = st + dur
        op_start[v], op_end[v] = st, en
        dev_free[dev] = en
        busy[dev] += dur
        remaining.discard(v)
        for s in graph.succs(v):
            done_preds[s] += 1
            if done_preds[s] == n_preds[s]:
                ready.append(s)

    makespan = max(op_end.values(), default=0.0) - start_time
    return SimResult(makespan=makespan, op_start=op_start, op_end=op_end,
                     device_busy=busy, comm_bytes=comm_bytes,
                     comm_time=comm_time)


# ---------------------------------------------------------------------------
# Level 2: hybrid-parallel training-step simulation
# ---------------------------------------------------------------------------


@dataclass
class StepSim:
    """Predicted timing of one optimizer step under a ParallelPlan."""

    step_time: float
    compute_time: float
    tp_comm_time: float
    pp_comm_time: float
    dp_sync_time: float
    bubble_time: float
    breakdown: dict = field(default_factory=dict)


def _stage_device(topo: ClusterTopology, stage_devices: Sequence[int]) -> DeviceInstance:
    """Slowest alive device in the stage group bounds the stage (synchronous TP)."""
    devs = [topo.device(d) for d in stage_devices if topo.device(d).alive]
    if not devs:
        raise ValueError("stage has no alive devices")
    return min(devs, key=lambda d: d.spec.peak_flops * d.perf_factor)


def _tp_group_time(topo: ClusterTopology, stage_devices: Sequence[int],
                   tp: int, size: float) -> float:
    """One activation all-reduce over the first TP subgroup of the stage."""
    if tp <= 1:
        return 0.0
    group = tuple(stage_devices[:tp])
    return collective_time(
        topo, CommOp("tp_ar", "all_reduce", size, group))


def simulate_training_step(plan: ParallelPlan, model: ModelDesc,
                           topo: ClusterTopology, *,
                           global_batch: int, seq: int,
                           at_time: float = 0.0) -> StepSim:
    """Deterministic step-time prediction for a hybrid-parallel plan.

    Per DP rank r (batch share w_r), per pipeline stage s, per microbatch:
      fwd_s = sum_{l in stage s} roofline(layer flops / tp on slowest stage dev)
              + per-layer TP collectives (+ EP all-to-all for MoE layers)
      bwd_s ~= 2 * fwd compute + same collectives
    The 1F1B schedule is simulated exactly over (stages x microbatches); the
    step ends after the slowest DP rank finishes its pipeline flush plus
    (non-overlapped) gradient synchronization.
    """
    plan.validate(model.n_layers)
    snap = topo.snapshot(at_time)
    dp, tp, pp, M = plan.dp, plan.tp, plan.pp, plan.microbatches
    shares = plan.batch_shares or tuple([1.0 / dp] * dp)
    stages = plan.stages
    if not stages:
        from .plans import split_devices, uniform_stages
        stages = uniform_stages(model.n_layers, pp, split_devices(snap, dp, tp, pp))
    db = model.dtype_bytes

    rank_makespans: list[float] = []
    total_compute = total_tp = total_pp = 0.0
    bubble = 0.0

    for r in range(dp):
        mb_batch = max(global_batch * shares[r] / M, 1e-9)
        act_bytes = mb_batch * seq * model.d_model * db
        fwd: list[float] = []
        bwd: list[float] = []
        p2p: list[float] = []
        for s, st in enumerate(stages):
            # the TP subgroup serving DP rank r inside this stage
            group = st.device_ids[r * tp:(r + 1) * tp] if len(st.device_ids) >= dp * tp \
                else st.device_ids
            dev = _stage_device(snap, group)
            f = 0.0
            tp_c = 0.0
            for l in st.layers:
                fl = layer_flops(model, l, 1, seq) * mb_batch  # scale by batch
                params = model.layer_params(l) * db
                traffic = (4 * act_bytes + params) / tp
                if not dev.spec.supports_fusion and model.layer_kind(l) == "attn":
                    # no fused attention on this device (paper §2.3 / Fig. 2):
                    # the S x S score matrix round-trips HBM in fwd and bwd.
                    traffic += 4 * mb_batch * model.n_heads * seq * seq * db / tp
                f += dev.spec.roofline_time(fl / tp, traffic,
                                            perf_factor=dev.perf_factor)
                if tp > 1:
                    # 2 activation all-reduces fwd (attn out + mlp out); with
                    # sequence parallelism these become AG+RS of equal volume.
                    n_coll = 2
                    tp_c += n_coll * _tp_group_time(snap, group, tp, act_bytes)
                if model.n_experts and plan.ep > 1 and model.layer_kind(l) == "attn":
                    a2a = collective_time(snap, CommOp(
                        "a2a", "all_to_all",
                        act_bytes * model.top_k, tuple(group)))
                    tp_c += 2 * a2a
            fwd.append(f + tp_c)
            bwd.append(2.0 * f + tp_c)
            total_compute += M * 3.0 * f
            total_tp += M * 2 * tp_c
            if s + 1 < len(stages):
                nxt = stages[s + 1].device_ids
                nxt_dev = nxt[r * tp] if len(nxt) >= dp * tp else nxt[0]
                cur_dev = group[0]
                p2p.append(transfer_time(snap, cur_dev, nxt_dev, act_bytes))
            # remat: full recompute adds ~1 fwd to bwd
            if plan.remat == "full":
                bwd[-1] += f
            elif plan.remat == "selective":
                bwd[-1] += 0.3 * f

        makespan = _simulate_1f1b(fwd, bwd, p2p, M)
        ideal = sum(M * (fwd[s] + bwd[s]) for s in range(len(stages))) / max(len(stages), 1)
        bubble = max(bubble, makespan - ideal)
        total_pp += 2 * M * sum(p2p)
        rank_makespans.append(makespan)

    pipe_time = max(rank_makespans)

    # Gradient sync across DP ranks, per stage (worst stage counts).
    dp_sync = 0.0
    if dp > 1:
        for st in stages:
            params_bytes = sum(model.layer_params(l) for l in st.layers) * db / tp
            # participants: one device per DP rank in this stage
            members = tuple(st.device_ids[r * tp] for r in range(dp)) \
                if len(st.device_ids) >= dp * tp else tuple(st.device_ids)
            if plan.grad_compression == "int8":
                params_bytes *= 0.5
            elif plan.grad_compression == "topk":
                params_bytes *= 0.15
            t = allreduce_like(snap, params_bytes, members,
                               decomposed=(plan.grad_sync == "rs_ag"))
            dp_sync = max(dp_sync, t)

    step = pipe_time + dp_sync
    return StepSim(step_time=step, compute_time=total_compute,
                   tp_comm_time=total_tp, pp_comm_time=total_pp,
                   dp_sync_time=dp_sync, bubble_time=bubble,
                   breakdown={"pipe_time": pipe_time,
                              "rank_makespans": rank_makespans})


def simulate_many(plans: Sequence[ParallelPlan], model: ModelDesc,
                  topo: ClusterTopology, *, global_batch: int, seq: int,
                  at_time: float = 0.0,
                  obs=None) -> list["StepSim | None"]:
    """Batch step simulation: score many plans against one topology state.

    The topology snapshot is materialized once for the whole batch (one
    event replay + deep copy instead of one per plan), which is what lets
    search worker processes amortize per-process setup across their chunk.
    Per-plan infeasibility (ValueError / ZeroDivisionError) yields ``None``
    instead of aborting the batch — identical semantics to scoring each
    plan alone, so batched and per-plan scoring are interchangeable.  A
    non-finite step time is infeasibility too: with routed transfer pricing
    an unroutable collective or p2p hop (partitioned cluster) simulates to
    ``inf``, and planning must reject such plans, not rank them.

    ``obs`` is a :class:`repro.obs.Obs` bundle; the batch records one
    ``sim.batch`` span and a ``sim.plans`` counter (no-op by default).
    """
    from ..obs import resolve_obs
    obs = resolve_obs(obs)
    snap = topo.snapshot(at_time)
    out: list[StepSim | None] = []
    with obs.span("sim.batch", n_plans=len(plans)) as sp:
        for plan in plans:
            try:
                sim = simulate_training_step(
                    plan, model, snap, global_batch=global_batch, seq=seq)
            except (ValueError, ZeroDivisionError):
                sim = None
            if sim is not None and not math.isfinite(sim.step_time):
                sim = None
            out.append(sim)
        sp.set(feasible=sum(1 for s in out if s is not None))
    obs.inc("sim.plans", len(plans))
    return out


def allreduce_like(topo: ClusterTopology, size: float, ranks: Sequence[int],
                   *, decomposed: bool) -> float:
    """Gradient-sync collective time over ``ranks`` (ring allreduce, or
    the decomposed reduce-scatter + all-gather when ``decomposed``);
    thin forwarding wrapper over :func:`repro.core.costmodel.allreduce_time`."""
    from .costmodel import allreduce_time
    return allreduce_time(topo, size, ranks, decomposed=decomposed)


def _simulate_1f1b(fwd: Sequence[float], bwd: Sequence[float],
                   p2p: Sequence[float], M: int) -> float:
    """Exact event simulation of the 1F1B schedule for one DP rank.

    Stage s runs its microbatch queue; forward of mb m on stage s needs
    forward of m on s-1 (plus p2p); backward of m on stage s needs backward
    of m on s+1 (plus p2p).  Steady-state 1F1B interleaving is enforced by
    the standard warmup rule (stage s admits pp-s forwards before its first
    backward)."""
    S = len(fwd)
    if S == 1:
        return M * (fwd[0] + bwd[0])
    f_done = [[0.0] * M for _ in range(S)]
    b_done = [[0.0] * M for _ in range(S)]
    # Each stage executes its 1F1B queue (warmup = min(S-s, M) forwards, then
    # alternate B/F, then drain).  Cross-stage dependencies resolve by
    # relaxation to a fixed point (bounded by pipeline depth).
    for _ in range(2 * (S + M) + 4):
        changed = False
        for s in range(S):
            order = _1f1b_order(S, s, M)
            t = 0.0
            for kind, m in order:
                if kind == "F":
                    dep = f_done[s - 1][m] + p2p[s - 1] if s > 0 else 0.0
                    st = max(t, dep)
                    en = st + fwd[s]
                    if f_done[s][m] != en:
                        f_done[s][m] = en
                        changed = True
                else:
                    dep = b_done[s + 1][m] + p2p[s] if s < S - 1 else f_done[s][m]
                    st = max(t, dep)
                    en = st + bwd[s]
                    if b_done[s][m] != en:
                        b_done[s][m] = en
                        changed = True
                t = f_done[s][m] if kind == "F" else b_done[s][m]
        if not changed:
            break
    return max(b_done[0])


def _1f1b_order(S: int, s: int, M: int) -> list[tuple[str, int]]:
    order: list[tuple[str, int]] = []
    warm = min(S - s, M)
    for m in range(warm):
        order.append(("F", m))
    nb, nf = 0, warm
    while nb < M:
        order.append(("B", nb))
        nb += 1
        if nf < M:
            order.append(("F", nf))
            nf += 1
    return order


# ---------------------------------------------------------------------------
# Epoch-level simulation with dynamic events (paper Fig. 6 setting)
# ---------------------------------------------------------------------------


@dataclass
class EpochSim:
    """Epoch-level simulation outcome: total wall time over ``steps``
    optimizer steps, the per-step times, and the re-plan count plus total
    modeled reconfiguration charge."""

    total_time: float
    steps: int
    step_times: list[float]
    replans: int = 0
    reconfig_s: float = 0.0      # total modeled plan-switch cost charged


def simulate_epoch(plan: ParallelPlan, model: ModelDesc, topo: ClusterTopology,
                   *, global_batch: int, seq: int, steps: int,
                   replan_fn: Callable[[ClusterTopology, float],
                                       ParallelPlan] | None = None,
                   reconfig: "object | None" = None,
                   reroute_in_flight: bool = True,
                   obs=None) -> EpochSim:
    """Simulate ``steps`` optimizer steps over the temporal topology.

    With ``reroute_in_flight`` (the default), a bandwidth/link event that
    lands *inside* a step no longer waits for the step boundary: the step
    is split at the event time, and the remaining fraction of its work is
    re-priced on the post-event topology snapshot — in-flight relayed
    transfers see the post-event routing table instead of holding the
    stale route (a degraded relay slows the step remainder immediately; a
    recovered link speeds it up).  ``reroute_in_flight=False`` restores
    the old boundary-only semantics.  ``obs`` records
    ``sim.reroute.events`` (events applied mid-step) and
    ``sim.reroute.steps`` (steps split at least once).

    If ``replan_fn`` is given, topology changes trigger re-planning at the
    next step boundary.  A re-plan that actually *switches* plans is charged
    the physically-modeled checkpoint/reshard cost (checkpoint bytes,
    reshard traffic, post-event bandwidths) through ``reconfig`` — a
    :class:`repro.core.reconfig.ReconfigCostModel`, built from ``model``
    when not supplied.  Re-plans that keep the incumbent cost nothing."""
    from ..obs import resolve_obs
    from .reconfig import ReconfigCostModel
    obs = resolve_obs(obs)
    if reconfig is None:
        reconfig = ReconfigCostModel(model)
    t = 0.0
    times: list[float] = []
    replans = 0
    reconfig_s = 0.0
    current = plan
    pending = sorted(topo.events, key=lambda e: e.time)
    ei = 0
    fired = False      # events seen since the last re-plan opportunity
    for _ in range(steps):
        # apply any events that fired at / before the step boundary
        while ei < len(pending) and pending[ei].time <= t:
            fired = True
            ei += 1
        if fired and replan_fn is not None:
            snap = topo.snapshot(t)
            new = replan_fn(snap, t)
            if new.structural_key() != current.structural_key():
                charge = reconfig.cost(current, new, snap).total_s
                t += charge
                reconfig_s += charge
            current = new
            replans += 1
            fired = False
        sim = simulate_training_step(current, model, topo,
                                     global_batch=global_batch, seq=seq,
                                     at_time=t)
        step_t = sim.step_time
        cur, frac = t, 1.0
        split = False
        if reroute_in_flight:
            while (ei < len(pending) and math.isfinite(step_t) and step_t > 0
                   and pending[ei].time < cur + frac * step_t):
                tau = pending[ei].time
                # progress made on the pre-event pricing, then re-price the
                # remaining work fraction on the post-event snapshot
                frac -= (tau - cur) / step_t
                cur = tau
                while ei < len(pending) and pending[ei].time <= tau:
                    ei += 1
                    fired = True
                    split = True
                    obs.inc("sim.reroute.events")
                step_t = simulate_training_step(
                    current, model, topo, global_batch=global_batch,
                    seq=seq, at_time=tau).step_time
        if split:
            obs.inc("sim.reroute.steps")
        step_time = (cur + frac * step_t) - t
        times.append(step_time)
        t += step_time
    return EpochSim(total_time=t, steps=steps, step_times=times,
                    replans=replans, reconfig_s=reconfig_s)
