"""Simulation cost model: per-op roofline + multi-edge collective timing.

The paper (§2.1) argues execution time is a nonlinear multivariate function of
(operator, device) that defeats ILP/DP planners, and uses a simulator (SimAI)
for deterministic predictions.  We provide the same interface:

  * ``op_time(op, device)``         — T_exec(v, d_j), roofline Eq. 1-2 with
                                      per-kind efficiency and fusion awareness,
  * ``transfer_time(...)``          — T_comm(size, l_alpha) on a chosen edge,
  * ``collective_time(...)``        — ring/tree collectives over the bottleneck
                                      edge of the participant set, with the
                                      naive vs decomposed all-reduce split the
                                      paper highlights (Fig. 3),

plus TPU-mesh helpers used by the planner when targeting the production mesh.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from .cluster import ClusterTopology, DeviceInstance, Edge
from .opgraph import CommOp, OpNode

# ---------------------------------------------------------------------------
# Compute
# ---------------------------------------------------------------------------


def op_time(op: OpNode, device: DeviceInstance) -> float:
    """T_exec(v, d_j): deterministic per-op time on a device (paper §3.2.1)."""
    if not device.alive:
        return math.inf
    return device.spec.roofline_time(
        op.flops, op.bytes_accessed,
        is_matmul=op.is_matmul, perf_factor=device.perf_factor)


def graph_compute_lower_bound(total_flops: float,
                              devices: Sequence[DeviceInstance]) -> float:
    """Perfectly-balanced work bound: total flops / aggregate throughput.
    Admissible lower bound used by the branch-and-bound (§3.3)."""
    agg = sum(d.spec.peak_flops * d.spec.matmul_eff * d.perf_factor
              for d in devices if d.alive)
    return total_flops / agg if agg > 0 else math.inf


# ---------------------------------------------------------------------------
# Point-to-point communication
# ---------------------------------------------------------------------------


def _has_live_edge(topo: ClusterTopology, a: int, b: int) -> bool:
    """True iff the pair has a direct link with positive effective
    bandwidth (a fully degraded link routes like a missing one)."""
    link = topo.link(a, b)
    return link is not None and any(e.effective_bandwidth > 0
                                    for e in link.edges)


def transfer_time(topo: ClusterTopology, a: int, b: int, size: float,
                  *, edge: Edge | None = None, routing=None) -> float:
    """T_comm(size, l_alpha): transfer over a selected physical edge.

    Pairs without a live direct link are priced over the topology's widest
    multi-hop route (:mod:`repro.core.routing`): store-and-forward, i.e.
    the sum of per-hop latencies plus per-hop serialization — never below
    any single hop's own time.  Unreachable pairs (partitioned cluster,
    dead relay) price at ``inf``.  Hot loops pricing many pairs should
    fetch ``topo.routing()`` once and pass it as ``routing`` — the cached
    lookup re-checks the topology state signature per call."""
    if a == b:
        return 0.0
    if edge is not None:
        return edge.transfer_time(size)
    if _has_live_edge(topo, a, b):
        return topo.link(a, b).best_edge(size).transfer_time(size)
    route = (routing if routing is not None else topo.routing()).route(a, b)
    if route is None:
        return math.inf
    return route.transfer_time(size)


# ---------------------------------------------------------------------------
# Collectives
# ---------------------------------------------------------------------------


def _bottleneck_bw(topo: ClusterTopology, ranks: Sequence[int]) -> tuple[float, float]:
    """(bandwidth, latency) of the slowest pair on the participant ring.

    Pairs without a live direct link are priced at their widest route's
    end-to-end bandwidth (``1 / sum(1/bw_hop)`` — relay hops serialize,
    :mod:`repro.core.routing`) instead of the old flat min-cluster-bw
    fallback, which was optimistic on sparse graphs and forced the coarse
    search tier to disable its ring caps there.  A ring crossing a
    partition (no route) returns bandwidth 0 — the collective is
    unpriceable and the candidate infeasible."""
    if len(ranks) < 2:
        return math.inf, 0.0
    bw = math.inf
    lat = 0.0
    n = len(ranks)
    table = None          # fetched once: routing() re-checks the topology
    #                       state signature per call, too hot for this loop
    for i in range(n):
        a, b = ranks[i], ranks[(i + 1) % n]
        if _has_live_edge(topo, a, b):
            e = topo.link(a, b).best_edge(1 << 20)
            bw = min(bw, e.effective_bandwidth)
            lat = max(lat, e.latency)
            continue
        if table is None:
            table = topo.routing()
        route = table.route(a, b)
        if route is None:
            return 0.0, 0.0
        bw = min(bw, route.effective_bandwidth)
        lat = max(lat, route.latency)
    return bw, lat


def collective_time(topo: ClusterTopology, comm: CommOp) -> float:
    """Deterministic collective cost on the multi-edge topology.

    ring reduce-scatter / all-gather move (n-1)/n of the data over the
    bottleneck edge; the naive reduce/broadcast pair funnels the full tensor
    through the root's single link (the single-node bottleneck the paper's
    Fig. 3 decomposition removes).
    """
    ranks = comm.participants
    n = len(ranks)
    if n <= 1 or comm.size <= 0:
        return 0.0
    bw, lat = _bottleneck_bw(topo, ranks)
    if bw <= 0:
        return math.inf
    steps_lat = (n - 1) * lat
    if comm.kind in ("reduce_scatter", "all_gather"):
        return steps_lat + (n - 1) / n * comm.size / bw
    if comm.kind == "all_reduce":
        return 2 * steps_lat + 2 * (n - 1) / n * comm.size / bw
    if comm.kind == "reduce":
        # gather full tensor at root: (n-1) peers each send size (serialized
        # on the root's ingress link).
        return steps_lat + (n - 1) * comm.size / bw
    if comm.kind == "broadcast":
        return steps_lat + (n - 1) * comm.size / bw
    if comm.kind == "all_to_all":
        return steps_lat + (n - 1) / n * comm.size / bw
    if comm.kind == "p2p":
        return transfer_time(topo, ranks[0], ranks[1], comm.size)
    raise ValueError(f"unknown collective kind {comm.kind}")


def allreduce_time(topo: ClusterTopology, size: float, ranks: Sequence[int],
                   *, decomposed: bool = True) -> float:
    """Fig. 3 comparison entry point."""
    if decomposed:
        rs = CommOp("rs", "reduce_scatter", size, tuple(ranks))
        ag = CommOp("ag", "all_gather", size, tuple(ranks))
        return collective_time(topo, rs) + collective_time(topo, ag)
    rd = CommOp("r", "reduce", size, tuple(ranks))
    bc = CommOp("b", "broadcast", size, tuple(ranks))
    return collective_time(topo, rd) + collective_time(topo, bc)


# ---------------------------------------------------------------------------
# TPU mesh shorthand (used when planning for the production pod)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshCollectiveModel:
    """Analytic collective costs on a TPU mesh axis.

    On a torus each mesh axis has its own ICI links (multi-edge!), so
    collectives on different axes do not contend; collectives on the same
    axis serialize.  This is the TPU analogue of the paper's conflicting
    NVLink/PCIe edges.
    """

    ici_bw: float = 50e9             # bytes/s per link per direction
    dci_bw: float = 12.5e9
    latency: float = 1e-6

    def axis_allreduce(self, size: float, axis_size: int,
                       *, inter_pod: bool = False) -> float:
        if axis_size <= 1:
            return 0.0
        bw = self.dci_bw if inter_pod else self.ici_bw
        # bidirectional ring: effective 2x link bw
        return 2 * (axis_size - 1) / axis_size * size / (2 * bw) \
            + 2 * (axis_size - 1) * self.latency

    def axis_allgather(self, size: float, axis_size: int,
                       *, inter_pod: bool = False) -> float:
        if axis_size <= 1:
            return 0.0
        bw = self.dci_bw if inter_pod else self.ici_bw
        return (axis_size - 1) / axis_size * size / (2 * bw) \
            + (axis_size - 1) * self.latency

    def axis_reduce_scatter(self, size: float, axis_size: int,
                            *, inter_pod: bool = False) -> float:
        return self.axis_allgather(size, axis_size, inter_pod=inter_pod)

    def axis_all_to_all(self, size: float, axis_size: int,
                        *, inter_pod: bool = False) -> float:
        if axis_size <= 1:
            return 0.0
        bw = self.dci_bw if inter_pod else self.ici_bw
        return (axis_size - 1) / axis_size * size / (2 * bw) / axis_size \
            + (axis_size - 1) * self.latency
