"""Simulation cost model: per-op roofline + multi-edge collective timing.

The paper (§2.1) argues execution time is a nonlinear multivariate function of
(operator, device) that defeats ILP/DP planners, and uses a simulator (SimAI)
for deterministic predictions.  We provide the same interface:

  * ``op_time(op, device)``         — T_exec(v, d_j), roofline Eq. 1-2 with
                                      per-kind efficiency and fusion awareness,
  * ``transfer_time(...)``          — T_comm(size, l_alpha) on a chosen edge,
  * ``collective_time(...)``        — ring/tree collectives over the bottleneck
                                      edge of the participant set, with the
                                      naive vs decomposed all-reduce split the
                                      paper highlights (Fig. 3),

plus TPU-mesh helpers used by the planner when targeting the production mesh.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from .cluster import ClusterTopology, DeviceInstance, Edge
from .fabric import default_fabric
from .opgraph import CommOp, OpNode

# ---------------------------------------------------------------------------
# Compute
# ---------------------------------------------------------------------------


def op_time(op: OpNode, device: DeviceInstance) -> float:
    """T_exec(v, d_j): deterministic per-op time on a device (paper §3.2.1)."""
    if not device.alive:
        return math.inf
    return device.spec.roofline_time(
        op.flops, op.bytes_accessed,
        is_matmul=op.is_matmul, perf_factor=device.perf_factor)


def graph_compute_lower_bound(total_flops: float,
                              devices: Sequence[DeviceInstance]) -> float:
    """Perfectly-balanced work bound: total flops / aggregate throughput.
    Admissible lower bound used by the branch-and-bound (§3.3)."""
    agg = sum(d.spec.peak_flops * d.spec.matmul_eff * d.perf_factor
              for d in devices if d.alive)
    return total_flops / agg if agg > 0 else math.inf


# ---------------------------------------------------------------------------
# Point-to-point communication
# ---------------------------------------------------------------------------


def _has_live_edge(topo: ClusterTopology, a: int, b: int) -> bool:
    """True iff the pair has a direct link with positive effective
    bandwidth (a fully degraded link routes like a missing one); alias of
    the fabric layer's liveness predicate."""
    from .fabric import _has_live_direct
    return _has_live_direct(topo, a, b)


def transfer_time(topo: ClusterTopology, a: int, b: int, size: float,
                  *, edge: Edge | None = None, routing=None) -> float:
    """T_comm(size, l_alpha): transfer over a selected physical edge.

    Thin delegate to the default :class:`repro.core.fabric.FabricModel` —
    the single transfer-pricing implementation.  Pairs without a live
    direct link are priced over the topology's widest multi-hop route
    (:mod:`repro.core.routing`) with chunked cut-through pipelining:
    never below any single hop's own time, never above the
    store-and-forward sum of hops.  Unreachable pairs (partitioned
    cluster, dead relay) price at ``inf``.  Hot loops pricing many pairs
    should fetch ``topo.routing()`` once and pass it as ``routing`` — the
    cached lookup re-checks the topology state signature per call."""
    return default_fabric().transfer_time(topo, a, b, size,
                                          edge=edge, routing=routing)


# ---------------------------------------------------------------------------
# Collectives
# ---------------------------------------------------------------------------


def _bottleneck_bw(topo: ClusterTopology, ranks: Sequence[int]) -> tuple[float, float]:
    """(bandwidth, latency) of the slowest pair on the participant ring.

    Thin delegate to the default fabric's
    :meth:`repro.core.fabric.FabricModel.ring_capacity`: relayed pairs
    stream at their route's bottleneck rate (cut-through pipelining) but
    share every physical link they relay over with the other ring pairs
    routed across it — more faithful than the old independent
    resistance-sum pricing (a streaming relay is not store-and-forward),
    and never above any hop's bandwidth, which is what keeps the coarse
    search tier's ring caps admissible.  A ring
    crossing a partition (no route) returns bandwidth 0 — the collective
    is unpriceable and the candidate infeasible."""
    return default_fabric().ring_capacity(topo, ranks)


def collective_time(topo: ClusterTopology, comm: CommOp) -> float:
    """Deterministic collective cost on the multi-edge topology.

    ring reduce-scatter / all-gather move (n-1)/n of the data over the
    bottleneck edge; the naive reduce/broadcast pair funnels the full tensor
    through the root's single link (the single-node bottleneck the paper's
    Fig. 3 decomposition removes).
    """
    ranks = comm.participants
    n = len(ranks)
    if n <= 1 or comm.size <= 0:
        return 0.0
    bw, lat = _bottleneck_bw(topo, ranks)
    if bw <= 0:
        return math.inf
    steps_lat = (n - 1) * lat
    if comm.kind in ("reduce_scatter", "all_gather"):
        return steps_lat + (n - 1) / n * comm.size / bw
    if comm.kind == "all_reduce":
        return 2 * steps_lat + 2 * (n - 1) / n * comm.size / bw
    if comm.kind == "reduce":
        # gather full tensor at root: (n-1) peers each send size (serialized
        # on the root's ingress link).
        return steps_lat + (n - 1) * comm.size / bw
    if comm.kind == "broadcast":
        return steps_lat + (n - 1) * comm.size / bw
    if comm.kind == "all_to_all":
        return steps_lat + (n - 1) / n * comm.size / bw
    if comm.kind == "p2p":
        return transfer_time(topo, ranks[0], ranks[1], comm.size)
    raise ValueError(f"unknown collective kind {comm.kind}")


def collective_floor(kind: str, size: float, n: int, bw: float) -> float:
    """Latency-free linear floor of :func:`collective_time` over an
    ``n``-member ring at bottleneck bandwidth ``bw`` — the shared pricing
    primitive of the admissible search bounds (the coarse and LP tiers in
    :mod:`repro.core.search` / :mod:`repro.core.mip`), kept here so bound
    and simulator collective models cannot drift apart.  ``rs_ag`` is the
    decomposed reduce-scatter + all-gather pair; ``reduce_broadcast`` the
    naive root-funnel pair (Fig. 3)."""
    if n <= 1 or size <= 0:
        return 0.0
    if bw <= 0:
        return math.inf
    if kind in ("reduce_scatter", "all_gather", "all_to_all"):
        return (n - 1) / n * size / bw
    if kind in ("all_reduce", "rs_ag"):
        return 2.0 * (n - 1) / n * size / bw
    if kind in ("reduce", "broadcast"):
        return (n - 1) * size / bw
    if kind == "reduce_broadcast":
        return 2.0 * (n - 1) * size / bw
    raise ValueError(f"unknown collective kind {kind}")


def allreduce_time(topo: ClusterTopology, size: float, ranks: Sequence[int],
                   *, decomposed: bool = True) -> float:
    """Fig. 3 comparison entry point."""
    if decomposed:
        rs = CommOp("rs", "reduce_scatter", size, tuple(ranks))
        ag = CommOp("ag", "all_gather", size, tuple(ranks))
        return collective_time(topo, rs) + collective_time(topo, ag)
    rd = CommOp("r", "reduce", size, tuple(ranks))
    bc = CommOp("b", "broadcast", size, tuple(ranks))
    return collective_time(topo, rd) + collective_time(topo, bc)


# ---------------------------------------------------------------------------
# TPU mesh shorthand (used when planning for the production pod)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshCollectiveModel:
    """Analytic collective costs on a TPU mesh axis.

    On a torus each mesh axis has its own ICI links (multi-edge!), so
    collectives on different axes do not contend; collectives on the same
    axis serialize.  This is the TPU analogue of the paper's conflicting
    NVLink/PCIe edges.
    """

    ici_bw: float = 50e9             # bytes/s per link per direction
    dci_bw: float = 12.5e9
    latency: float = 1e-6

    def axis_allreduce(self, size: float, axis_size: int,
                       *, inter_pod: bool = False) -> float:
        if axis_size <= 1:
            return 0.0
        bw = self.dci_bw if inter_pod else self.ici_bw
        # bidirectional ring: effective 2x link bw
        return 2 * (axis_size - 1) / axis_size * size / (2 * bw) \
            + 2 * (axis_size - 1) * self.latency

    def axis_allgather(self, size: float, axis_size: int,
                       *, inter_pod: bool = False) -> float:
        if axis_size <= 1:
            return 0.0
        bw = self.dci_bw if inter_pod else self.ici_bw
        return (axis_size - 1) / axis_size * size / (2 * bw) \
            + (axis_size - 1) * self.latency

    def axis_reduce_scatter(self, size: float, axis_size: int,
                            *, inter_pod: bool = False) -> float:
        return self.axis_allgather(size, axis_size, inter_pod=inter_pod)

    def axis_all_to_all(self, size: float, axis_size: int,
                        *, inter_pod: bool = False) -> float:
        if axis_size <= 1:
            return 0.0
        bw = self.dci_bw if inter_pod else self.ici_bw
        return (axis_size - 1) / axis_size * size / (2 * bw) / axis_size \
            + (axis_size - 1) * self.latency
