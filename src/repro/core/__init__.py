"""Core contribution of the paper: automatic parallelization planning for
heterogeneous, dynamic clusters via multi-edge topology modelling, a
discrete-event simulator cost model, and parallel branch-and-bound search."""

from .cluster import (DEVICE_PROFILES, ClusterTopology, DeviceInstance,
                      DeviceSpec, Edge, MultiEdgeLink, NetworkEvent,
                      dgx_h100_node, hetero_cluster, homogeneous_cluster,
                      multi_pod_tpu, tpu_pod)
from .costmodel import (MeshCollectiveModel, allreduce_time, collective_time,
                        graph_compute_lower_bound, op_time, transfer_time)
from .dynamic import (AdaptationRecord, DynamicOrchestrator, PlanTemplates,
                      reassign_for_straggler)
from .fabric import (FabricModel, calibrated, default_fabric,
                     set_default_fabric, use_fabric)
from .engine import (CacheStats, HierarchicalReplanEngine,
                     HierarchicalReplanResult, ReplanEngine, ReplanResult,
                     StrategyCache, TopologyFingerprint, fingerprint_topology)
from .islands import (ComposedPlan, HierarchicalResult, Island, IslandPlan,
                      inter_island_sync_bound, partition_islands,
                      plan_hierarchical, remap_plan)
from .opgraph import (CommOp, ModelDesc, OpGraph, OpNode, allreduce_decomposed,
                      allreduce_naive, build_llm_graph, layer_costs,
                      layer_flops)
from .planner import (PlanResult, SearchStats, StrategyPoint,
                      megatron_tuned_plan,
                      branch_and_bound_assign, bnb_layer_split,
                      enumerate_strategies, exhaustive_assign, greedy_assign,
                      hetero_batch_shares, materialize_plan, plan_hybrid,
                      point_lower_bound)
from .reconfig import ReconfigCost, ReconfigCostModel, plan_sequence_dp
from .routing import Route, RoutingTable
from .plans import (ParallelPlan, StageAssignment, megatron_default_plan,
                    split_devices, stages_from_sizes, uniform_stages)
from .mip import (LPBoundContext, MIPResult, SimplexResult, lp_bound_context,
                  lp_lower_bound, mip_optimum, simplex_solve)
from .search import (CandidateOutcome, SearchExecutor, coarse_lower_bound,
                     materialize_variant, point_feasible, score_candidates)
from .simulator import (EpochSim, SimResult, StepSim, check_memory,
                        memory_feasible, simulate_epoch, simulate_many,
                        simulate_schedule, simulate_training_step)

__all__ = [k for k in dir() if not k.startswith("_")]
