"""Multi-edge heterogeneous cluster model (paper §3.1).

The paper's first contribution is a *multi-edge* physical-link abstraction:
a pair of devices may be connected by several physical links (NVLink + PCIe,
multiple NVSwitch ports, TPU torus axes) with unequal bandwidth, which may be
concurrently usable or mutually conflicting.  We model:

  * ``DeviceSpec``    — a device *type* (peak FLOP/s, HBM bandwidth, memory),
  * ``DeviceInstance``— one physical device with a dynamic performance factor,
  * ``Edge``          — one physical link with bandwidth/latency/tag,
  * ``MultiEdgeLink`` — the bundle of edges between a device pair,
  * ``ClusterTopology``— the temporal graph G(t): devices + multi-edge links +
                         a timeline of :class:`NetworkEvent`.

Dynamic behaviour (paper §2.2): bandwidth fluctuation (S1), heterogeneous
performance (S2) and node failure / join (S3) are all expressed as events on
the topology; the simulator and planner consume ``snapshot(t)`` views.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import threading
from dataclasses import dataclass, field, replace
from typing import Callable, Iterable, Mapping, Sequence

GB = 1e9
TB = 1e12
TFLOPS = 1e12

# ---------------------------------------------------------------------------
# Device types
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DeviceSpec:
    """A device *type*: the paper's per-device roofline parameters (Eq. 1)."""

    name: str
    peak_flops: float          # FLOP/s at the training dtype (bf16/fp16 tensor)
    hbm_bw: float              # bytes/s peak memory bandwidth (memBW_p)
    mem_bytes: float           # device memory capacity (Eq. 6 bound M_dj)
    # Fraction of peak realistically attained by large matmuls / small ops.
    matmul_eff: float = 0.80
    vector_eff: float = 0.25
    # Whether fused attention kernels are available (sm80+/TPU).  Without
    # fusion the S x S score matrix round-trips HBM (paper §2.3 / Fig. 2:
    # the same attention kernel performs very differently across devices).
    supports_fusion: bool = True

    def roofline_time(self, flops: float, bytes_moved: float,
                      *, is_matmul: bool = True, perf_factor: float = 1.0) -> float:
        """Attainable execution time via the roofline model (paper Eq. 1-2).

        time = max(flops / attained_flops, bytes / memBW)  which is equivalent
        to flops / min(K * memBW, FLOPs_p) with K = flops/bytes.
        """
        eff = self.matmul_eff if is_matmul else self.vector_eff
        peak = self.peak_flops * eff * perf_factor
        t_compute = flops / peak if peak > 0 else math.inf
        t_memory = bytes_moved / self.hbm_bw if self.hbm_bw > 0 else math.inf
        return max(t_compute, t_memory)


# Device profiles.  GPU profiles follow the paper's evaluation hardware
# (§4 Environment Setup) plus the Fig. 2 pair; TPU v5e is our deployment
# target (roofline constants from the assignment).
DEVICE_PROFILES: dict[str, DeviceSpec] = {
    # paper §4: 14592 cores Ada @2.52 GHz, 24 GB GDDR6X (fp16 tensor, fp32 acc).
    "RTX4090D": DeviceSpec("RTX4090D", peak_flops=147 * TFLOPS, hbm_bw=1008 * GB,
                           mem_bytes=24 * GB),
    # paper §4: 11776 cores Ada @2.52 GHz, 48 GB GDDR6.
    "L20": DeviceSpec("L20", peak_flops=119.5 * TFLOPS, hbm_bw=864 * GB,
                      mem_bytes=48 * GB),
    # paper §4: Volta, 32 GB HBM2; sm70 — no fused flash attention.
    "V100": DeviceSpec("V100", peak_flops=112 * TFLOPS, hbm_bw=900 * GB,
                       mem_bytes=32 * GB, matmul_eff=0.65,
                       supports_fusion=False),
    # paper Fig. 2 comparison device.
    "H100": DeviceSpec("H100", peak_flops=989 * TFLOPS, hbm_bw=3350 * GB,
                       mem_bytes=80 * GB),
    # Deployment target: TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, 16 GB).
    "TPUv5e": DeviceSpec("TPUv5e", peak_flops=197 * TFLOPS, hbm_bw=819 * GB,
                         mem_bytes=16 * GB),
}

# Intra-node interconnect per device type: consumer Ada cards have no NVLink
# (PCIe 4.0 x16 only); V100/H100 DGX nodes have NVLink.  The paper's
# Scenario 2 explicitly uses "V100-32G-PCIe" — pass an override map there.
DEVICE_INTRA_BW: dict[str, tuple[float, str]] = {
    "RTX4090D": (25 * GB, "pcie"),
    "L20": (25 * GB, "pcie"),
    "V100": (300 * GB, "nvlink"),
    "H100": (450 * GB, "nvlink"),
    "TPUv5e": (100 * GB, "ici"),
}


@dataclass
class DeviceInstance:
    """One physical device.  ``perf_factor`` models dynamic slowdown (S2/S3);
    ``alive`` models failures (S3)."""

    device_id: int
    spec: DeviceSpec
    perf_factor: float = 1.0
    alive: bool = True

    @property
    def name(self) -> str:
        return f"{self.spec.name}:{self.device_id}"


# ---------------------------------------------------------------------------
# Multi-edge links
# ---------------------------------------------------------------------------


@dataclass
class Edge:
    """One physical link between a device pair.

    ``tag`` identifies the physical resource class (e.g. ``nvlink``, ``pcie``,
    ``ici-x``, ``ici-y``, ``dci``).  ``conflicts_with`` lists tags that cannot
    be active simultaneously with this edge on the same device (the paper's
    NVLink-vs-PCIe example, Fig. 5b).
    """

    bandwidth: float                     # bytes/s
    latency: float = 1e-6                # seconds per message
    tag: str = "link"
    conflicts_with: tuple[str, ...] = ()
    # dynamic state: multiplicative factor applied by bandwidth events (S1)
    bw_factor: float = 1.0

    @property
    def effective_bandwidth(self) -> float:
        return self.bandwidth * self.bw_factor

    def transfer_time(self, size_bytes: float) -> float:
        bw = self.effective_bandwidth
        if bw <= 0:
            return math.inf
        return self.latency + size_bytes / bw


@dataclass
class MultiEdgeLink:
    """All physical edges between an (unordered) device pair."""

    a: int
    b: int
    edges: list[Edge] = field(default_factory=list)

    def best_edge(self, size_bytes: float) -> Edge:
        return min(self.edges, key=lambda e: e.transfer_time(size_bytes))

    def aggregate_bandwidth(self) -> float:
        """Upper bound when non-conflicting edges are used concurrently."""
        # Group by conflict class: edges that conflict share a class budget.
        best_per_class: dict[frozenset, float] = {}
        for e in self.edges:
            cls = frozenset((e.tag, *e.conflicts_with))
            best_per_class[cls] = max(best_per_class.get(cls, 0.0),
                                      e.effective_bandwidth)
        return sum(best_per_class.values())


# ---------------------------------------------------------------------------
# Dynamic events (temporal graph, paper §2.2)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class NetworkEvent:
    """A change to the topology at time ``t``.

    kinds:
      * ``bandwidth``:  adjust edges matching ``selector`` by ``factor`` (S1)
      * ``slowdown``:   adjust device ``device_id`` perf by ``factor`` (S2)
      * ``fail``:       device ``device_id`` leaves the cluster (S3)
      * ``join``:       device ``device_id`` (re-)joins (S3)

    ``mode`` makes composition explicit for ``bandwidth``/``slowdown``:

      * ``"set"`` (default, the historical semantics): the factor is an
        *absolute* level — ``bw_factor = factor``.  Two overlapping events
        clobber each other; use it for single-source conditions (a sampled
        diurnal curve, the fig6c sweep).
      * ``"scale"``: the factor *multiplies* the current level —
        ``bw_factor *= factor``.  Overlapping events compose, and an event
        with the reciprocal factor restores the previous level exactly
        (multi-tenant congestion bursts, straggler churn).
    """

    time: float
    kind: str
    device_id: int | None = None
    factor: float = 1.0
    selector: str | None = None          # edge tag selector, e.g. "dci"
    mode: str = "set"                    # "set" (absolute) | "scale" (compose)


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------


class ClusterTopology:
    """Temporal multi-edge device graph G(t) = (V_D, E(t))."""

    def __init__(self, devices: Sequence[DeviceInstance],
                 links: Mapping[tuple[int, int], MultiEdgeLink] | None = None,
                 events: Sequence[NetworkEvent] = ()) -> None:
        self.devices: dict[int, DeviceInstance] = {d.device_id: d for d in devices}
        self.links: dict[tuple[int, int], MultiEdgeLink] = dict(links or {})
        self._events: list[NetworkEvent] = sorted(events, key=lambda e: e.time)
        # incremental-snapshot cache (see snapshot()): a private materialized
        # state at time _snap_t, valid while _snap_sig matches.
        self._version = 0
        self._snap_state: "ClusterTopology | None" = None
        self._snap_t = -math.inf
        self._snap_sig: tuple | None = None
        self._snap_events: list[NetworkEvent] = []
        # the planner simulates candidates from a thread pool and every
        # simulate call snapshots its topology — the cache must not tear
        self._snap_lock = threading.Lock()
        # cached widest-path routing table (repro.core.routing), invalidated
        # by the same state signature as the snapshot cache
        self._route_table = None
        self._route_sig: tuple | None = None

    # -- construction -------------------------------------------------------

    @property
    def events(self) -> list[NetworkEvent]:
        return self._events

    @events.setter
    def events(self, events: Sequence[NetworkEvent]) -> None:
        self._events = sorted(events, key=lambda e: e.time)
        self._version += 1

    def add_link(self, a: int, b: int, *edges: Edge) -> None:
        key = (min(a, b), max(a, b))
        link = self.links.setdefault(key, MultiEdgeLink(a=key[0], b=key[1]))
        link.edges.extend(edges)
        self._version += 1

    def link(self, a: int, b: int) -> MultiEdgeLink | None:
        return self.links.get((min(a, b), max(a, b)))

    # -- queries -------------------------------------------------------------

    @property
    def alive_devices(self) -> list[DeviceInstance]:
        return [d for d in self.devices.values() if d.alive]

    def alive_ids(self) -> list[int]:
        return sorted(d.device_id for d in self.alive_devices)

    def device(self, device_id: int) -> DeviceInstance:
        return self.devices[device_id]

    def device_types(self) -> dict[str, list[int]]:
        out: dict[str, list[int]] = {}
        for d in self.alive_devices:
            out.setdefault(d.spec.name, []).append(d.device_id)
        return out

    def is_heterogeneous(self) -> bool:
        return len(self.device_types()) > 1

    def min_link_bandwidth(self, ids: Sequence[int] | None = None) -> float:
        """Bottleneck single-edge bandwidth among the given devices."""
        ids = list(ids if ids is not None else self.alive_ids())
        idset = set(ids)
        best = math.inf
        for (a, b), link in self.links.items():
            if a in idset and b in idset and link.edges:
                best = min(best, max(e.effective_bandwidth for e in link.edges))
        return best if best < math.inf else 0.0

    def total_memory(self) -> float:
        return sum(d.spec.mem_bytes for d in self.alive_devices)

    # -- routing ---------------------------------------------------------------

    def routing(self):
        """Cached :class:`repro.core.routing.RoutingTable` over the *current*
        state (alive devices, current effective edge bandwidths).

        Invalidation follows the snapshot-cache signature: ``apply_event`` /
        ``add_link`` / events assignment and direct device-field mutation
        all produce a fresh table, so dynamic events (link death,
        degradation, device fail/join) re-route mid-trace.  Direct edge
        mutation is not tracked — call :meth:`invalidate_snapshots` after
        doing that (same caveat as the snapshot cache)."""
        from .routing import RoutingTable
        with self._snap_lock:
            sig = self._state_sig()
            if self._route_table is None or self._route_sig != sig:
                self._route_table = RoutingTable(self)
                self._route_sig = sig
            return self._route_table

    # -- islands (hierarchical search, repro.core.islands) ---------------------

    def island_partition(self, *, fast_frac: float = 0.5
                         ) -> list[tuple[int, ...]]:
        """Partition the alive devices into homogeneous *islands*.

        An island is a maximal set of same-class devices connected by *fast*
        links: within each device class, a link counts as fast when its best
        live edge reaches at least ``fast_frac`` times the fastest live
        same-class link bandwidth.  Slower links (and every cross-class
        link) become inter-island edges.  On a multi-pod TPU fleet the
        12.5 GB/s DCI edges fall under half the 50 GB/s ICI links, so each
        pod is one island; in a mixed GPU cluster each device class splits
        further wherever its nodes only meet over the slow fabric.

        Args:
            fast_frac: fraction of the per-class maximum link bandwidth a
                link must reach to be island-internal (0 < fast_frac <= 1).

        Returns:
            Sorted-id tuples, one per island, ordered by smallest member id.
            Every alive device appears in exactly one island; devices whose
            class has no live intra-class link form single-device islands.
        """
        by_class: dict[str, list[int]] = {}
        for d in self.alive_devices:
            by_class.setdefault(d.spec.name, []).append(d.device_id)
        out: list[tuple[int, ...]] = []
        for name in sorted(by_class):
            ids = sorted(by_class[name])
            idset = set(ids)
            pair_bw: dict[tuple[int, int], float] = {}
            for (a, b), link in self.links.items():
                if a in idset and b in idset and link.edges:
                    bw = max(e.effective_bandwidth for e in link.edges)
                    if bw > 0:
                        pair_bw[(a, b)] = bw
            parent = {i: i for i in ids}

            def find(x: int) -> int:
                while parent[x] != x:
                    parent[x] = parent[parent[x]]
                    x = parent[x]
                return x

            if pair_bw:
                thresh = fast_frac * max(pair_bw.values())
                for (a, b), bw in pair_bw.items():
                    if bw >= thresh:
                        ra, rb = find(a), find(b)
                        if ra != rb:
                            parent[max(ra, rb)] = min(ra, rb)
            comps: dict[int, list[int]] = {}
            for i in ids:
                comps.setdefault(find(i), []).append(i)
            out.extend(tuple(sorted(c)) for c in comps.values())
        out.sort(key=lambda ids: ids[0])
        return out

    def island_signature(self, ids: Sequence[int], *, bw_quant: float = 0.25,
                         perf_quant: float = 0.05) -> tuple:
        """Canonical id-free signature of the sub-cluster over ``ids``.

        Two islands with equal signatures hold the same multiset of
        (device class, quantized perf factor), the same multiset of
        internal (edge tag, log2-quantized bandwidth) edges, and the same
        internal link-degree sequence — i.e. they are indistinguishable to
        the planner up to device renaming (identical pods, identical DGX
        nodes).  The hierarchical search scores one representative per
        signature and reuses its sub-plan for the twins.

        Args:
            ids: member device ids (alive or not; order irrelevant).
            bw_quant: bandwidth bucket width in log2(bytes/s), matching
                :func:`repro.core.engine.fingerprint_topology`.
            perf_quant: linear bucket width for device perf factors.

        Returns:
            A hashable tuple; equality means "isomorphic for planning".
        """
        idset = set(ids)
        devs = sorted(
            (self.devices[i].spec.name,
             int(round(self.devices[i].perf_factor / perf_quant)))
            for i in idset)
        edges = []
        degree = {i: 0 for i in idset}
        for (a, b), link in self.links.items():
            if a in idset and b in idset:
                for e in link.edges:
                    bw = e.effective_bandwidth
                    bucket = int(round(math.log2(bw) / bw_quant)) \
                        if bw > 0 else -1
                    edges.append((e.tag, bucket))
                if link.edges:
                    degree[a] += 1
                    degree[b] += 1
        return (len(idset), tuple(devs), tuple(sorted(edges)),
                tuple(sorted(degree.values())))

    def subtopology(self, ids: Iterable[int]) -> "ClusterTopology":
        """Deep-copied topology restricted to ``ids``: the member devices
        (current perf/alive state) plus every link whose endpoints are both
        members.  The event timeline is NOT carried over — snapshot first if
        a particular time matters.  The hierarchical planner searches each
        island on its subtopology."""
        idset = set(ids)
        devs = [replace(d) for i, d in sorted(self.devices.items())
                if i in idset]
        links = {
            k: MultiEdgeLink(v.a, v.b, [replace(e) for e in v.edges])
            for k, v in self.links.items()
            if k[0] in idset and k[1] in idset
        }
        return ClusterTopology(devs, links, events=[])

    # -- temporal behaviour ---------------------------------------------------

    def events_between(self, t0: float, t1: float) -> list[NetworkEvent]:
        return [e for e in self.events if t0 <= e.time < t1]

    def apply_event(self, ev: NetworkEvent) -> None:
        """Apply an event in place (the simulator calls this at event time).

        ``mode="set"`` events overwrite the dynamic factor; ``mode="scale"``
        events multiply into it (see :class:`NetworkEvent`)."""
        scale = ev.mode == "scale"
        if ev.mode not in ("set", "scale"):
            raise ValueError(f"unknown event mode: {ev.mode}")
        if ev.kind == "bandwidth":
            for link in self.links.values():
                for e in link.edges:
                    if ev.selector is None or e.tag == ev.selector:
                        e.bw_factor = e.bw_factor * ev.factor if scale \
                            else ev.factor
        elif ev.kind == "slowdown":
            assert ev.device_id is not None
            d = self.devices[ev.device_id]
            d.perf_factor = d.perf_factor * ev.factor if scale else ev.factor
        elif ev.kind == "fail":
            assert ev.device_id is not None
            self.devices[ev.device_id].alive = False
        elif ev.kind == "join":
            assert ev.device_id is not None
            self.devices[ev.device_id].alive = True
            self.devices[ev.device_id].perf_factor = ev.factor or 1.0
        else:
            raise ValueError(f"unknown event kind: {ev.kind}")
        self._version += 1

    # -- snapshots (incremental) ----------------------------------------------

    def _copy_state(self) -> "ClusterTopology":
        """Deep copy of devices + links, no events attached."""
        devs = [replace(d) for d in self.devices.values()]
        links = {
            k: MultiEdgeLink(v.a, v.b, [replace(e) for e in v.edges])
            for k, v in self.links.items()
        }
        return ClusterTopology(devs, links, events=[])

    def copy(self) -> "ClusterTopology":
        """Deep copy of the full topology (devices, links, event timeline);
        the copy's snapshot cache starts cold."""
        c = self._copy_state()
        c.events = list(self._events)
        return c

    def _state_sig(self) -> tuple:
        """Cheap validity signature for the snapshot cache.  ``_version``
        covers apply_event/add_link/events-assignment; the events tuple
        catches in-place list mutation (append/insert, possibly out of
        order) and the device tuple direct mutation of device fields
        (templates toggling ``alive``).  Direct edge mutation is not
        tracked — call :meth:`invalidate_snapshots` after doing that."""
        return (self._version, tuple(self._events),
                tuple((d.device_id, d.alive, d.perf_factor)
                      for d in self.devices.values()))

    def invalidate_snapshots(self) -> None:
        with self._snap_lock:
            self._snap_state = None
            self._snap_sig = None
            self._snap_t = -math.inf
            self._snap_events = []
            self._route_table = None
            self._route_sig = None

    def snapshot(self, t: float) -> "ClusterTopology":
        """Deep-copied topology with all events up to time ``t`` applied.

        Replays are incremental: a private materialized state advances from
        the last queried time, so a monotone sequence of ``snapshot`` calls
        over an N-event timeline applies each event once (O(N) *event
        applications* total, each O(links); every call still pays an O(N)
        signature compare with tiny constants) instead of replaying the
        whole prefix per call (O(N^2) applications) — the regime scenario
        traces with hundreds of events put us in.  Going back in time or
        mutating the base topology rebuilds from scratch."""
        with self._snap_lock:
            sig = self._state_sig()
            if self._snap_state is None or self._snap_sig != sig \
                    or t < self._snap_t:
                self._snap_state = self._copy_state()
                self._snap_t = -math.inf
                self._snap_sig = sig
                # private sorted view: in-place appends may have left the
                # caller-visible list unsorted (any such mutation changes
                # the signature and lands here, so the view is always fresh)
                self._snap_events = sorted(self._events,
                                           key=lambda e: e.time)
            if self._snap_t < t:
                for ev in self._snap_events:
                    if self._snap_t < ev.time <= t:
                        self._snap_state.apply_event(ev)
                    elif ev.time > t:
                        break
                self._snap_t = t
                # applying events bumps the *base* signature only via our
                # own private copy, so the cache signature stays as computed
            return self._snap_state._copy_state()

    # -- pickling (search workers ship topologies to spawn processes) ----------

    def __getstate__(self) -> dict:
        """Drop the snapshot cache and its lock: a worker process rebuilds
        both lazily on first :meth:`snapshot` call."""
        return {"devices": list(self.devices.values()),
                "links": self.links,
                "events": list(self._events)}

    def __setstate__(self, state: dict) -> None:
        self.__init__(state["devices"], state["links"], state["events"])

    # -- pretty ----------------------------------------------------------------

    def describe(self) -> str:
        lines = [f"ClusterTopology: {len(self.alive_devices)} alive devices, "
                 f"{len(self.links)} links, {len(self.events)} events"]
        for name, ids in sorted(self.device_types().items()):
            lines.append(f"  {name} x{len(ids)}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Topology factories
# ---------------------------------------------------------------------------


def homogeneous_cluster(n: int, spec_name: str = "V100", *,
                        intra_bw: float | None = None,
                        inter_bw: float = 25 * GB,
                        gpus_per_node: int = 8) -> ClusterTopology:
    """n identical GPUs in nodes of ``gpus_per_node``.

    Intra-node links default to the device type's native interconnect
    (NVLink for DGX parts, PCIe for consumer cards); every pair also gets
    the conflicting PCIe edge (paper Fig. 5b)."""
    return hetero_cluster({spec_name: n},
                          intra_bw_map={spec_name: intra_bw} if intra_bw else None,
                          inter_bw=inter_bw, gpus_per_node=gpus_per_node)


def hetero_cluster(counts: Mapping[str, int], *,
                   intra_bw_map: Mapping[str, float | None] | None = None,
                   inter_bw: float = 25 * GB,
                   gpus_per_node: int = 8) -> ClusterTopology:
    """Mixed-type cluster: each node holds one device type (paper §4.1).

    Intra-node bandwidth follows :data:`DEVICE_INTRA_BW` per type unless
    overridden (e.g. ``{"V100": 25e9}`` for the paper's V100-32G-PCIe)."""
    devices: list[DeviceInstance] = []
    i = 0
    for name, count in counts.items():
        spec = DEVICE_PROFILES[name]
        for _ in range(count):
            devices.append(DeviceInstance(i, spec))
            i += 1
    topo = ClusterTopology(devices)
    node_of = {d.device_id: d.device_id // gpus_per_node for d in devices}
    for a, b in itertools.combinations(range(i), 2):
        if node_of[a] == node_of[b]:
            tname = devices[a].spec.name
            bw, tag = DEVICE_INTRA_BW.get(tname, (300 * GB, "nvlink"))
            if intra_bw_map and intra_bw_map.get(tname) is not None:
                bw = float(intra_bw_map[tname])  # type: ignore[arg-type]
            if tag == "pcie":
                # consumer card: PCIe is the only edge
                topo.add_link(a, b, Edge(bw, 5e-6, "pcie"))
            else:
                topo.add_link(a, b, Edge(bw, 1e-6, tag, ("pcie",)),
                              Edge(16 * GB, 5e-6, "pcie", (tag,)))
        else:
            topo.add_link(a, b, Edge(inter_bw, 5e-6, "ib"))
    return topo


def tpu_pod(chips: int = 256, *, ici_bw_per_link: float = 50 * GB,
            torus: tuple[int, int] = (16, 16)) -> ClusterTopology:
    """One TPU v5e pod as a 2-D torus with per-axis ICI edges (multi-edge:
    each torus axis is a distinct physical link class — paper §3.1 cites the
    TPU torus as a multi-edge case)."""
    assert torus[0] * torus[1] == chips
    spec = DEVICE_PROFILES["TPUv5e"]
    devices = [DeviceInstance(i, spec) for i in range(chips)]
    topo = ClusterTopology(devices)
    X, Y = torus
    for x in range(X):
        for y in range(Y):
            i = x * Y + y
            jx = ((x + 1) % X) * Y + y          # +x neighbour
            jy = x * Y + (y + 1) % Y            # +y neighbour
            topo.add_link(i, jx, Edge(ici_bw_per_link, 1e-6, "ici-x"))
            topo.add_link(i, jy, Edge(ici_bw_per_link, 1e-6, "ici-y"))
    return topo


def multi_pod_tpu(pods: int = 2, chips_per_pod: int = 256, *,
                  dci_bw: float = 12.5 * GB,
                  ici_bw_per_link: float = 50 * GB) -> ClusterTopology:
    """Multiple TPU pods; slow DCI edges between pod boundary chips."""
    base = None
    all_devices: list[DeviceInstance] = []
    topo = ClusterTopology([])
    spec = DEVICE_PROFILES["TPUv5e"]
    X = Y = int(math.isqrt(chips_per_pod))
    assert X * Y == chips_per_pod, "chips_per_pod must be a square"
    for p in range(pods):
        off = p * chips_per_pod
        for i in range(chips_per_pod):
            topo.devices[off + i] = DeviceInstance(off + i, spec)
        for x in range(X):
            for y in range(Y):
                i = off + x * Y + y
                jx = off + ((x + 1) % X) * Y + y
                jy = off + x * Y + (y + 1) % Y
                topo.add_link(i, jx, Edge(ici_bw_per_link, 1e-6, "ici-x"))
                topo.add_link(i, jy, Edge(ici_bw_per_link, 1e-6, "ici-y"))
    # DCI: connect corresponding chips of adjacent pods (optical/DCN).
    for p in range(pods - 1):
        for i in range(chips_per_pod):
            topo.add_link(p * chips_per_pod + i, (p + 1) * chips_per_pod + i,
                          Edge(dci_bw, 50e-6, "dci"))
    return topo


def dgx_h100_node() -> ClusterTopology:
    """A single DGX-H100: 8 GPUs, uneven NVSwitch connectivity (paper Fig. 5a).

    GPUs 0/7 sit next to the edge NVSwitches with more ports: we model this as
    an extra NVLink edge for pairs touching GPU 0 or 7."""
    spec = DEVICE_PROFILES["H100"]
    devices = [DeviceInstance(i, spec) for i in range(8)]
    topo = ClusterTopology(devices)
    for a, b in itertools.combinations(range(8), 2):
        edges = [Edge(450 * GB, 1e-6, "nvlink", ("pcie",)),
                 Edge(32 * GB, 5e-6, "pcie", ("nvlink",))]
        if a in (0, 7) or b in (0, 7):
            edges.insert(0, Edge(450 * GB, 1e-6, "nvlink-extra", ("pcie",)))
        topo.add_link(a, b, *edges)
    return topo
