"""Multi-hop routing over the live link graph (ISSUE 5 tentpole).

The simulator used to price any device pair without a direct link with an
optimistic flat bottleneck estimate — which is exactly why the tiered
search's coarse ring caps had to be disabled on sparse link graphs (TPU
torus), and why cross-region / degraded-fabric scenarios were not believable.
This module gives :class:`~repro.core.cluster.ClusterTopology` a cached
**widest-path** routing table:

  * routes maximize the bottleneck bandwidth over the live link graph
    (alive devices, edges with positive effective bandwidth), with
    deterministic tie-breaks (fewer hops, then canonical device order), so
    serial and process-parallel searches price identically;
  * a :class:`Route` carries the physical path plus three pricing
    aggregates: ``bottleneck_bw`` (min hop bandwidth — what the coarse
    bound's connectivity caps reason about), ``latency`` (sum of hop
    latencies) and ``resistance`` (sum of inverse hop bandwidths).  Those
    three are exactly what :class:`repro.core.fabric.FabricModel` needs to
    price the route — chunked cut-through pipelining by default
    (``latency + fill + size/bottleneck``), the store-and-forward sum
    ``latency + size * resistance`` as the un-pipelined reference.  Either
    way a routed price is never below any single hop's own
    serialization-aware time;
  * tables are built lazily per source (Dijkstra-style widest path,
    O(E log V) per source) and cached per topology state — the topology's
    existing snapshot version/signature mechanism invalidates them, so
    dynamic events (link death, degradation, device fail/join) re-route
    mid-trace.

Consumers: :class:`repro.core.fabric.FabricModel` — the single transfer
pricing implementation behind :func:`repro.core.costmodel.transfer_time`
(routed p2p), :func:`repro.core.costmodel._bottleneck_bw` (routed ring
collectives), :meth:`repro.core.reconfig.ReconfigCostModel` (routed
reshard pairs) and the discrete-event simulator (per-hop transfers
claiming each physical edge's serialization domain — relay traffic
contends with direct traffic).  The coarse search tier computes its
sparse-graph ring caps from the direct link graph, but their
*admissibility* rests on the routed-pricing invariant above: a routed
pair's end-to-end bandwidth never exceeds its bottleneck hop's.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass

# sentinel distinguishing "not computed" from "computed: unreachable"
_MISS = object()


@dataclass(frozen=True)
class Route:
    """One directed multi-hop route between a device pair."""

    path: tuple[int, ...]        # device ids, endpoints included (len >= 1)
    bottleneck_bw: float         # min best-edge bandwidth over the hops
    latency: float               # sum of per-hop latencies
    resistance: float            # sum of per-hop inverse bandwidths

    @property
    def hops(self) -> int:
        return len(self.path) - 1

    @property
    def effective_bandwidth(self) -> float:
        """End-to-end *store-and-forward* bandwidth: ``1 / resistance``.
        Kept as the un-pipelined reference aggregate (and the pre-fabric
        pricing, via ``FabricModel(pipelining=False)``); never exceeds
        :attr:`bottleneck_bw`, equals it for single-hop routes."""
        if self.resistance <= 0:
            return math.inf
        return 1.0 / self.resistance

    def transfer_time(self, size_bytes: float) -> float:
        """Thin delegate to the default fabric's routed pricing
        (:meth:`repro.core.fabric.FabricModel.route_time`): chunked
        cut-through pipelining by default, never below any single hop's
        own time, never above the store-and-forward sum of hops."""
        from .fabric import default_fabric
        return default_fabric().route_time(self, size_bytes)


class RoutingTable:
    """Widest-path routes over one topology *state* (no temporal events).

    Built from the alive device set and the links' current effective
    bandwidths; per-hop pricing uses each link's best live edge (max
    effective bandwidth, deterministic tie-break by latency then tag).
    Per-source shortest-widest trees are computed lazily and memoized, as
    are reconstructed :class:`Route` objects.  Instances are immutable
    snapshots — :meth:`repro.core.cluster.ClusterTopology.routing` handles
    cache invalidation against the live topology.
    """

    def __init__(self, topo) -> None:
        alive = {d.device_id for d in topo.devices.values() if d.alive}
        self._adj: dict[int, list[tuple[int, float, float]]] = \
            {d: [] for d in sorted(alive)}
        self._pair: dict[tuple[int, int], tuple[float, float]] = {}
        for (a, b), link in sorted(topo.links.items()):
            if a not in alive or b not in alive:
                continue
            best: tuple[float, float] | None = None
            for e in link.edges:
                bw = e.effective_bandwidth
                if bw <= 0:
                    continue                      # dead edge: not routable
                if best is None or (bw, -e.latency) > (best[0], -best[1]):
                    best = (bw, e.latency)
            if best is None:
                continue
            self._pair[(a, b)] = best
            self._adj[a].append((b, best[0], best[1]))
            self._adj[b].append((a, best[0], best[1]))
        for lst in self._adj.values():
            lst.sort()
        # src -> (best: node -> (bw, hops), prev: node -> predecessor)
        self._trees: dict[int, tuple[dict, dict]] = {}
        self._routes: dict[tuple[int, int], Route | None] = {}

    def hop_price(self, u: int, v: int) -> tuple[float, float] | None:
        """(bandwidth, latency) of the best live edge this table priced the
        direct hop ``u``-``v`` at, or ``None`` when the pair has no live
        direct link.  The fabric's ring-capacity load accounting uses this
        so collective pricing sees exactly the edges the routes priced."""
        return self._pair.get((min(u, v), max(u, v)))

    # -- widest-path trees -----------------------------------------------------

    def _tree(self, src: int) -> tuple[dict[int, tuple[float, int]],
                                       dict[int, int]]:
        """Shortest-widest-path tree from ``src``: maximize bottleneck
        bandwidth, break ties by hop count, then by deterministic pop order
        (device id) — identical across processes for identical states."""
        state = self._trees.get(src)
        if state is not None:
            return state
        best: dict[int, tuple[float, int]] = {src: (math.inf, 0)}
        prev: dict[int, int] = {}
        heap: list[tuple[float, int, int]] = [(-math.inf, 0, src)]
        while heap:
            nbw, nh, u = heapq.heappop(heap)
            nbw = -nbw
            cur = best.get(u)
            if cur is None or (-cur[0], cur[1]) < (-nbw, nh):
                continue                          # stale entry
            for v, bw, _lat in self._adj.get(u, ()):
                cb = min(nbw, bw)
                ch = nh + 1
                old = best.get(v)
                if old is None or (-cb, ch) < (-old[0], old[1]):
                    best[v] = (cb, ch)
                    prev[v] = u
                    heapq.heappush(heap, (-cb, ch, v))
        state = (best, prev)
        self._trees[src] = state
        return state

    # -- routes ----------------------------------------------------------------

    def _compute(self, a: int, b: int) -> Route | None:
        best, prev = self._tree(a)
        if b not in best:
            return None
        path = [b]
        while path[-1] != a:
            path.append(prev[path[-1]])
        path.reverse()
        lat = res = 0.0
        for u, v in zip(path, path[1:]):
            bw, hop_lat = self._pair[(min(u, v), max(u, v))]
            lat += hop_lat
            res += 1.0 / bw
        return Route(path=tuple(path), bottleneck_bw=best[b][0],
                     latency=lat, resistance=res)

    def route(self, a: int, b: int) -> Route | None:
        """The widest route ``a -> b`` (``None`` when disconnected).
        Canonicalized: ``route(b, a)`` is always the exact reverse of
        ``route(a, b)`` no matter the query order."""
        if a == b:
            return Route(path=(a,), bottleneck_bw=math.inf,
                         latency=0.0, resistance=0.0)
        key = (min(a, b), max(a, b))
        r = self._routes.get(key, _MISS)
        if r is _MISS:
            r = self._compute(*key)
            self._routes[key] = r
        if r is None or a == key[0]:
            return r
        return Route(path=tuple(reversed(r.path)),
                     bottleneck_bw=r.bottleneck_bw,
                     latency=r.latency, resistance=r.resistance)
