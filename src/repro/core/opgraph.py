"""Operator graphs and the split/fusion search vocabulary (paper §2.3, §3.2.1).

The paper formulates planning over a computational graph G_C = (V_C, E_C) of
atomic operators with data dependencies.  We provide:

  * :class:`OpNode` / :class:`OpGraph` — the DAG with per-op flops / memory
    traffic / working-set / parameter sizes (inputs to Eq. 1-2 and Eq. 6),
  * builders that expand an LLM architecture config into a graph at *layer*
    granularity (the paper's "first-level optimization": split the model
    across devices, search at the global-memory level),
  * transforms: ``split_layer`` (operator splitting), ``fuse`` (operator
    fusion, FlashAttention-style), and all-reduce decomposition helpers.

Sizes are computed for one *training step* (fwd+bwd, factor 3x fwd flops) or
one forward/decode step, from an abstract model description so that the same
builders serve all 10 assigned architectures.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Iterable, Mapping, Sequence

# ---------------------------------------------------------------------------
# Graph primitives
# ---------------------------------------------------------------------------


@dataclass
class OpNode:
    """An atomic (or fused) operator — paper §3.2.1 V_C element.

    flops         : floating point operations for one execution
    bytes_accessed: HBM traffic (reads+writes) — denominator of K (Eq. 2)
    mem_required  : working set during execution, Mem_op(v)  (Eq. 6)
    params_bytes  : resident parameter+optimizer bytes attributable to v
    out_bytes     : activation bytes produced for each consumer, Mem_data (Eq. 6)
    is_matmul     : selects MXU vs VPU roofline efficiency
    """

    name: str
    kind: str
    flops: float = 0.0
    bytes_accessed: float = 0.0
    mem_required: float = 0.0
    params_bytes: float = 0.0
    out_bytes: float = 0.0
    is_matmul: bool = True
    meta: dict = field(default_factory=dict)


@dataclass
class OpGraph:
    """DAG of operators.  Edges carry the transferred tensor size."""

    nodes: dict[str, OpNode] = field(default_factory=dict)
    edges: dict[tuple[str, str], float] = field(default_factory=dict)  # (u,v)->bytes

    # -- construction --------------------------------------------------------

    def add(self, node: OpNode) -> OpNode:
        if node.name in self.nodes:
            raise ValueError(f"duplicate op name: {node.name}")
        self.nodes[node.name] = node
        return node

    def connect(self, u: str, v: str, nbytes: float | None = None) -> None:
        if u not in self.nodes or v not in self.nodes:
            raise KeyError(f"unknown op in edge ({u}, {v})")
        self.edges[(u, v)] = self.nodes[u].out_bytes if nbytes is None else nbytes

    # -- queries --------------------------------------------------------------

    def preds(self, v: str) -> list[str]:
        return [a for (a, b) in self.edges if b == v]

    def succs(self, v: str) -> list[str]:
        return [b for (a, b) in self.edges if a == v]

    def topo_order(self) -> list[str]:
        indeg = {n: 0 for n in self.nodes}
        for (_, b) in self.edges:
            indeg[b] += 1
        frontier = sorted(n for n, d in indeg.items() if d == 0)
        order: list[str] = []
        while frontier:
            n = frontier.pop(0)
            order.append(n)
            for s in sorted(self.succs(n)):
                indeg[s] -= 1
                if indeg[s] == 0:
                    frontier.append(s)
        if len(order) != len(self.nodes):
            raise ValueError("cycle in op graph")
        return order

    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes.values())

    def total_params_bytes(self) -> float:
        return sum(n.params_bytes for n in self.nodes.values())

    def critical_path_flops(self) -> float:
        """Longest path by flops — an admissible work lower bound."""
        order = self.topo_order()
        dist = {n: 0.0 for n in order}
        for n in order:
            dist[n] = max((dist[p] for p in self.preds(n)), default=0.0) \
                + self.nodes[n].flops
        return max(dist.values()) if dist else 0.0

    # -- transforms (paper §2.3) ----------------------------------------------

    def fuse(self, names: Sequence[str], fused_name: str, *,
             traffic_discount: float = 0.5) -> "OpGraph":
        """Fuse a chain of ops into one.  Fusion removes intermediate HBM
        round-trips: the fused node keeps the summed flops but only a
        fraction of the internal memory traffic (FlashAttention effect)."""
        names = list(names)
        g = self.copy()
        members = [g.nodes[n] for n in names]
        internal = {(u, v) for (u, v) in g.edges if u in names and v in names}
        internal_bytes = sum(g.edges[e] for e in internal)
        fused = OpNode(
            name=fused_name,
            kind="fused:" + "+".join(m.kind for m in members),
            flops=sum(m.flops for m in members),
            bytes_accessed=sum(m.bytes_accessed for m in members)
            - (1.0 - traffic_discount) * 2 * internal_bytes,
            mem_required=max(m.mem_required for m in members),
            params_bytes=sum(m.params_bytes for m in members),
            out_bytes=members[-1].out_bytes,
            is_matmul=any(m.is_matmul for m in members),
            meta={"fused_from": names},
        )
        fused.bytes_accessed = max(fused.bytes_accessed, fused.out_bytes)
        # Rewire edges.
        new_edges: dict[tuple[str, str], float] = {}
        for (u, v), sz in g.edges.items():
            if (u, v) in internal:
                continue
            nu = fused_name if u in names else u
            nv = fused_name if v in names else v
            if nu != nv:
                new_edges[(nu, nv)] = max(new_edges.get((nu, nv), 0.0), sz)
        for n in names:
            del g.nodes[n]
        g.nodes[fused_name] = fused
        g.edges = new_edges
        return g

    def split_node(self, name: str, parts: int, *, axis: str = "data") -> "OpGraph":
        """Split an operator into ``parts`` equal sub-operators (paper's
        operator splitting).  Sub-ops are independent (data/tensor split) and
        inherit the parent's predecessors/successors with scaled edges."""
        if parts <= 1:
            return self.copy()
        g = self.copy()
        node = g.nodes.pop(name)
        subs = []
        for i in range(parts):
            sub = replace(
                node,
                name=f"{name}.s{i}",
                flops=node.flops / parts,
                bytes_accessed=node.bytes_accessed / parts,
                mem_required=node.mem_required / parts,
                params_bytes=node.params_bytes / parts
                if axis != "data" else node.params_bytes,
                out_bytes=node.out_bytes / parts,
                meta={**node.meta, "split_of": name, "split_axis": axis},
            )
            g.nodes[sub.name] = sub
            subs.append(sub.name)
        new_edges: dict[tuple[str, str], float] = {}
        for (u, v), sz in g.edges.items():
            if u == name:
                for s in subs:
                    new_edges[(s, v)] = sz / parts
            elif v == name:
                for s in subs:
                    new_edges[(u, s)] = sz / parts
            else:
                new_edges[(u, v)] = sz
        g.edges = new_edges
        return g

    def copy(self) -> "OpGraph":
        return OpGraph(nodes={k: replace(v, meta=dict(v.meta))
                              for k, v in self.nodes.items()},
                       edges=dict(self.edges))


# ---------------------------------------------------------------------------
# Abstract model description -> op graph
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelDesc:
    """Architecture summary sufficient for cost modelling.

    This mirrors the assigned-architecture configs (repro.configs) but is
    deliberately framework-independent so the planner can also describe the
    paper's own LLaMA/GPT models.
    """

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # hybrid / ssm
    ssm_state: int = 0
    block_pattern: tuple[str, ...] = ()   # e.g. ("mamba","mamba","attn") cycle
    ffn_kind: str = "swiglu"              # swiglu | geglu | gelu (2 vs 3 matrices)
    cross_attn_every: int = 0             # VLM: cross-attn layer frequency
    encoder_layers: int = 0               # enc-dec: encoder depth
    dtype_bytes: int = 2

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    def layer_kind(self, i: int) -> str:
        if self.block_pattern:
            return self.block_pattern[i % len(self.block_pattern)]
        return "attn"

    # -- parameter counting ----------------------------------------------------

    def attn_params(self) -> int:
        d, q, kv = self.d_model, self.q_dim, self.kv_dim
        return d * q + 2 * d * kv + q * d

    def ffn_params(self) -> int:
        mats = 3 if self.ffn_kind in ("swiglu", "geglu") else 2
        return mats * self.d_model * self.d_ff

    def moe_params(self) -> int:
        return self.n_experts * self.ffn_params() + self.d_model * self.n_experts

    def ssm_params(self) -> int:
        # Mamba2-style block: in_proj (2x expand), conv, dt/A/D, out_proj.
        d, e = self.d_model, 2 * self.d_model
        return d * 2 * e + e * self.ssm_state * 2 + e + e * d

    def layer_params(self, i: int) -> int:
        kind = self.layer_kind(i)
        if kind == "mamba":
            p = self.ssm_params()
        elif kind in ("slstm", "mlstm"):
            p = self.attn_params() + self.ffn_params() if self.d_ff else \
                4 * self.d_model * self.d_model + 2 * self.d_model * 4 * self.d_model
        else:
            p = self.attn_params()
            p += self.moe_params() if self.n_experts else self.ffn_params()
        if self.cross_attn_every and (i % self.cross_attn_every ==
                                      self.cross_attn_every - 1):
            p += self.attn_params()
        return p

    def total_params(self) -> int:
        body = sum(self.layer_params(i) for i in range(self.n_layers))
        body += sum(self.attn_params() + self.ffn_params()
                    for _ in range(self.encoder_layers))
        return body + self.vocab * self.d_model  # tied embedding/lm head

    def active_params(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.total_params()
        dense = self.total_params() - self.n_layers * self.moe_params()
        return dense + self.n_layers * (self.top_k * self.ffn_params()
                                        + self.d_model * self.n_experts)


# -- per-layer cost helpers ---------------------------------------------------


def _attn_flops(m: ModelDesc, batch: int, seq: int, kv_len: int | None = None,
                *, causal: bool = True) -> float:
    kv_len = kv_len or seq
    b, d, q, kv, hd, h = batch, m.d_model, m.q_dim, m.kv_dim, m.hd, m.n_heads
    proj = 2 * b * seq * d * (q + 2 * kv) + 2 * b * seq * q * d
    score_factor = 0.5 if (causal and kv_len == seq) else 1.0
    scores = 2 * 2 * b * h * seq * kv_len * hd * score_factor
    return proj + scores


def _ffn_flops(m: ModelDesc, batch: int, seq: int) -> float:
    mats = 3 if m.ffn_kind in ("swiglu", "geglu") else 2
    return mats * 2 * batch * seq * m.d_model * m.d_ff


def _moe_flops(m: ModelDesc, batch: int, seq: int) -> float:
    router = 2 * batch * seq * m.d_model * m.n_experts
    return router + m.top_k * _ffn_flops(m, batch, seq)


def _ssm_flops(m: ModelDesc, batch: int, seq: int) -> float:
    e = 2 * m.d_model
    proj = 2 * batch * seq * m.d_model * 2 * e + 2 * batch * seq * e * m.d_model
    scan = 6 * batch * seq * e * m.ssm_state
    return proj + scan


def layer_flops(m: ModelDesc, i: int, batch: int, seq: int,
                *, kv_len: int | None = None) -> float:
    """Forward FLOPs of layer ``i`` at the given batch/seq (attention,
    FFN, SSM or hybrid per ``m.layer_kind``); ``kv_len`` prices decode
    steps against a longer KV cache."""
    kind = m.layer_kind(i)
    if kind == "mamba":
        f = _ssm_flops(m, batch, seq)
    elif kind in ("slstm", "mlstm"):
        f = _ssm_flops(m, batch, seq) if not m.d_ff else \
            _attn_flops(m, batch, seq, kv_len) + _ffn_flops(m, batch, seq)
    else:
        f = _attn_flops(m, batch, seq, kv_len)
        f += _moe_flops(m, batch, seq) if m.n_experts else _ffn_flops(m, batch, seq)
    if m.cross_attn_every and (i % m.cross_attn_every == m.cross_attn_every - 1):
        f += _attn_flops(m, batch, seq, kv_len=1576, causal=False)
    return f


# ---------------------------------------------------------------------------
# LLM graph builders
# ---------------------------------------------------------------------------


def build_llm_graph(m: ModelDesc, *, batch: int, seq: int,
                    training: bool = True,
                    granularity: str = "layer") -> OpGraph:
    """Expand an LLM into an op graph for one step.

    granularity="layer": one node per transformer layer (paper's first-level
    search space).  granularity="op": each layer split into attention + ffn
    nodes (operator splitting, used by the fusion/splitting experiments).
    Training multiplies fwd flops by 3 (bwd = 2x fwd) and adds gradient
    activation traffic.
    """
    g = OpGraph()
    db = m.dtype_bytes
    act = batch * seq * m.d_model * db
    fwd_mult = 3.0 if training else 1.0
    # optimizer-resident bytes: params (2B) + grads (2B) + adam m,v (4B fp32 x2)
    state_mult = (2 + 2 + 8) / db if training else 1.0

    embed = g.add(OpNode(
        name="embed", kind="embed",
        flops=2 * batch * seq * m.d_model,
        bytes_accessed=act * 2 + batch * seq * 4,
        mem_required=act,
        params_bytes=m.vocab * m.d_model * db * state_mult,
        out_bytes=act, is_matmul=False))

    prev = ["embed"]
    enc_out: str | None = None
    for e in range(m.encoder_layers):
        flops = (_attn_flops(m, batch, 1500, causal=False)
                 + _ffn_flops(m, batch, 1500)) * fwd_mult
        node = g.add(OpNode(
            name=f"enc{e}", kind="encoder_layer",
            flops=flops,
            bytes_accessed=3 * act + (m.attn_params() + m.ffn_params()) * db,
            mem_required=2 * act,
            params_bytes=(m.attn_params() + m.ffn_params()) * db * state_mult,
            out_bytes=act))
        g.connect(prev[0], node.name)
        prev = [node.name]
        enc_out = node.name

    body_in = "embed"
    for i in range(m.n_layers):
        pb = m.layer_params(i) * db
        flops = layer_flops(m, i, batch, seq) * fwd_mult
        traffic = 4 * act + pb
        if granularity == "op" and m.layer_kind(i) == "attn":
            a = g.add(OpNode(
                name=f"layer{i}.attn", kind="attention",
                flops=_attn_flops(m, batch, seq) * fwd_mult,
                bytes_accessed=3 * act + m.attn_params() * db
                + 2 * batch * m.n_heads * seq * seq * db,   # unfused scores
                mem_required=2 * act + batch * m.n_heads * seq * seq * db,
                params_bytes=m.attn_params() * db * state_mult,
                out_bytes=act))
            fkind = "moe_ffn" if m.n_experts else "ffn"
            fflops = (_moe_flops(m, batch, seq) if m.n_experts
                      else _ffn_flops(m, batch, seq)) * fwd_mult
            fparams = (m.moe_params() if m.n_experts else m.ffn_params()) * db
            f = g.add(OpNode(
                name=f"layer{i}.ffn", kind=fkind,
                flops=fflops,
                bytes_accessed=3 * act + (m.top_k * m.ffn_params() * db
                                          if m.n_experts else fparams),
                mem_required=2 * act,
                params_bytes=fparams * state_mult,
                out_bytes=act))
            g.connect(body_in, a.name)
            g.connect(a.name, f.name)
            body_in = f.name
        else:
            node = g.add(OpNode(
                name=f"layer{i}", kind=f"{m.layer_kind(i)}_layer",
                flops=flops, bytes_accessed=traffic,
                mem_required=2 * act, params_bytes=pb * state_mult,
                out_bytes=act))
            g.connect(body_in, node.name)
            if enc_out is not None and m.layer_kind(i) == "attn":
                g.connect(enc_out, node.name, batch * 1500 * m.d_model * db)
            body_in = node.name

    head = g.add(OpNode(
        name="lm_head", kind="lm_head",
        flops=2 * batch * seq * m.d_model * m.vocab * fwd_mult,
        bytes_accessed=act + m.vocab * m.d_model * db
        + batch * seq * m.vocab * db,
        mem_required=batch * seq * m.vocab * db,
        params_bytes=0.0,      # tied with embed
        out_bytes=batch * seq * 4))
    g.connect(body_in, "lm_head")
    return g


def layer_costs(m: ModelDesc, *, batch: int, seq: int,
                training: bool = True) -> list[float]:
    """Per-layer flops vector (embed/head excluded) — the planner's layer
    assignment works over this."""
    mult = 3.0 if training else 1.0
    return [layer_flops(m, i, batch, seq) * mult for i in range(m.n_layers)]


# ---------------------------------------------------------------------------
# Collective decomposition (paper §2.3, Fig. 3)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CommOp:
    """A communication task: ``size`` bytes among ``participants``."""

    name: str
    kind: str                       # p2p | reduce | broadcast | reduce_scatter | all_gather
    size: float
    participants: tuple[int, ...]


def allreduce_naive(name: str, size: float, ranks: Sequence[int]) -> list[CommOp]:
    """Traditional all-reduce: gather-to-root then broadcast (paper Fig. 3 left)."""
    return [CommOp(f"{name}.reduce", "reduce", size, tuple(ranks)),
            CommOp(f"{name}.bcast", "broadcast", size, tuple(ranks))]


def allreduce_decomposed(name: str, size: float,
                         ranks: Sequence[int]) -> list[CommOp]:
    """Decomposed all-reduce: reduce-scatter + all-gather (paper Fig. 3 right)."""
    return [CommOp(f"{name}.rs", "reduce_scatter", size, tuple(ranks)),
            CommOp(f"{name}.ag", "all_gather", size, tuple(ranks))]
