"""LP-relaxation bound tier + exact branch-and-bound MIP oracle (ISSUE 9).

The MIP formulation of operator-level parallel planning (arxiv 2503.09357)
casts strategy selection as an integer program over stage/shard assignment;
its LP relaxation is an *admissible lower bound* on any integral schedule.
This module supplies both halves for the tiered search cascade
(:mod:`repro.core.search`):

  * :func:`simplex_solve` — a dense two-phase primal simplex (numpy only,
    Bland's rule, so degenerate bases terminate) for the small LPs below;
  * :func:`lp_lower_bound` / :class:`LPBoundContext` — the per-candidate
    LP bound, slotted between ``coarse_lower_bound`` and full simulation;
  * :func:`mip_optimum` — an exact best-first branch-and-bound over the
    discrete strategy lattice using the LP relaxation at interior nodes and
    the full simulator at leaves: the certification oracle CI uses to prove
    the cascade never discards the true argmin (AMP, arxiv 2210.07297,
    takes the same bound-then-verify stance).

The LP ("class-capacity packing program")
-----------------------------------------

Fix a candidate ``(dp, tp, pp, M)``.  Any materialization partitions the
``n`` alive devices into ``G = n / tp`` synchronous TP groups (one per
(DP rank, stage) pair); the simulator prices each group's per-layer time at
the roofline of its *slowest member* (by ``peak_flops * perf_factor`` —
:func:`repro.core.simulator._stage_device`), and the group is busy for all
``M`` microbatches of its stage at its rank's batch share, which can never
exceed the rank's 1F1B makespan, hence never the pipeline time.  That gives
a linear program over fractional layer->group assignment ``x``:

  minimize  T
  s.t.      sum_b x[k][b]               == w_k          (every layer placed)
            sum_k t[k][b] * x[k][b]     <= G_b * T      (bucket busy time)
            x >= 0

where layers are merged into kinds ``k`` (count ``w_k``) and group slots
into *buckets* ``b`` of identical admissible class sets: sort devices by
scalar rate; slot ``j``'s real bottleneck rate is at most the
``(j*tp)``-th fastest device's (for ANY grouping — the top-``j`` groups by
bottleneck contain ``j*tp`` devices at least that fast), so slot ``j`` may
optimistically price each layer at the cheapest roofline among classes no
faster than that — including the TP-collective floor (4 activation
all-reduces per layer per microbatch at the fabric-linearized ring cap,
:func:`repro.core.costmodel.collective_floor`).  The slot rows are the
*microbatch pipeline occupancy* constraints: a slot's full-step load
(``M`` microbatches folded into the full-batch pricing) must fit inside
``T``.  Every real plan induces a feasible ``(x, pipe_time)``, so the LP
optimum undershoots the simulator; the gradient-sync ring floor (charged
after the pipeline flush, exactly as the coarse tier does) adds on top,
and the final bound takes ``max`` with the coarse bound — giving the tier
monotonicity ``point <= coarse <= lp <= simulated`` by construction.

On a heterogeneous fleet this is much tighter than the coarse bound's
min-over-classes pricing: half the slots of an 8+8 RTX4090D/V100 cluster
can only be V100-priced (unfused-attention HBM traffic included), which is
exactly the capacity the min-over-classes floor gives away.

The grouped per-variant LP
--------------------------

The packing program relaxes the device *grouping* — but the materializer
is deterministic: ``split_devices`` (speed-sorted on heterogeneous
clusters) fixes every (rank, stage) TP group, ``hetero_batch_shares`` /
the uniform override fix every rank's batch share, and the layer split is
uniform (``L // pp`` per stage minimum) unless the layer B&B runs (which
assigns at least one layer per stage).  So for a concrete ``(point,
refine)`` work item :meth:`LPBoundContext.variant_bound` prices each
(rank, stage) slot at its *actual* bottleneck device and *actual* ring
bandwidth (:func:`repro.core.costmodel._bottleneck_bw` — the very numbers
the simulator will use) and solves, per rank, a small LP over fractional
layer-kind -> stage-class assignment ``z``:

  minimize  T
  s.t.      sum_c z[k][c]                    == count_k    (layers placed)
            sum_k z[k][c]                    >= n_c * fl   (split floor)
            sum_k t[k][c] * z[k][c]          <= n_c/M * T  (class busy)
            Vf * sum_{k,c} t[k][c] * z[k][c] <= T          (1F1B chain)

with ``t[k][c]`` the fwd+bwd kind time at the rank's exact microbatch.
The last row is the geometric pipeline bound: for ANY stage ``s`` of any
schedule, ``makespan >= M * t_s + sum_{s' < s} t_{s'}`` (microbatch 0
must cross every earlier stage before ``s``'s first forward; ``s``
serializes all ``M`` microbatches; the last microbatch's backward still
drains through every earlier stage afterwards — three disjoint windows).
Minimizing the max of those ``pp`` inequalities over all chain splits
gives ``makespan >= chain / (1 - (1 - 1/M)^pp) = Vf * chain``, which
dominates both the round-trip (``chain``) and busy (``M/pp * chain``)
legs.  The rank's bound is the LP optimum; the variant bound is the max
over ranks plus the gradient-sync floor, maxed with the packing bound.

Do not tighten any term toward the simulator without re-running the
admissibility property test in ``tests/test_property_planner.py``.
"""

from __future__ import annotations

import heapq
import math
import time
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from ..obs import Obs, resolve_obs
from .cluster import ClusterTopology
from .costmodel import collective_floor
from .opgraph import ModelDesc
from .planner import StrategyPoint, point_lower_bound

__all__ = [
    "SimplexResult", "simplex_solve", "LPBoundContext", "lp_bound_context",
    "lp_lower_bound", "MIPResult", "mip_optimum",
]


# ---------------------------------------------------------------------------
# Dense two-phase primal simplex (stdlib + numpy, no new dependencies)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimplexResult:
    """Outcome of :func:`simplex_solve` (a minimization).

    ``status`` is ``"optimal"``, ``"infeasible"``, ``"unbounded"`` or
    ``"iteration_limit"``.  ``objective`` is ``+inf`` when infeasible and
    ``-inf`` when unbounded, so bound code can consume it directly
    (an infeasible relaxation proves the candidate cannot be scheduled —
    price it at ``inf`` and let the cascade discard it)."""

    status: str
    x: tuple[float, ...] | None
    objective: float


def _pivot(T: np.ndarray, basis: list[int], row: int, col: int) -> None:
    T[row] /= T[row, col]
    for i in range(T.shape[0]):
        if i != row and T[i, col] != 0.0:
            T[i] -= T[i, col] * T[row]
    basis[row] = col


def _run_simplex(T: np.ndarray, basis: list[int], cost: np.ndarray, *,
                 allowed: int, max_iter: int, tol: float) -> str:
    """Minimize ``cost @ x`` on the tableau ``T`` = [A | b] in place.

    Bland's smallest-index rule for both the entering and leaving choices:
    slower than Dantzig but provably cycle-free, which is what the
    degenerate-basis unit tests pin down.  ``allowed`` restricts entering
    columns (phase 2 must not re-enter artificials)."""
    m = T.shape[0]
    for _ in range(max_iter):
        # reduced costs for the current basis
        z = cost[:allowed] - cost[basis] @ T[:, :allowed]
        enter = -1
        for j in range(allowed):
            if z[j] < -tol:
                enter = j
                break
        if enter < 0:
            return "optimal"
        leave, best = -1, math.inf
        for i in range(m):
            a = T[i, enter]
            if a > tol:
                ratio = T[i, -1] / a
                if ratio < best - tol or (ratio < best + tol
                                          and (leave < 0
                                               or basis[i] < basis[leave])):
                    leave, best = i, ratio
        if leave < 0:
            return "unbounded"
        _pivot(T, basis, leave, enter)
    return "iteration_limit"


def simplex_solve(c: Sequence[float],
                  A_ub: Sequence[Sequence[float]] | None = None,
                  b_ub: Sequence[float] | None = None,
                  A_eq: Sequence[Sequence[float]] | None = None,
                  b_eq: Sequence[float] | None = None, *,
                  max_iter: int = 5000,
                  tol: float = 1e-9) -> SimplexResult:
    """Minimize ``c @ x`` subject to ``A_ub @ x <= b_ub``,
    ``A_eq @ x == b_eq`` and ``x >= 0`` via a dense two-phase tableau."""
    c = np.asarray(c, dtype=float)
    n = c.size
    rows: list[np.ndarray] = []
    rhs: list[float] = []
    slack_of_row: list[int] = []          # row index -> has a slack
    if A_ub is not None:
        A = np.asarray(A_ub, dtype=float).reshape(-1, n)
        b = np.asarray(b_ub, dtype=float).reshape(-1)
        for i in range(A.shape[0]):
            rows.append(A[i].copy())
            rhs.append(float(b[i]))
            slack_of_row.append(len(rows) - 1)
    if A_eq is not None:
        A = np.asarray(A_eq, dtype=float).reshape(-1, n)
        b = np.asarray(b_eq, dtype=float).reshape(-1)
        for i in range(A.shape[0]):
            rows.append(A[i].copy())
            rhs.append(float(b[i]))
    m = len(rows)
    if m == 0:
        if np.any(c < -tol):
            return SimplexResult("unbounded", None, -math.inf)
        return SimplexResult("optimal", tuple([0.0] * n), 0.0)
    nslack = len(slack_of_row)
    body = np.zeros((m, n + nslack))
    for i, r in enumerate(rows):
        body[i, :n] = r
    for j, r in enumerate(slack_of_row):
        body[r, n + j] = 1.0
    b_col = np.asarray(rhs, dtype=float)
    neg = b_col < 0
    body[neg] *= -1.0
    b_col = np.abs(b_col)
    # initial basis: slack columns that survived the sign flip; everything
    # else gets a phase-1 artificial
    basis = [-1] * m
    for j, r in enumerate(slack_of_row):
        if body[r, n + j] > 0 and basis[r] == -1:
            basis[r] = n + j
    need_art = [i for i in range(m) if basis[i] == -1]
    n_art = len(need_art)
    art = np.zeros((m, n_art))
    for k, i in enumerate(need_art):
        art[i, k] = 1.0
        basis[i] = n + nslack + k
    T = np.hstack([body, art, b_col.reshape(-1, 1)])
    total = n + nslack + n_art
    if n_art:
        cost1 = np.zeros(total)
        cost1[n + nslack:] = 1.0
        status = _run_simplex(T, basis, cost1, allowed=n + nslack,
                              max_iter=max_iter, tol=tol)
        if status == "iteration_limit":
            return SimplexResult("iteration_limit", None, math.nan)
        phase1 = float(cost1[basis] @ T[:, -1])
        if phase1 > math.sqrt(tol):
            return SimplexResult("infeasible", None, math.inf)
        # drive any residual (degenerate) artificial out of the basis
        for i in range(m):
            if basis[i] >= n + nslack:
                for j in range(n + nslack):
                    if abs(T[i, j]) > tol:
                        _pivot(T, basis, i, j)
                        break
        if any(v >= n + nslack for v in basis):
            # redundant row: its artificial stays at zero — harmless, but
            # it must not re-enter phase 2 (cost 0 columns guard below)
            pass
    cost2 = np.zeros(total)
    cost2[:n] = c
    status = _run_simplex(T, basis, cost2, allowed=n + nslack,
                          max_iter=max_iter, tol=tol)
    if status == "unbounded":
        return SimplexResult("unbounded", None, -math.inf)
    if status == "iteration_limit":
        return SimplexResult("iteration_limit", None, math.nan)
    x = np.zeros(total)
    for i, v in enumerate(basis):
        x[v] = T[i, -1]
    return SimplexResult("optimal", tuple(float(v) for v in x[:n]),
                         float(c @ x[:n]))


# ---------------------------------------------------------------------------
# The class-capacity packing LP (tier between coarse and simulation)
# ---------------------------------------------------------------------------


@dataclass
class LPBoundContext:
    """Per-search state for the LP tier: pricing tables shared by every
    candidate plus a per-``tp`` memo (the packing LP depends on the
    candidate only through ``tp`` — the sync floor and the coarse ``max``
    are added per point), and the measured solve wall the cascade's cost
    guard projects from."""

    topo: ClusterTopology
    model: ModelDesc
    global_batch: int
    seq: int
    bctx: object                       # repro.core.search._BoundCtx
    rates: tuple[float, ...]           # alive device scalar rates, desc
    class_rate: tuple[float, ...]      # per bound-class scalar rate
    kinds: tuple[tuple[int, int], ...]  # (layer index exemplar, count)
    _tp_memo: dict[int, float] = field(default_factory=dict)
    _variant_memo: dict[tuple[StrategyPoint, bool], float] = \
        field(default_factory=dict)
    _rank_memo: dict[tuple, float] = field(default_factory=dict)
    _snap: ClusterTopology | None = None
    lp_solves: int = 0
    lp_wall: float = 0.0

    # -- cost-guard probes ---------------------------------------------------

    def would_solve(self, tp: int) -> bool:
        """True iff bounding a candidate with this ``tp`` needs a fresh
        (non-memoized) LP solve."""
        return tp not in self._tp_memo

    def solve_wall_estimate(self) -> float:
        """Measured mean wall per LP solve (a prior before the first)."""
        if self.lp_solves:
            return self.lp_wall / self.lp_solves
        return 2e-3

    # -- pricing -------------------------------------------------------------

    def _kind_time(self, layer: int, spec, perf: float, tp: int,
                   tp_coll: float) -> float:
        """Full-global-batch fwd+bwd time for one layer kind on a ``tp``
        group bottlenecked by device class ``(spec, perf)`` — mirrors the
        simulator's per-layer pricing term by term, at batch fraction 1
        (processing a fraction ``phi`` then costs at least ``phi`` times
        this: the roofline is monotone and positively homogeneous, and the
        parameter-traffic constant is paid per rank, not per fraction)."""
        b = self.bctx
        B = float(self.global_batch)
        fl = b.layer_flops1[layer] * B / tp
        traffic = (4.0 * B * b.act_per_sample
                   + b.layer_params[layer] * b.dtype_bytes) / tp
        if b.layer_is_attn[layer] and not spec.supports_fusion:
            traffic += 4.0 * B * b.n_heads * b.seq * b.seq * b.dtype_bytes \
                / tp
        return 3.0 * spec.roofline_time(fl, traffic, perf_factor=perf) \
            + tp_coll

    def packing_value(self, tp: int) -> float:
        """Admissible lower bound on *pipeline* time for every candidate
        with this ``tp`` (memoized).  See the module docstring for the
        program and its admissibility argument."""
        got = self._tp_memo.get(tp)
        if got is not None:
            return got
        t0 = time.perf_counter()
        value = self._solve_packing(tp)
        self.lp_wall += time.perf_counter() - t0
        self.lp_solves += 1
        self._tp_memo[tp] = value
        return value

    def _solve_packing(self, tp: int) -> float:
        from .search import _ring_bw
        b = self.bctx
        n = len(self.rates)
        if tp <= 0 or n < tp:
            return 0.0
        G = n // tp
        if G <= 0:
            return 0.0
        # per-layer TP-collective floor over the full step: 4 activation
        # all-reduces per layer per microbatch (2 fwd + 2 bwd), M microbatches
        # at share w summing to the full global batch — fabric-linearized
        # ring pricing shared with the coarse tier
        tp_coll = 0.0
        if tp > 1:
            bw = _ring_bw(b, tp)
            if bw > 0:
                act = float(self.global_batch) * b.act_per_sample
                tp_coll = 4.0 * collective_floor("all_reduce", act, tp, bw)
        classes = list(b.classes)
        # slot j's real bottleneck scalar rate <= rates[(j+1)*tp - 1]; the
        # admissible class set for the slot is every class at most that
        # fast.  Buckets = runs of slots with the same class set.
        order = sorted(range(len(classes)), key=lambda i: -self.class_rate[i])
        bucket_count: dict[int, int] = {}
        for j in range(G):
            rho = self.rates[(j + 1) * tp - 1]
            lo = len(order)
            for pos, ci in enumerate(order):
                if self.class_rate[ci] <= rho * (1.0 + 1e-12):
                    lo = pos
                    break
            bucket_count[lo] = bucket_count.get(lo, 0) + 1
        buckets = sorted(bucket_count)
        nb = len(buckets)
        kinds = self.kinds
        nk = len(kinds)
        # t[k][b] = cheapest admissible pricing of kind k on bucket b
        t = np.empty((nk, nb))
        for ki, (layer, _cnt) in enumerate(kinds):
            by_class = [self._kind_time(layer, *classes[ci], tp, tp_coll)
                        for ci in order]
            for bi, lo in enumerate(buckets):
                t[ki, bi] = min(by_class[lo:])
        if not np.isfinite(t).all():
            if np.isinf(t).all(axis=1).any():
                return math.inf      # some layer prices inf everywhere
            t = np.where(np.isfinite(t), t, 1e30)
        # variables: [T, x_{k,b} ...]
        nvar = 1 + nk * nb
        c = np.zeros(nvar)
        c[0] = 1.0
        A_eq = np.zeros((nk, nvar))
        b_eq = np.zeros(nk)
        for ki, (_layer, cnt) in enumerate(kinds):
            for bi in range(nb):
                A_eq[ki, 1 + ki * nb + bi] = 1.0
            b_eq[ki] = float(cnt)
        A_ub = np.zeros((nb, nvar))
        b_ub = np.zeros(nb)
        for bi, lo in enumerate(buckets):
            A_ub[bi, 0] = -float(bucket_count[lo])
            for ki in range(nk):
                A_ub[bi, 1 + ki * nb + bi] = t[ki, bi]
        res = simplex_solve(c, A_ub, b_ub, A_eq, b_eq)
        if res.status == "optimal":
            return max(0.0, res.objective)
        if res.status == "infeasible":
            return math.inf
        return 0.0                   # numerical trouble: fall back, stay sound

    # -- per-point bound -----------------------------------------------------

    def point_bound(self, point: StrategyPoint, lb2: float = 0.0) -> float:
        """The LP-tier bound for one candidate: packing LP + gradient-sync
        ring floor, maxed with the supplied coarse bound so the cascade's
        tier monotonicity ``coarse <= lp`` holds by construction."""
        from .search import _sync_floor
        lp = self.packing_value(point.tp)
        return max(lb2, lp + _sync_floor(point, self.bctx))

    # -- per-(point, refine) grouped bound -----------------------------------

    def variant_bound(self, point: StrategyPoint, refine: bool,
                      lb2: float = 0.0) -> float:
        """The LP-tier bound for one ``(point, refine)`` work item: the
        grouped per-rank LP (exact stage classes / ring bandwidths /
        batch shares — see the module docstring) maxed with
        :meth:`point_bound`, so it can only tighten the packing bound."""
        from .search import _sync_floor
        key = (point, refine)
        got = self._variant_memo.get(key)
        if got is None:
            t0 = time.perf_counter()
            got = self._grouped_value(point, refine)
            self.lp_wall += time.perf_counter() - t0
            self._variant_memo[key] = got
        base = self.point_bound(point, lb2)
        if got <= 0.0:
            return base
        return max(base, got + _sync_floor(point, self.bctx))

    def _snapshot(self) -> ClusterTopology:
        # price against the same t=0 snapshot the simulator scores plans on
        if self._snap is None:
            self._snap = self.topo.snapshot(0.0)
        return self._snap

    def _grouped_value(self, point: StrategyPoint, refine: bool) -> float:
        """Pipeline-time lower bound from the deterministic materialization
        layout (0.0 when the layout cannot be reconstructed — the caller
        falls back to the packing bound)."""
        from .costmodel import _bottleneck_bw
        from .planner import hetero_batch_shares
        from .plans import split_devices
        from .simulator import _stage_device
        dp, tp, pp, M = point.dp, point.tp, point.pp, point.microbatches
        snap = self._snapshot()
        hetero = snap.is_heterogeneous()
        try:
            groups = split_devices(snap, dp, tp, pp, sort_by_speed=hetero)
        except ValueError:
            return 0.0
        if refine and hetero and dp > 1:
            rank_devs = [[g[r * tp] for g in groups] for r in range(dp)]
            shares = hetero_batch_shares(snap, rank_devs)
        else:
            shares = tuple([1.0 / dp] * dp)
        L = self.model.n_layers
        # minimum layers per stage: the uniform split pins L // pp; the
        # layer B&B (refine on heterogeneous deep pipes) guarantees >= 1
        floor = 1 if (pp > 1 and refine and hetero) else L // pp
        Vf = 1.0 / (1.0 - (1.0 - 1.0 / M) ** pp) if M > 1 else 1.0
        worst = 0.0
        for r in range(dp):
            mb = max(self.global_batch * shares[r] / M, 1e-9)
            # stage -> pricing class: exact bottleneck device + exact ring
            classes: dict[tuple, list] = {}
            broken = False
            for s in range(pp):
                grp = tuple(groups[s][r * tp:(r + 1) * tp])
                if len(grp) < tp:
                    broken = True
                    break
                try:
                    dev = _stage_device(snap, grp)
                except ValueError:
                    broken = True
                    break
                bw = math.inf
                if tp > 1:
                    bw, _lat = _bottleneck_bw(snap, grp)
                ckey = (id(dev.spec), dev.perf_factor, bw)
                rec = classes.get(ckey)
                if rec is None:
                    classes[ckey] = [dev, bw, 1]
                else:
                    rec[2] += 1
            if broken:
                continue
            rkey = (mb, M, pp, floor,
                    tuple(sorted((k, rec[2]) for k, rec in classes.items())))
            val = self._rank_memo.get(rkey)
            if val is None:
                val = self._solve_rank(list(classes.values()), mb, tp, M,
                                       Vf, floor)
                self._rank_memo[rkey] = val
            worst = max(worst, val)
        return worst

    def _solve_rank(self, classes: list, mb: float, tp: int, M: int,
                    Vf: float, floor: int) -> float:
        """Min over fractional layer->class splits of the rank's admissible
        makespan legs (class busy, geometric 1F1B chain)."""
        b = self.bctx
        kinds = self.kinds
        nk, nc = len(kinds), len(classes)
        t = np.empty((nk, nc))
        for ci, (dev, bw, _cnt) in enumerate(classes):
            coll = 0.0
            if tp > 1:
                coll = 4.0 * collective_floor(
                    "all_reduce", mb * b.act_per_sample, tp, bw) \
                    if bw > 0 else math.inf
            for ki, (layer, _n) in enumerate(kinds):
                fl = b.layer_flops1[layer] * mb / tp
                traffic = (4.0 * mb * b.act_per_sample
                           + b.layer_params[layer] * b.dtype_bytes) / tp
                if b.layer_is_attn[layer] and not dev.spec.supports_fusion:
                    traffic += 4.0 * mb * b.n_heads * b.seq * b.seq \
                        * b.dtype_bytes / tp
                t[ki, ci] = 3.0 * dev.spec.roofline_time(
                    fl, traffic, perf_factor=dev.perf_factor) + coll
        if not np.isfinite(t).all():
            t = np.where(np.isfinite(t), t, 1e30)
        # variables: [T, z_{k,c} ...]
        nvar = 1 + nk * nc
        c = np.zeros(nvar)
        c[0] = 1.0
        A_eq = np.zeros((nk, nvar))
        b_eq = np.zeros(nk)
        for ki, (_layer, cnt) in enumerate(kinds):
            for ci in range(nc):
                A_eq[ki, 1 + ki * nc + ci] = 1.0
            b_eq[ki] = float(cnt)
        rows: list[np.ndarray] = []
        rhs: list[float] = []
        for ci, (_dev, _bw, n_c) in enumerate(classes):
            busy = np.zeros(nvar)
            busy[0] = -float(n_c) / M
            for ki in range(nk):
                busy[1 + ki * nc + ci] = t[ki, ci]
            rows.append(busy)
            rhs.append(0.0)
            if floor > 0 and nc > 1:
                low = np.zeros(nvar)
                for ki in range(nk):
                    low[1 + ki * nc + ci] = -1.0
                rows.append(low)
                rhs.append(-float(floor * n_c))
        chain = np.zeros(nvar)
        chain[0] = -1.0
        chain[1:] = Vf * t.reshape(-1)
        rows.append(chain)
        rhs.append(0.0)
        res = simplex_solve(c, rows, rhs, A_eq, b_eq)
        self.lp_solves += 1
        if res.status == "optimal":
            if res.objective >= 1e29:
                return math.inf          # some kind only prices at inf
            return max(0.0, res.objective)
        if res.status == "infeasible":
            return math.inf
        return 0.0                       # numerical trouble: stay sound


def lp_bound_context(topo: ClusterTopology, model: ModelDesc, *,
                     global_batch: int, seq: int,
                     bctx=None) -> LPBoundContext:
    """Build the LP tier's shared pricing state (one per cascade run;
    ``bctx`` lets :func:`repro.core.search.score_candidates` reuse the
    coarse tier's already-built bound context)."""
    from .search import _bound_context
    if bctx is None:
        bctx = _bound_context(topo, model, seq=seq)
    # scalar ordering must match simulator._stage_device (peak * perf): the
    # slot-domination argument is stated for the rate the simulator uses to
    # pick each group's bottleneck member
    rates = tuple(sorted(
        (d.spec.peak_flops * d.perf_factor for d in topo.alive_devices),
        reverse=True))
    class_rate = tuple(spec.peak_flops * perf for spec, perf in bctx.classes)
    by_shape: dict[tuple, list[int]] = {}
    for l in range(model.n_layers):
        key = (bctx.layer_flops1[l], bctx.layer_params[l],
               bctx.layer_is_attn[l])
        by_shape.setdefault(key, []).append(l)
    kinds = tuple((layers[0], len(layers))
                  for layers in by_shape.values())
    return LPBoundContext(topo=topo, model=model, global_batch=global_batch,
                          seq=seq, bctx=bctx, rates=rates,
                          class_rate=class_rate, kinds=kinds)


def lp_lower_bound(point: StrategyPoint, topo: ClusterTopology,
                   model: ModelDesc, *, global_batch: int, seq: int,
                   refine: bool | None = None,
                   ctx: LPBoundContext | None = None) -> float:
    """LP-relaxation lower bound on the simulated step time of every
    materialization of ``point`` — by construction
    ``point_lower_bound <= coarse_lower_bound <= lp_lower_bound <= sim``.
    With ``refine`` given, the bound additionally uses the deterministic
    materialization layout of that work item (tighter; still admissible).
    Pass ``ctx`` (:func:`lp_bound_context`) when bounding many candidates
    of one search: the packing LP is memoized per ``tp`` and the grouped
    LPs per (point, refine) / rank class profile."""
    from .search import _coarse_bound
    if ctx is None:
        ctx = lp_bound_context(topo, model, global_batch=global_batch,
                               seq=seq)
    lb1 = point_lower_bound(point, topo, model, global_batch=global_batch,
                            seq=seq)
    lb2 = max(lb1, _coarse_bound(point, ctx.bctx, global_batch=global_batch))
    if refine is None:
        return ctx.point_bound(point, lb2)
    return ctx.variant_bound(point, refine, lb2)


# ---------------------------------------------------------------------------
# Exact branch-and-bound MIP oracle
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MIPResult:
    """Outcome of :func:`mip_optimum`.

    ``completed`` is the certification flag: True means the branch-and-bound
    exhausted the tree within its budgets, so ``plan`` is *provably* the
    ``(step_time, canonical index)`` argmin over the candidate lattice —
    the exact optimum the cascade must match.  With ``completed`` False the
    incumbent is only a feasible solution and certification must be
    skipped, never failed."""

    point: StrategyPoint | None
    refine: bool
    plan: object | None              # ParallelPlan
    sim: object | None               # StepSim
    step_time: float
    index: int
    completed: bool
    nodes: int
    sims: int
    lp_solves: int
    wall_s: float


def mip_optimum(topo: ClusterTopology, model: ModelDesc, *,
                global_batch: int, seq: int, gpus_per_node: int = 8,
                max_candidates: int | None = None,
                points: Sequence[StrategyPoint] | None = None,
                node_budget: int = 100_000,
                sim_budget: int | None = None,
                wall_budget_s: float | None = None,
                obs: Obs | None = None) -> MIPResult:
    """Exact best-first branch-and-bound over the strategy lattice.

    The integer variables are the parallelism degrees: the root splits on
    ``tp`` (whose subtree bound is the pure packing LP — every other choice
    relaxed), ``tp`` nodes split on ``pp`` (adding the cheapest
    gradient-sync floor the fixed ``dp = n/(tp*pp)`` admits), and leaves
    are the concrete ``(point, refine)`` candidates, bounded by the full
    :func:`lp_lower_bound` and evaluated by the same
    materialize-and-simulate pipeline the cascade uses.  Pruning is strict
    (``bound > incumbent``) and the incumbent orders by
    ``(step_time, canonical index)``, so a completed run returns the exact
    candidate the cascade's argmin must equal, byte for byte.

    ``points`` / ``max_candidates`` mirror :func:`repro.core.planner
    .plan_hybrid`'s candidate-set resolution so oracle and cascade search
    the identical lattice.  Budgets (``node_budget`` LP-bounded nodes,
    ``sim_budget`` leaf simulations, ``wall_budget_s`` seconds) make the
    oracle safe on medium instances: exhausting any of them returns the
    incumbent with ``completed=False``.

    Raises RuntimeError when no leaf simulates feasibly (mirrors
    ``plan_hybrid``'s "no feasible plan found").
    """
    from .planner import DEFAULT_MAX_CANDIDATES, enumerate_strategies
    from .search import (_score_variant, _sync_floor, point_feasible)
    t0 = time.perf_counter()
    obs = resolve_obs(obs)
    if points is None:
        points, _stats = enumerate_strategies(
            topo, model, global_batch=global_batch,
            gpus_per_node=gpus_per_node)
    points = list(points)[:max_candidates if max_candidates is not None
                          else DEFAULT_MAX_CANDIDATES]
    variants = (True, False) if topo.is_heterogeneous() else (False,)
    nv = len(variants)
    lctx = lp_bound_context(topo, model, global_batch=global_batch, seq=seq)

    leaves: list[tuple[int, StrategyPoint, bool]] = []
    for pi, point in enumerate(points):
        if not point_feasible(point, topo, model, global_batch=global_batch):
            continue
        for vi, refine in enumerate(variants):
            leaves.append((pi * nv + vi, point, refine))

    by_tp: dict[int, list[tuple[int, StrategyPoint, bool]]] = {}
    for leaf in leaves:
        by_tp.setdefault(leaf[1].tp, []).append(leaf)

    # heap entries: (bound, min canonical index, seq#, kind, payload)
    heap: list = []
    tick = 0
    for tp, group in sorted(by_tp.items()):
        bound = lctx.packing_value(tp)
        heapq.heappush(heap, (bound, min(i for i, _, _ in group), tick,
                              "tp", (tp, group)))
        tick += 1

    best_step = math.inf
    best_index = -1
    best: tuple[StrategyPoint, bool, object, object] | None = None
    nodes = sims = 0
    completed = True
    memo: dict = {}
    with obs.span("search.mip", n_candidates=len(leaves)) as span:
        while heap:
            if nodes >= node_budget \
                    or (sim_budget is not None and sims >= sim_budget) \
                    or (wall_budget_s is not None
                        and time.perf_counter() - t0 > wall_budget_s):
                completed = False
                break
            bound, _minidx, _tick, kind, payload = heapq.heappop(heap)
            if bound > best_step:
                continue                      # strict: ties stay explored
            nodes += 1
            if kind == "tp":
                tp, group = payload
                by_pp: dict[int, list] = {}
                for leaf in group:
                    by_pp.setdefault(leaf[1].pp, []).append(leaf)
                for pp, sub in sorted(by_pp.items()):
                    sync = min(_sync_floor(p, lctx.bctx) for _, p, _ in sub)
                    heapq.heappush(
                        heap, (max(bound, lctx.packing_value(tp) + sync),
                               min(i for i, _, _ in sub), tick, "pp", sub))
                    tick += 1
            elif kind == "pp":
                for index, point, refine in payload:
                    lb = lp_lower_bound(point, topo, model,
                                        global_batch=global_batch, seq=seq,
                                        refine=refine, ctx=lctx)
                    heapq.heappush(heap, (lb, index, tick, "leaf",
                                          (index, point, refine)))
                    tick += 1
            else:
                index, point, refine = payload
                res = _score_variant(point, refine, topo, model,
                                     global_batch=global_batch, seq=seq,
                                     memo=memo)
                sims += 1
                if res is None:
                    continue
                plan, sim = res
                if (sim.step_time, index) < (best_step, best_index if
                                             best is not None else math.inf):
                    best_step, best_index = sim.step_time, index
                    best = (point, refine, plan, sim)
        span.set(nodes=nodes, sims=sims, completed=completed)
    obs.inc("search.mip.nodes", nodes)
    obs.inc("search.mip.sims", sims)
    if best is None:
        if not completed:
            return MIPResult(point=None, refine=False, plan=None, sim=None,
                             step_time=math.inf, index=-1, completed=False,
                             nodes=nodes, sims=sims,
                             lp_solves=lctx.lp_solves,
                             wall_s=time.perf_counter() - t0)
        raise RuntimeError("no feasible plan found")
    point, refine, plan, sim = best
    return MIPResult(point=point, refine=refine, plan=plan, sim=sim,
                     step_time=best_step, index=best_index,
                     completed=completed, nodes=nodes, sims=sims,
                     lp_solves=lctx.lp_solves,
                     wall_s=time.perf_counter() - t0)
