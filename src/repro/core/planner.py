"""Parallel branch-and-bound planner (paper §3.3, Algorithm 1) with
strategy pruning (§3.4).

Two faithful instantiations of Algorithm 1:

  * :func:`branch_and_bound_assign` — the general operator→device assignment
    search over an arbitrary :class:`OpGraph` (small graphs; used to verify
    optimality against exhaustive search in tests),
  * :func:`bnb_layer_split` — the LLM-scale instantiation at layer
    granularity (the paper's "first-level optimization"): contiguous layer →
    pipeline-stage assignment for heterogeneous stages.

Both follow Alg. 1 structure exactly: greedy initialization of the incumbent
(upper bound), a priority queue ordered by an admissible cost bound F(N),
feasible-child generation under the constraint system (Eq. 4-7), pruning of
children with F(N_child) >= best_UB, and parallel child evaluation.

:func:`plan_hybrid` is the end-to-end entry point: enumerate hybrid-parallel
strategy candidates (DP/TP/PP/EP/microbatching + collective decomposition),
then hand them to the tiered pruning cascade in :mod:`repro.core.search`
(feasibility → analytic bound → coarse estimate → full simulation, with the
final tier optionally scored in worker processes — the paper accelerates its
search with parallel simulation, §3.4/§4).
"""

from __future__ import annotations

import functools
import heapq
import itertools
import math
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Iterable, Mapping, Sequence

from ..obs import Obs, resolve_obs
from .cluster import ClusterTopology, DeviceInstance
from .costmodel import graph_compute_lower_bound, op_time, transfer_time
from .opgraph import ModelDesc, OpGraph, layer_flops
from .plans import (ParallelPlan, StageAssignment, megatron_default_plan,
                    split_devices, stages_from_sizes, uniform_stages)
from .simulator import (StepSim, memory_feasible, simulate_schedule,
                        simulate_training_step)

# ---------------------------------------------------------------------------
# Generic Algorithm 1: operator -> device assignment
# ---------------------------------------------------------------------------


@dataclass
class SearchStats:
    """Search telemetry, shared by every planner entry point.

    ``explored``/``pruned``/``infeasible`` count enumeration/B&B work;
    the ``pruned_*``/``simulated``/``budget_skipped`` block is the tiered
    cascade's per-(point, refine)-candidate accounting (all sharing the
    :attr:`cascade_candidates` denominator); ``cache_hits``/``cache_misses``
    tell warm resolution apart from real simulator work.  Mutated in place
    by :func:`repro.core.search.score_candidates`."""

    explored: int = 0
    pruned: int = 0
    infeasible: int = 0
    # candidates whose materialization/simulation raised (ValueError /
    # ZeroDivisionError) — previously swallowed silently; surfaced so the
    # paper-style search statistics show pruning efficacy.
    rejected: int = 0
    # strategy-cache telemetry (filled when plan_hybrid runs with a cache)
    cache_hits: int = 0
    cache_misses: int = 0
    wall_time: float = 0.0
    # -- tiered-cascade telemetry (repro.core.search), counted per
    # (point, refine) candidate so the tiers share one denominator:
    # candidates cut by the structural/memory feasibility tier,
    pruned_feasibility: int = 0
    # ...by the analytic point_lower_bound tier,
    pruned_bound: int = 0
    # ...by the coarse pipeline/sync estimate tier,
    pruned_coarse: int = 0
    # ...by the LP-relaxation packing bound (repro.core.mip, tier 2.5),
    pruned_lp: int = 0
    # ...and candidates that reached the final tier and were fully scored —
    # by a fresh simulation OR a session-cache hit (the cascade's pruning
    # denominator; ``cache_hits``/``cache_misses`` tell warm resolution
    # apart from real simulator work).
    simulated: int = 0
    # candidates skipped by the ``max_sims`` anytime budget — NOT soundly
    # pruned (one of them might have been the argmin); nonzero only when a
    # caller bounds the final tier (the hierarchical island searches do)
    budget_skipped: int = 0
    # wall seconds spent inside the LP tier (context build + simplex
    # solves + per-candidate bound assembly) — the cost the guard weighs
    # against projected simulation savings
    lp_wall_time: float = 0.0

    @property
    def cascade_candidates(self) -> int:
        """Candidates that entered the cascade (all tiers' denominator)."""
        return (self.pruned_feasibility + self.pruned_bound
                + self.pruned_coarse + self.pruned_lp + self.simulated
                + self.rejected + self.budget_skipped)

    @property
    def prune_rate(self) -> float:
        """Fraction of cascade candidates cut before full simulation."""
        total = self.cascade_candidates
        cut = (self.pruned_feasibility + self.pruned_bound
               + self.pruned_coarse + self.pruned_lp)
        return cut / total if total else 0.0


def greedy_assign(graph: OpGraph, topo: ClusterTopology) -> dict[str, int]:
    """HEFT-like greedy initialization (Alg. 1 line 4): place each op, in
    topological order, on the device minimizing its finish time."""
    order = graph.topo_order()
    assignment: dict[str, int] = {}
    dev_free = {d.device_id: 0.0 for d in topo.alive_devices}
    end: dict[str, float] = {}
    for v in order:
        best_dev, best_en = None, math.inf
        for d in topo.alive_devices:
            arrive = 0.0
            for u in graph.preds(v):
                du = assignment[u]
                x = 0.0 if du == d.device_id else transfer_time(
                    topo, du, d.device_id, graph.edges[(u, v)])
                arrive = max(arrive, end[u] + x)
            st = max(arrive, dev_free[d.device_id])
            en = st + op_time(graph.nodes[v], d)
            if en < best_en:
                best_dev, best_en = d.device_id, en
        assert best_dev is not None
        assignment[v] = best_dev
        end[v] = best_en
        dev_free[best_dev] = best_en
    return assignment


def _partial_bound(graph: OpGraph, topo: ClusterTopology,
                   assignment: Mapping[str, int], order: Sequence[str],
                   k: int) -> float:
    """Admissible F(N) = max of three individually-admissible lower bounds:

      * makespan of the assigned prefix simulated alone (adding the suffix
        can only delay prefix ops under the deterministic ready-order
        scheduler, never accelerate them),
      * remaining work over aggregate cluster throughput,
      * the suffix critical path on the fastest device.

    NOTE: summing prefix + suffix bounds is NOT admissible — independent
    suffix ops can overlap the prefix on idle devices (caught by the
    hypothesis optimality property test)."""
    prefix = {n: assignment[n] for n in order[:k]}
    if prefix:
        sub = OpGraph(
            nodes={n: graph.nodes[n] for n in prefix},
            edges={(u, v): s for (u, v), s in graph.edges.items()
                   if u in prefix and v in prefix})
        prefix_time = simulate_schedule(sub, prefix, topo).makespan
    else:
        prefix_time = 0.0
    rest = order[k:]
    if not rest:
        return prefix_time
    rest_flops = sum(graph.nodes[n].flops for n in rest)
    work_lb = graph_compute_lower_bound(rest_flops, topo.alive_devices)
    # critical path of the suffix on the fastest device
    fastest = max(topo.alive_devices,
                  key=lambda d: d.spec.peak_flops * d.perf_factor)
    cp = 0.0
    dist: dict[str, float] = {}
    for n in order:
        t = op_time(graph.nodes[n], fastest)
        dist[n] = max((dist[p] for p in graph.preds(n) if p in dist),
                      default=0.0) + (t if n in rest else 0.0)
        cp = max(cp, dist[n])
    return max(prefix_time, work_lb, cp)


def branch_and_bound_assign(
        graph: OpGraph, topo: ClusterTopology, *,
        max_nodes: int = 200_000, n_workers: int = 8,
        feasible_only: bool = True) -> tuple[dict[str, int], float, SearchStats]:
    """Algorithm 1 verbatim for operator→device assignment.

    Returns (assignment, makespan, stats).  Guaranteed optimal w.r.t. the
    simulator when the node budget is not exhausted (checked in tests against
    exhaustive enumeration).
    """
    t0 = time.perf_counter()
    order = graph.topo_order()
    devices = topo.alive_ids()
    stats = SearchStats()

    # line 4: greedy incumbent
    best_assignment = greedy_assign(graph, topo)
    best_ub = simulate_schedule(graph, best_assignment, topo).makespan

    # priority queue of (F(N), tiebreak, depth, partial assignment)
    counter = itertools.count()
    root = (0.0, next(counter), 0, ())
    pq: list[tuple[float, int, int, tuple[int, ...]]] = [root]

    pool = ThreadPoolExecutor(max_workers=n_workers)
    try:
        while pq and stats.explored < max_nodes:
            f, _, depth, partial = heapq.heappop(pq)
            if f >= best_ub - 1e-12:
                stats.pruned += 1
                continue
            stats.explored += 1
            if depth == len(order):
                # complete solution (Alg. 1 lines 9-10)
                assignment = dict(zip(order, partial))
                cost = simulate_schedule(graph, assignment, topo).makespan
                if cost < best_ub:
                    best_ub, best_assignment = cost, assignment
                continue
            # feasible children: next op on each device (lines 12-15)
            children = []
            for d in devices:
                cand = partial + (d,)
                assignment = dict(zip(order, cand))
                if feasible_only and not memory_feasible(
                        graph,
                        {**{n: assignment[n] for n in order[:depth + 1]}},
                        topo):
                    stats.infeasible += 1
                    continue
                children.append(cand)
            # estimate costs concurrently (paper: parallel simulation)
            bounds = list(pool.map(
                lambda c: _partial_bound(graph, topo,
                                         dict(zip(order, c)), order,
                                         len(c)), children))
            for cand, fb in zip(children, bounds):
                if fb < best_ub - 1e-12:
                    heapq.heappush(pq, (fb, next(counter), depth + 1, cand))
                else:
                    stats.pruned += 1
    finally:
        pool.shutdown(wait=False)
    stats.wall_time = time.perf_counter() - t0
    return best_assignment, best_ub, stats


def exhaustive_assign(graph: OpGraph, topo: ClusterTopology
                      ) -> tuple[dict[str, int], float]:
    """Brute force oracle for tests."""
    order = graph.topo_order()
    devices = topo.alive_ids()
    best, best_cost = None, math.inf
    for combo in itertools.product(devices, repeat=len(order)):
        assignment = dict(zip(order, combo))
        if not memory_feasible(graph, assignment, topo):
            continue
        c = simulate_schedule(graph, assignment, topo).makespan
        if c < best_cost:
            best, best_cost = assignment, c
    assert best is not None, "no feasible assignment"
    return best, best_cost


# ---------------------------------------------------------------------------
# Layer-level Algorithm 1: contiguous layer -> stage split
# ---------------------------------------------------------------------------


def _stage_rate(topo: ClusterTopology, group: Sequence[int], tp: int) -> float:
    """Effective flops rate of a stage: slowest member bounds synchronous TP."""
    devs = [topo.device(d) for d in group if topo.device(d).alive]
    slow = min(devs, key=lambda d: d.spec.peak_flops * d.perf_factor)
    return slow.spec.peak_flops * slow.spec.matmul_eff * slow.perf_factor * tp


def bnb_layer_split(model: ModelDesc, topo: ClusterTopology,
                    groups: Sequence[Sequence[int]], tp: int, *,
                    batch: int, seq: int, max_nodes: int = 50_000
                    ) -> tuple[list[int], SearchStats]:
    """Algorithm 1 at layer granularity: choose stage sizes (contiguous layer
    counts) minimizing the bottleneck stage time on heterogeneous stages.

    Node = (bound, next stage index, layers consumed, current max stage time).
    Greedy incumbent: proportional-to-capacity allocation.  Memory-infeasible
    children (stage params exceed stage memory, Eq. 6) are pruned.
    """
    t0 = time.perf_counter()
    S = len(groups)
    L = model.n_layers
    costs = [layer_flops(model, i, batch, seq) * 3.0 for i in range(L)]
    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + c)
    rates = [_stage_rate(topo, g, tp) for g in groups]
    mems = [min(topo.device(d).spec.mem_bytes for d in g) * tp * 0.95
            for g in groups]
    state_mult = 12  # bytes per param: bf16 p+g + fp32 adam m,v
    stats = SearchStats()

    def stage_time(s: int, lo: int, hi: int) -> float:
        return (prefix[hi] - prefix[lo]) / rates[s]

    def stage_mem(lo: int, hi: int) -> float:
        return sum(model.layer_params(i) for i in range(lo, hi)) * state_mult

    def greedy_sizes() -> list[int]:
        total_rate = sum(rates)
        sizes, used = [], 0
        for s in range(S):
            if s == S - 1:
                sizes.append(L - used)
                break
            want = round(L * rates[s] / total_rate)
            want = max(1, min(want, L - used - (S - 1 - s)))
            sizes.append(want)
            used += want
        return sizes

    def eval_sizes(sizes: Sequence[int]) -> float:
        lo = 0
        worst = 0.0
        for s, sz in enumerate(sizes):
            worst = max(worst, stage_time(s, lo, lo + sz))
            lo += sz
        return worst

    incumbent = greedy_sizes()
    best_ub = eval_sizes(incumbent)

    counter = itertools.count()
    # node: (bound, tiebreak, stage idx, consumed layers, sizes, cur_max)
    pq: list[tuple[float, int, int, int, tuple[int, ...], float]] = [
        (0.0, next(counter), 0, 0, (), 0.0)]
    while pq and stats.explored < max_nodes:
        f, _, s, used, sizes, cur_max = heapq.heappop(pq)
        if f >= best_ub - 1e-12:
            stats.pruned += 1
            continue
        stats.explored += 1
        if s == S:
            if used == L and cur_max < best_ub:
                best_ub, incumbent = cur_max, list(sizes)
            continue
        remaining_stages = S - s - 1
        max_take = L - used - remaining_stages
        for take in range(1, max_take + 1):
            lo, hi = used, used + take
            if stage_mem(lo, hi) > mems[s]:
                stats.infeasible += 1
                break  # adding more layers only grows memory
            t_here = max(cur_max, stage_time(s, lo, hi))
            # admissible bound: remaining work over remaining capacity
            rem_work = prefix[L] - prefix[hi]
            rem_rate = sum(rates[s + 1:])
            lb = max(t_here,
                     (rem_work / rem_rate) if rem_rate > 0 else
                     (math.inf if rem_work > 0 else 0.0))
            if lb >= best_ub - 1e-12:
                stats.pruned += 1
                continue
            heapq.heappush(pq, (lb, next(counter), s + 1, hi,
                                sizes + (take,), t_here))
    stats.wall_time = time.perf_counter() - t0
    return incumbent, stats


# ---------------------------------------------------------------------------
# Heterogeneous batch shares (uneven DP)
# ---------------------------------------------------------------------------


def hetero_batch_shares(topo: ClusterTopology,
                        rank_devices: Sequence[Sequence[int]]) -> tuple[float, ...]:
    """Batch share per DP rank proportional to its slowest device's rate."""
    rates = []
    for group in rank_devices:
        devs = [topo.device(d) for d in group]
        slow = min(devs, key=lambda d: d.spec.peak_flops * d.perf_factor)
        rates.append(slow.spec.peak_flops * slow.perf_factor)
    total = sum(rates)
    if total <= 0:
        return tuple(1.0 / len(rates) for _ in rates)
    return tuple(r / total for r in rates)


# ---------------------------------------------------------------------------
# Strategy enumeration + pruning (paper §3.4)
# ---------------------------------------------------------------------------


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


@dataclass(frozen=True)
class StrategyPoint:
    """One point in the hybrid-parallel strategy lattice: the degrees of
    data/tensor/pipeline/expert parallelism, the microbatch count, and the
    gradient-sync schedule (``"rs_ag"`` decomposed vs ``"allreduce"``
    naive).  Materialization (device grouping, layer split, batch shares)
    happens later in :func:`materialize_plan` — a point is the cascade's
    unit of pruning, hashable and cheap to enumerate."""

    dp: int
    tp: int
    pp: int
    ep: int
    microbatches: int
    grad_sync: str


def enumerate_strategies(topo: ClusterTopology, model: ModelDesc, *,
                         global_batch: int, gpus_per_node: int = 8,
                         max_tp: int = 64) -> tuple[list[StrategyPoint], SearchStats]:
    """Enumerate hybrid-parallel candidates with strategy pruning.

    Pruning rules (cheap, before any simulation — §3.4 "apply constraints to
    eliminate infeasible choices"):
      * dp*tp*pp == alive devices; tp | n_heads & n_kv_heads alignment;
        pp <= n_layers; microbatches | per-rank batch
      * memory (Eq. 6): optimizer state per device must fit
      * MoE: ep | n_experts, ep <= tp (experts ride the model axis)
    """
    stats = SearchStats()
    n = len(topo.alive_ids())
    mem = min(d.spec.mem_bytes for d in topo.alive_devices)
    pts: list[StrategyPoint] = []
    state_bytes = model.total_params() * 12
    act_per_token = model.d_model * model.dtype_bytes * 12  # rough act factor
    for tp in _divisors(n):
        if tp > max_tp or model.n_heads % tp:
            continue
        for pp in _divisors(n // tp):
            if pp > model.n_layers:
                continue
            dp = n // (tp * pp)
            if global_batch % dp:
                stats.infeasible += 1
                continue
            # Eq. 6 pruning: params+opt state sharded over tp*pp (+zero1 dp)
            per_dev = state_bytes / (tp * pp)
            if per_dev > mem * 0.9:
                stats.pruned += 1
                continue
            eps = [1]
            if model.n_experts:
                eps = [e for e in _divisors(model.n_experts) if e <= tp]
            for ep in eps:
                for mb in (pp, 2 * pp, 4 * pp):
                    if (global_batch // dp) % mb:
                        continue
                    for sync in ("rs_ag", "allreduce"):
                        pts.append(StrategyPoint(dp, tp, pp, ep, mb, sync))
    stats.explored = len(pts)
    return pts, stats


# ---------------------------------------------------------------------------
# End-to-end planning
# ---------------------------------------------------------------------------


@dataclass
class PlanResult:
    """Everything :func:`plan_hybrid` returns: the argmin plan with its
    simulated step time, the optional Megatron baselines (literal default
    and tuned-uniform), per-tier :class:`SearchStats`, and the distinct
    ``top_k`` best plans for downstream candidate widening."""

    plan: ParallelPlan
    predicted: StepSim
    candidates_evaluated: int
    candidates_pruned: int
    wall_time: float
    candidates_rejected: int = 0
    baseline: ParallelPlan | None = None
    baseline_predicted: StepSim | None = None
    tuned_baseline: ParallelPlan | None = None
    tuned_baseline_predicted: StepSim | None = None
    search_stats: SearchStats | None = None
    # best distinct plans by predicted step time (length <= the ``top_k``
    # requested from plan_hybrid); feeds the cross-interval DP oracle's
    # widened per-interval candidate set
    top_plans: tuple[tuple[ParallelPlan, StepSim], ...] = ()

    @property
    def speedup_vs_baseline(self) -> float:
        """vs the literal Megatron default configuration (paper's baseline)."""
        if self.baseline_predicted is None:
            return 1.0
        return self.baseline_predicted.step_time / self.predicted.step_time

    @property
    def speedup_vs_tuned(self) -> float:
        """vs the best *uniform* (heterogeneity-blind) configuration — a
        stronger baseline isolating the gain from heterogeneity awareness."""
        if self.tuned_baseline_predicted is None:
            return 1.0
        return self.tuned_baseline_predicted.step_time / self.predicted.step_time


def megatron_tuned_plan(topo: ClusterTopology, model: ModelDesc, *,
                        global_batch: int, seq: int) -> tuple[ParallelPlan, StepSim]:
    """Best heterogeneity-*blind* plan: grid over (tp, pp, mb) with uniform
    layer split, even batch shares and naive all-reduce — what a careful
    practitioner gets from Megatron without the paper's technique."""
    n = len(topo.alive_ids())
    mem = min(d.spec.mem_bytes for d in topo.alive_devices)
    state_bytes = model.total_params() * 12
    best: tuple[float, ParallelPlan, StepSim] | None = None
    for tp in _divisors(n):
        if model.n_heads % tp or tp > 64:
            continue
        for pp in _divisors(n // tp):
            if pp > model.n_layers:
                continue
            dp = n // (tp * pp)
            if global_batch % dp:
                continue
            # same Eq. 6 feasibility the planner enforces — without it the
            # baseline "wins" with memory-infeasible configs
            if state_bytes / (tp * pp) > mem * 0.9:
                continue
            for mb in (pp, 2 * pp, 4 * pp):
                if (global_batch // dp) % mb:
                    continue
                groups = split_devices(topo, dp, tp, pp)
                plan = ParallelPlan(
                    dp=dp, tp=tp, pp=pp, microbatches=mb,
                    stages=uniform_stages(model.n_layers, pp, groups),
                    batch_shares=tuple([1.0 / dp] * dp),
                    grad_sync="allreduce", zero1=False,
                    meta={"source": "megatron-tuned-uniform"})
                try:
                    sim = simulate_training_step(
                        plan, model, topo, global_batch=global_batch, seq=seq)
                except (ValueError, ZeroDivisionError):
                    continue
                if best is None or sim.step_time < best[0]:
                    best = (sim.step_time, plan, sim)
    assert best is not None, "no feasible uniform plan"
    return best[1], best[2]


@functools.lru_cache(maxsize=128)
def _total_step_flops(model: ModelDesc, global_batch: int, seq: int) -> float:
    return 3.0 * sum(layer_flops(model, l, global_batch, seq)
                     for l in range(model.n_layers))


def point_lower_bound(point: StrategyPoint, topo: ClusterTopology,
                      model: ModelDesc, *, global_batch: int,
                      seq: int) -> float:
    """Optimistic step-time bound for a strategy point — no materialization,
    no simulation.  Used by the re-planning engine to cut candidates against
    an incumbent plan's score (Alg. 1 pruning reused across plans).

    compute-over-aggregate-throughput plus a gradient-sync floor.  Both
    terms undershoot the simulator by construction — the sync term charges
    one *average* stage's bytes at the cluster's best single-edge bandwidth,
    while the simulator pays the worst stage at the group's bottleneck — so
    a cut candidate can never have beaten the incumbent.  Keep it that way:
    tightening either term toward the simulator breaks the never-over-prune
    invariant the re-planning engine relies on.
    """
    rate = sum(d.spec.peak_flops * d.spec.matmul_eff * d.perf_factor
               for d in topo.alive_devices)
    if rate <= 0:
        return math.inf
    lb = _total_step_flops(model, global_batch, seq) / rate
    if point.dp > 1:
        stage_bytes = (model.total_params() * model.dtype_bytes
                       / (point.pp * point.tp))
        best_bw = max((e.effective_bandwidth
                       for link in topo.links.values() for e in link.edges),
                      default=0.0)
        if best_bw > 0:
            lb += (point.dp - 1) / point.dp * stage_bytes / best_bw
    return lb


def materialize_plan(point: StrategyPoint, topo: ClusterTopology,
                     model: ModelDesc, *, global_batch: int, seq: int,
                     refine_layers: bool = True) -> ParallelPlan:
    """Turn a strategy point into a concrete plan: device grouping, layer
    B&B for heterogeneous stages, uneven batch shares for heterogeneous DP."""
    hetero = topo.is_heterogeneous()
    groups = split_devices(topo, point.dp, point.tp, point.pp,
                           sort_by_speed=hetero)
    if point.pp > 1 and refine_layers and hetero:
        sizes, _ = bnb_layer_split(model, topo, groups, point.tp,
                                   batch=global_batch // point.dp, seq=seq)
        stages = stages_from_sizes(sizes, groups)
    else:
        stages = uniform_stages(model.n_layers, point.pp, groups)
    if hetero and point.dp > 1:
        rank_devs = [[g[r * point.tp] for g in groups] for r in range(point.dp)]
        shares = hetero_batch_shares(topo, rank_devs)
    else:
        shares = tuple([1.0 / point.dp] * point.dp)
    return ParallelPlan(
        dp=point.dp, tp=point.tp, pp=point.pp, ep=point.ep,
        microbatches=point.microbatches, stages=stages, batch_shares=shares,
        grad_sync=point.grad_sync, zero1=(point.grad_sync == "rs_ag"),
        meta={"source": "auto-planner"})


# Default search-space knobs.  Test fixtures (tests/conftest.py) shrink these
# so the tier-1 suite stays within its CI budget; explicit arguments win.
DEFAULT_MAX_CANDIDATES = 512


def plan_hybrid(topo: ClusterTopology, model: ModelDesc, *,
                global_batch: int, seq: int, gpus_per_node: int = 8,
                with_baseline: bool = True,
                max_candidates: int | None = None,
                allow_subset: bool = True,
                cache=None,
                incumbent_bound: float | None = None,
                points: Sequence[StrategyPoint] | None = None,
                executor=None, top_k: int = 1,
                prune: bool = True,
                lp_prune: bool = True,
                max_sims: int | None = None,
                obs: Obs | None = None) -> PlanResult:
    """End-to-end planning: resolve the candidate set (cache / enumeration /
    Oobleck-style degrade), then hand it to the tiered search pipeline in
    :mod:`repro.core.search` — feasibility check, analytic bound, coarse
    estimate, full simulation — and return the argmin with per-tier search
    statistics.  This is a thin wrapper; the score loop lives in
    :func:`repro.core.search.score_candidates`.

    Args:
        topo: the cluster, current state (apply events / snapshot first).
        model: the workload description.
        global_batch: total samples per optimizer step.
        seq: sequence length.
        gpus_per_node: node size assumed by enumeration heuristics and the
            Megatron baselines (part of the cache-context identity).
        with_baseline: also score the Megatron default + tuned-uniform
            baselines (fills ``baseline*`` / ``tuned_baseline*``).
        max_candidates: cap on the enumerated candidate list (default
            :data:`DEFAULT_MAX_CANDIDATES`).
        allow_subset: when no feasible (dp, tp, pp) factorization exists
            for the exact alive-device count (e.g. 7 survivors after a
            failure), retire the slowest devices until one does — the
            Oobleck-style degrade path.
        cache: a :class:`repro.core.engine.StrategyCache` (duck-typed — any
            object with a ``context(topo, model, global_batch, seq)``
            method).  Enumeration output, materialized plans and simulator
            scores are then memoized per topology fingerprint, so
            re-planning after a dynamic event only pays for what changed.
        incumbent_bound: a known-achievable step time (the incumbent
            plan's score); candidates whose analytic lower bounds already
            meet it are cut before materialization/simulation.
        points: pre-seeded candidate list (the re-planning engine passes
            the incumbent's neighborhood); skips enumeration entirely.
        executor: a :class:`repro.core.search.SearchExecutor` — the final
            simulation tier then runs in worker processes (the serial and
            parallel paths pick byte-identical plans).
        top_k: how many distinct best plans to report in
            :attr:`PlanResult.top_plans`; the cascade keeps pruning sound
            for the full top-``k`` set, not just the argmin.
        prune: ``False`` disables every pre-simulation tier and
            exhaustively simulates every candidate (the soundness
            reference for tests/benchmarks).
        lp_prune: ``False`` disables only the tier-2.5 LP-relaxation bound
            (:mod:`repro.core.mip`).  The tier is admissible, so toggling
            it never changes the chosen plan — only how many candidates
            reach the simulator.
        max_sims: anytime budget on fully scored candidates (best-bound
            first; see ``score_candidates``).  NOT sound — the argmin
            identity is waived when it binds.  Used by the hierarchical
            island tier to bound fleet-scale sub-searches.
        obs: a :class:`repro.obs.Obs` telemetry bundle; the search records
            ``plan.hybrid``/``plan.enumerate``/``search.*`` spans and
            counters into it.  Defaults to the ``REPRO_TRACE``-driven
            process default (a shared no-op when the env var is unset).

    Returns:
        A :class:`PlanResult` holding the argmin plan, its simulated
        :class:`~repro.core.simulator.StepSim`, baselines and search stats.

    Raises:
        RuntimeError: no candidate survived scoring ("no feasible plan
            found") — undersized/partitioned cluster, or a batch that no
            factorization divides.
    """
    from . import search as search_mod  # deferred: search imports planner
    t0 = time.perf_counter()
    obs = resolve_obs(obs)
    plan_span = obs.span("plan.hybrid", devices=len(topo.alive_ids()),
                         global_batch=global_batch)
    plan_span.__enter__()
    if max_candidates is None:
        max_candidates = DEFAULT_MAX_CANDIDATES
    ctx = cache.context(topo, model, global_batch=global_batch, seq=seq,
                        gpus_per_node=gpus_per_node) \
        if cache is not None else None
    enum_stats = SearchStats()
    if points is None:
        with obs.span("plan.enumerate") as enum_span:
            cached_pts = ctx.get_points() if ctx is not None else None
            if cached_pts is not None:
                points = cached_pts
                enum_stats.explored = len(points)
            else:
                points, enum_stats = enumerate_strategies(
                    topo, model, global_batch=global_batch,
                    gpus_per_node=gpus_per_node)
                if not points and allow_subset:
                    ids = sorted(topo.alive_ids(),
                                 key=lambda i: -topo.device(i).spec.peak_flops
                                 * topo.device(i).perf_factor)
                    for n_use in range(len(ids) - 1, 0, -1):
                        sub = topo.snapshot(0.0)
                        for d in ids[n_use:]:
                            sub.devices[d].alive = False
                        points, enum_stats = enumerate_strategies(
                            sub, model, global_batch=global_batch,
                            gpus_per_node=gpus_per_node)
                        if points:
                            topo = sub
                            # degraded topology is a different fingerprint
                            ctx = cache.context(topo, model,
                                                global_batch=global_batch,
                                                seq=seq,
                                                gpus_per_node=gpus_per_node) \
                                if cache is not None else None
                            break
                if ctx is not None:
                    ctx.put_points(points)
            enum_span.set(explored=enum_stats.explored,
                          cached=cached_pts is not None)
    else:
        points = list(points)
        enum_stats.explored = len(points)
    points = list(points)[:max_candidates]

    stats = SearchStats(explored=enum_stats.explored,
                        pruned=enum_stats.pruned,
                        infeasible=enum_stats.infeasible)
    scored = search_mod.score_candidates(
        topo, model, global_batch=global_batch, seq=seq, points=points,
        ctx=ctx, incumbent_bound=incumbent_bound, keep_top_k=max(1, top_k),
        executor=executor, prune=prune, lp_prune=lp_prune, stats=stats,
        max_sims=max_sims, obs=obs)
    if not scored:
        plan_span.__exit__(None, None, None)
        raise RuntimeError("no feasible plan found")
    best = scored[0]
    top_plans: list[tuple[ParallelPlan, StepSim]] = []
    seen_keys: set = set()
    for out in scored:
        key = out.plan.structural_key()
        if key in seen_keys:
            continue
        seen_keys.add(key)
        top_plans.append((out.plan, out.sim))
        if len(top_plans) >= max(1, top_k):
            break

    baseline = baseline_sim = tuned = tuned_sim = None
    if with_baseline:
        with obs.span("plan.baselines"):
            baseline = megatron_default_plan(topo, model,
                                             gpus_per_node=gpus_per_node)
            baseline_sim = simulate_training_step(
                baseline, model, topo, global_batch=global_batch, seq=seq)
            tuned, tuned_sim = megatron_tuned_plan(
                topo, model, global_batch=global_batch, seq=seq)

    if ctx is not None:
        stats.cache_hits, stats.cache_misses = ctx.counters()
    stats.wall_time = time.perf_counter() - t0
    plan_span.set(simulated=stats.simulated, pruned=stats.pruned,
                  step_time=best.sim.step_time)
    plan_span.__exit__(None, None, None)
    return PlanResult(
        plan=best.plan, predicted=best.sim,
        candidates_evaluated=stats.simulated,
        candidates_pruned=stats.pruned + stats.infeasible,
        candidates_rejected=stats.rejected,
        wall_time=stats.wall_time,
        baseline=baseline, baseline_predicted=baseline_sim,
        tuned_baseline=tuned, tuned_baseline_predicted=tuned_sim,
        search_stats=stats, top_plans=tuple(top_plans))
