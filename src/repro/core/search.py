"""Tiered strategy-search pipeline (paper §3.4 pruning + §4 parallel sim).

The paper's search acceleration rests on two legs: *strategy pruning* that
discards infeasible/hopeless configurations before they reach the expensive
simulator, and *parallel execution within the simulator* for the candidates
that survive.  This module is both legs:

Tier 0  ``point_feasible``      structural/memory feasibility (Eq. 6,
                                divisibility, EP alignment) — pure arithmetic.
Tier 1  ``point_lower_bound``   analytic optimistic step time (compute over
                                aggregate throughput + dp-sync floor).
Tier 2  ``coarse_lower_bound``  tighter closed-form estimate adding the
                                pipeline-chain / bottleneck-stage and TP
                                collective floors — still admissible.
Tier 2.5 ``lp`` (repro.core.mip) class-capacity packing LP: fractional
                                layer->TP-group assignment with per-class
                                slot capacities, fabric-priced collective
                                floors and microbatch occupancy rows —
                                still admissible, much tighter on
                                heterogeneous fleets (memoized per tp and
                                skipped by a cost guard when the projected
                                solver wall exceeds projected sim savings).
Tier 3  materialize + simulate  the full pipeline (layer B&B, batch shares,
                                1F1B step simulation).

Tiers prune against a **monotonically tightening incumbent bound**: the
k-th best *simulated* step time seen so far (``keep_top_k``), plus any
externally supplied ``incumbent_bound``.  Every tier-1/2 bound undershoots
the simulator by construction, and dynamic pruning is strict (``>``), so the
cascade can never discard the true argmin (or any member of the true
top-k) — the hypothesis property test in ``tests/test_search.py`` checks
this against exhaustive scoring.

:class:`SearchExecutor` runs tier 3 in **worker processes** (spawn-safe,
chunked, picklable ``(point, refine)`` work items).  Workers share one
cross-process incumbent bound (a ``multiprocessing.Value``) so pruning keeps
tightening while chunks are in flight, amortize per-process topology/model
setup via a token-keyed context cache + :func:`repro.core.simulator
.simulate_many`, and return per-worker cache deltas that the parent merges
back into the session :class:`repro.core.engine.StrategyCache`.  Because
pruned candidates provably cannot beat (or tie) any simulated one, and final
selection is the deterministic ``(step_time, canonical index)`` argmin,
serial and process-parallel searches pick **byte-identical plans** no matter
the completion order.
"""

from __future__ import annotations

import hashlib
import math
import os
import pickle
import time
from concurrent.futures import ProcessPoolExecutor, as_completed
from dataclasses import dataclass
from multiprocessing import get_context
from typing import Sequence

from ..obs import Obs, resolve_obs
from .cluster import ClusterTopology
from .costmodel import collective_floor
from .fabric import default_fabric, set_default_fabric
from .opgraph import ModelDesc
from .planner import (SearchStats, StrategyPoint, materialize_plan,
                      point_lower_bound)
from .plans import ParallelPlan
from .simulator import StepSim, simulate_many

# Cascade-tier slugs: SearchStats field suffix == repro.obs counter suffix,
# so _note_pruned is the single tally point for both (ISSUE 7 satellite —
# the per-tier counters and the ``pruned`` total used to be bumped in five
# separate places and could silently drift from ``cascade_candidates``).
_TIERS = ("feasibility", "bound", "coarse", "lp")


def _note_pruned(stats: SearchStats, obs: Obs, tier: str, n: int) -> None:
    """Record ``n`` candidates cut by cascade tier ``tier`` — bumps the
    per-tier :class:`SearchStats` field, the shared ``pruned`` total, and
    the ``search.pruned.<tier>`` registry counter together."""
    if n <= 0:
        return
    setattr(stats, f"pruned_{tier}", getattr(stats, f"pruned_{tier}") + n)
    stats.pruned += n
    obs.inc(f"search.pruned.{tier}", n)

# ---------------------------------------------------------------------------
# Tier 0: structural / memory feasibility
# ---------------------------------------------------------------------------


def point_feasible(point: StrategyPoint, topo: ClusterTopology,
                   model: ModelDesc, *, global_batch: int) -> bool:
    """The same constraint system ``enumerate_strategies`` emits under
    (§3.4 / Eq. 6), re-checked cheaply so pre-seeded candidate lists (the
    re-planning engine's neighborhood) go through identical pruning.  Any
    point emitted by enumeration passes by construction."""
    n = len(topo.alive_ids())
    if point.dp * point.tp * point.pp != n:
        return False
    if point.tp < 1 or model.n_heads % point.tp:
        return False
    if point.pp > model.n_layers:
        return False
    if global_batch % point.dp:
        return False
    if (global_batch // point.dp) % point.microbatches:
        return False
    mem = min(d.spec.mem_bytes for d in topo.alive_devices)
    if model.total_params() * 12 / (point.tp * point.pp) > mem * 0.9:
        return False
    if model.n_experts and (model.n_experts % point.ep
                            or point.ep > point.tp):
        return False
    return True


# ---------------------------------------------------------------------------
# Tier 2: coarse admissible estimate
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class _BoundCtx:
    """Cluster/model aggregates shared by every candidate's tier-2 bound."""

    # distinct alive (DeviceSpec, perf_factor) classes — per-layer floors
    # take the min across classes, so a heterogeneous fleet collapses to a
    # handful of roofline evaluations instead of one per device
    classes: tuple[tuple, ...]
    # fastest bottleneck any ring over g devices can achieve, indexed by
    # g - 1; combines three sound caps (see _bound_context)
    ring_bw_by_size: tuple[float, ...]
    layer_flops1: tuple[float, ...]    # per-layer flops at batch=1
    layer_params: tuple[float, ...]    # per-layer parameter counts
    layer_is_attn: tuple[bool, ...]    # unfused-attention traffic applies
    act_per_sample: float              # seq * d_model * dtype bytes
    dtype_bytes: float
    n_heads: int
    seq: int


def _bound_context(topo: ClusterTopology, model: ModelDesc, *,
                   seq: int) -> _BoundCtx:
    from .opgraph import layer_flops
    classes = tuple({(d.spec, d.perf_factor) for d in topo.alive_devices})
    alive = set(d.device_id for d in topo.alive_devices)
    pair_best: dict[tuple[int, int], float] = {}
    incident: dict[int, float] = {d: 0.0 for d in alive}
    for (a, b), link in topo.links.items():
        if a in alive and b in alive and link.edges:
            bw = max(e.effective_bandwidth for e in link.edges)
            if bw <= 0:
                # a fully dead link routes like a missing one
                # (costmodel._has_live_edge) — keep the pair graph in sync
                # so `complete` below means "every pair priced direct"
                continue
            pair_best[(a, b)] = bw
            incident[a] = max(incident[a], bw)
            incident[b] = max(incident[b], bw)
    # Fastest bottleneck a ring over g devices can possibly achieve.  Three
    # independently sound caps, combined by min:
    #   (a) a ring over g >= 3 devices crosses g distinct pairs -> g-th
    #       largest pair bw (a 2-ring reuses its single pair both ways, so
    #       only the best pair caps it),
    #   (b) every member's two ring hops are capped by its best incident
    #       link -> g-th best-connected device,
    #   (c) a ring with bottleneck B connects its g members through edges
    #       of bw >= B -> highest B whose >=B-subgraph has a connected
    #       component of >= g devices (union-find over descending bw).
    # (c) is what catches multi-node rings: a tp=32 group over 4 NVLink
    # islands must cross the inter-node fabric no matter how it is laid
    # out.
    #
    # On a sparse link graph (TPU torus) a ring pair without a direct link
    # is priced by the fabric's ring_capacity (repro.core.fabric): the
    # pair streams cut-through chunks at its route's bottleneck rate,
    # divided by how many ring pairs share each directed physical link.
    # That price never exceeds ANY hop's own bandwidth (load >= 1), which
    # is the invariant both surviving caps rest on: (b) stays sound (a
    # routed pair's first hop is incident to the member, so its price <=
    # the member's best incident link) and (c) stays sound (a pair priced
    # >= B has every hop's bandwidth >= B, so its whole route lies in the
    # >=B subgraph and the g members share a component there).  The old
    # store-and-forward resistance-sum argument (price <= 1/sum(1/bw))
    # was *stronger* than needed and no longer holds under pipelining;
    # only the per-hop form above is load-bearing.  Cap (a) does NOT
    # survive routing — g routed pairs may share one fast physical edge
    # (e.g. a line graph's wrap-around pair reuses every link) — so it
    # applies on complete graphs only.  The caps are then scaled by the
    # fabric's linearized rate (FabricModel.linear_bw): the simulator
    # prices every hop at beta * bw, so a calibrated beta < 1 tightens the
    # ring caps by the same factor, while linear_bw's clamp at 1 keeps a
    # non-physical beta > 1 (which would price sims *below* the raw caps)
    # from breaking admissibility — tools/calibrate_fabric.py clamps
    # beta <= 1 anyway, and the never-over-prune property test guards the
    # rest.
    pair_bws = sorted(pair_best.values(), reverse=True)
    dev_bws = sorted(incident.values(), reverse=True)
    n = len(alive)
    complete = len(pair_best) == n * (n - 1) // 2
    comp_bw = [0.0] * (n + 1)
    parent = {d: d for d in alive}
    size = {d: 1 for d in alive}

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    reached = 1
    for (a, b), bw in sorted(pair_best.items(), key=lambda kv: -kv[1]):
        ra, rb = find(a), find(b)
        if ra != rb:
            if size[ra] < size[rb]:
                ra, rb = rb, ra
            parent[rb] = ra
            size[ra] += size[rb]
            while reached < size[ra]:
                reached += 1
                comp_bw[reached] = bw
    ring_by_size = []
    for g in range(1, n + 1):
        if not pair_bws:
            ring_by_size.append(0.0)
        elif g == 1:
            ring_by_size.append(pair_bws[0])
        else:
            # comp_bw[g] == 0 means no component holds g devices: every
            # g-ring crosses a partition and simulates to inf, so any cap
            # is sound — 0.0 simply disables the term (still admissible)
            caps = [dev_bws[min(g, len(dev_bws)) - 1], comp_bw[g]]
            if complete:
                pairs_crossed = g if g >= 3 else 1
                caps.append(pair_bws[min(pairs_crossed, len(pair_bws)) - 1])
            ring_by_size.append(min(caps))
    fab = default_fabric()
    ring_by_size = [fab.linear_bw(bw) for bw in ring_by_size]
    L = model.n_layers
    return _BoundCtx(
        classes=classes,
        ring_bw_by_size=tuple(ring_by_size),
        layer_flops1=tuple(layer_flops(model, l, 1, seq) for l in range(L)),
        layer_params=tuple(float(model.layer_params(l)) for l in range(L)),
        layer_is_attn=tuple(model.layer_kind(l) == "attn" for l in range(L)),
        act_per_sample=seq * model.d_model * model.dtype_bytes,
        dtype_bytes=model.dtype_bytes, n_heads=model.n_heads, seq=seq)


def _ring_bw(bctx: _BoundCtx, group_size: int) -> float:
    """Fastest bottleneck any ring over ``group_size`` devices can achieve
    (precomputed in :func:`_bound_context`); 0.0 disables the term."""
    if not bctx.ring_bw_by_size:
        return 0.0
    return bctx.ring_bw_by_size[
        min(group_size, len(bctx.ring_bw_by_size)) - 1]


def _sync_floor(point: StrategyPoint, bctx: _BoundCtx) -> float:
    """Gradient-sync ring floor shared by the coarse and LP tiers: the
    point's sync collective (decomposed rs+ag, or the naive root-funnel
    reduce+broadcast pair) on the *mean* per-stage parameter shard at the
    fastest dp-ring bandwidth.  The simulator adds dp_sync — the max over
    stages, for both sync modes >= the decomposed ring time — after the
    pipeline flush, so this undershoots it for every materialization."""
    dp = point.dp
    if dp <= 1:
        return 0.0
    bw = _ring_bw(bctx, dp)
    if bw <= 0:
        return 0.0
    shard = sum(bctx.layer_params) * bctx.dtype_bytes / (point.pp * point.tp)
    kind = "rs_ag" if point.grad_sync == "rs_ag" else "reduce_broadcast"
    return collective_floor(kind, shard, dp, bw)


def _coarse_bound(point: StrategyPoint, bctx: _BoundCtx, *,
                  global_batch: int) -> float:
    """Admissible pipeline floor for one strategy point, tighter than the
    aggregate-throughput bound on comm-heavy / memory-bound / deep-pipeline
    candidates.

    Derivation (each step undershoots the 1F1B simulator):

      * some DP rank carries a batch share >= 1/dp, so its per-microbatch
        batch is >= global_batch / (dp * M);
      * that rank's microbatch-0 chain crosses every stage forward then
        backward: sum_s (fwd_s + bwd_s) = 3x per-layer compute + 2x the TP
        collective total (2 all-reduces per layer in fwd, 2 in bwd);
      * per-layer compute is floored by the *minimum over device classes*
        of that class's own roofline (fusion-aware traffic included) — the
        actual stage device is one of the classes, so its time can only be
        higher;
      * collectives are floored by the ring bandwidth term at the g-th
        largest pair bandwidth (:func:`_ring_bw`) — any real tp/dp ring's
        bottleneck edge is at most that;
      * the bottleneck stage serializes all M microbatches and by
        pigeonhole holds >= 1/pp of the total work, so the chain also
        scales by max(1, M / pp);
      * the 1F1B drain lemma adds a fill/drain floor the busy-time factor
        misses on deep pipelines (M <= pp): for ANY stage s, microbatch 0
        must cross every earlier stage before s's first forward, round-trip
        the later stages before s's first backward, s then serializes its M
        backwards, and the last microbatch's backward still drains through
        the earlier stages — so makespan >= chain + (M-1) * bwd_s.  With
        bwd_s >= (fwd_s + bwd_s) / 2 for every stage the simulator prices
        (bwd = 2x fwd compute + the same collectives; remat only raises
        it), the bottleneck stage (>= chain / pp by pigeonhole) gives
        makespan >= chain * (1 + (M-1) / (2 pp)) — the pipeline factor is
        the max of both legs;
      * the gradient-sync floor (2x ring factor on the mean per-stage
        parameter shard) adds on top — the simulator adds dp_sync (the max
        over stages, for both sync modes >= the decomposed ring time) after
        the pipeline flush.

    Ignored-but-positive simulator terms (p2p transfers, remat recompute,
    MoE all-to-all, collective latency) keep it admissible.  Do not tighten
    any term toward the simulator without re-running the never-over-prune
    property test in ``tests/test_search.py``.
    """
    dp, tp, pp, M = point.dp, point.tp, point.pp, point.microbatches
    mb = global_batch / (dp * M)
    act = mb * bctx.act_per_sample
    chain = 0.0
    for l, fl1 in enumerate(bctx.layer_flops1):
        fl = fl1 * mb / tp
        base_traffic = (4.0 * act + bctx.layer_params[l] * bctx.dtype_bytes) \
            / tp
        attn_traffic = (4.0 * mb * bctx.n_heads * bctx.seq * bctx.seq
                        * bctx.dtype_bytes / tp) if bctx.layer_is_attn[l] \
            else 0.0
        t = math.inf
        for spec, perf in bctx.classes:
            traffic = base_traffic
            if attn_traffic and not spec.supports_fusion:
                traffic += attn_traffic
            t = min(t, spec.roofline_time(fl, traffic, perf_factor=perf))
        chain += 3.0 * t
    if tp > 1:
        bw = _ring_bw(bctx, tp)
        if bw > 0:
            chain += 4.0 * len(bctx.layer_flops1) \
                * collective_floor("all_reduce", act, tp, bw)
    pipe = chain * max(1.0, M / pp, 1.0 + (M - 1.0) / (2.0 * pp))
    return pipe + _sync_floor(point, bctx)


def coarse_lower_bound(point: StrategyPoint, topo: ClusterTopology,
                       model: ModelDesc, *, global_batch: int,
                       seq: int) -> float:
    """Tier-2 estimate: max of the tier-1 analytic bound and the pipeline
    floor — by construction >= :func:`point_lower_bound` and still <= the
    simulated step time of every materialization of ``point``."""
    lb1 = point_lower_bound(point, topo, model, global_batch=global_batch,
                            seq=seq)
    bctx = _bound_context(topo, model, seq=seq)
    return max(lb1, _coarse_bound(point, bctx, global_batch=global_batch))


# ---------------------------------------------------------------------------
# Tier 3: materialization + simulation (shared by parent and workers)
# ---------------------------------------------------------------------------


def materialize_variant(point: StrategyPoint, refine: bool,
                        topo: ClusterTopology, model: ModelDesc, *,
                        global_batch: int, seq: int) -> ParallelPlan:
    """One concrete plan per (point, refine) work item.  ``refine=True`` is
    the heterogeneity-refined materialization (layer B&B + uneven shares);
    ``refine=False`` forces the plain uniform layout — on near-identical
    devices the forced uneven split can lose to uniform, so the search
    space includes both (operator splitting is a *choice*, §2.3)."""
    plan = materialize_plan(point, topo, model, global_batch=global_batch,
                            seq=seq, refine_layers=refine)
    if not refine:
        plan = ParallelPlan(
            dp=plan.dp, tp=plan.tp, pp=plan.pp, ep=plan.ep,
            microbatches=plan.microbatches, stages=plan.stages,
            batch_shares=tuple([1.0 / plan.dp] * plan.dp),
            grad_sync=plan.grad_sync, zero1=plan.zero1, meta=plan.meta)
    return plan


@dataclass(frozen=True)
class CandidateOutcome:
    """One fully simulated (point, refine) candidate."""

    index: int               # canonical position in the expanded candidate
    #                          list — the deterministic tie-break
    point: StrategyPoint
    refine: bool
    plan: ParallelPlan
    sim: StepSim


def _score_variant(point: StrategyPoint, refine: bool,
                   topo: ClusterTopology, model: ModelDesc, *,
                   global_batch: int, seq: int, ctx=None,
                   memo: dict | None = None, obs=None,
                   plans: dict | None = None
                   ) -> tuple[ParallelPlan, StepSim] | None:
    """Cache-aware materialize + simulate; None on rejection (the candidate
    raised ValueError/ZeroDivisionError somewhere in the pipeline).  ``obs``
    reaches :func:`repro.core.simulator.simulate_many` so traced serial
    searches record per-candidate ``sim.batch`` spans (worker chunks leave
    it unset — shared-bound timing makes their sim counts nondeterministic,
    and the chunk span already covers the time).  ``plans`` is a read-only
    materialization snapshot (worker processes receive the parent
    :class:`repro.core.engine.StrategyCache`'s already-built plans in the
    context blob) consulted after ``ctx`` — a snapshot hit skips the
    materialization pipeline but never the simulation."""
    plan = ctx.get_plan(point, refine) if ctx is not None else None
    if plan is None and plans is not None:
        plan = plans.get((point, refine))
    if plan is None:
        try:
            plan = materialize_variant(point, refine, topo, model,
                                       global_batch=global_batch, seq=seq)
        except (ValueError, ZeroDivisionError):
            return None
        if ctx is not None:
            ctx.put_plan(point, refine, plan)
    sim = ctx.get_score(plan) if ctx is not None else None
    if sim is None:
        key = plan.structural_key()
        sim = memo.get(key) if memo is not None else None
        if sim is None:
            sim = simulate_many([plan], model, topo,
                                global_batch=global_batch, seq=seq,
                                obs=obs)[0]
            if sim is None:
                return None
            if memo is not None:
                memo[key] = sim
        if ctx is not None:
            ctx.put_score(plan, sim)
    return plan, sim


# ---------------------------------------------------------------------------
# Worker side (module-level for spawn picklability)
# ---------------------------------------------------------------------------

_SHARED_BOUND = None       # multiprocessing.Value('d') injected at pool init
_CTX_TOKEN: str | None = None
_CTX_STATE: tuple | None = None
_CTX_MEMO: dict = {}
_CTX_SNAPSHOT: dict = {}   # read-only (point, refine) -> ParallelPlan


def _pool_init(shared_bound) -> None:
    global _SHARED_BOUND
    _SHARED_BOUND = shared_bound
    # Workers must not inherit the parent's REPRO_TRACE default: each would
    # atexit-dump its own (uncollected) trace over the parent's file.
    # Worker telemetry is shipped explicitly (_score_chunk traced=True).
    os.environ.pop("REPRO_TRACE", None)


def _pool_warm(_: int) -> int:
    """No-op used to absorb worker start-up (interpreter + repro import)."""
    return 0


def _load_search_ctx(token: str, blob: bytes) -> tuple:
    """(topo, model, global_batch, seq), unpickled once per worker per
    search — chunks of the same search reuse it (amortized setup).  The
    parent's default :class:`repro.core.fabric.FabricModel` rides along and
    is installed as this worker's default, so serial and process-parallel
    searches price identically even under a non-default calibration; so
    does a read-only :class:`repro.core.engine.StrategyCache`
    materialization snapshot, sparing workers plan rebuilds the parent
    already paid for.  The token hashes the whole blob — fabric AND
    snapshot version included — so a stale context (recalibrated fabric,
    cache grown since the last search) forces a reload instead of serving
    old state."""
    global _CTX_TOKEN, _CTX_STATE, _CTX_MEMO, _CTX_SNAPSHOT
    if token != _CTX_TOKEN:
        *state, fabric, snapshot = pickle.loads(blob)
        set_default_fabric(fabric)
        _CTX_STATE = tuple(state)
        _CTX_SNAPSHOT = snapshot
        _CTX_TOKEN = token
        _CTX_MEMO = {}
    return _CTX_STATE  # type: ignore[return-value]


def _sim_chunk(token: str, blob: bytes,
               items: "list[tuple[int, ParallelPlan]]"
               ) -> "list[tuple[int, StepSim | None]]":
    """Score one chunk of explicit (index, plan) items via the batched
    :func:`repro.core.simulator.simulate_many` (one topology snapshot per
    chunk).  Serves :meth:`SearchExecutor.simulate_plans` — the warm
    bandwidth-rescore path's top-K portfolio re-simulation."""
    topo, model, global_batch, seq = _load_search_ctx(token, blob)
    sims = simulate_many([p for _, p in items], model, topo,
                         global_batch=global_batch, seq=seq)
    return [(i, sim) for (i, _), sim in zip(items, sims)]


def _score_chunk(token: str, blob: bytes,
                 tasks: list[tuple[float, int, StrategyPoint, bool]],
                 threshold: float, tighten: bool, chunk_index: int = 0,
                 traced: bool = False
                 ) -> tuple[list[tuple[int, StrategyPoint, bool,
                                       ParallelPlan, StepSim]], int, int,
                            "tuple[list[dict], dict] | None"]:
    """Score one chunk of (bound, index, point, refine) work items.

    Returns (outcomes, n_rejected, n_pruned, obs_delta).  The pruning
    threshold is the static ``threshold`` tightened by the cross-process
    shared bound (only read when ``tighten`` — i.e. ``keep_top_k == 1``,
    where a single shared scalar is the correct k-th best).

    With ``traced`` the chunk records into a worker-local
    :class:`repro.obs.Obs` and ships the delta (span dicts + metrics
    snapshot) back for the parent to re-parent under its tier-3 span —
    tracing never touches scoring, so serial == parallel plan identity is
    unaffected."""
    topo, model, global_batch, seq = _load_search_ctx(token, blob)
    wobs = Obs(enabled=True) if traced else None
    handle = wobs.span("search.worker.chunk", chunk=chunk_index,
                       n_tasks=len(tasks)) if wobs is not None else None
    out: list[tuple[int, StrategyPoint, bool, ParallelPlan, StepSim]] = []
    rejected = pruned = 0
    for bound, index, point, refine in tasks:
        thr = threshold
        if tighten and _SHARED_BOUND is not None:
            thr = min(thr, _SHARED_BOUND.value)
        if bound > thr:
            pruned += 1
            continue
        res = _score_variant(point, refine, topo, model,
                             global_batch=global_batch, seq=seq,
                             memo=_CTX_MEMO, plans=_CTX_SNAPSHOT)
        if res is None:
            rejected += 1
            continue
        plan, sim = res
        out.append((index, point, refine, plan, sim))
        if tighten and _SHARED_BOUND is not None \
                and sim.step_time < _SHARED_BOUND.value:
            with _SHARED_BOUND.get_lock():
                if sim.step_time < _SHARED_BOUND.value:
                    _SHARED_BOUND.value = sim.step_time
    delta = None
    if wobs is not None:
        handle.set(simulated=len(out), rejected=rejected, pruned=pruned)
        handle.__exit__(None, None, None)
        wobs.inc("search.worker.chunks")
        delta = wobs.export_delta()
    return out, rejected, pruned, delta


# ---------------------------------------------------------------------------
# SearchExecutor: long-lived spawn pool for the final tier
# ---------------------------------------------------------------------------


class SearchExecutor:
    """Process pool that scores the cascade's final tier.

    Spawn-safe (workers import only dependency-free ``repro.core``), reusable
    across many searches (the scenario harness keeps one executor alive for
    a whole trace replay instead of re-spawning per interval), and
    deterministic: whatever the completion order, the parent's
    ``(step_time, index)`` argmin matches the serial cascade's.

    ``n_procs`` defaults to the machine's core count; ``chunk_size`` to an
    even split into ~4 chunks per worker (small enough that the tightening
    bound keeps helping, large enough to amortize dispatch).
    """

    def __init__(self, n_procs: int | None = None,
                 chunk_size: int | None = None):
        self.n_procs = max(1, n_procs if n_procs is not None
                           else (os.cpu_count() or 1))
        self.chunk_size = chunk_size
        self._mp = get_context("spawn")
        self._pool: ProcessPoolExecutor | None = None
        self._bound = None

    # -- lifecycle -------------------------------------------------------------

    def _ensure(self) -> ProcessPoolExecutor:
        if self._pool is None:
            self._bound = self._mp.Value("d", math.inf)
            self._pool = ProcessPoolExecutor(
                max_workers=self.n_procs, mp_context=self._mp,
                initializer=_pool_init, initargs=(self._bound,))
        return self._pool

    def warm(self) -> None:
        """Start the workers now so pool spin-up does not pollute timed
        regions (benchmarks call this before measuring)."""
        pool = self._ensure()
        list(pool.map(_pool_warm, range(self.n_procs)))

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None
            self._bound = None

    def __enter__(self) -> "SearchExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- scoring ---------------------------------------------------------------

    def run(self, topo: ClusterTopology, model: ModelDesc, *,
            global_batch: int, seq: int,
            tasks: Sequence[tuple[float, int, StrategyPoint, bool]],
            threshold: float, tighten: bool, obs: Obs | None = None,
            snapshot: "dict[tuple[StrategyPoint, bool], ParallelPlan] "
                      "| None" = None
            ) -> tuple[list[tuple[int, StrategyPoint, bool,
                                  ParallelPlan, StepSim]], int, int]:
        """Score ``tasks`` across the pool; returns (outcomes, rejected,
        pruned) merged over all chunks.  With an enabled ``obs``, worker
        chunk spans are shipped back and re-parented under the caller's
        current span (one Perfetto lane per worker process).  ``snapshot``
        ships the parent session cache's already-materialized plans to the
        workers read-only (it is part of the hashed context blob, so a
        grown cache invalidates stale worker contexts)."""
        obs = resolve_obs(obs)
        pool = self._ensure()
        blob = pickle.dumps((topo, model, global_batch, seq,
                             default_fabric(), snapshot or {}),
                            protocol=pickle.HIGHEST_PROTOCOL)
        token = hashlib.sha1(blob).hexdigest()
        assert self._bound is not None
        with self._bound.get_lock():
            self._bound.value = threshold
        n_chunks = max(1, min(
            len(tasks),
            self.n_procs * 4 if self.chunk_size is None
            else -(-len(tasks) // self.chunk_size)))
        # stride assignment: tasks arrive bound-sorted, so striding spreads
        # the most promising candidates across workers — every worker lands
        # a good incumbent early and the shared bound tightens fast
        chunks = [list(tasks[i::n_chunks]) for i in range(n_chunks)]
        parent_id = obs.current_span_id()
        futures = [pool.submit(_score_chunk, token, blob, chunk,
                               threshold, tighten, ci, obs.enabled)
                   for ci, chunk in enumerate(chunks) if chunk]
        outcomes: list = []
        rejected = pruned = 0
        for fut in as_completed(futures):
            out, rej, pr, delta = fut.result()
            outcomes.extend(out)
            rejected += rej
            pruned += pr
            if delta is not None:
                obs.adopt(delta[0], parent_id, delta[1])
        return outcomes, rejected, pruned

    def simulate_plans(self, topo: ClusterTopology, model: ModelDesc,
                       plans: Sequence[ParallelPlan], *,
                       global_batch: int, seq: int
                       ) -> list[StepSim | None]:
        """Score explicit plans across the pool (input order preserved).

        Each worker chunk goes through :func:`repro.core.simulator
        .simulate_many`, so the topology snapshot is materialized once per
        chunk and infeasible / unroutable plans come back as ``None`` —
        identical semantics to scoring each plan alone in the parent.  The
        re-planning engine's warm bandwidth-rescore ships its top-K
        portfolio through this instead of simulating serially."""
        if not plans:
            return []
        pool = self._ensure()
        blob = pickle.dumps((topo, model, global_batch, seq,
                             default_fabric(), {}),
                            protocol=pickle.HIGHEST_PROTOCOL)
        token = hashlib.sha1(blob).hexdigest()
        n_chunks = max(1, min(len(plans), self.n_procs))
        chunks = [[(i, plans[i]) for i in range(c, len(plans), n_chunks)]
                  for c in range(n_chunks)]
        futures = [pool.submit(_sim_chunk, token, blob, chunk)
                   for chunk in chunks if chunk]
        out: list[StepSim | None] = [None] * len(plans)
        for fut in as_completed(futures):
            for i, sim in fut.result():
                out[i] = sim
        return out


# ---------------------------------------------------------------------------
# The cascade
# ---------------------------------------------------------------------------

# LP-tier cost-guard constants.  A candidate's simulation walks every DP
# rank over its stages' layers plus the M x pp 1F1B grid, so its wall is
# estimated at _LP_SIM_SECONDS_PER_UNIT * (dp*L + dp*pp*M) — order of
# magnitude is all the guard needs.  The guard blocks a fresh LP solve only
# when the projected solver wall (distinct unsolved tp values x measured
# solve EMA) exceeds _LP_GUARD_SAVINGS_FRACTION of the projected remaining
# sim wall; per-tp memoization keeps real searches at a handful of solves,
# so the guard binds only on degenerate tiny candidate sets where even a
# 100% prune rate could not repay the solver.
_LP_SIM_SECONDS_PER_UNIT = 1e-4
_LP_GUARD_SAVINGS_FRACTION = 0.1


def _lp_est_sim_seconds(point: StrategyPoint, n_layers: int) -> float:
    return _LP_SIM_SECONDS_PER_UNIT * (
        point.dp * n_layers
        + point.dp * point.pp * point.microbatches)


def _lp_guard_blocks(lp_ctx,
                     remaining: "Sequence[tuple[float, int, StrategyPoint, "
                                "bool]]") -> bool:
    """True when the LP tier's projected cost exceeds its projected
    savings for the rest of this cascade (see constants above)."""
    n_layers = lp_ctx.model.n_layers
    unsolved = {p.tp for _, _, p, _ in remaining if lp_ctx.would_solve(p.tp)}
    projected_lp = len(unsolved) * lp_ctx.solve_wall_estimate()
    projected_sim = sum(_lp_est_sim_seconds(p, n_layers)
                       for _, _, p, _ in remaining)
    return projected_lp > _LP_GUARD_SAVINGS_FRACTION * projected_sim


def score_candidates(topo: ClusterTopology, model: ModelDesc, *,
                     global_batch: int, seq: int,
                     points: Sequence[StrategyPoint], ctx=None,
                     incumbent_bound: float | None = None,
                     keep_top_k: int = 1,
                     executor: SearchExecutor | None = None,
                     prune: bool = True,
                     lp_prune: bool = True,
                     stats: SearchStats | None = None,
                     max_sims: int | None = None,
                     obs: Obs | None = None
                     ) -> list[CandidateOutcome]:
    """Run the staged pruning cascade over ``points`` and return every fully
    simulated candidate, sorted by ``(step_time, canonical index)`` — the
    head is the argmin, the first ``keep_top_k`` distinct plans are the
    sound top-k.  ``stats`` (mutated in place) accumulates the per-tier
    pruned counts.

    ``max_sims`` is an *anytime* budget: at most that many candidates are
    fully scored (best-bound-first — the most promising candidates by the
    tier-2 estimate go first), and the unscored tail is counted in
    ``stats.budget_skipped``.  Unlike the pruning tiers the budget is NOT
    sound: a skipped candidate might have been the argmin, so the
    serial == parallel and cascade == exhaustive identities are waived when
    it binds.  The hierarchical island tier (:mod:`repro.core.islands`)
    uses it to keep fleet-scale sub-searches bounded.

    ``lp_prune`` toggles the tier-2.5 LP-relaxation bound
    (:mod:`repro.core.mip`): admissible like tiers 1-2, so the argmin /
    top-k portfolio is byte-identical with it on or off — only how many
    candidates reach the simulator changes.  Set
    ``REPRO_SEARCH_DEBUG=1`` to assert the tier monotonicity
    ``point <= coarse <= lp <= simulated`` on every simulated candidate."""
    if stats is None:
        stats = SearchStats()
    obs = resolve_obs(obs)
    # drift invariant (ISSUE 7 satellite): everything this call adds to
    # ``stats.pruned`` must land in exactly one per-tier counter — checked
    # on exit against the deltas, so a new tally site that bypasses
    # ``_note_pruned`` fails loudly instead of skewing cascade_candidates
    pruned_at_entry = stats.pruned
    tiers_at_entry = (stats.pruned_feasibility + stats.pruned_bound
                      + stats.pruned_coarse + stats.pruned_lp)
    debug = os.environ.get("REPRO_SEARCH_DEBUG", "") not in ("", "0")
    variants = (True, False) if topo.is_heterogeneous() else (False,)
    nv = len(variants)
    cascade = obs.span("search.cascade", n_points=len(points),
                       n_devices=len(topo.alive_ids()), prune=prune)
    cascade.__enter__()

    # canonical expansion: indices cover the FULL candidate list (pruned
    # included) so tie-breaking matches exhaustive scoring exactly
    bctx = _bound_context(topo, model, seq=seq) if prune else None
    point_bounds: dict[StrategyPoint, tuple[float, float]] = {}
    tasks: list[tuple[float, int, StrategyPoint, bool]] = []
    with obs.span("search.tiers012"):
        for pi, point in enumerate(points):
            base = pi * nv
            if prune:
                if not point_feasible(point, topo, model,
                                      global_batch=global_batch):
                    _note_pruned(stats, obs, "feasibility", nv)
                    continue
                lb1 = point_lower_bound(point, topo, model,
                                        global_batch=global_batch, seq=seq)
                if incumbent_bound is not None and lb1 >= incumbent_bound:
                    _note_pruned(stats, obs, "bound", nv)
                    continue
                lb2 = max(lb1,
                          _coarse_bound(point, bctx,  # type: ignore[arg-type]
                                        global_batch=global_batch))
                if incumbent_bound is not None and lb2 >= incumbent_bound:
                    _note_pruned(stats, obs, "coarse", nv)
                    continue
            else:
                lb1 = lb2 = 0.0
            point_bounds[point] = (lb1, lb2)
            for vi, refine in enumerate(variants):
                tasks.append((lb2, base + vi, point, refine))

    # Tier 2.5: LP-relaxation bound (repro.core.mip).  Same admissibility
    # contract as tiers 1-2 so it prunes against the same bounds — the
    # packing LP is memoized per tp (a handful of solves per search), and
    # the cost guard skips fresh solves outright when the projected solver
    # wall for the remaining unsolved tp values exceeds a conservative
    # fraction of the projected simulation wall still on the table (the
    # tier can then not pay for itself — degenerate tiny searches).
    lb3_by_variant: dict[tuple[StrategyPoint, bool], float] = {}
    if prune and lp_prune and tasks:
        from .mip import lp_bound_context
        t_lp = time.perf_counter()
        with obs.span("search.tier_lp", n_tasks=len(tasks)) as lp_span:
            lp_ctx = lp_bound_context(topo, model, global_batch=global_batch,
                                      seq=seq, bctx=bctx)
            kept: list[tuple[float, int, StrategyPoint, bool]] = []
            guard_skipped = 0
            for ti, (lb2, index, point, refine) in enumerate(tasks):
                lb3 = lb3_by_variant.get((point, refine))
                if lb3 is None:
                    if lp_ctx.would_solve(point.tp) \
                            and _lp_guard_blocks(lp_ctx, tasks[ti:]):
                        lb3 = lb2           # fall back to the coarse bound
                        guard_skipped += 1
                    else:
                        lb3 = lp_ctx.variant_bound(point, refine, lb2)
                    lb3_by_variant[(point, refine)] = lb3
                if incumbent_bound is not None and lb3 >= incumbent_bound:
                    _note_pruned(stats, obs, "lp", 1)
                    continue
                kept.append((lb3, index, point, refine))
            tasks = kept
            lp_span.set(solves=lp_ctx.lp_solves,
                        guard_skipped=guard_skipped)
        stats.lp_wall_time += time.perf_counter() - t_lp
    # best-first simulation order tightens the incumbent fastest; the index
    # tie-break keeps equal-bound ordering canonical
    tasks.sort(key=lambda t: (t[0], t[1]))

    outcomes: list[CandidateOutcome] = []
    sim_times: list[float] = []

    def threshold() -> float:
        if not prune or len(sim_times) < keep_top_k:
            return math.inf
        return sorted(sim_times)[keep_top_k - 1]

    def note(index: int, point: StrategyPoint, refine: bool,
             plan: ParallelPlan, sim: StepSim) -> None:
        if debug and prune:
            # tier monotonicity: point <= coarse holds by the max() in the
            # tier loop and coarse <= lp by the max() in point_bound — the
            # load-bearing leg is lp <= simulated (admissibility)
            lb1d, lb2d = point_bounds.get(point, (0.0, 0.0))
            lb3d = lb3_by_variant.get((point, refine), lb2d)
            ok = (lb1d <= lb2d * (1 + 1e-9) + 1e-12
                  and lb2d <= lb3d * (1 + 1e-9) + 1e-12
                  and lb3d <= sim.step_time * (1 + 1e-9) + 1e-12)
            if not ok:
                raise AssertionError(
                    f"cascade tier monotonicity violated for {point} "
                    f"refine={refine}: point={lb1d} coarse={lb2d} "
                    f"lp={lb3d} simulated={sim.step_time}")
        outcomes.append(CandidateOutcome(index=index, point=point,
                                         refine=refine, plan=plan, sim=sim))
        sim_times.append(sim.step_time)
        stats.simulated += 1

    tier3 = obs.span("search.tier3", n_tasks=len(tasks),
                     parallel=executor is not None and len(tasks) > 1)
    tier3.__enter__()
    # Worker pre-pass (performance only): ship the likely-live work to the
    # pool so the sims are hot when the canonical walk below needs them.
    # The walk is the sole authority on outcomes, session-cache content,
    # and stats — worker results are consumed as a sim cache, gaps (tasks a
    # racing shared bound pruned that the walk's threshold admits) are
    # scored in the parent, and worker sims the walk prunes are discarded.
    # That keeps serial and process-parallel searches plan-for-plan AND
    # portfolio-for-portfolio identical whatever the chunk completion
    # order; the shared bound only decides how much worker time is spent.
    available: dict[int, tuple[ParallelPlan, StepSim]] = {}
    if executor is not None and len(tasks) > 1:
        # resolve session-cache hits in the parent first: they are free and
        # pre-tighten the static bound the workers start from.  Plans the
        # cache materialized but never scored ride to the workers as a
        # read-only snapshot so they skip the rebuild.
        hit_times: list[float] = []
        pending: list[tuple[float, int, StrategyPoint, bool]] = []
        snapshot: dict[tuple[StrategyPoint, bool], ParallelPlan] = {}
        for bound, index, point, refine in tasks:
            plan = ctx.get_plan(point, refine) if ctx is not None else None
            sim = ctx.get_score(plan) \
                if (plan is not None and ctx is not None) else None
            if plan is not None and sim is not None:
                hit_times.append(sim.step_time)
            else:
                if plan is not None:
                    snapshot[(point, refine)] = plan
                pending.append((bound, index, point, refine))
        thr0 = math.inf
        if prune and len(hit_times) >= keep_top_k:
            thr0 = sorted(hit_times)[keep_top_k - 1]
        live = [t for t in pending if not (prune and t[0] > thr0)]
        if max_sims is not None:
            # dispatch cap only — the walk does the budget accounting
            live = live[:max(0, max_sims - len(hit_times))]
        if live:
            out, _rejected, _pruned = executor.run(
                topo, model, global_batch=global_batch, seq=seq,
                tasks=live, threshold=thr0, tighten=(keep_top_k == 1),
                obs=obs, snapshot=snapshot)
            for index, point, refine, plan, sim in out:
                available[index] = (plan, sim)
    memo: dict = {}
    for bound, index, point, refine in tasks:
        if max_sims is not None and len(sim_times) >= max_sims:
            stats.budget_skipped += 1
            obs.inc("search.budget_skipped")
            continue
        thr = threshold()
        if prune and bound > thr:
            # attribute the cut to the cheapest tier whose bound did it —
            # with the LP tier off, bound == the coarse bound and the lp
            # branch is unreachable, so pruned_bound / pruned_coarse tally
            # exactly as they did before the tier existed
            lb1, lb2 = point_bounds[point]
            if lb1 > thr:
                _note_pruned(stats, obs, "bound", 1)
            elif lb2 > thr:
                _note_pruned(stats, obs, "coarse", 1)
            else:
                _note_pruned(stats, obs, "lp", 1)
            continue
        got = available.get(index)
        if got is not None:
            plan, sim = got
            # merge the worker's result into the session cache
            if ctx is not None:
                ctx.put_plan(point, refine, plan)
                ctx.put_score(plan, sim)
            note(index, point, refine, plan, sim)
            continue
        res = _score_variant(point, refine, topo, model,
                             global_batch=global_batch, seq=seq,
                             ctx=ctx, memo=memo if ctx is None else None,
                             obs=obs)
        if res is None:
            stats.rejected += 1
            continue
        note(index, point, refine, res[0], res[1])
    tier3.set(simulated=stats.simulated)
    tier3.__exit__(None, None, None)

    obs.inc("search.simulated", stats.simulated)
    obs.inc("search.rejected", stats.rejected)
    tier_delta = (stats.pruned_feasibility + stats.pruned_bound
                  + stats.pruned_coarse + stats.pruned_lp) - tiers_at_entry
    if stats.pruned - pruned_at_entry != tier_delta:
        raise RuntimeError(
            f"cascade prune-counter drift: pruned "
            f"delta {stats.pruned - pruned_at_entry} != per-tier delta "
            f"{tier_delta} — some tally site bypassed _note_pruned")
    cascade.set(simulated=stats.simulated, pruned=stats.pruned)
    cascade.__exit__(None, None, None)
    outcomes.sort(key=lambda o: (o.sim.step_time, o.index))
    return outcomes
