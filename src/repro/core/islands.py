"""Hierarchical island search for fleet-scale planning (ISSUE 6 tentpole).

Flat enumeration over ``(dp, tp, pp)`` factorizations of the *whole* device
count is what the cascade (PR 4-5) accelerates, and it tops out around 64
GPUs / 32 TPU chips: past that, every candidate simulation walks thousands
of DP ranks and the divisor lattice explodes.  Tangram-style decomposition
(PAPERS.md) is the lever for 1k-10k-device fleets:

  1. **Partition** the cluster into homogeneous islands
     (:meth:`~repro.core.cluster.ClusterTopology.island_partition`): same
     device class, dense fast links inside; slow/sparse links become
     inter-island edges.  On a multi-pod TPU fleet each pod is one island.
  2. **Search** a sub-plan per island through the existing tiered cascade
     (:func:`repro.core.planner.plan_hybrid` on the island's
     :meth:`~repro.core.cluster.ClusterTopology.subtopology`), with
     **symmetry deduplication**: islands with equal canonical signatures
     (:meth:`~repro.core.cluster.ClusterTopology.island_signature`) and
     equal batch shares are isomorphic for planning, so one representative
     is scored and its plan is remapped onto the twins.
  3. **Compose** across islands as inter-island data parallelism: each
     island trains its quantized share of the global batch under its own
     sub-plan, and islands exchange gradients over the slow fabric.  The
     composed step estimate is ``max_i(island step) + inter_sync``, where
     ``inter_sync`` is the admissible ring bound of
     :func:`inter_island_sync_bound` — the same coarse roofline/ring
     reasoning tier 2 of the cascade uses, applied at island granularity.

Small clusters (``<= flat_limit`` alive devices) and single-island
partitions **fall back to the flat cascade**, so every existing
``cascade == exhaustive`` identity gate keeps holding verbatim — the
hierarchical tier only engages where flat search is intractable.

The composed plan searches a *restricted* space (no parallel group may
span two islands), so on clusters where flat search completes the flat
argmin can be at or below the composed estimate; the fallback guarantees
the two never disagree where both run.  ``docs/search.md`` carries the
admissibility argument for the inter-island bound.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, replace
from typing import Mapping, Sequence

from ..obs import Obs, resolve_obs
from .cluster import ClusterTopology
from .opgraph import ModelDesc
from .planner import PlanResult, SearchStats, plan_hybrid
from .plans import ParallelPlan, StageAssignment
from .simulator import StepSim

# Alive-device count at or under which plan_hierarchical delegates to the
# flat cascade (the regime where flat search is tractable and exhaustively
# verified).  ISSUE 6 acceptance pins identity to flat argmin up to here.
DEFAULT_FLAT_LIMIT = 64


# ---------------------------------------------------------------------------
# Partition
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Island:
    """One homogeneous island: a maximal same-class, fast-link-connected
    device group (see :meth:`ClusterTopology.island_partition`)."""

    index: int                       # position in the partition (stable)
    device_ids: tuple[int, ...]      # sorted member ids
    signature: tuple                 # canonical id-free signature

    @property
    def n(self) -> int:
        return len(self.device_ids)


def partition_islands(topo: ClusterTopology, *,
                      fast_frac: float = 0.5) -> list[Island]:
    """Partition ``topo`` into :class:`Island` objects with signatures.

    Args:
        topo: the cluster (current state; apply events/snapshot first).
        fast_frac: intra-island link threshold, forwarded to
            :meth:`ClusterTopology.island_partition`.

    Returns:
        Islands ordered by smallest member id; indices are positions in
        this list.
    """
    groups = topo.island_partition(fast_frac=fast_frac)
    return [Island(i, ids, topo.island_signature(ids))
            for i, ids in enumerate(groups)]


# ---------------------------------------------------------------------------
# Composition pieces
# ---------------------------------------------------------------------------


def remap_plan(plan: ParallelPlan,
               mapping: Mapping[int, int]) -> ParallelPlan:
    """Rewrite a sub-plan's device ids through ``mapping`` (representative
    island member -> twin island member, sorted-order correspondence).

    Signature equality guarantees the twin holds the same device-class
    multiset and internal edge multiset, so the remapped plan is
    structurally valid on the twin; for exactly repeated hardware (pods,
    DGX nodes) the sorted-id correspondence is exact.  ``meta`` records the
    reuse for telemetry.
    """
    stages = tuple(
        StageAssignment(st.layers, tuple(mapping[d] for d in st.device_ids))
        for st in plan.stages)
    return replace(plan, stages=stages,
                   meta={**plan.meta, "island_remapped": True})


def inter_island_sync_bound(topo: ClusterTopology,
                            island_ids: Sequence[Sequence[int]],
                            model: ModelDesc) -> float:
    """Admissible lower bound on the per-step inter-island gradient sync.

    Composed islands form a data-parallel ring of ``K`` members: every
    member must send and receive ``2 (K-1)/K`` of the full gradient volume
    (the decomposed reduce-scatter + all-gather floor, same term as the
    cascade's tier-2 sync bound).  All of an island's traffic crosses its
    boundary cut, so the time is floored by the *tightest* island's
    aggregate cut bandwidth — summing every live direct link leaving the
    island is optimistic (perfect striping, zero latency, full overlap
    across pairs), which keeps the bound admissible.

    Args:
        topo: the cluster (current effective bandwidths).
        island_ids: one id-sequence per composed island.
        model: supplies the gradient volume (``total_params * dtype``).

    Returns:
        Seconds; ``0.0`` for a single island.

    Raises:
        RuntimeError: some island has zero live cut bandwidth — the cluster
            is partitioned and no composed plan can sync across it.
    """
    K = len(island_ids)
    if K <= 1:
        return 0.0
    member: dict[int, int] = {}
    for k, ids in enumerate(island_ids):
        for d in ids:
            member[d] = k
    cut = [0.0] * K
    for (a, b), link in topo.links.items():
        ka, kb = member.get(a), member.get(b)
        if ka is None or kb is None or ka == kb or not link.edges:
            continue
        bw = max(e.effective_bandwidth for e in link.edges)
        cut[ka] += bw
        cut[kb] += bw
    bottleneck = min(cut)
    if bottleneck <= 0:
        bad = cut.index(bottleneck)
        raise RuntimeError(
            "no feasible plan found: cluster is partitioned — island "
            f"{bad} (devices {list(island_ids[bad])[:4]}...) has no live "
            "inter-island link")
    grad_bytes = model.total_params() * model.dtype_bytes
    return 2.0 * (K - 1) / K * grad_bytes / bottleneck


def _island_weight(topo: ClusterTopology, isl: Island) -> float:
    """Aggregate attainable throughput of an island (relative batch-share
    weight): sum of members' effective matmul rates."""
    total = 0.0
    for i in isl.device_ids:
        d = topo.device(i)
        if d.alive:
            total += d.spec.peak_flops * d.spec.matmul_eff * d.perf_factor
    return total


def _quantize_shares(weights: Sequence[float],
                     global_batch: int) -> tuple[list[int], int]:
    """Split ``global_batch`` into integer per-island shares proportional
    to ``weights``, quantized to a power-of-two unit so sub-searches keep
    friendly microbatch divisibility.

    Largest-remainder apportionment in units; every island gets at least
    one unit.  Equal weights get equal shares whenever the unit count
    divides evenly — the property symmetry deduplication relies on (twin
    islands with equal shares search once).

    Returns:
        (shares summing exactly to ``global_batch``, the unit used).

    Raises:
        RuntimeError: ``global_batch`` is smaller than the island count.
    """
    K = len(weights)
    if global_batch < K:
        raise RuntimeError(
            f"no feasible plan found: global batch {global_batch} smaller "
            f"than island count {K}")
    unit = 1
    while unit * 2 <= max(1, global_batch // (8 * K)) \
            and global_batch % (unit * 2) == 0:
        unit *= 2
    units = global_batch // unit
    total_w = sum(weights)
    raw = [units * (w / total_w) if total_w > 0 else units / K
           for w in weights]
    base = [max(1, math.floor(r)) for r in raw]
    # the max(1, .) floors can overshoot when many islands round to the
    # minimum; steal back from the largest shares first
    over = sum(base) - units
    if over > 0:
        for i in sorted(range(K), key=lambda i: (-base[i], i)):
            take = min(over, base[i] - 1)
            base[i] -= take
            over -= take
            if over == 0:
                break
    rem = units - sum(base)
    by_frac = sorted(range(K),
                     key=lambda i: (-(raw[i] - math.floor(raw[i])), i))
    for j in range(rem):
        base[by_frac[j % K]] += 1
    return [b * unit for b in base], unit


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class IslandPlan:
    """One island's slot in a composed plan."""

    island: Island
    plan: ParallelPlan               # device ids are the island's global ids
    predicted: StepSim               # sub-plan step time at ``batch``
    batch: int                       # the island's global-batch share
    searched: bool                   # False: reused from an isomorphic twin


@dataclass(frozen=True)
class ComposedPlan:
    """Cross-island composition: per-island sub-plans + the admissible
    inter-island sync bound.  ``step_time`` is the composed estimate
    ``max_i(island step) + inter_sync_s`` — islands run their shares
    concurrently, then sync gradients over the slow fabric."""

    islands: tuple[IslandPlan, ...]
    inter_sync_s: float
    step_time: float


@dataclass
class HierarchicalResult:
    """Outcome of :func:`plan_hierarchical`.

    Exactly one of ``composed`` / ``flat`` is set, per ``path``:
    ``"flat"`` means the cluster was small (or single-island) and the flat
    cascade ran — byte-identical to calling ``plan_hybrid`` directly;
    ``"hierarchical"`` means island decomposition engaged.
    """

    path: str                        # "flat" | "hierarchical"
    wall_time: float
    stats: SearchStats               # aggregated over all sub-searches
    n_islands: int                   # partition size (before any drops)
    n_signatures: int                # distinct canonical signatures
    islands_deduped: int             # islands that reused a twin's sub-plan
    islands_dropped: int = 0         # islands with no feasible sub-plan
    composed: ComposedPlan | None = None
    flat: PlanResult | None = None

    @property
    def predicted_step(self) -> float:
        """The composed (or flat) predicted step time, seconds."""
        if self.composed is not None:
            return self.composed.step_time
        assert self.flat is not None
        return self.flat.predicted.step_time


def _merge_stats(dst: SearchStats, src: SearchStats | None) -> None:
    if src is None:
        return
    dst.explored += src.explored
    dst.pruned += src.pruned
    dst.infeasible += src.infeasible
    dst.rejected += src.rejected
    dst.cache_hits += src.cache_hits
    dst.cache_misses += src.cache_misses
    dst.pruned_feasibility += src.pruned_feasibility
    dst.pruned_bound += src.pruned_bound
    dst.pruned_coarse += src.pruned_coarse
    dst.simulated += src.simulated
    dst.budget_skipped += src.budget_skipped


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def plan_hierarchical(topo: ClusterTopology, model: ModelDesc, *,
                      global_batch: int, seq: int,
                      flat_limit: int = DEFAULT_FLAT_LIMIT,
                      fast_frac: float = 0.5,
                      gpus_per_node: int = 8,
                      max_candidates: int | None = None,
                      max_sims: int | None = None,
                      cache=None, executor=None,
                      top_k: int = 1,
                      lp_prune: bool = True,
                      obs: Obs | None = None) -> HierarchicalResult:
    """Plan a (possibly fleet-scale) cluster via hierarchical island search.

    Small clusters (``len(alive) <= flat_limit``) and single-island
    partitions delegate to :func:`repro.core.planner.plan_hybrid` unchanged
    (``path == "flat"``), so the flat cascade's argmin identity is
    preserved exactly where it is verified.  Otherwise each island's
    sub-plan is searched independently (one search per distinct
    ``(signature, batch share)`` group — isomorphic islands are scored
    once) and composed with the admissible inter-island sync bound.

    Args:
        topo: the cluster, current state (snapshot first for a given time).
        model: the workload.
        global_batch: total batch; split across islands proportionally to
            their aggregate throughput, quantized by :func:`_quantize_shares`.
        seq: sequence length.
        flat_limit: alive-device count at or under which the flat cascade
            runs instead (``0`` forces hierarchical whenever K > 1).
        fast_frac: island partition threshold (see
            :meth:`ClusterTopology.island_partition`).
        gpus_per_node / max_candidates / cache / executor / top_k /
        lp_prune:
            forwarded to every ``plan_hybrid`` call (flat and per-island) —
            ``lp_prune`` toggles the tier-2.5 LP bound in each sub-search's
            cascade.
        max_sims: per-sub-search anytime simulation budget (forwarded to
            the cascade; see ``score_candidates``).  Essential at fleet
            scale — an island sub-search then stops after the budget's
            best-bound-first simulations.
        obs: a :class:`repro.obs.Obs` bundle; records a
            ``plan.hierarchical`` span with one ``island.search`` child per
            distinct sub-search (no-op by default).

    Returns:
        A :class:`HierarchicalResult`; ``predicted_step`` is the composed
        (or flat) step-time estimate.

    Raises:
        RuntimeError: no feasible plan — every island's sub-search failed,
            the cluster is partitioned (some island unroutable / zero cut
            bandwidth), or the batch cannot cover the island count.
    """
    t0 = time.perf_counter()
    obs = resolve_obs(obs)
    alive = topo.alive_ids()
    islands = partition_islands(topo, fast_frac=fast_frac)
    n_signatures = len({isl.signature for isl in islands})

    if len(alive) <= flat_limit or len(islands) <= 1:
        res = plan_hybrid(topo, model, global_batch=global_batch, seq=seq,
                          gpus_per_node=gpus_per_node, with_baseline=False,
                          max_candidates=max_candidates, cache=cache,
                          executor=executor, top_k=top_k, max_sims=max_sims,
                          lp_prune=lp_prune, obs=obs)
        stats = res.search_stats or SearchStats()
        wall = time.perf_counter() - t0
        return HierarchicalResult(
            path="flat", wall_time=wall, stats=stats,
            n_islands=len(islands), n_signatures=n_signatures,
            islands_deduped=0, flat=res)

    # Inter-island routability (the existing routing machinery): if any
    # island cannot reach island 0 over live links, no composed plan can
    # sync gradients — same verdict flat search reaches via infinite
    # simulated transfers, surfaced before any sub-search runs.
    table = topo.routing()
    root = islands[0].device_ids[0]
    for isl in islands[1:]:
        if table.route(root, isl.device_ids[0]) is None:
            raise RuntimeError(
                "no feasible plan found: cluster is partitioned (island "
                f"{isl.index} is unreachable from island 0)")

    hier_span = obs.span("plan.hierarchical", n_islands=len(islands),
                         n_signatures=n_signatures, devices=len(alive))
    hier_span.__enter__()
    stats = SearchStats()
    active = list(islands)
    dropped = 0
    shares: list[int] = []
    groups: dict[tuple, list[Island]] = {}
    results: dict[tuple, PlanResult] = {}
    for _ in range(len(islands)):
        weights = [_island_weight(topo, isl) for isl in active]
        shares, _unit = _quantize_shares(weights, global_batch)
        groups = {}
        for isl, share in zip(active, shares):
            groups.setdefault((isl.signature, share), []).append(isl)
        results = {}
        infeasible: set[int] = set()
        for key, members in groups.items():
            rep = members[0]
            sub = topo.subtopology(rep.device_ids)
            with obs.span("island.search", signature=str(key[0]),
                          share=key[1], members=len(members)) as isl_span:
                try:
                    res = plan_hybrid(
                        sub, model, global_batch=key[1], seq=seq,
                        gpus_per_node=gpus_per_node, with_baseline=False,
                        max_candidates=max_candidates, allow_subset=False,
                        cache=cache, executor=executor, max_sims=max_sims,
                        lp_prune=lp_prune, obs=obs)
                except RuntimeError:
                    isl_span.set(feasible=False)
                    infeasible.update(m.index for m in members)
                    continue
                isl_span.set(feasible=True,
                             step_time=res.predicted.step_time)
            results[key] = res
            _merge_stats(stats, res.search_stats)
        if not infeasible:
            break
        # drop islands that cannot host the model at their share, recompute
        # shares over the survivors, and retry (at most K rounds)
        dropped += len(infeasible)
        active = [isl for isl in active if isl.index not in infeasible]
        if not active:
            raise RuntimeError(
                "no feasible plan found: no island can host the model")
    else:
        raise RuntimeError("no feasible plan found: island sub-searches "
                           "did not converge")

    plans: list[IslandPlan] = []
    for isl, share in zip(active, shares):
        key = (isl.signature, share)
        res = results[key]
        rep = groups[key][0]
        if isl.index == rep.index:
            plan, searched = res.plan, True
        else:
            mapping = dict(zip(rep.device_ids, isl.device_ids))
            plan, searched = remap_plan(res.plan, mapping), False
        plans.append(IslandPlan(island=isl, plan=plan,
                                predicted=res.predicted, batch=share,
                                searched=searched))
    inter = inter_island_sync_bound(
        topo, [isl.device_ids for isl in active], model)
    step = max(p.predicted.step_time for p in plans) + inter
    stats.wall_time = time.perf_counter() - t0
    hier_span.set(step_time=step, islands_dropped=dropped)
    hier_span.__exit__(None, None, None)
    return HierarchicalResult(
        path="hierarchical", wall_time=stats.wall_time, stats=stats,
        n_islands=len(islands), n_signatures=n_signatures,
        islands_deduped=len(active) - len(groups),
        islands_dropped=dropped,
        composed=ComposedPlan(islands=tuple(plans), inter_sync_s=inter,
                              step_time=step))
