"""Incremental re-planning engine with a persistent strategy cache.

The paper's adaptability claim (§2.2) only holds if re-planning is cheap
enough to run *during* training when the network shifts.  The seed planner
re-enumerated and re-simulated every candidate from scratch on every
topology event; this module makes re-planning incremental:

  * :class:`TopologyFingerprint` — canonical, quantized hash of the alive
    device set (spec + perf-factor bucket) and the effective edge bandwidths
    (log-scale buckets), so "the same topology modulo noise" maps to the
    same cache key while a real change maps to a new one.
  * :class:`StrategyCache` — LRU-bounded memo of ``enumerate_strategies``
    output, per-:class:`StrategyPoint` materialized plans, and simulator
    scores, keyed by fingerprint context.  Hit/miss telemetry folds into
    :class:`SearchStats`.
  * :class:`ReplanEngine` — the ``replan(topo, event)`` entry point.  It
    classifies the topology delta and picks the cheapest sound path:

    ========== ============== ==================================================
    event      device set     re-plan path
    ========== ============== ==================================================
    bandwidth  unchanged      re-score cached materialized plans (simulation
                              only — no enumeration, no layer B&B); only the
                              top-K candidates ranked by a bandwidth-adjusted
                              estimate of their previous score are simulated
                              (batched through ``simulate_many`` on the
                              shared ``SearchExecutor`` when one is
                              attached).
    slowdown   unchanged      ReCycle-style local rebalance of the incumbent
                              (layer split + batch shares) *plus* the top-K
                              re-score above; best of both wins.
    fail/join  changed        seed a bounded search from the incumbent plan's
                              strategy neighborhood (dp/tp/pp within a factor
                              of 2); fall back to full enumeration — with the
                              neighborhood winner's score as the pruning
                              bound — only when the neighborhood is infeasible.
    ========== ============== ==================================================

The engine's cold path *is* :func:`repro.core.planner.plan_hybrid` (with the
cache threaded through), so warm results stay comparable to a from-scratch
plan; `benchmarks/bench_replan.py` measures the latency gap and
`tests/test_engine.py` checks warm/cold step-time equivalence.
"""

from __future__ import annotations

import hashlib
import math
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Sequence

from ..obs import Obs, resolve_obs
from .cluster import ClusterTopology, NetworkEvent
from .opgraph import ModelDesc
from .planner import SearchStats, StrategyPoint, _divisors, plan_hybrid
from .plans import ParallelPlan
from .reconfig import ReconfigCostModel
from .simulator import StepSim, simulate_many, simulate_training_step

# ---------------------------------------------------------------------------
# Topology fingerprinting
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TopologyFingerprint:
    """Canonical quantized view of a topology snapshot.

    ``devices``: sorted (device_id, spec name, perf-factor bucket) triples of
    the alive set.  ``edges``: sorted (a, b, tag, bandwidth bucket) tuples of
    the edges between alive devices.  Bandwidth buckets are log2-scale, so a
    few-percent wobble keeps the key stable while a real shift (2x drop, link
    swap) moves to a new bucket and therefore a new key.
    """

    devices: tuple[tuple[int, str, int], ...]
    edges: tuple[tuple[int, int, str, int], ...]

    @property
    def key(self) -> str:
        return hashlib.sha1(repr((self.devices, self.edges))
                            .encode()).hexdigest()[:16]

    @property
    def device_key(self) -> tuple[tuple[int, str], ...]:
        """Identity of the alive device set, ignoring perf factors — used to
        classify a delta as device-set-changing (fail/join) or not."""
        return tuple((i, name) for i, name, _ in self.devices)


def fingerprint_topology(topo: ClusterTopology, *, bw_quant: float = 0.25,
                         perf_quant: float = 0.05) -> TopologyFingerprint:
    """Fingerprint the *current* state of ``topo`` (apply events/snapshot
    first if you need a particular time).

    ``bw_quant``: bucket width in log2(bytes/s) — 0.25 means edges within
    ~±9% of a bucket center hash identically.  ``perf_quant``: linear bucket
    width for device perf factors.
    """
    devices = tuple(sorted(
        (d.device_id, d.spec.name, int(round(d.perf_factor / perf_quant)))
        for d in topo.alive_devices))
    alive = {d.device_id for d in topo.alive_devices}
    edges = []
    for (a, b), link in sorted(topo.links.items()):
        if a not in alive or b not in alive:
            continue
        for e in link.edges:
            bw = e.effective_bandwidth
            bucket = int(round(math.log2(bw) / bw_quant)) if bw > 0 else -1
            edges.append((a, b, e.tag, bucket))
    return TopologyFingerprint(devices, tuple(sorted(edges)))


# ---------------------------------------------------------------------------
# Strategy cache
# ---------------------------------------------------------------------------


@dataclass
class CacheStats:
    """Session-wide :class:`StrategyCache` telemetry: lookup hits/misses
    across every context plus LRU evictions."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class _CacheEntry:
    """Everything memoized for one (fingerprint, model, batch, seq) context."""

    __slots__ = ("points", "plans", "scores")

    def __init__(self) -> None:
        self.points: list[StrategyPoint] | None = None
        # (StrategyPoint, refine_layers) -> materialized ParallelPlan
        self.plans: dict[tuple[StrategyPoint, bool], ParallelPlan] = {}
        # structural plan key -> StepSim
        self.scores: dict[tuple, StepSim] = {}


def _plan_key(plan: ParallelPlan) -> tuple:
    """Structural identity of a plan — everything the simulator reads."""
    return plan.structural_key()


class _CacheContext:
    """Handle bound to one cache entry; the duck-typed interface
    :func:`plan_hybrid` consumes.  Thread-safe (the planner scores
    candidates from a thread pool)."""

    def __init__(self, cache: "StrategyCache", entry: _CacheEntry):
        self._cache = cache
        self._entry = entry
        self._hits = 0
        self._misses = 0
        self._lock = threading.Lock()

    def _count(self, hit: bool) -> None:
        with self._lock:
            if hit:
                self._hits += 1
            else:
                self._misses += 1
        self._cache._count(hit)

    def counters(self) -> tuple[int, int]:
        with self._lock:
            return self._hits, self._misses

    # -- points ----------------------------------------------------------------

    def get_points(self) -> list[StrategyPoint] | None:
        pts = self._entry.points
        self._count(pts is not None)
        return list(pts) if pts is not None else None

    def put_points(self, points: list[StrategyPoint]) -> None:
        self._entry.points = list(points)

    # -- materialized plans ----------------------------------------------------

    def get_plan(self, point: StrategyPoint, refine: bool) -> ParallelPlan | None:
        plan = self._entry.plans.get((point, refine))
        self._count(plan is not None)
        return plan

    def put_plan(self, point: StrategyPoint, refine: bool,
                 plan: ParallelPlan) -> None:
        with self._lock:
            self._entry.plans[(point, refine)] = plan

    # -- simulator scores ------------------------------------------------------

    def get_score(self, plan: ParallelPlan) -> StepSim | None:
        sim = self._entry.scores.get(_plan_key(plan))
        self._count(sim is not None)
        return sim

    def put_score(self, plan: ParallelPlan, sim: StepSim) -> None:
        with self._lock:
            self._entry.scores[_plan_key(plan)] = sim

    # -- bulk view (warm re-scoring) -------------------------------------------

    def materialized(self) -> list[tuple[tuple[StrategyPoint, bool],
                                         ParallelPlan, StepSim | None]]:
        """All materialized plans with their scores (if simulated)."""
        with self._lock:
            return [(key, plan, self._entry.scores.get(_plan_key(plan)))
                    for key, plan in self._entry.plans.items()]


class StrategyCache:
    """LRU cache of planning work, keyed by topology fingerprint context.

    One *entry* holds the strategy points, materialized plans and simulator
    scores for one (fingerprint, model, global_batch, seq).  ``max_entries``
    bounds memory; least-recently-used contexts are evicted.
    """

    def __init__(self, max_entries: int = 64, *, bw_quant: float = 0.25,
                 perf_quant: float = 0.05, obs: "Obs | None" = None):
        self.max_entries = max_entries
        self.bw_quant = bw_quant
        self.perf_quant = perf_quant
        self.obs = resolve_obs(obs)
        self.stats = CacheStats()
        self._entries: OrderedDict[tuple, _CacheEntry] = OrderedDict()
        self._lock = threading.Lock()

    def _count(self, hit: bool) -> None:
        with self._lock:
            if hit:
                self.stats.hits += 1
            else:
                self.stats.misses += 1
        self.obs.inc("cache.hit" if hit else "cache.miss")

    def fingerprint(self, topo: ClusterTopology) -> TopologyFingerprint:
        return fingerprint_topology(topo, bw_quant=self.bw_quant,
                                    perf_quant=self.perf_quant)

    def context(self, topo: ClusterTopology, model: ModelDesc, *,
                global_batch: int, seq: int,
                gpus_per_node: int = 8) -> _CacheContext:
        fp = self.fingerprint(topo)
        # gpus_per_node shapes enumerate_strategies output, so it is part
        # of the context identity
        key = (fp.key, model, global_batch, seq, gpus_per_node)
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                entry = _CacheEntry()
                self._entries[key] = entry
                while len(self._entries) > self.max_entries:
                    self._entries.popitem(last=False)
                    self.stats.evictions += 1
                    self.obs.inc("cache.eviction")
            else:
                self._entries.move_to_end(key)
        return _CacheContext(self, entry)

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()


# ---------------------------------------------------------------------------
# Re-planning engine
# ---------------------------------------------------------------------------


@dataclass
class ReplanResult:
    """Outcome of one (cold or warm) planning call."""

    plan: ParallelPlan
    predicted: StepSim
    path: str                     # cold-plan | bandwidth-rescore |
    #                               straggler-rebalance |
    #                               straggler-neighborhood | neighborhood |
    #                               full-replan
    wall_time: float
    stats: SearchStats
    cold: bool
    # switch-cost hysteresis: modeled cost (s) of moving off the incumbent,
    # and whether the engine kept the incumbent because the projected
    # savings over the remaining horizon did not cover that cost
    switch_cost: float = 0.0
    kept: bool = False
    # best distinct plans from a full (cold) search, best-first — fills the
    # cross-interval DP oracle's widened per-interval candidate set when the
    # engine was built with ``plan_top_k > 1``
    top_plans: tuple[tuple[ParallelPlan, StepSim], ...] = ()


def _comm_scale_estimate(sim: StepSim, plan: ParallelPlan,
                         ratio: float) -> float:
    """Heuristic re-estimate of a plan's step time after every edge bandwidth
    scales by ``ratio``.  Only used to *rank* cached candidates before the
    top-K get truly re-simulated, so it needs the right shape, not accuracy:
    the additive dp-sync term scales exactly, the in-pipeline collective
    totals are normalized and clamped so comm-heavy plans move more than
    compute-heavy ones."""
    if ratio <= 0:
        ratio = 1.0
    inpipe = (sim.tp_comm_time + sim.pp_comm_time) / max(plan.dp, 1)
    comm = min(sim.dp_sync_time + inpipe, 0.95 * sim.step_time)
    return (sim.step_time - comm) + comm / ratio


class ReplanEngine:
    """Incremental re-planner for one (model, global_batch, seq) workload.

    Call :meth:`plan` once to establish the incumbent (cold, full search),
    then :meth:`replan` on every :class:`NetworkEvent`.  All paths share the
    :class:`StrategyCache`, so repeated events on similar topologies keep
    getting cheaper.
    """

    def __init__(self, model: ModelDesc, *, global_batch: int, seq: int,
                 cache: StrategyCache | None = None,
                 max_candidates: int | None = None, rescore_top_k: int = 12,
                 rescore_min_sims: int = 4, rescore_stop_margin: float = 1.35,
                 gpus_per_node: int = 8,
                 reconfig: ReconfigCostModel | None = None,
                 switch_horizon_s: float | None = None,
                 straggler_escalate_gap: float = 1.15,
                 executor=None, plan_top_k: int = 1,
                 lp_prune: bool = True,
                 obs: Obs | None = None):
        self.model = model
        self.global_batch = global_batch
        self.seq = seq
        # tier-2.5 LP bound toggle, forwarded to every plan_hybrid this
        # engine issues (admissible — never changes the chosen plan)
        self.lp_prune = lp_prune
        # telemetry bundle: every replan records a ``replan.<path>`` span,
        # a ``replan.path.<path>`` counter and a ``replan.latency_s``
        # observation into it (no-op unless tracing is on)
        self.obs = resolve_obs(obs)
        self.cache = cache if cache is not None \
            else StrategyCache(obs=self.obs)
        # a repro.core.search.SearchExecutor: full searches then score their
        # final simulation tier in worker processes (plan identity with the
        # serial path is guaranteed by the pipeline's canonical tie-break)
        self.executor = executor
        # how many distinct best plans a cold search reports in
        # ReplanResult.top_plans (the DP oracle's widened candidate set)
        self.plan_top_k = plan_top_k
        self.max_candidates = max_candidates
        self.rescore_top_k = rescore_top_k
        self.rescore_min_sims = rescore_min_sims
        self.rescore_stop_margin = rescore_stop_margin
        self.gpus_per_node = gpus_per_node
        # switch-cost model: every keep/switch decision prices the move off
        # the incumbent through this (no hard-coded reconfig constants).
        # ``switch_horizon_s`` is the remaining-horizon budget the projected
        # savings must amortize the switch over; None means an unbounded
        # horizon (any strictly-better plan is worth its switch cost).
        self.reconfig = reconfig if reconfig is not None \
            else ReconfigCostModel(model)
        self.switch_horizon_s = switch_horizon_s
        # straggler path: escalate to the dp/tp/pp neighborhood search when
        # the local rebalance stays above this factor of the engine's last
        # (pre-event) predicted step time
        self.straggler_escalate_gap = straggler_escalate_gap
        self.incumbent: tuple[ParallelPlan, StepSim] | None = None
        self._device_key: tuple | None = None
        # last applied bandwidth factor per event selector, so consecutive
        # S1 events rank by the *relative* change
        self._bw_factor: dict[str | None, float] = {}
        # (point-key, plan, last StepSim) portfolio for the current device set
        self._portfolio: list[tuple[tuple[StrategyPoint, bool],
                                    ParallelPlan, StepSim | None]] = []
        self.history: list[ReplanResult] = []

    # -- shared helpers --------------------------------------------------------

    def _simulate(self, plan: ParallelPlan, topo: ClusterTopology,
                  ctx: _CacheContext | None = None) -> StepSim | None:
        if ctx is not None:
            sim = ctx.get_score(plan)
            if sim is not None:
                return sim
        try:
            sim = simulate_training_step(plan, self.model, topo,
                                         global_batch=self.global_batch,
                                         seq=self.seq)
        except (ValueError, ZeroDivisionError):
            return None
        if not math.isfinite(sim.step_time):
            # unroutable transfer (partitioned cluster): the plan is
            # infeasible, same verdict simulate_many returns
            return None
        if ctx is not None:
            ctx.put_score(plan, sim)
        return sim

    def _keep_or_switch(self, plan: ParallelPlan, sim: StepSim,
                        topo: ClusterTopology, ctx: _CacheContext | None
                        ) -> tuple[ParallelPlan, StepSim, float, bool]:
        """Switch-cost hysteresis: price moving off the incumbent through
        the :class:`ReconfigCostModel` and keep the incumbent when the
        projected step-time savings over the remaining horizon do not cover
        the modeled switch cost.  Returns (plan, sim, switch_cost, kept)."""
        prev = self.incumbent
        if prev is None or self.reconfig is None:
            return plan, sim, 0.0, False
        prev_plan, _ = prev
        if plan.structural_key() == prev_plan.structural_key():
            return plan, sim, 0.0, False
        # never keep an incumbent naming dead devices: the simulator silently
        # drops dead TP-group members, so its score would be optimistic while
        # the plan is actually unrunnable.  The switch is forced, but still
        # price it (reshard from survivors + store fallback) for telemetry.
        alive = set(topo.alive_ids())
        if prev_plan.world > len(alive) or (prev_plan.stages and not
                                            {d for st in prev_plan.stages
                                             for d in st.device_ids} <= alive):
            return plan, sim, self.reconfig.cost(prev_plan, plan,
                                                 topo).total_s, False
        prev_sim = self._simulate(prev_plan, topo, ctx)
        cost = self.reconfig.cost(prev_plan, plan, topo).total_s
        if prev_sim is None or not math.isfinite(prev_sim.step_time) \
                or prev_sim.step_time <= 0:
            # incumbent no longer simulatable: the switch is forced, but
            # the telemetry still carries what it costs
            return plan, sim, cost, False
        if self.switch_horizon_s is None:
            # unbounded horizon: any strictly-better plan amortizes any
            # finite cost eventually; equal-or-worse keeps the incumbent
            switch = sim.step_time < prev_sim.step_time
        else:
            # running old for H costs H/old steps; switching yields
            # (H - c)/new -> switch iff H * (1 - new/old) > c
            saved = self.switch_horizon_s \
                * (1.0 - sim.step_time / prev_sim.step_time)
            switch = saved > cost
        if switch:
            return plan, sim, cost, False
        return prev_plan, prev_sim, cost, True

    def _finish(self, plan: ParallelPlan, sim: StepSim, path: str,
                t0: float, stats: SearchStats, *, cold: bool,
                topo: ClusterTopology, ctx: _CacheContext | None,
                refresh_portfolio: bool = False,
                top_plans: tuple = ()) -> ReplanResult:
        switch_cost, kept = 0.0, False
        if not cold:
            plan, sim, switch_cost, kept = \
                self._keep_or_switch(plan, sim, topo, ctx)
        self.incumbent = (plan, sim)
        self._device_key = self.cache.fingerprint(topo).device_key
        if refresh_portfolio and ctx is not None:
            # Rebuild the warm-start portfolio from the plans this full
            # search materialized for its own context.  Strategy points that
            # keep a stale prior score still rank in future re-scores.
            # Canonical ordering matters: the context's plan memo is filled
            # in thread-completion order, and downstream tie-breaks (stable
            # rank sort, strict-< best selection) follow portfolio order —
            # identical replays must pick identical plans.
            stale = {key: s for key, _, s in self._portfolio if s is not None}
            self._portfolio = [
                (key, p, s if s is not None else stale.get(key))
                for key, p, s in sorted(
                    ctx.materialized(),
                    key=lambda item: (item[0][0].dp, item[0][0].tp,
                                      item[0][0].pp, item[0][0].ep,
                                      item[0][0].microbatches,
                                      item[0][0].grad_sync, item[0][1]))]
        wall = time.perf_counter() - t0
        res = ReplanResult(plan=plan, predicted=sim, path=path,
                           wall_time=wall, stats=stats,
                           cold=cold, switch_cost=switch_cost, kept=kept,
                           top_plans=tuple(top_plans))
        self.history.append(res)
        # single telemetry funnel: every planning call (cold or warm) exits
        # through here, so the registry sees each path exactly once
        self.obs.inc(f"replan.path.{path}")
        self.obs.observe("replan.latency_s", wall)
        if self.obs.enabled:
            # the path is only known at the end, so the span is backdated
            # to t0 (same perf_counter clock) to cover the whole call
            handle = self.obs.span(f"replan.{path}", cold=cold, kept=kept,
                                   step_time=sim.step_time)
            handle.span.t0 = t0
            handle.__exit__(None, None, None)
        return res

    def seed_incumbent(self, topo: ClusterTopology, plan: ParallelPlan,
                       sim: StepSim) -> None:
        """Adopt an externally-provided incumbent as if :meth:`plan` had
        produced it — warm :meth:`replan` paths dispatch against it without
        a cold search.  The cross-job planner service uses this to hand an
        engine a shared-cache plan remapped onto its device slice
        (:meth:`repro.service.SharedStrategyCache.lookup`); the portfolio
        starts empty, so the first bandwidth re-score falls back to
        re-simulating the incumbent alone and rebuilds from there."""
        self.incumbent = (plan, sim)
        self._device_key = self.cache.fingerprint(topo).device_key
        self._bw_factor = {}
        ctx = self.cache.context(topo, self.model,
                                 global_batch=self.global_batch, seq=self.seq,
                                 gpus_per_node=self.gpus_per_node)
        ctx.put_score(plan, sim)

    def score_plan(self, plan: ParallelPlan,
                   topo: ClusterTopology) -> StepSim | None:
        """Cache-backed simulation of an explicit plan.  Returns None when
        the plan is infeasible on ``topo``.  Prefer :meth:`score_plans` for
        a batch — the topology fingerprint is computed once per call."""
        return self.score_plans([plan], topo)[0]

    def score_plans(self, plans: Sequence[ParallelPlan],
                    topo: ClusterTopology) -> list[StepSim | None]:
        """Simulate explicit plans against one topology through the score
        cache (one fingerprint/context for the whole batch; cache misses go
        through the batched :func:`repro.core.simulator.simulate_many`, so
        the topology snapshot is materialized once).  Benchmarks that sweep
        fixed configurations across dynamic network conditions (fig6c) use
        this; scores repeat for free when the same condition is scored
        again."""
        ctx = self.cache.context(topo, self.model,
                                 global_batch=self.global_batch, seq=self.seq,
                                 gpus_per_node=self.gpus_per_node)
        out: list[StepSim | None] = [ctx.get_score(p) for p in plans]
        missing = [i for i, s in enumerate(out) if s is None]
        if missing:
            fresh = simulate_many([plans[i] for i in missing], self.model,
                                  topo, global_batch=self.global_batch,
                                  seq=self.seq, obs=self.obs)
            for i, sim in zip(missing, fresh):
                if sim is not None:
                    ctx.put_score(plans[i], sim)
                out[i] = sim
        return out

    # -- cold path -------------------------------------------------------------

    def plan(self, topo: ClusterTopology) -> ReplanResult:
        """Full search (enumerate + materialize + simulate), cache-backed.
        Establishes the incumbent plan and the warm-start portfolio."""
        t0 = time.perf_counter()
        ctx = self.cache.context(topo, self.model,
                                 global_batch=self.global_batch, seq=self.seq,
                                 gpus_per_node=self.gpus_per_node)
        res = plan_hybrid(topo, self.model, global_batch=self.global_batch,
                          seq=self.seq, gpus_per_node=self.gpus_per_node,
                          with_baseline=False,
                          max_candidates=self.max_candidates,
                          cache=self.cache, executor=self.executor,
                          top_k=self.plan_top_k, lp_prune=self.lp_prune,
                          obs=self.obs)
        stats = res.search_stats or SearchStats()
        return self._finish(res.plan, res.predicted, "cold-plan", t0, stats,
                            cold=True, topo=topo, ctx=ctx,
                            refresh_portfolio=True,
                            top_plans=res.top_plans)

    # -- warm paths ------------------------------------------------------------

    def replan(self, topo: ClusterTopology,
               event: NetworkEvent | None = None) -> ReplanResult:
        """Re-plan after ``event`` on the (already updated) topology.

        Classifies the actual delta — device set changed vs parameters-only —
        rather than trusting ``event.kind`` alone, and dispatches per the
        decision table in the module docstring.

        Args:
            topo: the cluster with the event ALREADY applied (the caller
                applies events; the engine only reads the current state).
            event: the triggering :class:`NetworkEvent`, used as a routing
                hint (slowdown -> straggler path, bandwidth -> re-score
                ratio); ``None`` falls back to fingerprint classification.

        Returns:
            A :class:`ReplanResult`; ``path`` names the chosen warm/cold
            path, ``kept`` whether switch-cost hysteresis retained the
            incumbent.  The incumbent and history are updated in place.
        """
        if self.incumbent is None or self._device_key is None:
            return self.plan(topo)
        fp = self.cache.fingerprint(topo)
        if fp.device_key != self._device_key:
            return self._replan_device_set(topo)
        if event is not None and event.kind == "slowdown":
            return self._replan_straggler(topo)
        ratio = 1.0
        if event is not None and event.kind == "bandwidth":
            if event.mode == "scale":
                # compositional event: the factor IS the relative change
                ratio = event.factor
                prev = self._bw_factor.get(event.selector, 1.0)
                self._bw_factor[event.selector] = prev * event.factor
            else:
                prev = self._bw_factor.get(event.selector, 1.0)
                ratio = event.factor / prev if prev > 0 else event.factor
                self._bw_factor[event.selector] = event.factor
        return self._replan_bandwidth(topo, ratio)

    def _rescore_portfolio(self, topo: ClusterTopology, ctx: _CacheContext,
                           ratio: float, stats: SearchStats
                           ) -> tuple[float, ParallelPlan, StepSim] | None:
        """Simulate the top-K cached plans (ranked by a bandwidth-adjusted
        estimate of their previous score) on the new topology."""
        inc_plan, _ = self.incumbent  # type: ignore[misc]
        ranked = sorted(
            (p for p in self._portfolio if p[2] is not None),
            key=lambda p: _comm_scale_estimate(p[2], p[1], ratio))
        chosen = ranked[:self.rescore_top_k]
        min_sims = min(self.rescore_min_sims,
                       max(1, len(ranked) // 3))
        # With a shared SearchExecutor, the whole top-K batch (plus the
        # incumbent) is pre-scored in worker processes through the batched
        # simulate_many path.  The serial walk below then *consumes* the
        # pre-computed scores, so the executor path picks the exact plans
        # and portfolio state the serial walk would — only wall time
        # changes.  (ROADMAP open item 3: the warm path used to simulate
        # its top-K serially even when the harness held an executor.)
        pre: dict[int, StepSim | None] = {}
        if self.executor is not None and len(chosen) > 1:
            # ship only the score-cache misses, deduplicated by structural
            # key (the incumbent is usually the best-ranked entry): on
            # cache-hot fingerprints the serial walk simulates ~nothing,
            # and the executor path must not do strictly more work than it
            walk = [(i, p) for i, (_, p, _) in enumerate(chosen)]
            walk.append((len(chosen), inc_plan))
            indices_by_key: dict[tuple, list[int]] = {}
            batch: list[ParallelPlan] = []
            for i, p in walk:
                if ctx is not None and ctx.get_score(p) is not None:
                    continue            # the walk reads it from ctx
                key = p.structural_key()
                if key not in indices_by_key:
                    indices_by_key[key] = []
                    batch.append(p)
                indices_by_key[key].append(i)
            if len(batch) > 1:
                sims = self.executor.simulate_plans(
                    topo, self.model, batch,
                    global_batch=self.global_batch, seq=self.seq)
                for p, sim in zip(batch, sims):
                    for i in indices_by_key[p.structural_key()]:
                        pre[i] = sim

        def scored(idx: int, plan: ParallelPlan) -> StepSim | None:
            if idx not in pre:
                return self._simulate(plan, topo, ctx)
            sim = ctx.get_score(plan) if ctx is not None else None
            if sim is None:
                sim = pre[idx]
                if sim is not None and ctx is not None:
                    ctx.put_score(plan, sim)
            return sim

        fresh: dict[tuple[StrategyPoint, bool], StepSim] = {}
        best: tuple[float, ParallelPlan, StepSim] | None = None
        for i, (key, plan, old) in enumerate(chosen):
            # estimate-gated early stop: the ranking estimate consistently
            # *over*shoots the true step time, so once the next candidate's
            # estimate clears the best simulated time by the stop margin the
            # remaining tail cannot plausibly win
            if (best is not None and stats.explored >= min_sims
                    and _comm_scale_estimate(old, plan, ratio)
                    >= best[0] * self.rescore_stop_margin):
                stats.pruned += len(chosen) - i
                break
            sim = scored(i, plan)
            if sim is None:
                stats.rejected += 1
                continue
            stats.explored += 1
            fresh[key] = sim
            if best is None or sim.step_time < best[0]:
                best = (sim.step_time, plan, sim)
        # the incumbent always gets re-scored, even if ranked out
        inc_sim = scored(len(chosen), inc_plan)
        if inc_sim is not None and (best is None
                                    or inc_sim.step_time < best[0]):
            best = (inc_sim.step_time, inc_plan, inc_sim)
        # fold fresh scores back into the engine-private portfolio (the
        # context's plan memo stays untouched: its materializations belong
        # to full searches on *this* fingerprint, not recycled ones)
        if fresh:
            self._portfolio = [(k, p, fresh.get(k, s))
                               for k, p, s in self._portfolio]
        return best

    def _replan_bandwidth(self, topo: ClusterTopology,
                          ratio: float) -> ReplanResult:
        """S1: same devices, different links — simulation only (no
        enumeration, no layer B&B)."""
        t0 = time.perf_counter()
        stats = SearchStats()
        ctx = self.cache.context(topo, self.model,
                                 global_batch=self.global_batch, seq=self.seq,
                                 gpus_per_node=self.gpus_per_node)
        best = self._rescore_portfolio(topo, ctx, ratio, stats)
        if best is None:                       # cache somehow useless: cold
            return self.plan(topo)
        stats.cache_hits, stats.cache_misses = ctx.counters()
        stats.wall_time = time.perf_counter() - t0
        return self._finish(best[1], best[2], "bandwidth-rescore", t0, stats,
                            cold=False, topo=topo, ctx=ctx)

    def _replan_straggler(self, topo: ClusterTopology) -> ReplanResult:
        """S2: same devices, changed perf factor — local rebalance of the
        incumbent (keep dp/tp/pp; re-split layers and batch shares) raced
        against the top-K portfolio re-score."""
        from .dynamic import reassign_for_straggler
        t0 = time.perf_counter()
        stats = SearchStats()
        ctx = self.cache.context(topo, self.model,
                                 global_batch=self.global_batch, seq=self.seq,
                                 gpus_per_node=self.gpus_per_node)
        inc_plan, _ = self.incumbent  # type: ignore[misc]
        best = self._rescore_portfolio(topo, ctx, 1.0, stats)
        try:
            rebalanced = reassign_for_straggler(
                inc_plan, self.model, topo, batch=self.global_batch,
                seq=self.seq)
            sim = self._simulate(rebalanced, topo, ctx)
        except (ValueError, ZeroDivisionError):
            sim = None
        if sim is not None:
            stats.explored += 1
            if best is None or sim.step_time < best[0]:
                best = (sim.step_time, rebalanced, sim)
        if best is None:
            return self.plan(topo)
        # Escalation: the local rebalance keeps dp/tp/pp frozen, which on
        # strong slowdowns leaves a documented ~11% gap to the oracle.  When
        # the best local result stays above ``straggler_escalate_gap`` x the
        # engine's last (pre-event) prediction, revisit dp/tp/pp through the
        # bounded neighborhood search and race the winner.
        baseline = self.history[-1].predicted.step_time if self.history \
            else math.inf
        path = "straggler-rebalance"
        if math.isfinite(baseline) \
                and best[0] > self.straggler_escalate_gap * baseline:
            neigh = self._neighborhood(len(topo.alive_ids()))
            if neigh:
                try:
                    res = plan_hybrid(
                        topo, self.model, global_batch=self.global_batch,
                        seq=self.seq, gpus_per_node=self.gpus_per_node,
                        with_baseline=False,
                        max_candidates=self.max_candidates, cache=self.cache,
                        points=neigh, allow_subset=False,
                        incumbent_bound=best[0], executor=self.executor,
                        lp_prune=self.lp_prune, obs=self.obs)
                    ns = res.search_stats or SearchStats()
                    stats.explored += ns.explored
                    stats.pruned += ns.pruned
                    stats.rejected += ns.rejected
                    if res.predicted.step_time < best[0]:
                        best = (res.predicted.step_time, res.plan,
                                res.predicted)
                        path = "straggler-neighborhood"
                except RuntimeError:
                    pass
        stats.cache_hits, stats.cache_misses = ctx.counters()
        stats.wall_time = time.perf_counter() - t0
        return self._finish(best[1], best[2], path, t0,
                            stats, cold=False, topo=topo, ctx=ctx,
                            refresh_portfolio=(path ==
                                               "straggler-neighborhood"))

    def _neighborhood(self, n: int) -> list[StrategyPoint]:
        """Strategy points within a factor-2 dp/tp/pp neighborhood of the
        incumbent, valid for an ``n``-device cluster."""
        inc_plan, _ = self.incumbent  # type: ignore[misc]
        m = self.model
        tps = {inc_plan.tp, inc_plan.tp * 2, max(1, inc_plan.tp // 2)}
        pps = {inc_plan.pp, inc_plan.pp + 1, max(1, inc_plan.pp - 1),
               inc_plan.pp * 2, max(1, inc_plan.pp // 2)}
        syncs = {inc_plan.grad_sync, "rs_ag", "allreduce"}
        pts: list[StrategyPoint] = []
        for tp in sorted(tps):
            if n % tp or m.n_heads % tp:
                continue
            for pp in sorted(pps):
                if (n // tp) % pp or pp > m.n_layers:
                    continue
                dp = n // (tp * pp)
                if self.global_batch % dp:
                    continue
                eps = [1]
                if m.n_experts:
                    eps = [e for e in _divisors(m.n_experts) if e <= tp]
                    if inc_plan.ep in eps:
                        eps = [inc_plan.ep]
                for ep in eps:
                    for mb in (pp, 2 * pp, 4 * pp):
                        if (self.global_batch // dp) % mb:
                            continue
                        for sync in sorted(syncs):
                            pts.append(StrategyPoint(dp, tp, pp, ep, mb,
                                                     sync))
        return pts

    def _replan_device_set(self, topo: ClusterTopology) -> ReplanResult:
        """S3: the alive set changed — cached plans reference a dead layout.
        Seed from the incumbent's strategy neighborhood; only when that is
        infeasible, run the full search with the best known score as the
        pruning bound."""
        t0 = time.perf_counter()
        ctx = self.cache.context(topo, self.model,
                                 global_batch=self.global_batch, seq=self.seq,
                                 gpus_per_node=self.gpus_per_node)
        n = len(topo.alive_ids())
        neigh = self._neighborhood(n)
        if neigh:
            try:
                res = plan_hybrid(
                    topo, self.model, global_batch=self.global_batch,
                    seq=self.seq, gpus_per_node=self.gpus_per_node,
                    with_baseline=False,
                    max_candidates=self.max_candidates, cache=self.cache,
                    points=neigh, allow_subset=False,
                    executor=self.executor, lp_prune=self.lp_prune,
                    obs=self.obs)
                stats = res.search_stats or SearchStats()
                return self._finish(res.plan, res.predicted, "neighborhood",
                                    t0, stats, cold=False, topo=topo,
                                    ctx=ctx, refresh_portfolio=True)
            except RuntimeError:
                pass
        # fall back to the full search; a surviving incumbent score bounds
        # the candidates (point_lower_bound cut inside plan_hybrid).  The
        # incumbent only participates if every device it names is still
        # alive — the simulator silently drops dead members from TP groups,
        # so scoring a stale plan would look optimistic while the plan is
        # actually unrunnable.
        alive = set(topo.alive_ids())
        inc_sim = None
        if self.incumbent is not None:
            inc_plan = self.incumbent[0]
            inc_alive = {d for st in inc_plan.stages for d in st.device_ids}
            if inc_plan.world <= len(alive) and inc_alive <= alive:
                inc_sim = self._simulate(inc_plan, topo, ctx)
        bound = inc_sim.step_time if inc_sim is not None else None
        res = plan_hybrid(topo, self.model, global_batch=self.global_batch,
                          seq=self.seq, gpus_per_node=self.gpus_per_node,
                          with_baseline=False,
                          max_candidates=self.max_candidates,
                          cache=self.cache, incumbent_bound=bound,
                          executor=self.executor, lp_prune=self.lp_prune,
                          obs=self.obs)
        stats = res.search_stats or SearchStats()
        best_plan, best_sim = res.plan, res.predicted
        if inc_sim is not None and inc_sim.step_time < best_sim.step_time:
            best_plan, best_sim = self.incumbent[0], inc_sim
        return self._finish(best_plan, best_sim, "full-replan", t0, stats,
                            cold=False, topo=topo, ctx=ctx,
                            refresh_portfolio=True)

    # -- telemetry -------------------------------------------------------------

    def describe(self) -> str:
        """One-paragraph status: plan counts, cache hit rate, and the last
        few :class:`ReplanResult` rows (path, latency, step time, work)."""
        cs = self.cache.stats
        lines = [f"ReplanEngine: {len(self.history)} plans "
                 f"({sum(1 for r in self.history if not r.cold)} warm), "
                 f"cache {cs.hits} hits / {cs.misses} misses "
                 f"({cs.hit_rate:.0%}), {cs.evictions} evictions"]
        for r in self.history[-8:]:
            lines.append(
                f"  {r.path:20s} {r.wall_time * 1e3:8.1f} ms  "
                f"step {r.predicted.step_time * 1e3:8.2f} ms  "
                f"explored {r.stats.explored:4d} pruned {r.stats.pruned:4d} "
                f"rejected {r.stats.rejected:3d}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Hierarchical re-planning (island-routed, ISSUE 6)
# ---------------------------------------------------------------------------


@dataclass
class HierarchicalReplanResult:
    """Outcome of one hierarchical plan/replan.

    ``islands_replanned`` lists the island indices whose per-island engine
    actually ran (empty when only the inter-island composition was
    refreshed, e.g. a DCI-only bandwidth event); ``island_results`` maps
    those indices to the inner :class:`ReplanResult`.  ``flat_result`` is
    set instead when the cluster was small enough for the flat engine."""

    path: str
    step_time: float
    inter_sync_s: float
    wall_time: float
    islands_replanned: tuple[int, ...] = ()
    island_results: dict = None  # type: ignore[assignment]
    flat_result: ReplanResult | None = None

    def __post_init__(self) -> None:
        if self.island_results is None:
            self.island_results = {}


class HierarchicalReplanEngine:
    """Island-routed incremental re-planner for fleet-scale clusters.

    Wraps :func:`repro.core.islands.plan_hierarchical` the way
    :class:`ReplanEngine` wraps ``plan_hybrid``: :meth:`plan` establishes
    the composed incumbent, :meth:`replan` routes each
    :class:`NetworkEvent` to the narrowest sound scope —

    ========== ================================================================
    event      re-plan scope
    ========== ================================================================
    slowdown   only the island containing the device (its per-island
               :class:`ReplanEngine` runs its warm straggler path on the
               island's subtopology), then recompose.
    bandwidth  only islands holding an *intra-island* edge matching the
               event selector; a selector touching exclusively inter-island
               fabric (e.g. ``"dci"``) replans nothing and just recomputes
               the inter-island sync bound on the updated topology.
    fail/join  full repartition + hierarchical re-plan (island membership
               may shift); sub-searches stay warm through the shared
               :class:`StrategyCache`.
    ========== ================================================================

    Small clusters / single-island partitions delegate to one inner flat
    :class:`ReplanEngine`, preserving its decision table unchanged.
    Batch shares are rebalanced only on full (re-)plans: a degraded island
    keeps its share between full plans, and the composed estimate reflects
    the hit through the max over island step times.
    """

    def __init__(self, model: ModelDesc, *, global_batch: int, seq: int,
                 cache: StrategyCache | None = None, executor=None,
                 flat_limit: int | None = None, fast_frac: float = 0.5,
                 gpus_per_node: int = 8,
                 max_candidates: int | None = None,
                 max_sims: int | None = None,
                 lp_prune: bool = True,
                 obs: Obs | None = None):
        from .islands import DEFAULT_FLAT_LIMIT
        self.model = model
        self.lp_prune = lp_prune
        self.global_batch = global_batch
        self.seq = seq
        self.obs = resolve_obs(obs)
        self.cache = cache if cache is not None \
            else StrategyCache(obs=self.obs)
        self.executor = executor
        self.flat_limit = DEFAULT_FLAT_LIMIT if flat_limit is None \
            else flat_limit
        self.fast_frac = fast_frac
        self.gpus_per_node = gpus_per_node
        self.max_candidates = max_candidates
        self.max_sims = max_sims
        # per-island warm engines, keyed by the island's device-id tuple;
        # created lazily on the first event routed to that island
        self._engines: dict[tuple[int, ...], ReplanEngine] = {}
        # island device-id tuple -> current IslandPlan (composition state)
        self._plans: dict[tuple[int, ...], object] = {}
        self._flat: ReplanEngine | None = None
        self.history: list[HierarchicalReplanResult] = []

    # -- cold path -------------------------------------------------------------

    def _flat_engine(self) -> ReplanEngine:
        if self._flat is None:
            self._flat = ReplanEngine(
                self.model, global_batch=self.global_batch, seq=self.seq,
                cache=self.cache, executor=self.executor,
                max_candidates=self.max_candidates,
                gpus_per_node=self.gpus_per_node, lp_prune=self.lp_prune,
                obs=self.obs)
        return self._flat

    def _wrap_flat(self, inner: ReplanResult) -> HierarchicalReplanResult:
        res = HierarchicalReplanResult(
            path="flat:" + inner.path, step_time=inner.predicted.step_time,
            inter_sync_s=0.0, wall_time=inner.wall_time,
            flat_result=inner)
        self.history.append(res)
        return res

    def plan(self, topo: ClusterTopology) -> HierarchicalReplanResult:
        """Full hierarchical (or flat-fallback) plan; establishes the
        composed incumbent and the island -> sub-plan state.

        Returns a :class:`HierarchicalReplanResult`; raises
        ``RuntimeError`` when no feasible plan exists (partitioned or
        undersized cluster)."""
        from .islands import partition_islands, plan_hierarchical
        t0 = time.perf_counter()
        islands = partition_islands(topo, fast_frac=self.fast_frac)
        if len(topo.alive_ids()) <= self.flat_limit or len(islands) <= 1:
            self._plans, self._engines = {}, {}
            return self._wrap_flat(self._flat_engine().plan(topo))
        hres = plan_hierarchical(
            topo, self.model, global_batch=self.global_batch, seq=self.seq,
            flat_limit=self.flat_limit, fast_frac=self.fast_frac,
            gpus_per_node=self.gpus_per_node,
            max_candidates=self.max_candidates, max_sims=self.max_sims,
            cache=self.cache, executor=self.executor,
            lp_prune=self.lp_prune, obs=self.obs)
        assert hres.composed is not None
        self._plans = {ip.island.device_ids: ip
                       for ip in hres.composed.islands}
        self._engines = {}
        res = HierarchicalReplanResult(
            path="hierarchical:cold",
            step_time=hres.composed.step_time,
            inter_sync_s=hres.composed.inter_sync_s,
            wall_time=time.perf_counter() - t0,
            islands_replanned=tuple(ip.island.index
                                    for ip in hres.composed.islands))
        self.history.append(res)
        return res

    # -- warm path -------------------------------------------------------------

    def _engine_for(self, topo: ClusterTopology, ip) -> ReplanEngine:
        """The island's warm engine, lazily seeded with the island's
        current sub-plan as incumbent (portfolio starts empty: warm paths
        always re-score the incumbent, so the seed suffices)."""
        key = ip.island.device_ids
        eng = self._engines.get(key)
        if eng is None:
            eng = ReplanEngine(
                self.model, global_batch=ip.batch, seq=self.seq,
                cache=self.cache, executor=self.executor,
                max_candidates=self.max_candidates,
                gpus_per_node=self.gpus_per_node, lp_prune=self.lp_prune,
                obs=self.obs)
            eng.incumbent = (ip.plan, ip.predicted)
            eng._device_key = self.cache.fingerprint(
                topo.subtopology(key)).device_key
            self._engines[key] = eng
        return eng

    def _intra_island_tags(self, topo: ClusterTopology
                           ) -> dict[tuple[int, ...], set[str]]:
        """Edge tags appearing on links internal to each composed island
        (one pass over the link table)."""
        member: dict[int, tuple[int, ...]] = {}
        for key in self._plans:
            for d in key:
                member[d] = key
        tags: dict[tuple[int, ...], set[str]] = {k: set()
                                                 for k in self._plans}
        for (a, b), link in topo.links.items():
            ka, kb = member.get(a), member.get(b)
            if ka is not None and ka is kb:
                tags[ka].update(e.tag for e in link.edges)
        return tags

    def _compose(self, topo: ClusterTopology) -> tuple[float, float]:
        from .islands import inter_island_sync_bound
        ids = [ip.island.device_ids for ip in self._plans.values()]
        inter = inter_island_sync_bound(topo, ids, self.model) \
            if len(ids) > 1 else 0.0
        step = max(ip.predicted.step_time
                   for ip in self._plans.values()) + inter
        return step, inter

    def replan(self, topo: ClusterTopology,
               event: NetworkEvent | None = None
               ) -> HierarchicalReplanResult:
        """Re-plan after ``event`` on the (already updated) topology,
        touching only the affected island(s) — see the class docstring's
        routing table.

        Args:
            topo: the cluster with the event ALREADY applied.
            event: the triggering event; ``None`` (or a device-set change)
                repartitions via :meth:`plan`.

        Returns:
            A :class:`HierarchicalReplanResult` with the refreshed composed
            step estimate; per-island inner results in ``island_results``.
        """
        if not self._plans:
            if self._flat is not None and self._flat.incumbent is not None:
                return self._wrap_flat(self._flat.replan(topo, event))
            return self.plan(topo)
        if event is None or event.kind in ("fail", "join"):
            return self.plan(topo)
        t0 = time.perf_counter()
        from .islands import IslandPlan
        if event.kind == "slowdown":
            targets = [ip for ip in self._plans.values()
                       if event.device_id in ip.island.device_ids]
            if not targets:
                return self.plan(topo)   # unknown device: repartition
        else:  # bandwidth
            tags = self._intra_island_tags(topo)
            targets = [ip for ip in self._plans.values()
                       if event.selector is None
                       or event.selector in tags[ip.island.device_ids]]
        results: dict[int, ReplanResult] = {}
        for ip in targets:
            eng = self._engine_for(topo, ip)
            inner = eng.replan(topo.subtopology(ip.island.device_ids),
                               event)
            results[ip.island.index] = inner
            self._plans[ip.island.device_ids] = IslandPlan(
                island=ip.island, plan=inner.plan,
                predicted=inner.predicted, batch=ip.batch, searched=True)
        step, inter = self._compose(topo)
        paths = sorted({r.path for r in results.values()}) or ["recompose"]
        res = HierarchicalReplanResult(
            path="hierarchical:" + "+".join(paths),
            step_time=step, inter_sync_s=inter,
            wall_time=time.perf_counter() - t0,
            islands_replanned=tuple(sorted(results)),
            island_results=results)
        self.history.append(res)
        return res
