"""Physically-modeled reconfiguration cost (checkpoint/reshard traffic).

Switching between two :class:`~repro.core.plans.ParallelPlan`\\ s mid-run is
not free: the runtime checkpoints the train state, tears the mesh down,
re-materializes the new plan's layout and reshards every parameter/optimizer
shard onto it (``repro.checkpoint.store.restore`` with the new sharding tree
— Oobleck's template switch).  The harness and simulator used to charge two
disagreeing made-up constants for this (2 s vs 5 s); this module prices the
switch from first principles:

  * :meth:`ReconfigCostModel.checkpoint_bytes` — the full sharded train-state
    footprint (params at the training dtype + Adam moments) that the
    checkpoint store's flattened reshard tree carries,
  * :meth:`ReconfigCostModel.reshard_traffic` — which bytes actually cross
    the fabric: per (device, layer) shard *signatures* (tp size, tp rank,
    owned layers) are compared between the old and new layouts; a device
    whose signature for a layer is unchanged keeps its shard in place, every
    other destination pulls its shard from the nearest alive old owner —
    or from the host checkpoint store when no alive peer holds it (post-S3
    failover),
  * :meth:`ReconfigCostModel.cost` — prices that traffic over the
    *post-event* topology's links (per-device serialization: a device's
    total send+receive time bounds the reshard; disjoint pairs overlap),
    plus host-store I/O and a fixed teardown/rebuild term.

The model carries a calibration hook (:meth:`calibrate_io` /
:meth:`calibrate`) fed by the runtime :class:`repro.runtime.trainer.Trainer`'s
measured checkpoint-restore path, so simulated switch charges track what the
real restore actually costs on the deployment.

:func:`plan_sequence_dp` is the cross-interval clairvoyant bound built on
top: given per-interval step times for a candidate plan set and a switch-cost
function, it chooses the plan *sequence* maximizing completed optimizer
steps — the true oracle once switches are no longer free (the per-interval
greedy oracle over-switches and over-pays).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Sequence

from .cluster import ClusterTopology
from .costmodel import transfer_time
from .opgraph import ModelDesc
from .plans import ParallelPlan, split_devices, uniform_stages

# ---------------------------------------------------------------------------
# Cost breakdown
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ReconfigCost:
    """One plan switch, decomposed.  ``total_s`` is what callers charge."""

    total_s: float
    checkpoint_bytes: float      # full train-state footprint of the new plan
    reshard_bytes: float         # bytes moved device-to-device over the fabric
    store_bytes: float           # bytes with no alive peer source (host store)
    transfer_s: float            # fabric reshard time on the given topology
    io_s: float                  # host checkpoint-store read time
    base_s: float                # fixed teardown / re-jit / rebuild term
    bottleneck_bw: float         # slowest link the reshard actually used


_ZERO = ReconfigCost(total_s=0.0, checkpoint_bytes=0.0, reshard_bytes=0.0,
                     store_bytes=0.0, transfer_s=0.0, io_s=0.0, base_s=0.0,
                     bottleneck_bw=math.inf)


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


def _plan_stages(plan: ParallelPlan, model: ModelDesc,
                 topo: ClusterTopology):
    """The plan's stages, synthesizing the default layout for plans built
    without explicit stages (templates, hand-written configs) — the same
    fallback the simulator applies."""
    if plan.stages:
        return plan.stages
    return uniform_stages(model.n_layers, plan.pp,
                          split_devices(topo, plan.dp, plan.tp, plan.pp))


class ReconfigCostModel:
    """Prices a plan switch from the model/plan sharding and the topology.

    ``opt_bytes_per_param`` covers the Adam moment pair (2x fp32); the
    optimizer shard is additionally split over DP under ZeRO-1.  ``io_bw``
    is the host checkpoint-store bandwidth used for bytes with no alive
    peer source — replace it with a measured value via :meth:`calibrate_io`.
    ``calibration`` is a global scale trimmed by :meth:`calibrate` against an
    end-to-end measured switch.
    """

    def __init__(self, model: ModelDesc, *,
                 opt_bytes_per_param: float = 8.0,
                 base_overhead_s: float = 0.25,
                 io_bw: float = 4e9,
                 calibration: float = 1.0,
                 fabric_scale: float = 1.0,
                 store_scale: float = 1.0):
        self.model = model
        self.opt_bytes_per_param = opt_bytes_per_param
        self.base_overhead_s = base_overhead_s
        self.io_bw = io_bw
        self.calibration = calibration
        # per-term scales fit by :meth:`calibrate_terms` from a few measured
        # switches: fabric covers teardown + peer-to-peer reshard, store the
        # host checkpoint-store I/O — a single global scale cannot fit both
        # when the deployment's fabric and disk drift differently.
        self.fabric_scale = fabric_scale
        self.store_scale = store_scale

    # -- checkpoint footprint --------------------------------------------------

    def bytes_per_param(self) -> float:
        return self.model.dtype_bytes + self.opt_bytes_per_param

    def checkpoint_bytes(self, plan: ParallelPlan | None = None) -> float:
        """Total train-state bytes the store's flattened tree carries.  The
        sharded layout spreads, but does not shrink, this footprint (ZeRO-1
        shards the moments across DP; every byte still exists once)."""
        del plan  # the global footprint is plan-independent
        return float(self.model.total_params()) * self.bytes_per_param()

    # -- layouts ---------------------------------------------------------------

    def _unit_bytes(self, unit: int | str) -> tuple[float, float]:
        """(param bytes, optimizer bytes) of one reshard unit — a layer, or
        the tied embedding/head matrix (``"embed"``, owned by stage 0)."""
        m = self.model
        if unit == "embed":
            params = float(m.vocab * m.d_model)
        else:
            params = float(m.layer_params(unit))
        return params * m.dtype_bytes, params * self.opt_bytes_per_param

    def _layout(self, plan: ParallelPlan, topo: ClusterTopology
                ) -> dict[int, dict[int | str, tuple]]:
        """device -> unit -> (param frac, opt frac, param sig, opt sig).

        The param signature ``(tp_size, tp_rank)`` identifies *which* slice
        of the unit the device holds — independent of which other layers
        share the stage, so a layer-rebalance only moves the layers that
        actually changed hands.  The optimizer signature additionally pins
        the ZeRO-1 partition ``(dp_size, dp_rank)``: a device that keeps its
        TP slice but lands in a different DP group holds the wrong moment
        slice and must refetch it."""
        stages = _plan_stages(plan, self.model, topo)
        dp, tp = plan.dp, plan.tp
        out: dict[int, dict[int | str, tuple]] = {}
        for si, st in enumerate(stages):
            G = st.device_ids
            if len(G) >= dp * tp:
                groups = [G[r * tp:(r + 1) * tp] for r in range(dp)]
            else:                      # degenerate stage: one shared group
                groups = [G]
            units: list[int | str] = list(st.layers)
            if si == 0:
                units.append("embed")
            for dp_rank, sub in enumerate(groups):
                width = max(1, len(sub))
                for rank, dev in enumerate(sub):
                    slot = out.setdefault(dev, {})
                    pf = 1.0 / width
                    psig = (width, rank)
                    if plan.zero1 and dp > 1:
                        of = pf / dp
                        osig = (width, rank, dp, dp_rank)
                    else:
                        of = pf
                        osig = psig
                    for u in units:
                        slot[u] = (pf, of, psig, osig)
        return out

    # -- reshard traffic -------------------------------------------------------

    @staticmethod
    def _sig_interval(sig: tuple) -> tuple[float, float]:
        """A shard signature as the [lo, hi) slice of its unit it covers.

        Param signatures ``(tp_width, tp_rank)`` slice the unit into
        ``tp_width`` contiguous equal pieces; ZeRO-1 optimizer signatures
        ``(tp_width, tp_rank, dp, dp_rank)`` subdivide that TP slice across
        the DP group.  Expressing signatures as intervals is what lets a
        nested tp reshape (width 2 -> 4, rank chosen inside the old half)
        claim its overlap instead of pricing a whole-shard pull."""
        if len(sig) == 2:
            w, r = sig
            return r / w, (r + 1) / w
        w, r, dp, dpr = sig
        width = 1.0 / (w * dp)
        lo = r / w + dpr * width
        return lo, lo + width

    @classmethod
    def _missing_fraction(cls, new_sig: tuple, old_sig: tuple) -> float:
        """Fraction of the unit the destination must fetch: its new slice
        minus the overlap with the slice it already holds."""
        nlo, nhi = cls._sig_interval(new_sig)
        olo, ohi = cls._sig_interval(old_sig)
        overlap = max(0.0, min(nhi, ohi) - max(nlo, olo))
        return (nhi - nlo) - overlap

    def reshard_traffic(self, old: ParallelPlan, new: ParallelPlan,
                        topo: ClusterTopology
                        ) -> tuple[dict[tuple[int, int], float], float]:
        """(pair -> bytes moved peer-to-peer, bytes served by the host store).

        Destinations are the new layout's owners; sources are *alive* old
        owners of the same unit (nearest by transfer time, deterministic
        tie-break by device id).  Identical shard signatures move nothing —
        two structurally identical plans therefore cost zero — and a
        destination whose old slice *partially overlaps* its new one (a
        nested tp reshape) pulls only the missing slice remainder.

        A stage-less old plan whose default layout no longer fits the
        (post-failure) topology has no peer sources at all: everything the
        new layout needs comes from the host checkpoint store.  A new plan
        whose layout cannot be synthesized is priced as a full store
        restore."""
        if old.structural_key() == new.structural_key():
            return {}, 0.0
        try:
            old_map = self._layout(old, topo)
        except ValueError:
            old_map = {}
        try:
            new_map = self._layout(new, topo)
        except ValueError:
            return {}, self.checkpoint_bytes(new)
        alive = set(topo.alive_ids())
        # fetched once: the nearest-owner loop prices O(units x sources)
        # pairs, too hot for routing()'s per-call signature re-check
        table = topo.routing()
        # unit -> alive old owners (for source selection)
        owners: dict[int | str, list[int]] = {}
        for dev, units in old_map.items():
            if dev in alive:
                for u in units:
                    owners.setdefault(u, []).append(dev)
        pair_bytes: dict[tuple[int, int], float] = {}
        store_bytes = 0.0
        for dev in sorted(new_map):
            held = old_map.get(dev, {})
            for u, (pf, of, psig, osig) in sorted(new_map[dev].items(),
                                                  key=str):
                pb, ob = self._unit_bytes(u)
                old_entry = held.get(u)
                need = 0.0
                if old_entry is None:
                    need = pf * pb + of * ob
                else:
                    # slice-overlap credit: only the part of the new shard
                    # the device does not already hold crosses the fabric
                    if old_entry[2] != psig:
                        need += self._missing_fraction(psig,
                                                       old_entry[2]) * pb
                    if old_entry[3] != osig:
                        need += self._missing_fraction(osig,
                                                       old_entry[3]) * ob
                if need <= 0.0:
                    continue
                srcs = [s for s in owners.get(u, ()) if s != dev]
                # nearest alive owner by (routed) transfer time; owners the
                # fabric cannot reach (partitioned post-event topology) are
                # no sources at all — those bytes come from the host store
                timed = sorted((transfer_time(topo, s, dev, need,
                                              routing=table), s)
                               for s in srcs)
                if not timed or not math.isfinite(timed[0][0]):
                    store_bytes += need
                    continue
                src = timed[0][1]
                pair_bytes[(src, dev)] = pair_bytes.get((src, dev), 0.0) + need
        return pair_bytes, store_bytes

    # -- pricing ---------------------------------------------------------------

    @staticmethod
    def _path_time(topo: ClusterTopology, a: int, b: int, size: float,
                   *, routing=None) -> tuple[float, float]:
        """(seconds, bandwidth) for one transfer — thin delegate to the
        default fabric's :meth:`repro.core.fabric.FabricModel.path_time`.
        Pairs without a live direct link are priced on their widest
        multi-hop route with chunked cut-through pipelining; unreachable
        pairs return ``(inf, 0.0)`` and callers fall back to the host
        store."""
        from .fabric import default_fabric
        return default_fabric().path_time(topo, a, b, size, routing=routing)

    @staticmethod
    def _pair_links(topo: ClusterTopology, a: int, b: int,
                    table) -> list[tuple[tuple[int, int], float]]:
        """The physical ``(min, max)`` link keys (with per-hop bandwidth) a
        reshard pair actually rides: the live direct link when one exists,
        otherwise every hop of the widest route — the same per-edge
        serialization domains :func:`repro.core.simulator.simulate_schedule`
        claims for relayed transfers.  Unreachable pairs ride nothing (they
        are store-served)."""
        direct = table.hop_price(a, b)
        if direct is not None:
            return [((min(a, b), max(a, b)), direct[0])]
        route = table.route(a, b)
        if route is None:
            return []
        out = []
        for u, v in zip(route.path, route.path[1:]):
            hop = table.hop_price(u, v)
            if hop is not None:
                out.append(((min(u, v), max(u, v)), hop[0]))
        return out

    def edge_traffic(self, old: ParallelPlan, new: ParallelPlan,
                     topo: ClusterTopology) -> dict[tuple[int, int], float]:
        """Route-expanded reshard traffic of the switch: physical link key
        ``(min, max)`` -> bytes this switch pushes over that link (a relayed
        pair charges every hop).  This is what one job's reshard looks like
        *to another job sharing the fabric* — the load board concurrent
        switches are priced against (see :meth:`cost`'s ``edge_load``)."""
        pair_bytes, _ = self.reshard_traffic(old, new, topo)
        if not pair_bytes:
            return {}
        table = topo.routing()
        load: dict[tuple[int, int], float] = {}
        for (src, dst), nbytes in sorted(pair_bytes.items()):
            for key, _bw in self._pair_links(topo, src, dst, table):
                load[key] = load.get(key, 0.0) + nbytes
        return load

    def cost(self, old: ParallelPlan, new: ParallelPlan,
             topo: ClusterTopology, *,
             edge_load: dict[tuple[int, int], float] | None = None
             ) -> ReconfigCost:
        """Price switching ``old -> new`` on (post-event) ``topo``.

        ``edge_load`` maps physical link keys ``(min, max)`` to *other*
        jobs' in-flight bytes on that link (their :meth:`edge_traffic`).
        Each reshard pair then queues behind the foreign bytes on its most
        contended hop — ``extra / (beta * hop_bw)`` added to the solo fabric
        price, exactly the simulator's serialize-behind-the-edge semantics.
        Without it the model prices every switch as if the job owned the
        fabric, silently optimistic whenever two jobs reshard at once."""
        if old.structural_key() == new.structural_key():
            return _ZERO
        pair_bytes, store_bytes = self.reshard_traffic(old, new, topo)
        per_dev: dict[int, float] = {}
        bottleneck = math.inf
        table = topo.routing() if pair_bytes else None
        beta = 1.0
        if edge_load and pair_bytes:
            from .fabric import default_fabric
            beta = max(default_fabric().beta, 1e-12)
        for (src, dst), nbytes in sorted(pair_bytes.items()):
            t, bw = self._path_time(topo, src, dst, nbytes, routing=table)
            if edge_load:
                queue = 0.0
                for key, hop_bw in self._pair_links(topo, src, dst, table):
                    extra = edge_load.get(key, 0.0)
                    if extra > 0 and hop_bw > 0:
                        queue = max(queue, extra / (beta * hop_bw))
                t += queue
            per_dev[src] = per_dev.get(src, 0.0) + t
            per_dev[dst] = per_dev.get(dst, 0.0) + t
            bottleneck = min(bottleneck, bw)
        transfer_s = max(per_dev.values(), default=0.0)
        io_s = store_bytes / self.io_bw if self.io_bw > 0 else 0.0
        total = self.calibration * (
            self.fabric_scale * (self.base_overhead_s + transfer_s)
            + self.store_scale * io_s)
        return ReconfigCost(
            total_s=total,
            checkpoint_bytes=self.checkpoint_bytes(new),
            reshard_bytes=sum(pair_bytes.values()),
            store_bytes=store_bytes, transfer_s=transfer_s, io_s=io_s,
            base_s=self.base_overhead_s, bottleneck_bw=bottleneck)

    def switch_seconds(self, old: ParallelPlan, new: ParallelPlan,
                       topo: ClusterTopology) -> float:
        return self.cost(old, new, topo).total_s

    def concurrent_costs(self, switches: Sequence[
            tuple[ParallelPlan, ParallelPlan, ClusterTopology]]
            ) -> list[ReconfigCost]:
        """Price several switches happening *at once* on a shared fabric.

        Each switch is charged its own :meth:`cost` with ``edge_load`` set
        to the sum of every *other* switch's :meth:`edge_traffic` — the
        symmetric fixed-point of "everyone queues behind everyone else's
        bytes".  Switches whose reshards ride disjoint links price exactly
        their solo cost; switches colliding on a link each pay the queueing
        term.  Deterministic in the input order (the pricing itself is
        order-independent).  ``topo`` may differ per switch (per-job device
        slices) — link keys are global device-id pairs, so traffic charged
        by one slice is visible to any other slice sharing the link."""
        traffics = [self.edge_traffic(old, new, topo)
                    for old, new, topo in switches]
        out: list[ReconfigCost] = []
        for i, (old, new, topo) in enumerate(switches):
            load: dict[tuple[int, int], float] = {}
            for j, tr in enumerate(traffics):
                if j == i:
                    continue
                for key, v in tr.items():
                    load[key] = load.get(key, 0.0) + v
            out.append(self.cost(old, new, topo, edge_load=load))
        return out

    # -- calibration hooks -----------------------------------------------------

    def calibrate_io(self, measured_s: float, nbytes: float) -> float:
        """Fold a measured checkpoint-restore (``nbytes`` restored in
        ``measured_s`` seconds) into the host-store bandwidth.  Returns the
        new ``io_bw``.  The runtime trainer calls this after every elastic
        restore, so simulated post-failover charges track the deployment."""
        if measured_s > 0 and nbytes > 0:
            self.io_bw = nbytes / measured_s
        return self.io_bw

    def calibrate(self, measured_total_s: float, old: ParallelPlan,
                  new: ParallelPlan, topo: ClusterTopology) -> float:
        """Scale the whole model so its prediction for an observed switch
        matches the end-to-end measurement.  Returns the new scale.  Prefer
        :meth:`calibrate_terms` when several measured switches are
        available — a single global scale cannot fit fabric-dominated and
        store-dominated switches at once."""
        predicted = self.cost(old, new, topo).total_s
        if predicted > 0 and measured_total_s > 0:
            self.calibration *= measured_total_s / predicted
        return self.calibration

    def calibrate_terms(self, measurements: Sequence[
            tuple[float, ParallelPlan, ParallelPlan, ClusterTopology]]
            ) -> tuple[float, float]:
        """Fit the fabric and host-store scales separately from measured
        switches (``(measured_s, old, new, topo)`` tuples) by least squares
        on ``measured = a * (base + transfer) + b * io``.

        With switches that exercise both the fabric and the store, the 2x2
        normal equations solve both scales; when every measurement is
        fabric-only (or store-only) the other scale is left untouched
        instead of extrapolating from zero signal.  Scales are clamped
        positive.  Returns ``(fabric_scale, store_scale)``.
        """
        rows: list[tuple[float, float, float]] = []
        for measured, old, new, topo in measurements:
            if measured <= 0:
                continue
            c = self.cost(old, new, topo)
            # un-scaled per-term predictions (ReconfigCost components carry
            # the raw physical terms; only total_s is scaled)
            rows.append((c.base_s + c.transfer_s, c.io_s,
                         measured / max(self.calibration, 1e-12)))
        if not rows:
            return self.fabric_scale, self.store_scale
        sff = sum(f * f for f, _, _ in rows)
        sss = sum(s * s for _, s, _ in rows)
        sfs = sum(f * s for f, s, _ in rows)
        sfm = sum(f * m for f, _, m in rows)
        ssm = sum(s * m for _, s, m in rows)
        det = sff * sss - sfs * sfs
        if det > 1e-18 * max(sff, 1.0) * max(sss, 1.0):
            fabric = (sfm * sss - ssm * sfs) / det
            store = (ssm * sff - sfm * sfs) / det
            self.fabric_scale = max(fabric, 1e-6)
            self.store_scale = max(store, 1e-6)
        elif sff > 0 and sss == 0:          # no store signal: fit fabric only
            self.fabric_scale = max(sfm / sff, 1e-6)
        elif sss > 0 and sff == 0:          # no fabric signal: fit store only
            self.store_scale = max(ssm / sss, 1e-6)
        elif sff > 0:
            # collinear terms: fall back to scaling the dominant fabric term
            self.fabric_scale = max(sfm / sff, 1e-6)
        return self.fabric_scale, self.store_scale


# ---------------------------------------------------------------------------
# Cross-interval DP oracle
# ---------------------------------------------------------------------------


def plan_sequence_dp(durations: Sequence[float],
                     step_times: Sequence[Sequence[float]],
                     switch_cost: Callable[[int, int, int], float]
                     ) -> tuple[float, list[int]]:
    """Clairvoyant plan schedule over consecutive intervals, switch costs
    included — the true oracle bound the per-interval greedy replay is not.

    ``durations[i]`` is interval *i*'s length in seconds; ``step_times[i][c]``
    the simulated step time of candidate plan *c* during interval *i*
    (``inf`` = infeasible); ``switch_cost(i, prev, cur)`` the seconds charged
    at interval *i*'s start for arriving on plan ``cur`` from ``prev``
    (called only when ``prev != cur``).  The initial plan is free — the
    clairvoyant picks its starting layout before training begins.

    Returns ``(steps, choices)`` maximizing total completed optimizer steps
    ``sum_i max(0, d_i - oh_i) / s_i``.  O(intervals * candidates^2).
    """
    B = len(durations)
    if B == 0 or not step_times or not step_times[0]:
        return 0.0, []
    C = len(step_times[0])

    def gain(d: float, oh: float, s: float) -> float:
        if not math.isfinite(s) or s <= 0:
            return 0.0
        return max(0.0, d - oh) / s

    best = [[-math.inf] * C for _ in range(B)]
    back = [[0] * C for _ in range(B)]
    for c in range(C):
        best[0][c] = gain(durations[0], 0.0, step_times[0][c])
    for i in range(1, B):
        for c in range(C):
            for q in range(C):
                if best[i - 1][q] == -math.inf:
                    continue
                oh = 0.0 if q == c else switch_cost(i, q, c)
                val = best[i - 1][q] + gain(durations[i], oh,
                                            step_times[i][c])
                if val > best[i][c]:
                    best[i][c] = val
                    back[i][c] = q
    end = max(range(C), key=lambda c: best[B - 1][c])
    choices = [end]
    for i in range(B - 1, 0, -1):
        choices.append(back[i][choices[-1]])
    choices.reverse()
    return best[B - 1][end], choices
