"""Composable LM covering all 10 assigned architectures.

One :class:`LM` consumes an :class:`repro.models.config.ArchConfig` and
provides ``init / forward / loss / prefill / decode_step / init_cache``.
Layers are stacked per *pattern position* and executed with ``lax.scan`` over
cycles (compile-time O(1) in depth — essential for the 88-layer dry-runs).

Block kinds (config.block_pattern):
  attn        — self-attention + FFN (or MoE when cfg.n_experts)
  cross_attn  — self-attention + cross-attention to a memory + FFN
                (whisper decoder, llama-3.2-vision image layers)
  mamba       — Mamba2 SSD block
  mlstm/slstm — xLSTM blocks
  shared_attn — zamba2-style shared transformer block (one weight set reused
                at every occurrence, per-occurrence input adapter)
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ArchConfig
from repro.parallel.axes import shard

Pytree = Any


def _tree_index(tree: Pytree, i) -> Pytree:
    return jax.tree.map(lambda x: x[i], tree)


@dataclass(frozen=True)
class LM:
    cfg: ArchConfig
    # Unroll the layer stack into straight-line HLO instead of lax.scan.
    # Used by the dry-run cost probes: XLA's cost_analysis reports ZERO
    # flops for while-loop bodies, so probes lower 1-2 unrolled cycles.
    unroll: bool = False

    def _scan(self, body, init, xs):
        if not self.unroll:
            return lax.scan(body, init, xs)
        carry = init
        ys = []
        n = jax.tree.leaves(xs)[0].shape[0]
        for c in range(n):
            carry, y = body(carry, _tree_index(xs, c))
            ys.append(y)
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys) if ys and \
            jax.tree.leaves(ys[0]) else ys[0] if ys else ()
        return carry, stacked

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------

    def block_defs(self, kind: str) -> dict:
        cfg = self.cfg
        if kind == "attn":
            d = {"attn": L.attn_defs(cfg)}
            d["moe" if cfg.n_experts else "ffn"] = \
                L.moe_defs(cfg) if cfg.n_experts else L.ffn_defs(cfg)
            return d
        if kind == "cross_attn":
            return {"attn": L.attn_defs(cfg),
                    "cross": L.cross_attn_defs(cfg),
                    "ffn": L.ffn_defs(cfg)}
        if kind == "mamba":
            return {"mamba": L.mamba_defs(cfg)}
        if kind == "mlstm":
            return {"mlstm": L.mlstm_defs(cfg)}
        if kind == "slstm":
            return {"slstm": L.slstm_defs(cfg)}
        if kind == "shared_attn":
            return {"in_proj": L.ParamDef(
                (cfg.d_model, cfg.d_model), ("fsdp", "embed"), scale=0.02)}
        raise ValueError(f"unknown block kind {kind}")

    def param_defs(self) -> dict:
        cfg = self.cfg
        defs: dict = {
            "embed": L.embed_defs(cfg),
            "final_norm": L.ParamDef((cfg.d_model,), ("embed",), init="ones"),
        }
        for p, kind in enumerate(cfg.pattern):
            defs[f"pos{p}"] = L.stack_defs(self.block_defs(kind),
                                           cfg.n_cycles)
        if "shared_attn" in cfg.pattern:
            defs["shared"] = {"attn": L.attn_defs(cfg),
                              "ffn": L.ffn_defs(cfg)}
        if cfg.encoder_layers:
            defs["encoder"] = L.stack_defs(
                {"attn": L.attn_defs(cfg), "ffn": L.ffn_defs(cfg)},
                cfg.encoder_layers)
            defs["enc_norm"] = L.ParamDef((cfg.d_model,), ("embed",),
                                          init="ones")
        return defs

    def init(self, key: jax.Array) -> Pytree:
        return L.materialize(self.param_defs(), key, self.cfg.jnp_dtype)

    def abstract_params(self) -> Pytree:
        return L.abstract(self.param_defs(), self.cfg.jnp_dtype)

    def param_axes(self) -> Pytree:
        return L.logical_tree(self.param_defs())

    def n_params(self) -> int:
        return sum(math.prod(d.shape) for d in jax.tree.leaves(
            self.param_defs(), is_leaf=lambda x: isinstance(x, L.ParamDef)))

    # ------------------------------------------------------------------
    # Encoder / memory (whisper audio stub, vision stub)
    # ------------------------------------------------------------------

    def encode(self, params: Pytree, audio_embed: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = shard(audio_embed, "batch", "seq", "embed")
        pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

        def body(h, lp):
            h = L.attn_block(lp["attn"], cfg, h, pos, causal=False,
                             unroll=self.unroll)
            h = L.ffn_block(lp["ffn"], cfg, h)
            return h, ()

        x, _ = self._scan(body, x, params["encoder"])
        return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _memory(self, params, audio_embed, vision_embed):
        if self.cfg.encoder_layers:
            assert audio_embed is not None, "whisper needs audio_embed"
            return self.encode(params, audio_embed)
        if self.cfg.cross_attn_every:
            assert vision_embed is not None, "VLM needs vision_embed"
            return shard(vision_embed, "batch", "seq", "embed")
        return None

    # ------------------------------------------------------------------
    # Forward (training / prefill)
    # ------------------------------------------------------------------

    def forward(self, params: Pytree, tokens: jax.Array, *,
                audio_embed: jax.Array | None = None,
                vision_embed: jax.Array | None = None,
                remat: str = "none",
                return_cache: bool = False):
        """Full-sequence forward.  Returns final hidden (B,S,d), and the
        decode cache when ``return_cache`` (prefill path)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = L.embed(params["embed"], cfg, tokens)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        memory = self._memory(params, audio_embed, vision_embed)
        shared = params.get("shared")

        def cycle(x, cyc_params):
            cache_out = []
            for p, kind in enumerate(cfg.pattern):
                bp = cyc_params[f"pos{p}"]
                if kind in ("attn", "cross_attn"):
                    h = L.rms_norm(x, bp["attn"]["ln"], cfg.norm_eps)
                    q, k, v = L._qkv(bp["attn"], cfg, h, positions)
                    o = L.mha(q, k, v, causal=cfg.causal,
                              q_chunk=cfg.attn_q_chunk, unroll=self.unroll)
                    o = jnp.einsum("bshk,hkd->bsd", o, bp["attn"]["wo"])
                    x = x + shard(o, "batch", "seq", "embed")
                    if return_cache:
                        cache_out.append({"k": k, "v": v})
                    if kind == "cross_attn":
                        x = L.cross_attn_block(bp["cross"], cfg, x, memory,
                                               unroll=self.unroll)
                    x = (L.moe_block(bp["moe"], cfg, x) if cfg.n_experts
                         else L.ffn_block(bp["ffn"] if "ffn" in bp else
                                          bp["moe"], cfg, x))
                elif kind == "shared_attn":
                    h = jnp.einsum("bsd,de->bse", x, bp["in_proj"])
                    hn = L.rms_norm(h, shared["attn"]["ln"], cfg.norm_eps)
                    q, k, v = L._qkv(shared["attn"], cfg, hn, positions)
                    o = L.mha(q, k, v, causal=True, window=cfg.attn_window,
                              q_chunk=cfg.attn_q_chunk, unroll=self.unroll)
                    o = jnp.einsum("bshk,hkd->bsd", o, shared["attn"]["wo"])
                    h = h + o
                    h = L.ffn_block(shared["ffn"], cfg, h)
                    x = x + h
                    if return_cache:
                        # ring-buffer layout: last W tokens at slots pos % W
                        W = cfg.attn_window or S
                        kc, vc = (t[:, -W:] if S >= W else
                                  jnp.pad(t, ((0, 0), (0, W - S),
                                              (0, 0), (0, 0)))
                                  for t in (k, v))
                        cache_out.append({"k": kc, "v": vc})
                elif kind == "mamba":
                    x, st, conv = L.mamba_block(bp["mamba"], cfg, x,
                                                return_state=True,
                                                unroll=self.unroll)
                    if return_cache:
                        cache_out.append({"ssm": st, "conv": conv})
                elif kind == "mlstm":
                    x, st = L.mlstm_block(bp["mlstm"], cfg, x,
                                          return_state=True,
                                          unroll=self.unroll)
                    if return_cache:
                        cache_out.append({"state": st})
                elif kind == "slstm":
                    x, st = L.slstm_block(bp["slstm"], cfg, x,
                                          return_state=True)
                    if return_cache:
                        cache_out.append({"state": st})
            return x, tuple(cache_out)

        body = cycle
        if remat == "full":
            body = jax.checkpoint(cycle,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        elif remat == "selective":
            body = jax.checkpoint(
                cycle, policy=jax.checkpoint_policies
                .dots_with_no_batch_dims_saveable)

        stacks = {f"pos{p}": params[f"pos{p}"]
                  for p in range(len(cfg.pattern))}
        x, caches = self._scan(body, x, stacks)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        if return_cache:
            return x, caches
        return x

    # ------------------------------------------------------------------
    # Losses / serving entry points
    # ------------------------------------------------------------------

    def loss(self, params: Pytree, tokens: jax.Array, labels: jax.Array,
             *, remat: str = "none", **mods) -> jax.Array:
        x = self.forward(params, tokens, remat=remat, **mods)
        return L.xent_loss(x, params["embed"]["tok"], labels, self.cfg)

    def prefill(self, params: Pytree, tokens: jax.Array, **mods):
        """Serving prefill: returns (last-token logits, decode cache)."""
        x, cache = self.forward(params, tokens, return_cache=True, **mods)
        last = x[:, -1:]
        logits = L.logits_chunked(last, params["embed"]["tok"], self.cfg)
        return logits[:, 0], cache

    # -- decode ---------------------------------------------------------
    #
    # The decode cache is a FLAT tuple with one entry per layer (not stacked
    # per pattern position): each entry is an independent buffer, so XLA
    # aliases the donated input cache in place — no double-buffering through
    # a scan's ys.  decode_step unrolls the (cheap per-layer) decode HLO.

    def _cache_entry(self, kind: str, batch: int, max_len: int, mk):
        cfg = self.cfg
        dt = cfg.jnp_dtype
        e = cfg.ssm_expand * cfg.d_model
        nh = e // cfg.ssm_head_dim
        H = cfg.n_heads
        if kind in ("attn", "cross_attn"):
            kvs = (batch, max_len, cfg.n_kv_heads, cfg.hd)
            return {"k": mk(kvs, dt), "v": mk(kvs, dt)}
        if kind == "shared_attn":
            W = min(cfg.attn_window or max_len, max_len)
            kvs = (batch, W, cfg.n_kv_heads, cfg.hd)
            return {"k": mk(kvs, dt), "v": mk(kvs, dt)}
        if kind == "mamba":
            return {"ssm": mk((batch, nh, cfg.ssm_head_dim, cfg.ssm_state),
                              jnp.float32),
                    "conv": mk((batch, cfg.ssm_conv_width - 1, e), dt)}
        if kind == "mlstm":
            hde = 2 * cfg.d_model // H
            return {"state": (mk((batch, H, hde, hde), jnp.float32),
                              mk((batch, H, hde), jnp.float32),
                              mk((batch, H), jnp.float32, -1e30))}
        if kind == "slstm":
            hds = cfg.d_model // H
            return {"state": (mk((batch, H, hds), jnp.float32),
                              mk((batch, H, hds), jnp.float32),
                              mk((batch, H, hds), dt),
                              mk((batch, H), jnp.float32, -1e30))}
        raise ValueError(kind)

    def init_cache(self, batch: int, max_len: int, *,
                   abstract: bool = False) -> Pytree:
        """Zeroed (or abstract) flat per-layer decode cache.  The xLSTM
        max-stabilizer states start at -1e30 (matching the blocks' internal
        init), everything else at zero."""
        mk = (lambda s, d, fill=0.0: jax.ShapeDtypeStruct(s, d)) if abstract \
            else (lambda s, d, fill=0.0: jnp.full(s, fill, d))
        return tuple(self._cache_entry(self.cfg.block_kind(i), batch,
                                       max_len, mk)
                     for i in range(self.cfg.n_layers))

    def cache_axes(self) -> Pytree:
        """Logical-axis tree matching :meth:`init_cache` (for sharding)."""
        cfg = self.cfg
        kv = ("batch", "kv_seq", "kv_heads", "head_dim")

        def entry(kind):
            if kind in ("attn", "cross_attn", "shared_attn"):
                return {"k": kv, "v": kv}
            if kind == "mamba":
                return {"ssm": ("batch", "heads", "head_dim", "state"),
                        "conv": ("batch", "conv", "mlp")}
            if kind == "mlstm":
                return {"state": (("batch", "heads", "head_dim", "head_dim"),
                                  ("batch", "heads", "head_dim"),
                                  ("batch", "heads"))}
            if kind == "slstm":
                h3 = ("batch", "heads", "head_dim")
                return {"state": (h3, h3, h3, ("batch", "heads"))}
            raise ValueError(kind)

        return tuple(entry(cfg.block_kind(i)) for i in range(cfg.n_layers))

    def stacked_cache_axes(self):
        """Logical axes for the PREFILL cache (stacked per pattern position,
        leading n_cycles dim) — used to pin prefill out_shardings."""
        cfg = self.cfg
        kv = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")

        def entry(kind):
            if kind in ("attn", "cross_attn", "shared_attn"):
                return {"k": kv, "v": kv}
            if kind == "mamba":
                return {"ssm": ("layers", "batch", "heads", "head_dim",
                                "state"),
                        "conv": ("layers", "batch", "conv", "mlp")}
            if kind == "mlstm":
                return {"state": (("layers", "batch", "heads", "head_dim",
                                   "head_dim"),
                                  ("layers", "batch", "heads", "head_dim"),
                                  ("layers", "batch", "heads"))}
            if kind == "slstm":
                h3 = ("layers", "batch", "heads", "head_dim")
                return {"state": (h3, h3, h3, ("layers", "batch", "heads"))}
            raise ValueError(kind)

        return tuple(entry(kind) for kind in cfg.pattern)

    def unstack_cache(self, stacked: Pytree) -> Pytree:
        """Convert a prefill cache (stacked per pattern position, the scan's
        ys layout) into the flat per-layer decode layout."""
        cfg = self.cfg
        flat = []
        for i in range(cfg.n_layers):
            c, p = divmod(i, cfg.cycle_len)
            flat.append(_tree_index(stacked[p], c))
        return tuple(flat)

    def decode_step(self, params: Pytree, cache: Pytree, tokens: jax.Array,
                    pos: jax.Array, *,
                    audio_embed: jax.Array | None = None,
                    vision_embed: jax.Array | None = None):
        """One decode step: tokens (B,1), pos (B,).  Returns (logits, cache).

        ``cache`` is the flat per-layer tuple; pass it donated so every
        layer's k/v/state updates alias in place.
        """
        cfg = self.cfg
        x = L.embed(params["embed"], cfg, tokens)
        memory = self._memory(params, audio_embed, vision_embed)
        shared = params.get("shared")
        new_cache: list = []
        for i in range(cfg.n_layers):
            c, p = divmod(i, cfg.cycle_len)
            kind = cfg.block_kind(i)
            bp = _tree_index(params[f"pos{p}"], c)
            cc = cache[i]
            if kind in ("attn", "cross_attn"):
                x, nk, nv = L.attn_decode(bp["attn"], cfg, x,
                                          cc["k"], cc["v"], pos)
                new_cache.append({"k": nk, "v": nv})
                if kind == "cross_attn":
                    x = L.cross_attn_block(bp["cross"], cfg, x, memory)
                x = (L.moe_block(bp["moe"], cfg, x) if cfg.n_experts
                     else L.ffn_block(bp["ffn"], cfg, x))
            elif kind == "shared_attn":
                h = jnp.einsum("bsd,de->bse", x, bp["in_proj"])
                h, nk, nv = L.attn_decode(shared["attn"], cfg, h,
                                          cc["k"], cc["v"], pos,
                                          window=cfg.attn_window)
                new_cache.append({"k": nk, "v": nv})
                h = L.ffn_block(shared["ffn"], cfg, h)
                x = x + h
            elif kind == "mamba":
                x, st, conv = L.mamba_block(
                    bp["mamba"], cfg, x, state=cc["ssm"],
                    conv_state=cc["conv"], return_state=True)
                new_cache.append({"ssm": st, "conv": conv})
            elif kind == "mlstm":
                x, st = L.mlstm_block(bp["mlstm"], cfg, x,
                                      state=cc["state"], return_state=True)
                new_cache.append({"state": st})
            elif kind == "slstm":
                x, st = L.slstm_block(bp["slstm"], cfg, x,
                                      state=cc["state"], return_state=True)
                new_cache.append({"state": st})
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = L.logits_chunked(x, params["embed"]["tok"], cfg)
        return logits[:, 0], tuple(new_cache)
