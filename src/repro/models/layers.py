"""Shared layer primitives for all 10 assigned architectures (pure JAX).

Every parameter is declared as a :class:`ParamDef` carrying its shape and
*logical* sharding axes; ``materialize``/``logical_tree`` turn a def-tree into
an initialized pytree and its axis-annotation tree.  Activations are
annotated through :func:`repro.parallel.axes.shard` so the same model code
runs unsharded on CPU (smoke tests) and GSPMD-sharded on the production mesh
(dry-run) without modification.

Attention is implemented memory-efficiently (query-chunked online softmax —
the jnp analogue of the Pallas flash kernel in ``repro.kernels``) so the
32k-prefill cells lower without materializing S×S score matrices.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.axes import shard

Axes = tuple[str | None, ...]


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: Axes
    scale: float | None = None       # None => 1/sqrt(fan_in) (first dim)
    init: str = "normal"             # normal | zeros | ones


def materialize(defs, key: jax.Array, dtype) -> Any:
    """Initialize a def-tree into a parameter pytree (deterministic)."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    out = []
    for i, d in enumerate(leaves):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dtype))
        else:
            k = jax.random.fold_in(key, i)
            scale = d.scale if d.scale is not None else \
                1.0 / math.sqrt(max(d.shape[0], 1))
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * scale).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract(defs, dtype) -> Any:
    """ShapeDtypeStruct tree (for dry-run lowering, no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def logical_tree(defs) -> Any:
    return jax.tree.map(lambda d: d.axes, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def stack_defs(defs, n: int) -> Any:
    """Prefix every def with a stacked layer dim (for lax.scan over layers)."""
    return jax.tree.map(
        lambda d: ParamDef((n, *d.shape), ("layers", *d.axes), d.scale, d.init),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Norms / rotary / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
             *, gemma_style: bool = False) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    w = w.astype(jnp.float32)
    y = y * (1.0 + w) if gemma_style else y * w
    return y.astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]   # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _act(kind: str, x: jax.Array) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(x)
    if kind == "geglu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# Attention (GQA, rope, qk-norm, optional window) — chunked online softmax
# ---------------------------------------------------------------------------


def attn_defs(cfg) -> dict:
    H, KV, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    defs = {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "wq": ParamDef((d, H, hd), ("fsdp", "heads", "head_dim")),
        "wk": ParamDef((d, KV, hd), ("fsdp", "kv_heads", "head_dim")),
        "wv": ParamDef((d, KV, hd), ("fsdp", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "fsdp")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H, hd), ("heads", "head_dim"), init="zeros")
        defs["bk"] = ParamDef((KV, hd), ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = ParamDef((KV, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), ("head_dim",), init="ones")
        defs["k_norm"] = ParamDef((hd,), ("head_dim",), init="ones")
    return defs


def _qkv(p, cfg, x, positions):
    """Project + rope.  Returns q:(B,S,KV,G,hd) grouped, k,v:(B,S,KV,hd)."""
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq", "kv_heads", "head_dim")
    G = H // KV
    q = q.reshape(*q.shape[:2], KV, G, hd)
    return q, k, v


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
        q_positions: jax.Array | None = None,
        kv_positions: jax.Array | None = None,
        window: int = 0, q_chunk: int = 1024,
        softcap: float = 0.0, unroll: bool = False) -> jax.Array:
    """Grouped-query attention, chunked over queries (bounded memory).

    q: (B, Sq, KV, G, hd);  k, v: (B, Skv, KV, hd).  Returns (B, Sq, KV*G, hd).
    Masks: causal by position, optional sliding ``window``.
    """
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    if q_positions is None:
        q_positions = jnp.arange(Sq)[None, :] + (Skv - Sq)
        q_positions = jnp.broadcast_to(q_positions, (B, Sq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(Skv)[None, :], (B, Skv))

    # GQA via explicit KV repeat to full head width: the repeated k/v are
    # transient and shard cleanly over "heads" (H = KV*G divides the model
    # axis for 9/10 archs), whereas a grouped (KV, G) einsum loses the head
    # sharding through the reshape and GSPMD replicates the score tensor
    # (measured 42 GB temp on granite MQA prefill).
    q = q.reshape(B, Sq, KV * G, hd)
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq", "heads", "head_dim")
    v = shard(v, "batch", "seq", "heads", "head_dim")

    qc = min(q_chunk, Sq)
    while Sq % qc:
        qc -= 1
    n_chunks = Sq // qc
    # Causal self-attention with KV slicing per chunk skips fully-masked
    # blocks (the flash-kernel behaviour; halves attention FLOPs).  The
    # python-unrolled form is used by the cost probes (XLA counts it) and
    # matches the Pallas kernel's compute; the runtime jnp fallback uses a
    # sequential lax.map over chunks (ONE score block live — the unrolled
    # chunks otherwise peak at the full S^2/2 matrix; measured 30 GB on
    # granite prefill) at the cost of computing masked blocks.
    causal_slice = causal and Sq == Skv and n_chunks > 1 and unroll

    def one_chunk(i, k=k, v=v, kvp=kv_positions):
        qs = lax.dynamic_slice_in_dim(q, i * qc, qc, axis=1)
        qp = lax.dynamic_slice_in_dim(q_positions, i * qc, qc, axis=1)
        s = jnp.einsum("bqhk,bshk->bhqs", qs, k).astype(jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = qp[:, :, None] >= kvp[:, None, :] if causal else \
            jnp.ones((B, qc, k.shape[1]), bool)
        if window:
            mask &= qp[:, :, None] - kvp[:, None, :] < window
        s = jnp.where(mask[:, None], s, -1e30)
        o = jnp.einsum("bhqs,bshk->bqhk",
                       jax.nn.softmax(s, axis=-1).astype(q.dtype), v)
        return o

    if n_chunks == 1:
        out = one_chunk(0)
    elif causal_slice:
        outs = []
        for i in range(n_chunks):
            hi = (i + 1) * qc
            lo = 0
            if window:
                lo = max(0, (i - math.ceil(window / qc)) * qc)
            outs.append(one_chunk(i, k=k[:, lo:hi], v=v[:, lo:hi],
                                  kvp=kv_positions[:, lo:hi]))
        out = jnp.concatenate(outs, axis=1)
    else:
        outs = lax.map(one_chunk, jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV * G, hd)
    return out


def attn_block(p, cfg, x, positions, *, window: int = 0,
               causal: bool | None = None,
               unroll: bool = False) -> jax.Array:
    """Pre-norm self-attention residual block (no FFN)."""
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h, positions)
    o = mha(q, k, v, causal=cfg.causal if causal is None else causal,
            window=window, q_chunk=cfg.attn_q_chunk, unroll=unroll)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return x + shard(o, "batch", "seq", "embed")


def attn_decode(p, cfg, x, cache_k, cache_v, pos, *, window: int = 0):
    """One-token decode: update the cache at ``pos``, attend to it.

    x: (B, 1, d); cache_k/v: (B, S, KV, hd); pos: (B,) int32.
    Returns (out (B,1,d), new_k, new_v).
    """
    B, S = cache_k.shape[0], cache_k.shape[1]
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = _qkv(p, cfg, h, pos[:, None])
    wpos = pos % S if window else pos   # ring buffer for windowed attention
    upd = jax.vmap(lambda c, n, i: lax.dynamic_update_slice(
        c, n, (i, 0, 0)))(cache_k, k, wpos)
    updv = jax.vmap(lambda c, n, i: lax.dynamic_update_slice(
        c, n, (i, 0, 0)))(cache_v, v, wpos)
    kv_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if window:
        # ring buffer: slot stores token (pos - ((wpos - slot) mod S));
        # never-written slots have kv_pos < 0 -> pushed out of the window.
        kv_pos = pos[:, None] - ((wpos[:, None] - kv_pos) % S)
        kv_pos = jnp.where(kv_pos >= 0, kv_pos, -(jnp.int32(1) << 30))
    else:
        # slots beyond pos are future/unwritten -> masked by the causal rule
        pass
    o = mha(q, upd, updv, causal=True, q_positions=pos[:, None],
            kv_positions=kv_pos, window=window, q_chunk=1)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return x + o, upd, updv


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------


def ffn_defs(cfg, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    defs = {"ln": ParamDef((d,), ("embed",), init="ones"),
            "w_up": ParamDef((d, f), ("fsdp", "mlp")),
            "w_down": ParamDef((f, d), ("mlp", "fsdp"))}
    if cfg.ffn_kind in ("swiglu", "geglu"):
        defs["w_gate"] = ParamDef((d, f), ("fsdp", "mlp"))
    return defs


def ffn_block(p, cfg, x) -> jax.Array:
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", h, p["w_up"])
    if "w_gate" in p:
        up = up * _act(cfg.ffn_kind,
                       jnp.einsum("bsd,df->bsf", h, p["w_gate"]))
    else:
        up = _act(cfg.ffn_kind, up)
    up = shard(up, "batch", "seq", "mlp")
    out = jnp.einsum("bsf,fd->bsd", up, p["w_down"])
    return x + shard(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-gather dispatch, static shapes)
# ---------------------------------------------------------------------------


def moe_defs(cfg) -> dict:
    """Expert weights use 2-D TP: experts over "model", d_ff over
    "expert_mlp" (mapped to "data" by the profile).  Unlike FSDP on the
    data axis this never re-gathers the (dominant) expert parameters — the
    data-axis traffic becomes activation-sized reduce/gathers, token-
    proportional instead of M×params (measured 79 s -> sub-second on
    dbrx-132b train_4k)."""
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "router": ParamDef((d, E), ("fsdp", "experts")),
        "w_gate": ParamDef((E, d, f), ("experts", "expert_in", "expert_mlp")),
        "w_up": ParamDef((E, d, f), ("experts", "expert_in", "expert_mlp")),
        "w_down": ParamDef((E, f, d), ("experts", "expert_mlp", "expert_in")),
    }


def moe_block(p, cfg, x) -> jax.Array:
    """Top-k MoE with GROUP-LOCAL capacity dispatch (expert parallelism).

    Tokens are split into ``cfg.moe_groups`` groups aligned with the data
    shards; the expert sort/rank/capacity bookkeeping is *per group* — a
    global argsort would force GSPMD to all-gather every token to every
    device (measured: 557 GB temp for ONE layer on the 256-chip mesh).
    The only cross-shard movement is the (G, E, C, d) -> (E, G·C, d)
    transpose feeding the expert einsum: a structured all-to-all from
    token-sharding to expert-sharding, exactly the EP dispatch collective.
    Static shapes throughout; tokens beyond the per-group capacity
    C = K·t_g·cf/E drop to a zero bin (standard capacity semantics).
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    t = B * S
    G = max(cfg.moe_groups, 1)
    if t % G:
        G = 1
    tg = t // G
    ht = h.reshape(G, tg, d)
    ht = shard(ht, "batch", None, "embed")
    logits = jnp.einsum("gtd,de->gte", ht,
                        p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = lax.top_k(probs, K)                      # (G, tg, K)
    gate = (gate / jnp.sum(gate, -1, keepdims=True)).astype(x.dtype)

    C = max(int(K * tg * cfg.moe_capacity_factor / E), 1)
    C = min(C, tg)
    # flatten (token, k) pairs per group; sort by expert id (group-local!)
    flat_e = idx.reshape(G, tg * K)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), K)[None], (G, tg * K))
    flat_g = gate.reshape(G, tg * K)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    stok = jnp.take_along_axis(flat_tok, order, axis=1)
    sg = jnp.take_along_axis(flat_g, order, axis=1)
    # position of each pair within its expert's per-group queue
    first = jax.vmap(lambda s: jnp.searchsorted(s, jnp.arange(E)))(se)
    rank_in_e = jnp.arange(tg * K)[None] - jnp.take_along_axis(first, se,
                                                               axis=1)
    keep = rank_in_e < C
    slot = jnp.where(keep, se * C + rank_in_e, E * C)    # E*C = drop bin

    # gather tokens into per-group (E*C+1, d) buffers, then expose the
    # expert dim for the sharded expert einsum (this transpose is the a2a).
    # vmap'd 1-D gather/scatter keeps XLA's index operands at (tgK, 1) —
    # take_along_axis/2-level .at[] broadcast u32 index grids to the full
    # (G, tgK, d) value shape (measured 68-86 GB EACH on the 256-chip mesh).
    vals = jax.vmap(lambda h, i: h[i])(ht, stok)
    buf = jax.vmap(lambda s, v: jnp.zeros((E * C + 1, d),
                                          x.dtype).at[s].set(v))(slot, vals)
    # (E, G, C, d): experts sharded over "model", groups over "data" — a
    # 2-D-sharded expert einsum.  Collapsing (G, C) would replicate the
    # capacity dim across the data axis (measured 16x expert FLOPs).
    xe = jnp.moveaxis(buf[:, :-1].reshape(G, E, C, d), 1, 0)
    xe = shard(xe, "experts", "batch", None, "embed")
    a = _act(cfg.ffn_kind, jnp.einsum("egcd,edf->egcf", xe, p["w_gate"]))
    up = jnp.einsum("egcd,edf->egcf", xe, p["w_up"]) * a
    ye = jnp.einsum("egcf,efd->egcd", up, p["w_down"])
    ye = shard(ye, "experts", "batch", None, "embed")

    # combine: back to token sharding (reverse a2a), weighted scatter-add
    yg = jnp.moveaxis(ye, 0, 1).reshape(G, E * C, d)
    yg = shard(yg, "batch", None, "embed")
    yg = jnp.concatenate([yg, jnp.zeros((G, 1, d), x.dtype)], axis=1)
    contrib = jax.vmap(lambda y, s: y[s])(yg, slot) * sg[..., None]
    out = jax.vmap(lambda c, i: jnp.zeros((tg, d), x.dtype).at[i].add(c))(
        contrib, stok)
    return x + shard(out.reshape(B, S, d), "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Chunked time scan (recurrent blocks)
#
# Differentiating a plain S-step lax.scan saves every step's inputs —
# measured 34 GB for xlstm train_4k.  Scanning chunks of ``chunk`` steps
# with a rematerialized inner scan stores only the per-chunk carries
# (S/chunk × state) and recomputes inside the chunk on the backward pass.
# ---------------------------------------------------------------------------

TIME_SCAN_CHUNK = 256


def chunked_time_scan(step, carry, xs, *, chunk: int = TIME_SCAN_CHUNK):
    """lax.scan(step, carry, xs) with per-chunk remat.  xs: time-major."""
    S = jax.tree.leaves(xs)[0].shape[0]
    if S <= chunk or S % chunk:
        return lax.scan(step, carry, xs)
    n = S // chunk
    xs_c = jax.tree.map(lambda x: x.reshape(n, chunk, *x.shape[1:]), xs)
    inner = jax.checkpoint(lambda c, x: lax.scan(step, c, x),
                           policy=jax.checkpoint_policies.nothing_saveable)
    carry, ys = lax.scan(inner, carry, xs_c)
    ys = jax.tree.map(lambda y: y.reshape(n * chunk, *y.shape[2:]), ys)
    return carry, ys


# ---------------------------------------------------------------------------
# Mamba2 block (SSD recurrence, time scan)
# ---------------------------------------------------------------------------


def mamba_defs(cfg) -> dict:
    d = cfg.d_model
    e = cfg.ssm_expand * d
    nh = e // cfg.ssm_head_dim
    N, W = cfg.ssm_state, cfg.ssm_conv_width
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "w_z": ParamDef((d, e), ("fsdp", "mlp")),
        "w_x": ParamDef((d, e), ("fsdp", "mlp")),
        "w_B": ParamDef((d, N), ("fsdp", "state")),
        "w_C": ParamDef((d, N), ("fsdp", "state")),
        "w_dt": ParamDef((d, nh), ("fsdp", "heads")),
        "conv_w": ParamDef((W, e), ("conv", "mlp"), scale=0.5),
        "A_log": ParamDef((nh,), ("heads",), init="zeros"),
        "D": ParamDef((nh,), ("heads",), init="ones"),
        "dt_bias": ParamDef((nh,), ("heads",), init="zeros"),
        "gn": ParamDef((e,), ("mlp",), init="ones"),
        "w_out": ParamDef((e, d), ("mlp", "fsdp")),
    }


def _mamba_scan_seq(x, B_in, C_in, dt, A_log, D, hd, *, h0=None):
    """Sequential SSD recurrence (reference / decode path).

    h_t = exp(A*dt_t) h_{t-1} + dt_t * x_t B_t^T ;  y_t = h_t C_t + D x_t
    Returns (y (B,S,nh,hd), h_final (B,nh,hd,N)).
    """
    Bb, S, nh, _ = x.shape
    N = B_in.shape[-1]
    A = -jnp.exp(A_log.astype(jnp.float32))              # (nh,) negative

    def step(h, inp):
        xt, Bt, Ct, dtt = inp                            # (B,nh,hd),(B,N),(B,N),(B,nh)
        decay = jnp.exp(A[None] * dtt)                   # (B,nh)
        dx = (dtt[..., None] * xt).astype(jnp.float32)   # (B,nh,hd)
        h = h * decay[..., None, None] + dx[..., None] * Bt[:, None, None, :]
        y = jnp.einsum("bhdn,bn->bhd", h, Ct.astype(jnp.float32))
        return h, y.astype(x.dtype)

    if h0 is None:
        h0 = jnp.zeros((Bb, nh, hd, N), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(B_in, 1, 0),
          jnp.moveaxis(C_in, 1, 0), jnp.moveaxis(dt, 1, 0))
    h_fin, ys = chunked_time_scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + D[None, None, :, None] * x
    return y, h_fin


MAMBA_CHUNK = 128


def _mamba_scan(x, B_in, C_in, dt, A_log, D, hd, *, h0=None,
                chunk: int = MAMBA_CHUNK, unroll: bool = False):
    """Chunkwise-parallel SSD (the Mamba2 paper's algorithm, TPU-adapted).

    A step-by-step scan round-trips the (B, nh, hd, N) fp32 state through
    HBM every token (memory-bound: ~7 s/step terms on the dry-run) and runs
    on the VPU.  The chunked form materializes the state once per ``chunk``
    tokens and turns intra-chunk work into MXU matmuls:

      y_intra[t] = sum_{s<=t} exp(logP_t - logP_s) (C_t.B_s) u_s
      y_cross[t] = exp(logP_t) C_t . h_in
      h_out      = exp(logP_c) h_in + sum_t exp(logP_c - logP_t) u_t (x) B_t

    All decay ratios are exp of non-positive numbers — stable in log space.
    """
    Bb, S, nh, _ = x.shape
    N = B_in.shape[-1]
    if S % chunk or S <= chunk:
        return _mamba_scan_seq(x, B_in, C_in, dt, A_log, D, hd, h0=h0)
    A = -jnp.exp(A_log.astype(jnp.float32))              # (nh,)
    n = S // chunk
    f32 = jnp.float32

    def reshape_c(t):
        return t.reshape(Bb, n, chunk, *t.shape[2:])

    xc = reshape_c(x)
    Bc = reshape_c(B_in).astype(f32)
    Cc = reshape_c(C_in).astype(f32)
    dtc = reshape_c(dt).astype(f32)
    u = dtc[..., None] * xc.astype(f32)                  # (B,n,c,nh,hd)
    loga = A[None, None, None] * dtc                     # (B,n,c,nh) <= 0
    logP = jnp.cumsum(loga, axis=2)                      # (B,n,c,nh)
    logPc = logP[:, :, -1]                               # (B,n,nh)

    # intra-chunk: (C_t.B_s) * exp(logP_t - logP_s), masked s <= t
    cb = jnp.einsum("bntk,bnsk->bnts", Cc, Bc)           # (B,n,c,c)
    ratio = logP[:, :, :, None, :] - logP[:, :, None, :, :]   # (B,n,t,s,nh)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    ratio = jnp.where(mask[None, None, :, :, None], ratio, -1e30)
    y_intra = jnp.einsum("bnts,bntsh,bnshd->bnthd", cb, jnp.exp(ratio), u)

    # chunk-boundary states via an outer scan over n chunks
    contrib = jnp.einsum("bnth,bnthd,bntk->bnhdk",
                         jnp.exp(logPc[:, :, None] - logP), u, Bc)

    if h0 is None:
        h0 = jnp.zeros((Bb, nh, hd, N), f32)

    def chunk_step(h, inp):
        lpc, contr, Ct, lP = inp
        y_cross = jnp.einsum("bth,btk,bhdk->bthd", jnp.exp(lP), Ct, h)
        h_new = h * jnp.exp(lpc)[..., None, None] + contr
        return h_new, y_cross

    xs = (jnp.moveaxis(logPc, 1, 0), jnp.moveaxis(contrib, 1, 0),
          jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(logP, 1, 0))
    if unroll:
        h, ys = h0, []
        for i in range(n):
            h, yc = chunk_step(h, jax.tree.map(lambda t: t[i], xs))
            ys.append(yc)
        y_cross = jnp.stack(ys, axis=1)
        h_fin = h
    else:
        h_fin, ys = lax.scan(chunk_step, h0, xs)
        y_cross = jnp.moveaxis(ys, 0, 1)

    y = (y_intra + y_cross).reshape(Bb, S, nh, hd).astype(x.dtype)
    return y + D[None, None, :, None] * x, h_fin


def mamba_block(p, cfg, x, *, state=None, conv_state=None,
                return_state=False, unroll: bool = False):
    """Mamba2 residual block.  Training/prefill path (full sequence,
    chunkwise-parallel SSD) or, with ``state``/``conv_state``, single-token
    decode (sequential step)."""
    Bb, S, d = x.shape
    e = cfg.ssm_expand * d
    hd = cfg.ssm_head_dim
    nh = e // hd
    W = cfg.ssm_conv_width
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", h, p["w_z"])
    xin = jnp.einsum("bsd,de->bse", h, p["w_x"])
    xin = shard(xin, "batch", "seq", "mlp")
    # causal depthwise conv
    if conv_state is not None:                           # decode: (B, W-1, e)
        window = jnp.concatenate([conv_state, xin], axis=1)   # (B, W, e)
        new_conv = window[:, 1:]
        xc = jnp.einsum("bwe,we->be", window, p["conv_w"])[:, None]
    else:
        pad = jnp.zeros((Bb, W - 1, e), xin.dtype)
        win = jnp.concatenate([pad, xin], axis=1)
        xc = sum(win[:, i:i + S] * p["conv_w"][i] for i in range(W))
        new_conv = win[:, S:]                            # last W-1 inputs
    xc = jax.nn.silu(xc)
    B_in = jnp.einsum("bsd,dn->bsn", h, p["w_B"])
    C_in = jnp.einsum("bsd,dn->bsn", h, p["w_C"])
    dt = jax.nn.softplus(jnp.einsum("bsd,dh->bsh", h, p["w_dt"])
                         + p["dt_bias"])
    y, h_fin = _mamba_scan(xc.reshape(Bb, -1, nh, hd), B_in, C_in, dt,
                           p["A_log"], p["D"], hd, h0=state, unroll=unroll)
    y = y.reshape(Bb, -1, e) * jax.nn.silu(z)
    y = rms_norm(y, p["gn"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    out = x + shard(out, "batch", "seq", "embed")
    if return_state:
        return out, h_fin, new_conv
    return out


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------


def mlstm_defs(cfg) -> dict:
    d = cfg.d_model
    e = 2 * d
    H = cfg.n_heads
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "w_up": ParamDef((d, e), ("fsdp", "mlp")),      # pre up-projection
        "wq": ParamDef((e, e), ("mlp", "mlp")),
        "wk": ParamDef((e, e), ("mlp", "mlp")),
        "wv": ParamDef((e, e), ("mlp", "mlp")),
        "w_i": ParamDef((e, H), ("mlp", "heads")),
        "w_f": ParamDef((e, H), ("mlp", "heads")),
        "w_o": ParamDef((e, e), ("mlp", "mlp")),
        "w_down": ParamDef((e, d), ("mlp", "fsdp")),
    }


def _mlstm_chunkwise(q, k, v, it, ft, state, *, chunk: int,
                     unroll: bool = False):
    """Chunkwise-parallel mLSTM (stabilized linear attention).

    Sequential form: m_t = max(logf_t + m_{t-1}, i_t);
      C_t = e^{logf_t+m_{t-1}-m_t} C_{t-1} + e^{i_t-m_t} k_t v_t^T
      h_t = C_t q_t / max(|n_t q_t|, 1)
    With F_t = cumsum(logf) the stabilizer is m_t = max(F_t + M_in,
    F_t + cummax_s(i_s - F_s)) — computable in parallel per chunk, so the
    intra-chunk part is a masked matmul A_ts = (q_t.k_s) e^{F_t-F_s+i_s-m_t}
    (all exponents <= 0 by construction) and the carried state contributes
    e^{F_t + M_in - m_t} (S_in q_t).  State materializes once per chunk and
    the MXU does the rest — same shape as the chunkwise SSD (Mamba2) path.

    q,k,v: (B,S,H,hd); it,ft: (B,S,H) f32 raw gates.  state = (C, n, m).
    Returns (y (B,S,H,hd) f32, new_state).
    """
    Bb, S, H, hd = q.shape
    n = S // chunk
    f32 = jnp.float32
    qc = q.reshape(Bb, n, chunk, H, hd).astype(f32)
    kc = k.reshape(Bb, n, chunk, H, hd).astype(f32)
    vc = v.reshape(Bb, n, chunk, H, hd).astype(f32)
    ic = it.reshape(Bb, n, chunk, H)
    logf = -jax.nn.softplus(-ft).reshape(Bb, n, chunk, H)
    F = jnp.cumsum(logf, axis=2)                          # (B,n,c,H)
    Gmax = jax.lax.cummax(ic - F, axis=2)                 # cummax(i_s - F_s)

    C_in, n_in, m_in = state

    def chunk_step(carry, inp):
        C, nv, M = carry                      # (B,H,hd,hd),(B,H,hd),(B,H)
        qt, kt, vt, i_t, F_t, Gm = inp        # k pre-scaled by 1/sqrt(hd)
        # stabilizer per position: m_t = F_t + max(M_in, cummax_s(i_s-F_s))
        m = F_t + jnp.maximum(M[:, None], Gm)             # (B,c,H)
        # intra-chunk masked scores A_ts = (q_t.k_s) e^{F_t-F_s+i_s-m_t}
        ratio = F_t[:, :, None] - F_t[:, None, :] + i_t[:, None, :] \
            - m[:, :, None]                               # (B,t,s,H)
        tri = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
        ratio = jnp.where(tri[None, :, :, None], ratio, -1e30)
        a = jnp.einsum("bthd,bshd->bhts", qt, kt)
        A = a * jnp.moveaxis(jnp.exp(ratio), 3, 1)        # (B,H,t,s)
        num_intra = jnp.einsum("bhts,bshd->bthd", A, vt)
        den_intra = jnp.moveaxis(jnp.sum(A, axis=3), 1, 2)  # (B,t,H)
        # cross-chunk contribution, decayed from the carried state
        w_in = jnp.exp(F_t + M[:, None] - m)              # (B,c,H)
        num_cross = jnp.einsum("bhkv,bthk->bthv", C, qt) * w_in[..., None]
        den_cross = jnp.einsum("bhk,bthk->bth", nv, qt) * w_in
        num = num_intra + num_cross
        den = jnp.abs(den_intra + den_cross)
        y = num / jnp.maximum(den, 1.0)[..., None]
        # state update to chunk end
        m_out = m[:, -1]                                  # (B,H)
        Fc = F_t[:, -1]                                   # (B,H)
        wS = jnp.exp(Fc + M - m_out)
        wk = jnp.exp(Fc[:, None] - F_t + i_t - m_out[:, None])  # (B,c,H)
        C_new = C * wS[..., None, None] + jnp.einsum(
            "bshk,bshv,bsh->bhkv", kt, vt, wk)
        n_new = nv * wS[..., None] + jnp.einsum("bshk,bsh->bhk", kt, wk)
        return (C_new, n_new, m_out), y

    xs = (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(ic, 1, 0),
          jnp.moveaxis(F, 1, 0), jnp.moveaxis(Gmax, 1, 0))
    if unroll and n <= 128:   # probe path; longer sequences would blow up
        carry, ys = (C_in, n_in, m_in), []   # the unrolled HLO
        for i in range(n):
            carry, y = chunk_step(carry, jax.tree.map(lambda t: t[i], xs))
            ys.append(y)
        y = jnp.stack(ys, axis=1)
    else:
        carry, ys = lax.scan(chunk_step, (C_in, n_in, m_in), xs)
        y = jnp.moveaxis(ys, 0, 1)
    return y.reshape(Bb, S, H, hd), carry


MLSTM_CHUNK = 64


def mlstm_block(p, cfg, x, *, state=None, return_state=False,
                unroll: bool = False):
    """mLSTM: matrix-memory recurrent block (xLSTM).

    Training/prefill uses the chunkwise-parallel stabilized linear-attention
    form (:func:`_mlstm_chunkwise`, state materialized once per chunk, MXU
    matmuls); decode/odd lengths fall back to the sequential scan."""
    Bb, S, d = x.shape
    H = cfg.n_heads
    e = p["w_up"].shape[1]
    hd = e // H
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    u = jax.nn.silu(jnp.einsum("bsd,de->bse", h, p["w_up"]))
    q = jnp.einsum("bse,ef->bsf", u, p["wq"]).reshape(Bb, S, H, hd)
    k = jnp.einsum("bse,ef->bsf", u, p["wk"]).reshape(Bb, S, H, hd) \
        / math.sqrt(hd)
    v = jnp.einsum("bse,ef->bsf", u, p["wv"]).reshape(Bb, S, H, hd)
    it = jnp.einsum("bse,eh->bsh", u, p["w_i"]).astype(jnp.float32)
    ft = jnp.einsum("bse,eh->bsh", u, p["w_f"]).astype(jnp.float32)

    def step(carry, inp):
        C, n, m = carry                                  # (B,H,hd,hd),(B,H,hd),(B,H)
        qt, kt, vt, i_t, f_t = inp
        logf = -jax.nn.softplus(-f_t)                    # log sigmoid(f)
        m_new = jnp.maximum(logf + m, i_t)
        fg = jnp.exp(logf + m - m_new)[..., None]
        ig = jnp.exp(i_t - m_new)[..., None]
        C = C * fg[..., None] + ig[..., None] * \
            (kt[..., :, None] * vt[..., None, :]).astype(jnp.float32)
        n = n * fg + ig * kt.astype(jnp.float32)
        num = jnp.einsum("bhkv,bhk->bhv", C, qt.astype(jnp.float32))
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt.astype(jnp.float32)))
        y = num / jnp.maximum(den, 1.0)[..., None]
        return (C, n, m_new), y.astype(x.dtype)

    if state is None:
        state = (jnp.zeros((Bb, H, hd, hd), jnp.float32),
                 jnp.zeros((Bb, H, hd), jnp.float32),
                 jnp.full((Bb, H), -1e30, jnp.float32))
    if S % MLSTM_CHUNK == 0 and S > MLSTM_CHUNK:
        ys, state = _mlstm_chunkwise(q, k, v, it, ft, state,
                                     chunk=MLSTM_CHUNK, unroll=unroll)
        y = ys.astype(x.dtype).reshape(Bb, S, e)
    else:
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, it, ft))
        state, ys = chunked_time_scan(step, state, xs)
        y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, e)
    y = y * jax.nn.silu(jnp.einsum("bse,ef->bsf", u, p["w_o"]))
    out = x + jnp.einsum("bse,ed->bsd", y, p["w_down"])
    if return_state:
        return out, state
    return out


def slstm_defs(cfg) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    f = int(4 * d / 3 / 64) * 64 or 64
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "w_zifo": ParamDef((d, 4 * d), ("fsdp", "mlp")),
        "r_zifo": ParamDef((H, hd, 4 * hd), ("heads", "head_dim", None),
                           scale=0.1),
        "gn": ParamDef((d,), ("embed",), init="ones"),
        "w_up": ParamDef((d, 2 * f), ("fsdp", "mlp")),
        "w_down": ParamDef((f, d), ("mlp", "fsdp")),
    }


def slstm_block(p, cfg, x, *, state=None, return_state=False):
    """sLSTM: scalar-memory recurrent block with block-diagonal recurrence
    and exponential gating, followed by a gated up/down MLP (xLSTM)."""
    Bb, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zifo = jnp.einsum("bsd,df->bsf", h, p["w_zifo"])     # (B,S,4d)

    def step(carry, inp):
        c, n, hprev, m = carry                           # (B,H,hd)x3,(B,H)
        g = inp.reshape(Bb, H, 4 * hd) + jnp.einsum(
            "bhk,hkf->bhf", hprev, p["r_zifo"])
        zt, it, ft, ot = jnp.split(g.astype(jnp.float32), 4, axis=-1)
        it, ft = it.mean(-1), ft.mean(-1)                # scalar gates per head
        logf = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(logf + m, it)
        fg = jnp.exp(logf + m - m_new)[..., None]
        ig = jnp.exp(it - m_new)[..., None]
        c = c * fg + ig * jnp.tanh(zt)
        n = n * fg + ig
        hn = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
        return (c, n, hn.astype(x.dtype), m_new), hn.astype(x.dtype)

    if state is None:
        z32 = lambda: jnp.zeros((Bb, H, hd), jnp.float32)
        state = (z32(), z32(), jnp.zeros((Bb, H, hd), x.dtype),
                 jnp.full((Bb, H), -1e30, jnp.float32))
    state, ys = chunked_time_scan(step, state, jnp.moveaxis(zifo, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, d)
    y = rms_norm(y, p["gn"], cfg.norm_eps)
    up, gate = jnp.split(jnp.einsum("bsd,df->bsf", y, p["w_up"]), 2, -1)
    y2 = jnp.einsum("bsf,fd->bsd", up * jax.nn.gelu(gate, approximate=True),
                    p["w_down"])
    out = x + y2
    if return_state:
        return out, state
    return out


# ---------------------------------------------------------------------------
# Cross-attention block (VLM / whisper decoder)
# ---------------------------------------------------------------------------


def cross_attn_defs(cfg) -> dict:
    H, KV, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.d_model
    return {
        "ln": ParamDef((d,), ("embed",), init="ones"),
        "wq": ParamDef((d, H, hd), ("fsdp", "heads", "head_dim")),
        "wk": ParamDef((d, KV, hd), ("fsdp", "kv_heads", "head_dim")),
        "wv": ParamDef((d, KV, hd), ("fsdp", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "fsdp")),
        "gate": ParamDef((1,), (None,), init="zeros"),   # llama-vision tanh gate
    }


def cross_attn_block(p, cfg, x, memory, *, unroll: bool = False) -> jax.Array:
    """Attend from x to an encoder/vision memory sequence (not causal)."""
    B, S, d = x.shape
    KV, hd, H = cfg.n_kv_heads, cfg.hd, cfg.n_heads
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"]).reshape(B, S, KV, H // KV, hd)
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    o = mha(q, k, v, causal=False, q_chunk=cfg.attn_q_chunk, unroll=unroll)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return x + jnp.tanh(p["gate"].astype(x.dtype)) * o


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------


def embed_defs(cfg) -> dict:
    return {"tok": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                            scale=0.02)}


def embed(p, cfg, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(cfg.jnp_dtype)
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    return shard(x, "batch", "seq", "embed")


def logits_chunked(x: jax.Array, emb: jax.Array, cfg,
                   chunk: int = 512) -> jax.Array:
    """(B,S,d) @ (V,d)^T in seq chunks; full logits only for small V use."""
    logits = jnp.einsum("bsd,vd->bsv", x, emb.astype(x.dtype))
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return shard(logits, "batch", "seq", "vocab")


def xent_loss(x: jax.Array, emb: jax.Array, labels: jax.Array, cfg,
              chunk: int = 256) -> jax.Array:
    """Chunked cross-entropy: never materializes (B,S,V) at once.

    x: (B,S,d) final hidden; emb: (V,d) tied unembedding; labels: (B,S).
    Label -100 entries are masked out.
    """
    B, S, d = x.shape
    cs = min(chunk, S)
    while S % cs:
        cs -= 1

    def one(i):
        xs = lax.dynamic_slice_in_dim(x, i * cs, cs, axis=1)
        ls = lax.dynamic_slice_in_dim(labels, i * cs, cs, axis=1)
        lg = jnp.einsum("bsd,vd->bsv", xs, emb.astype(xs.dtype))
        if cfg.logit_softcap:
            lg = jnp.tanh(lg / cfg.logit_softcap) * cfg.logit_softcap
        lg = shard(lg, "batch", "seq", "vocab").astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        pick = jnp.take_along_axis(
            lg, jnp.maximum(ls, 0)[..., None], axis=-1)[..., 0]
        mask = (ls >= 0).astype(jnp.float32)
        return jnp.sum((lse - pick) * mask), jnp.sum(mask)

    tot, cnt = jnp.zeros(()), jnp.zeros(())
    for i in range(S // cs):     # static python loop: cs chosen so few chunks
        a, b = one(i)
        tot, cnt = tot + a, cnt + b
    return tot / jnp.maximum(cnt, 1.0)
