"""Architecture configs and input-shape specs for the assigned model pool.

:class:`ArchConfig` is the single config type all 10 assigned architectures
instantiate (repro/configs/<id>.py).  It drives

  * the pure-JAX model definition (repro.models.lm),
  * the planner's analytic description (:meth:`to_model_desc`),
  * the dry-run input specs (:meth:`input_specs`).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Literal, Sequence

import jax
import jax.numpy as jnp

from repro.core.opgraph import ModelDesc

BlockKind = Literal["attn", "mamba", "mlstm", "slstm", "shared_attn"]


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape (arch x shape = one dry-run cell)."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The four LM shapes from the assignment.
TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")
ALL_SHAPES: tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                     LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None

    # attention details
    qkv_bias: bool = False            # qwen2
    qk_norm: bool = False             # qwen3
    rope_theta: float = 10000.0
    causal: bool = True

    # ffn details
    ffn_kind: Literal["swiglu", "geglu", "gelu"] = "swiglu"

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    # group-local dispatch: token groups aligned to the data shards (the
    # launcher sets this to the mesh's dp extent; 1 = single-group/CPU)
    moe_groups: int = 1

    # hybrid / recurrent
    block_pattern: tuple[BlockKind, ...] = ()   # cycle; empty => all "attn"
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4

    # enc-dec (whisper): encoder depth; frontend is a stub — inputs are
    # precomputed frame embeddings of length ``audio_seq``.
    encoder_layers: int = 0
    audio_seq: int = 1500

    # VLM: cross-attention to precomputed image patch embeddings every
    # ``cross_attn_every`` layers; ``vision_seq`` patch tokens at d_model.
    cross_attn_every: int = 0
    vision_seq: int = 1601

    # misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0        # gemma-style final-logit softcap
    scale_embed: bool = False         # gemma multiplies embed by sqrt(d)
    attn_q_chunk: int = 2048          # flash-style query chunk (memory bound)
    dtype: str = "bfloat16"
    # which archs can run long_500k (sub-quadratic path)
    subquadratic: bool = False
    # attention window for hybrid long-context shared attention (0 = full)
    attn_window: int = 0

    # ------------------------------------------------------------------

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def pattern(self) -> tuple[BlockKind, ...]:
        return self.block_pattern or ("attn",)

    @property
    def cycle_len(self) -> int:
        return len(self.pattern)

    @property
    def n_cycles(self) -> int:
        assert self.n_layers % self.cycle_len == 0, \
            f"{self.name}: n_layers {self.n_layers} % cycle {self.cycle_len}"
        return self.n_layers // self.cycle_len

    def block_kind(self, i: int) -> BlockKind:
        return self.pattern[i % self.cycle_len]

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    # -- planner bridge -------------------------------------------------------

    def to_model_desc(self) -> ModelDesc:
        pattern = tuple("mamba" if b == "mamba" else
                        ("mlstm" if b in ("mlstm", "slstm") else "attn")
                        for b in self.pattern) if self.block_pattern else ()
        return ModelDesc(
            name=self.name, n_layers=self.n_layers, d_model=self.d_model,
            n_heads=self.n_heads, n_kv_heads=self.n_kv_heads, d_ff=self.d_ff,
            vocab=self.vocab, head_dim=self.head_dim,
            n_experts=self.n_experts, top_k=self.top_k,
            ssm_state=self.ssm_state, block_pattern=pattern,
            ffn_kind=self.ffn_kind, cross_attn_every=self.cross_attn_every,
            encoder_layers=self.encoder_layers,
            dtype_bytes=jnp.dtype(self.dtype).itemsize)

    # -- reduced config for CPU smoke tests ------------------------------------

    def reduced(self, **overrides) -> "ArchConfig":
        """Small same-family config: few layers, narrow width, tiny vocab."""
        cyc = self.cycle_len
        base = dict(
            n_layers=max(cyc, 2 * cyc if self.n_layers >= 2 * cyc else cyc),
            d_model=128,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv_heads=1 if self.n_kv_heads == 1 else 2,
            head_dim=32 if self.head_dim else None,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            n_experts=8 if self.n_experts else 0,
            top_k=min(2, self.top_k) if self.n_experts else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            audio_seq=24,
            cross_attn_every=2 if self.cross_attn_every else 0,
            vision_seq=16,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            attn_q_chunk=64,
            attn_window=16 if self.attn_window else 0,
            dtype="float32",
        )
        base.update(overrides)
        # keep heads consistent with d_model when head_dim not pinned
        if base.get("head_dim") is None and not self.head_dim:
            base["head_dim"] = None
            base["n_heads"] = max(2, base["d_model"] // 32)
            base["n_kv_heads"] = 1 if self.n_kv_heads == 1 else 2
            # d_model/n_heads must be integral
            while base["d_model"] % base["n_heads"]:
                base["n_heads"] -= 1
        return replace(self, name=self.name + "-smoke", **base)

    # -- shapes ----------------------------------------------------------------

    def shapes(self) -> list[ShapeSpec]:
        """The assigned shapes this arch runs (skips documented in DESIGN.md)."""
        out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
        if self.subquadratic:
            out.append(LONG_500K)
        return out

    def skipped_shapes(self) -> list[tuple[ShapeSpec, str]]:
        if self.subquadratic:
            return []
        return [(LONG_500K, "pure full-attention arch: 500k needs "
                            "sub-quadratic attention (DESIGN.md §5)")]

    # -- dry-run input specs (ShapeDtypeStruct, no allocation) -----------------

    def input_specs(self, shape: ShapeSpec) -> dict[str, jax.ShapeDtypeStruct]:
        """Abstract model inputs for one cell.  Modality frontends are stubs:
        audio/vision entries are precomputed embeddings."""
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = self.jnp_dtype
        if shape.kind == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        elif shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        else:  # decode: one new token against a cache of length S
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, 1), i32),
                "pos": jax.ShapeDtypeStruct((B,), i32),
            }
        if self.encoder_layers:
            specs["audio_embed"] = jax.ShapeDtypeStruct(
                (B, self.audio_seq, self.d_model), dt)
        if self.cross_attn_every:
            specs["vision_embed"] = jax.ShapeDtypeStruct(
                (B, self.vision_seq, self.d_model), dt)
        return specs
