"""AdamW with cosine schedule, global-norm clipping and ZeRO-1 sharding.

Functional, pytree-based (no optax dependency).  First/second moments are
kept in fp32; parameters may be bf16.  ZeRO-1: the optimizer-state sharding
tree returned by :func:`repro.parallel.sharding.opt_state_shardings` shards
moments over the data axis even when parameters are not — GSPMD then emits
exactly the reduce-scatter + all-gather decomposition of the gradient
all-reduce that the paper's Fig. 3 advocates.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac * cfg.peak_lr + \
        (1 - cfg.min_lr_frac) * cfg.peak_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


class OptState(NamedTuple):
    m: Pytree
    v: Pytree
    step: jax.Array


def init_opt_state(params: Pytree) -> OptState:
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(z, params), v=jax.tree.map(z, params),
                    step=jnp.zeros((), jnp.int32))


def abstract_opt_state(params: Pytree) -> OptState:
    z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return OptState(m=jax.tree.map(z, params), v=jax.tree.map(z, params),
                    step=jax.ShapeDtypeStruct((), jnp.int32))


def global_norm(tree: Pytree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params: Pytree, grads: Pytree, state: OptState,
                 cfg: AdamWConfig) -> tuple[Pytree, OptState, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, step), metrics
