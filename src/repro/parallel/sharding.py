"""Plan → JAX sharding bridge.

Turns a :class:`repro.core.plans.ParallelPlan` (or a per-arch default) into
the :class:`AxisRules` table + concrete ``NamedSharding`` trees consumed by
``jax.jit``.  This is where the paper's planning decisions become GSPMD
behaviour:

  * TP on heads/mlp/vocab/experts        → "model" axis rules
  * ZeRO-3 / FSDP parameter sharding     → "fsdp" → ("data",)
  * ZeRO-1 (decomposed grad sync, Fig.3) → optimizer moments force-sharded
    over "data" even when parameters are replicated; GSPMD then emits
    reduce-scatter + all-gather instead of all-reduce.
  * GQA / head-count misalignment        → automatic divisibility fallback
    (replicate) plus split-KV decode (shard the cache length dim instead).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig, ShapeSpec
from repro.models.lm import LM
from repro.optim.adamw import OptState
from repro.parallel.axes import AxisRules

Pytree = Any


@dataclass(frozen=True)
class ShardingProfile:
    """Arch×mesh-resolved sharding decisions (derived from a ParallelPlan)."""

    rules: AxisRules          # parameter + activation rules
    opt_rules: AxisRules      # optimizer-moment rules (ZeRO-1 default)
    zero3: bool
    notes: tuple[str, ...] = ()


def profile_for(cfg: ArchConfig, mesh: Mesh, *, zero3: bool = True,
                zero1: bool = True,
                shard_kv_seq: bool | None = None) -> ShardingProfile:
    """Resolve the sharding profile for an architecture on a mesh.

    ``zero3`` shards parameters' "fsdp" dims over the data axis (needed by
    the ≥32B archs on 16 GB v5e chips); ``zero1`` shards only optimizer
    moments.  ``shard_kv_seq`` forces split-KV decode; by default it turns on
    exactly when kv heads do not divide the model axis.
    """
    notes = []
    model_extent = mesh.shape.get("model", 1)
    rules = AxisRules()
    if not zero3:
        rules = rules.updated(fsdp=())
        notes.append("megatron-style: params TP-sharded only (no FSDP)")
    if shard_kv_seq is None:
        shard_kv_seq = cfg.n_kv_heads % model_extent != 0
    if shard_kv_seq:
        rules = rules.updated(kv_seq=("model",))
        notes.append(f"split-KV decode: kv_heads={cfg.n_kv_heads} not "
                     f"divisible by model={model_extent}; cache length "
                     "sharded over model axis")
    if cfg.n_heads % model_extent != 0:
        notes.append(f"q heads {cfg.n_heads} not divisible by model axis "
                     f"{model_extent}: attention projections replicated "
                     "(divisibility fallback); consider pad_heads")
    opt_rules = rules if zero3 else (
        rules.updated(fsdp=("data",)) if zero1 else rules)
    return ShardingProfile(rules=rules, opt_rules=opt_rules, zero3=zero3,
                           notes=tuple(notes))


def pad_heads(cfg: ArchConfig, mesh: Mesh) -> ArchConfig:
    """Pad query heads up to a model-axis multiple (beyond-paper perf opt).

    Extra heads contribute nothing (their wo rows are trained from zero) but
    make the head dim shardable.  kv heads are left unpadded (GQA group size
    must stay integral)."""
    import dataclasses
    ext = mesh.shape.get("model", 1)
    if cfg.n_heads % ext == 0:
        return cfg
    new_h = math.ceil(cfg.n_heads / ext) * ext
    while new_h % cfg.n_kv_heads:
        new_h += ext
    return dataclasses.replace(cfg, n_heads=new_h,
                               head_dim=cfg.hd)


# ---------------------------------------------------------------------------
# Concrete sharding trees
# ---------------------------------------------------------------------------


def _tree_shardings(axes_tree: Pytree, abstract_tree: Pytree, mesh: Mesh,
                    rules: AxisRules) -> Pytree:
    def one(axes, ab):
        return rules.sharding(axes, ab.shape, mesh)
    return jax.tree.map(one, axes_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(e, (str, type(None))) for e in x))


def param_shardings(model: LM, mesh: Mesh, rules: AxisRules) -> Pytree:
    return _tree_shardings(model.param_axes(), model.abstract_params(),
                           mesh, rules)


def opt_state_shardings(model: LM, mesh: Mesh,
                        opt_rules: AxisRules) -> OptState:
    m = _tree_shardings(model.param_axes(), model.abstract_params(),
                        mesh, opt_rules)
    return OptState(m=m, v=m,
                    step=NamedSharding(mesh, P()))


def cache_shardings(model: LM, mesh: Mesh, rules: AxisRules,
                    batch: int, max_len: int) -> Pytree:
    ab = model.init_cache(batch, max_len, abstract=True)
    return _tree_shardings(model.cache_axes(), ab, mesh, rules)


def batch_shardings(mesh: Mesh, specs: dict[str, jax.ShapeDtypeStruct],
                    rules: AxisRules) -> dict[str, NamedSharding]:
    """Input batch: leading dim is batch for every entry."""
    out = {}
    for k, v in specs.items():
        axes = ["batch"] + [None] * (len(v.shape) - 1)
        if k in ("audio_embed", "vision_embed"):
            axes = ["batch", "seq", "embed"]
        out[k] = rules.sharding(axes, v.shape, mesh)
    return out
