"""Logical→physical axis mapping (MaxText-style) with divisibility fallback.

Models annotate every parameter and key activation with *logical* axis names
("batch", "heads", "mlp", ...).  A :class:`AxisRules` table maps logical
names onto physical mesh axes ("pod", "data", "model").  The mapping is the
hook through which a :class:`repro.core.plans.ParallelPlan` steers JAX
sharding: the planner's choices (TP on heads vs sequence, ZeRO-3 on the data
axis, EP on the model axis) are expressed as rule-table edits, and GSPMD
materializes the collectives.

Divisibility fallback: a logical dim whose size is not divisible by the
mapped mesh-axis extent is silently replicated for that dim (e.g. qwen2's 28
query heads on a 16-way model axis), and the attention layer then switches to
sequence sharding — the paper's "operator splitting picks a different axis"
in JAX terms.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# A logical→physical entry maps a logical axis name to one physical mesh axis
# or a tuple of them (major-to-minor).
Physical = tuple[str, ...]

DEFAULT_RULES: dict[str, Physical] = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),                 # query sequence: unsharded by default
    "seq_shard": ("model",),   # context-parallel fallback for attention
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_mlp": ("data",),   # 2-D expert TP (see layers.moe_defs)
    "expert_in": (),
    "kv_seq": (),              # kv cache length (split-KV decode may shard)
    # parameters
    "fsdp": ("data",),         # ZeRO-3 dim when plan.zero3 (else remapped to ())
    "layers": (),
    "conv": (),
    "state": (),
}


@dataclass(frozen=True)
class AxisRules:
    """Immutable rule table; planners derive edited copies."""

    rules: Mapping[str, Physical] = field(default_factory=lambda: dict(DEFAULT_RULES))

    def updated(self, **edits: Physical) -> "AxisRules":
        r = dict(self.rules)
        r.update(edits)
        return AxisRules(r)

    def physical(self, logical: str | None) -> Physical:
        if logical is None:
            return ()
        if logical not in self.rules:
            raise KeyError(f"unknown logical axis {logical!r}")
        return self.rules[logical]

    # -- spec building ---------------------------------------------------------

    def spec(self, logical_axes: Sequence[str | None],
             shape: Sequence[int] | None = None,
             mesh: Mesh | None = None) -> P:
        """PartitionSpec for a tensor annotated with logical axes.

        With ``shape``+``mesh``, drops mesh axes that do not divide the dim
        (divisibility fallback) and axes absent from the mesh (e.g. "pod" on
        the single-pod mesh).
        """
        entries: list[tuple[str, ...] | None] = []
        used: set[str] = set()
        for i, name in enumerate(logical_axes):
            phys = [a for a in self.physical(name) if a not in used]
            if mesh is not None:
                phys = [a for a in phys if a in mesh.shape]
            if shape is not None and mesh is not None and phys:
                extent = math.prod(mesh.shape[a] for a in phys)
                while phys and shape[i] % extent != 0:
                    phys.pop()           # drop minor-most until divisible
                    extent = math.prod(mesh.shape[a] for a in phys) if phys else 1
            used.update(phys)
            entries.append(tuple(phys) if phys else None)
        # strip trailing Nones for a tidy spec
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding(self, logical_axes: Sequence[str | None],
                 shape: Sequence[int], mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.spec(logical_axes, shape, mesh))

    def shardable(self, logical: str, size: int, mesh: Mesh) -> bool:
        phys = [a for a in self.physical(logical) if a in mesh.shape]
        extent = math.prod(mesh.shape[a] for a in phys) if phys else 1
        return extent > 1 and size % extent == 0


# ---------------------------------------------------------------------------
# Activation-constraint helper
# ---------------------------------------------------------------------------

# Set by `use_rules(mesh, rules)`; None => constraints are no-ops (CPU smoke).
_ACTIVE: list[tuple[Mesh, AxisRules]] = []


class use_rules:
    """Context manager activating sharding constraints inside model code."""

    def __init__(self, mesh: Mesh | None, rules: AxisRules | None = None):
        self.pair = (mesh, rules or AxisRules()) if mesh is not None else None

    def __enter__(self):
        if self.pair is not None:
            _ACTIVE.append(self.pair)  # type: ignore[arg-type]
        return self

    def __exit__(self, *exc):
        if self.pair is not None:
            _ACTIVE.pop()
        return False


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """Annotate an activation with logical axes (no-op without active mesh)."""
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    spec = rules.spec(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def active_rules() -> tuple[Mesh, AxisRules] | None:
    return _ACTIVE[-1] if _ACTIVE else None
