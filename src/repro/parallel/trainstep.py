"""Train / prefill / serve step builders (the pjit substrate).

``make_train_step`` builds one optimizer step: microbatched gradient
accumulation (lax.scan), global-norm clipping, AdamW, metrics.  The builders
are mesh-agnostic — sharding comes entirely from the in/out shardings the
launcher attaches (see repro.parallel.sharding + repro.launch.dryrun).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.lm import LM
from repro.optim.adamw import (AdamWConfig, OptState, adamw_update,
                               init_opt_state)

Pytree = Any

MOD_KEYS = ("audio_embed", "vision_embed")


def _split_mods(batch: dict) -> tuple[dict, dict]:
    mods = {k: v for k, v in batch.items() if k in MOD_KEYS}
    rest = {k: v for k, v in batch.items() if k not in MOD_KEYS}
    return rest, mods


def make_train_step(model: LM, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1,
                    remat: str = "selective") -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": OptState};
    batch = {"tokens": (B,S) int32, "labels": (B,S) int32, [mods...]}.
    """

    def loss_fn(params, mb):
        rest, mods = _split_mods(mb)
        return model.loss(params, rest["tokens"], rest["labels"],
                          remat=remat, **mods)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        M = microbatches
        if M == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]), batch)

            def acc(carry, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                cl, cg = carry
                return (cl + l,
                        jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     cg, g)), ()

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = lax.scan(acc, (jnp.zeros(()), zero), mbs)
            loss = loss / M
            grads = jax.tree.map(lambda g: g / M, grads)

        new_params, new_opt, om = adamw_update(params, grads,
                                               state["opt"], opt_cfg)
        metrics = {"loss": loss, **om,
                   "tokens": jnp.asarray(
                       batch["tokens"].shape[0] * batch["tokens"].shape[1],
                       jnp.float32)}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(model: LM, key: jax.Array) -> dict:
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params)}


def abstract_train_state(model: LM) -> dict:
    from repro.optim.adamw import abstract_opt_state
    params = model.abstract_params()
    return {"params": params, "opt": abstract_opt_state(params)}


def make_prefill_step(model: LM) -> Callable:
    def prefill_step(params: Pytree, batch: dict):
        rest, mods = _split_mods(batch)
        return model.prefill(params, rest["tokens"], **mods)
    return prefill_step


def make_serve_step(model: LM) -> Callable:
    def serve_step(params: Pytree, cache: Pytree, batch: dict):
        rest, mods = _split_mods(batch)
        return model.decode_step(params, cache, rest["tokens"],
                                 rest["pos"], **mods)
    return serve_step
