"""JAX version compatibility shims for the parallel layer.

``shard_map`` moved from ``jax.experimental.shard_map`` to a top-level
``jax.shard_map`` export, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma`` along the way.  This wrapper accepts the new
spelling and translates for older JAX so the rest of the codebase can use
one API.
"""

from __future__ import annotations

import inspect

try:
    from jax import shard_map as _shard_map      # jax >= 0.5
except ImportError:                              # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map(f, **kwargs)
