"""Explicit collective schedules (paper §2.3 Fig. 3) + gradient compression.

The paper's decomposition argument — all-reduce = reduce-scatter +
all-gather removes the single-root bottleneck — maps 1:1 onto
``lax.psum_scatter`` + ``lax.all_gather`` inside ``shard_map``.  The main
train step gets this implicitly through ZeRO-1 sharding (GSPMD emits RS+AG
when optimizer moments are sharded over "data"); these explicit versions are
used by the benchmark reproducing Fig. 3 and by the gradient-compression
path (int8 + error feedback, a beyond-paper extension for the slow DCI
inter-pod edge).
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.parallel.compat import shard_map

Pytree = Any


# -- inside-shard_map primitives --------------------------------------------


def allreduce_naive(x: jax.Array, axis: str) -> jax.Array:
    """Single fused all-reduce (the baseline schedule)."""
    return lax.psum(x, axis)


def allreduce_decomposed(x: jax.Array, axis: str) -> jax.Array:
    """reduce-scatter + all-gather over the leading dim (Fig. 3 right).

    Requires dim0 % axis_size == 0 — the caller pads (see
    :func:`sync_grads`)."""
    s = lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)
    return lax.all_gather(s, axis, axis=0, tiled=True)


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def allreduce_int8(x: jax.Array, axis: str,
                   err: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Int8-compressed all-reduce with error feedback.

    Wire volume drops 4x (modeled in the planner's cost model; on the
    emulated mesh we keep numerics faithful: quantize locally, sum the
    dequantized values, and fold the quantization residual into ``err`` so
    it is re-applied next step — convergence-neutral in expectation)."""
    g = x + err
    q, scale = _quantize_int8(g)
    deq = q.astype(x.dtype) * scale
    new_err = g - deq
    return lax.psum(deq, axis), new_err


# -- pytree-level gradient sync ---------------------------------------------


def sync_grads(grads: Pytree, mesh: Mesh, axis: str = "data", *,
               schedule: str = "rs_ag",
               err: Pytree | None = None) -> tuple[Pytree, Pytree | None]:
    """Mean-reduce grads across ``axis`` with an explicit schedule.

    schedule: "allreduce" | "rs_ag" | "int8".  Returns (grads, new_err);
    ``err`` must be a zeros-like tree for "int8" (error feedback state).
    """
    n = mesh.shape[axis]

    def one(g, e):
        def inner(gl, el):
            if schedule == "allreduce":
                return allreduce_naive(gl, axis) / n, el
            if schedule == "rs_ag":
                flat = gl.reshape(-1)
                pad = (-flat.shape[0]) % n
                flat = jnp.pad(flat, (0, pad))
                out = allreduce_decomposed(flat, axis) / n
                return out[:flat.shape[0] - pad].reshape(gl.shape) \
                    if pad else out.reshape(gl.shape), el
            if schedule == "int8":
                s, ne = allreduce_int8(gl, axis, el)
                return s / n, ne
            raise ValueError(schedule)

        spec = P()  # grads replicated across the sync axis
        f = shard_map(inner, mesh=mesh, in_specs=(spec, spec),
                      out_specs=(spec, spec), check_vma=False)
        return f(g, e)

    es = err if err is not None else jax.tree.map(jnp.zeros_like, grads)
    pairs = jax.tree.map(one, grads, es)
    synced = jax.tree.map(lambda p: p[0], pairs,
                          is_leaf=lambda x: isinstance(x, tuple)
                          and len(x) == 2 and isinstance(x[0], jax.Array))
    new_err = jax.tree.map(lambda p: p[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple)
                           and len(x) == 2 and isinstance(x[0], jax.Array))
    return synced, (new_err if schedule == "int8" else None)
