"""shard_map pipeline parallelism with uneven (planner-chosen) stages.

The planner assigns *contiguous layer counts per stage* (possibly uneven —
its heterogeneity mechanism, paper §4.1).  All pipeline ranks run the same
program under ``shard_map`` over a "pipe" mesh axis, so uneven stages are
expressed by padding every stage to ``max_layers`` and masking the padding
layers to identity:

  stage_params: pytree with leading (n_stages, max_layers, ...) sharded over
  "pipe"; layer_mask: (n_stages, max_layers) bool.

Schedule: GPipe-style microbatch loop over ``lax.ppermute`` — activations
flow stage→stage+1; JAX autodiff transposes ppermute to the reverse
permutation, so one ``jax.grad`` of :func:`pipeline_forward` yields the
backward pipeline for free.  (The simulator models 1F1B for *timing*; the
numerics here are schedule-independent.)
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.parallel.compat import shard_map

Pytree = Any


def pad_stages(per_layer_params: Pytree, sizes: list[int]) -> tuple[Pytree,
                                                                    jax.Array]:
    """Regroup a per-layer stacked pytree (L, ...) into padded stages.

    Returns (stage_params (S, Lmax, ...), layer_mask (S, Lmax))."""
    S = len(sizes)
    Lmax = max(sizes)
    starts = [sum(sizes[:i]) for i in range(S)]

    def regroup(x):
        out = []
        for s in range(S):
            sl = x[starts[s]:starts[s] + sizes[s]]
            pad = jnp.zeros((Lmax - sizes[s], *x.shape[1:]), x.dtype)
            out.append(jnp.concatenate([sl, pad], axis=0))
        return jnp.stack(out)

    mask = jnp.stack([jnp.arange(Lmax) < s for s in sizes])
    return jax.tree.map(regroup, per_layer_params), mask


def pipeline_forward(layer_fn: Callable, stage_params: Pytree,
                     layer_mask: jax.Array, x_mb: jax.Array, *,
                     mesh: Mesh, axis: str = "pipe") -> jax.Array:
    """Run microbatches through the pipeline.

    x_mb: (M, mb, ...) microbatched inputs (replicated across pipe ranks —
    only stage 0 reads them).  Returns (M, mb, ...) outputs (valid on the
    last rank; replicated back for convenience).
    """
    S = mesh.shape[axis]
    M = x_mb.shape[0]

    def stage_apply(params, mask, h):
        def body(carry, inp):
            p_l, m_l = inp
            out = layer_fn(p_l, carry)
            return jnp.where(m_l, out, carry), ()
        h, _ = lax.scan(body, h, (params, mask))
        return h

    def per_rank(params, mask, xs):
        sid = lax.axis_index(axis)
        params = jax.tree.map(lambda a: a[0], params)   # local (Lmax, ...)
        mask = mask[0]
        perm_fwd = [(i, i + 1) for i in range(S - 1)]
        state = jnp.zeros(xs.shape[1:], xs.dtype)
        outs = jnp.zeros_like(xs)
        # tick t: rank s computes microbatch m = t - s (garbage flows through
        # warmup/drain ticks but is never stored)
        for t in range(M + S - 1):
            h = jnp.where(sid == 0, xs[min(t, M - 1)], state)
            h = stage_apply(params, mask, h)
            out_idx = t - (S - 1)
            ok = (sid == S - 1) & (0 <= out_idx) & (out_idx < M)
            ci = min(max(out_idx, 0), M - 1)
            outs = outs.at[ci].set(jnp.where(ok, h, outs[ci]))
            if S > 1:
                state = lax.ppermute(h, axis, perm_fwd)
        # deliver collected outputs from the last rank to all ranks
        outs = lax.psum(jnp.where(sid == S - 1, outs,
                                  jnp.zeros_like(outs)), axis)
        return outs

    f = shard_map(per_rank, mesh=mesh,
                  in_specs=(P(axis), P(axis), P()),
                  out_specs=P(), check_vma=False)
    return f(stage_params, layer_mask, x_mb)
