"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240 ssm_state=64.

Mamba2 backbone with a SHARED attention+MLP block every third layer
(one weight set reused at each occurrence, per-occurrence input adapter).
Runs long_500k: SSM state is O(1) and the shared attention uses a 4096-token
sliding window (ring-buffer cache).  [arXiv:2411.15242; hf]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    block_pattern=("mamba", "mamba", "shared_attn"),
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    attn_window=4096, subquadratic=True,
    ffn_kind="swiglu", rope_theta=10000.0,
)
