"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

GQA with QKV bias.  28 heads are not divisible by the 16-way model axis:
the sharding layer falls back to sequence sharding for attention (see
repro.parallel.axes divisibility fallback).  [arXiv:2407.10671; hf]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064,
    qkv_bias=True, ffn_kind="swiglu", rope_theta=1e6,
)
