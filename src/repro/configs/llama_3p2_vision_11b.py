"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; gated cross-attention image layers every 5th layer.

The vision tower is a STUB — input_specs supplies precomputed patch
embeddings (B, 1601, d).  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256,
    block_pattern=("attn", "attn", "attn", "attn", "cross_attn"),
    cross_attn_every=5, vision_seq=1601,
    ffn_kind="swiglu", rope_theta=5e5,
)
