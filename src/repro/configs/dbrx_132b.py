"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) expert d_ff=10752
vocab=100352, 16 experts top-4 (fine-grained).
[hf:databricks/dbrx-base; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352,
    n_experts=16, top_k=4,
    ffn_kind="swiglu", rope_theta=5e5,
)
