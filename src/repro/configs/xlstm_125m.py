"""xlstm-125m [ssm]: 12L d_model=768 4H vocab=50304, alternating
mLSTM (matrix memory) / sLSTM (scalar memory, block-diagonal recurrence)
blocks; d_ff=0 — expansion lives inside the blocks.  Runs long_500k
(recurrent state, no KV growth).  [arXiv:2405.04517; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    block_pattern=("mlstm", "slstm"),
    subquadratic=True,
)
