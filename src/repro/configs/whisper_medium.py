"""whisper-medium [audio]: 24+24L d_model=1024 16H d_ff=4096 vocab=51865.

Encoder-decoder; the conv frontend is a STUB — input_specs supplies
precomputed 1500-frame embeddings (B, 1500, d).  Decoder layers carry
cross-attention to the encoder output; GELU MLPs.  Decode shapes run at the
assigned 32k cache length (backbone exercise; beyond the audio model's
native 448).  [arXiv:2212.04356; unverified]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    ffn_kind="gelu",
    encoder_layers=24, audio_seq=1500,
    block_pattern=("cross_attn",),
    rope_theta=10000.0,
)
