"""Assigned-architecture registry: ``get_config(id)`` / ``ARCH_IDS``.

One module per architecture (exact configs from the assignment table);
``get_config`` returns its ``CONFIG``.  ``repro.launch.dryrun`` iterates
``ARCH_IDS`` × ``config.shapes()`` for the 40-cell dry-run matrix.
"""

from __future__ import annotations

import importlib

from repro.models.config import (ALL_SHAPES, SHAPES_BY_NAME, ArchConfig,
                                 ShapeSpec)

ARCH_IDS: tuple[str, ...] = (
    "gemma_7b",
    "qwen2_7b",
    "qwen3_32b",
    "granite_34b",
    "qwen3_moe_30b_a3b",
    "dbrx_132b",
    "whisper_medium",
    "zamba2_2p7b",
    "llama_3p2_vision_11b",
    "xlstm_125m",
)

# assignment ids (with dashes/dots) -> module names
ALIASES = {
    "gemma-7b": "gemma_7b",
    "qwen2-7b": "qwen2_7b",
    "qwen3-32b": "qwen3_32b",
    "granite-34b": "granite_34b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "dbrx-132b": "dbrx_132b",
    "whisper-medium": "whisper_medium",
    "zamba2-2.7b": "zamba2_2p7b",
    "llama-3.2-vision-11b": "llama_3p2_vision_11b",
    "xlstm-125m": "xlstm_125m",
}


def get_config(arch: str) -> ArchConfig:
    mod = ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    if mod not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    return importlib.import_module(f"repro.configs.{mod}").CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
