"""gemma-7b [dense]: 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000.

GeGLU MLP, head_dim=256 (q_dim 4096 > d_model), sqrt(d) embedding scale,
final-logit softcap.  [arXiv:2403.08295; hf]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    d_ff=24576, vocab=256000, head_dim=256,
    ffn_kind="geglu", scale_embed=True, logit_softcap=30.0,
    rope_theta=10000.0,
)
