"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (kv=4) expert d_ff=768
vocab=151936, 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab=151936, head_dim=128,
    n_experts=128, top_k=8, qk_norm=True,
    ffn_kind="swiglu", rope_theta=1e6,
)
