"""Bounded admission queue: priority + FIFO tie-break, twin bucketing,
backpressure.

The service cannot plan an unbounded backlog — a full queue *rejects* new
submissions (backpressure: the caller sees the rejection immediately
instead of queueing into an ever-growing latency tail).  Queued specs pop
in ``(-priority, arrival order)`` order, and :meth:`AdmissionQueue.pop_bucket`
additionally drains every queued spec whose :meth:`~repro.service.jobs.
JobSpec.signature` matches the head — isomorphic twins admitted in one
round share a single cold search (the tensor2tensor batching idiom of
bucketing same-shaped work, applied to plan searches instead of examples).
"""

from __future__ import annotations

import heapq
import threading

from .jobs import JobSpec


class AdmissionQueue:
    """Bounded priority queue of :class:`~repro.service.jobs.JobSpec`.

    ``capacity`` bounds the backlog; :meth:`offer` returns ``False`` (and
    counts a rejection) when full.  Pop order is highest ``priority``
    first, FIFO within a priority level (a monotone sequence number breaks
    ties, so two equal-priority twins pop in submission order —
    deterministic across replays).  Thread-safe.
    """

    def __init__(self, capacity: int = 64):
        self.capacity = capacity
        self.rejected = 0
        self._heap: list[tuple[int, int, JobSpec]] = []
        self._seq = 0
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._heap)

    @property
    def depth(self) -> int:
        """Current backlog size (the ``service.queue_depth`` metric)."""
        return len(self._heap)

    def offer(self, spec: JobSpec) -> bool:
        """Enqueue ``spec``; ``False`` = queue full, spec rejected
        (backpressure — the service never buffers past ``capacity``)."""
        with self._lock:
            if len(self._heap) >= self.capacity:
                self.rejected += 1
                return False
            heapq.heappush(self._heap, (-spec.priority, self._seq, spec))
            self._seq += 1
            return True

    def peek(self) -> JobSpec | None:
        """The spec :meth:`pop` would return, without removing it."""
        with self._lock:
            return self._heap[0][2] if self._heap else None

    def pop(self) -> JobSpec | None:
        """Highest-priority (FIFO within level) spec, or ``None``."""
        with self._lock:
            if not self._heap:
                return None
            return heapq.heappop(self._heap)[2]

    def pop_bucket(self) -> tuple[JobSpec, list[JobSpec]]:
        """Pop the head plus every queued twin (equal ``signature()``).

        Returns ``(head, twins)``; the twins keep their pop order.  The
        service admits the whole bucket in one round so the head's cold
        search is the only one — each twin's plan is a shared-cache remap.
        Raises ``IndexError`` on an empty queue.
        """
        with self._lock:
            if not self._heap:
                raise IndexError("pop_bucket on empty AdmissionQueue")
            neg_pri, seq, head = heapq.heappop(self._heap)
            sig = head.signature()
            twins: list[tuple[int, int, JobSpec]] = []
            keep: list[tuple[int, int, JobSpec]] = []
            for item in self._heap:
                (twins if item[2].signature() == sig else keep).append(item)
            twins.sort()
            self._heap = keep
            heapq.heapify(self._heap)
            return head, [t[2] for t in twins]
