"""Job specifications for the multi-job planner service.

A :class:`JobSpec` is everything the service needs to plan one training
job: the model, its batch geometry, how many devices it wants, and its
admission priority.  :func:`model_signature` and :meth:`JobSpec.signature`
canonicalize the *shape* of the request — two specs with equal signatures
are isomorphic for planning (same model architecture, same batch geometry,
same device count), so the admission layer buckets them and the shared
cache serves one cold search to all of them, independent of job or model
*names*.
"""

from __future__ import annotations

from dataclasses import dataclass, fields

from repro.core.opgraph import ModelDesc


def model_signature(model: ModelDesc) -> tuple:
    """Canonical name-free shape key of a model: every :class:`ModelDesc`
    field except ``name``, in declaration order.  Two models with equal
    signatures produce identical op graphs, parameter counts and plan
    search spaces — the planner cannot tell them apart, so the cross-job
    cache must not either."""
    return tuple(getattr(model, f.name) for f in fields(ModelDesc)
                 if f.name != "name")


@dataclass(frozen=True)
class JobSpec:
    """One tenant's planning request.

    ``priority`` orders admission (higher first; FIFO within a level);
    ``arrival_s`` / ``duration_s`` place the job on the service timeline
    (a finished job frees its devices for the queue).  ``name`` is the
    job's identity — it never participates in bucketing.
    """

    name: str
    model: ModelDesc
    global_batch: int
    seq: int
    n_devices: int
    priority: int = 0
    arrival_s: float = 0.0
    duration_s: float = 0.0
    gpus_per_node: int = 8

    def signature(self) -> tuple:
        """The isomorphism bucket key: jobs with equal signatures want the
        same search on the same-shaped device slice and may share one cold
        plan (remapped per slice)."""
        return (model_signature(self.model), self.global_batch, self.seq,
                self.n_devices, self.gpus_per_node)
