"""``repro.service`` — planner-as-a-service: a persistent in-process
daemon multiplexing N concurrent training jobs on one shared cluster
(ISSUE 10 tentpole; the ROADMAP's "shared cluster, many jobs, heavy
traffic" open item).

  * :mod:`repro.service.jobs` — :class:`JobSpec` + the name-free
    :func:`model_signature` bucketing key,
  * :mod:`repro.service.admission` — bounded :class:`AdmissionQueue`
    (priority + FIFO tie-break, isomorphic-twin bucketing, backpressure),
  * :mod:`repro.service.cache` — :class:`SharedStrategyCache`, the
    versioned cross-job store with exact event-driven invalidation,
  * :mod:`repro.service.service` — :class:`PlannerService` itself, plus
    the :class:`LinkLoadBoard` / :class:`ContentionChargedReconfig` pair
    that charges concurrent reshards onto shared links.

See ``docs/service.md`` for architecture, semantics, and the operator
runbook; ``benchmarks/bench_service.py`` measures sustained replan
throughput and p99 latency under a multi-tenant arrival storm.
"""

from .admission import AdmissionQueue
from .cache import SharedStrategyCache, StoredPlan
from .jobs import JobSpec, model_signature
from .service import (ContentionChargedReconfig, JobHandle, LinkLoadBoard,
                      PlannerService, ServiceReport)

__all__ = [
    "AdmissionQueue", "ContentionChargedReconfig", "JobHandle", "JobSpec",
    "LinkLoadBoard", "PlannerService", "ServiceReport",
    "SharedStrategyCache", "StoredPlan", "model_signature",
]
