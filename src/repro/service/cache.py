"""Shared cross-job strategy cache with event-driven invalidation.

PR 9 ships per-search read-only materialization snapshots; this module
extends them into the *shared, versioned* store the planner service
multiplexes jobs over.  Two layers:

  * the inner :class:`repro.core.engine.StrategyCache` (one instance shared
    by every per-job :class:`~repro.core.engine.ReplanEngine`) memoizes
    enumeration / materialized plans / simulator scores per topology
    fingerprint — jobs replanning on the *same* device slice under the same
    conditions reuse each other's work for free;
  * the **finished-plan store** keyed by
    ``(island_signature(slice), JobSpec.signature())`` — id-free on both
    axes, so a job admitted onto *any* slice isomorphic to one already
    planned gets the stored plan remapped onto its own device ids
    (sorted-order correspondence, exactly the hierarchical search's twin
    dedup) instead of a cold search.

Invalidation is event-driven and *exact*: every stored entry records which
device ids and edge tags its source slice touched, and
:meth:`SharedStrategyCache.invalidate` drops precisely the entries the
:class:`~repro.core.cluster.NetworkEvent` can affect — a failed device
kills the entries whose slice contains it, a selector-tagged bandwidth
event kills the entries whose slice crosses that fabric, and everything
else survives.  Each invalidation bumps :attr:`SharedStrategyCache.version`
so operators can correlate store generations with the event timeline.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, replace

from repro.core.cluster import NetworkEvent
from repro.core.engine import StrategyCache
from repro.core.plans import ParallelPlan, StageAssignment
from repro.core.simulator import StepSim
from repro.obs import Obs, resolve_obs


@dataclass(frozen=True)
class StoredPlan:
    """One finished-plan store entry: the representative's plan + score,
    plus the fingerprint facts invalidation matches against (``devices``:
    the slice's ids; ``tags``: its internal edge tags)."""

    plan: ParallelPlan
    sim: StepSim
    device_ids: tuple[int, ...]          # sorted representative slice ids
    devices: frozenset[int]
    tags: frozenset[str]
    version: int                         # store generation at write time


def _remap(plan: ParallelPlan, mapping: dict[int, int]) -> ParallelPlan:
    # sorted-order correspondence; meta untouched so a remapped plan is
    # byte-identical to a cold search on the isomorphic target slice
    stages = tuple(
        StageAssignment(st.layers, tuple(mapping[d] for d in st.device_ids))
        for st in plan.stages)
    return replace(plan, stages=stages)


class SharedStrategyCache:
    """The service's cross-job cache: shared inner :class:`StrategyCache`
    plus the versioned finished-plan store (see module docstring).

    Thread-safe.  :meth:`acquire` is the single-flight entry point: under
    concurrent admission of twins, exactly one caller is told ``"cold"``
    (it must :meth:`complete` or :meth:`abandon` the key) and every other
    caller blocks until the search lands, then gets the remapped hit.
    """

    def __init__(self, *, max_entries: int = 256,
                 strategy_cache: StrategyCache | None = None,
                 obs: Obs | None = None):
        self.obs = resolve_obs(obs)
        self.strategy = strategy_cache if strategy_cache is not None \
            else StrategyCache(max_entries=max_entries, obs=self.obs)
        self.max_entries = max_entries
        self.version = 0
        self.hits = 0
        self.misses = 0
        self._plans: "OrderedDict[tuple, StoredPlan]" = OrderedDict()
        self._pending: dict[tuple, threading.Event] = {}
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._plans)

    @property
    def hit_rate(self) -> float:
        """Finished-plan store hit rate over every lookup so far."""
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    # -- lookup / single-flight ----------------------------------------------

    def _serve(self, entry: StoredPlan, target_ids) -> tuple[ParallelPlan,
                                                             StepSim]:
        ids = tuple(sorted(target_ids))
        if ids == entry.device_ids:
            return entry.plan, entry.sim
        mapping = dict(zip(entry.device_ids, ids))
        return _remap(entry.plan, mapping), entry.sim

    def lookup(self, key: tuple, target_ids) -> tuple[ParallelPlan,
                                                      StepSim] | None:
        """The stored plan for ``key`` remapped onto ``target_ids``
        (sorted-order correspondence), or ``None``.  Counts hit/miss
        telemetry (``service.plan_cache.hit`` / ``.miss``)."""
        with self._lock:
            entry = self._plans.get(key)
            if entry is not None:
                self._plans.move_to_end(key)
                self.hits += 1
            else:
                self.misses += 1
        self.obs.inc("service.plan_cache.hit" if entry is not None
                     else "service.plan_cache.miss")
        if entry is None:
            return None
        return self._serve(entry, target_ids)

    def acquire(self, key: tuple, target_ids
                ) -> tuple[str, tuple[ParallelPlan, StepSim] | None]:
        """Single-flight lookup: ``("hit", (plan, sim))`` or
        ``("cold", None)``.

        The first caller for an absent key becomes its owner and MUST call
        :meth:`complete` (or :meth:`abandon` on failure); concurrent
        callers for the same key block until then and re-resolve — so N
        twins admitted at once cost exactly one cold search.
        """
        while True:
            with self._lock:
                entry = self._plans.get(key)
                if entry is not None:
                    self._plans.move_to_end(key)
                    self.hits += 1
                    self.obs.inc("service.plan_cache.hit")
                    return "hit", self._serve(entry, target_ids)
                ev = self._pending.get(key)
                if ev is None:
                    self._pending[key] = threading.Event()
                    self.misses += 1
                    self.obs.inc("service.plan_cache.miss")
                    return "cold", None
            ev.wait()

    def complete(self, key: tuple, plan: ParallelPlan, sim: StepSim,
                 device_ids, tags) -> None:
        """Land a cold search's result under ``key`` and release any
        waiters.  ``device_ids``/``tags`` become the entry's invalidation
        fingerprint."""
        ids = tuple(sorted(device_ids))
        entry = StoredPlan(plan=plan, sim=sim, device_ids=ids,
                           devices=frozenset(ids),
                           tags=frozenset(tags), version=self.version)
        with self._lock:
            self._plans[key] = entry
            self._plans.move_to_end(key)
            while len(self._plans) > self.max_entries:
                self._plans.popitem(last=False)
                self.obs.inc("service.plan_cache.eviction")
            ev = self._pending.pop(key, None)
        if ev is not None:
            ev.set()

    def abandon(self, key: tuple) -> None:
        """Release ``key``'s waiters without storing (the owner's search
        failed); the next caller becomes the new owner."""
        with self._lock:
            ev = self._pending.pop(key, None)
        if ev is not None:
            ev.set()

    # -- event-driven invalidation --------------------------------------------

    def invalidate(self, event: NetworkEvent) -> list[tuple]:
        """Drop exactly the entries ``event`` can affect; returns their
        keys and bumps :attr:`version`.

        Matching rules (the documented invalidation contract,
        ``docs/service.md``):

        * ``fail`` / ``join`` / ``slowdown`` — entries whose slice contains
          ``event.device_id``;
        * ``bandwidth`` with a selector — entries whose slice has an edge
          tagged ``event.selector``;
        * ``bandwidth`` with no selector (whole-fabric) — every entry with
          any internal edge.

        Entries on disjoint device slices / untouched fabrics survive — the
        store is never cleared wholesale.
        """
        dropped: list[tuple] = []
        with self._lock:
            for key, entry in list(self._plans.items()):
                hit = False
                if event.kind in ("fail", "join", "slowdown"):
                    hit = event.device_id in entry.devices
                elif event.kind == "bandwidth":
                    hit = (event.selector in entry.tags
                           if event.selector is not None else bool(entry.tags))
                if hit:
                    del self._plans[key]
                    dropped.append(key)
            self.version += 1
        if dropped:
            self.obs.inc("service.plan_cache.invalidated", len(dropped))
        return dropped

    def counters(self) -> dict[str, int]:
        """Snapshot of the store's telemetry (size, hits, misses,
        version)."""
        with self._lock:
            return {"size": len(self._plans), "hits": self.hits,
                    "misses": self.misses, "version": self.version}
