"""`PlannerService`: one planner daemon, many concurrent jobs, one fabric.

Everything below ``repro.service`` plans exactly one training job; this
module multiplexes N of them over one shared
:class:`~repro.core.cluster.ClusterTopology`:

  * **admission** — submissions enter a bounded
    :class:`~repro.service.admission.AdmissionQueue` (priority + FIFO,
    backpressure on overload); when devices free up, the head bucket is
    admitted onto a deterministic slice of the free pool and isomorphic
    twins ride the head's single cold search via the
    :class:`~repro.service.cache.SharedStrategyCache`;
  * **replanning** — every :class:`~repro.core.cluster.NetworkEvent` is
    applied to the shared topology once, invalidates exactly the affected
    cache entries, and triggers warm
    :meth:`~repro.core.engine.ReplanEngine.replan` calls on the affected
    jobs only (optionally in a thread pool — results are byte-identical to
    the serial order, gated in CI);
  * **contention charging** — each job's keep/switch hysteresis prices its
    reshard against the *other* jobs' in-flight reshard bytes on shared
    links (:class:`LinkLoadBoard` + :meth:`repro.core.reconfig.
    ReconfigCostModel.cost`'s ``edge_load``), and switches decided in the
    same round are re-priced jointly — no job ever sees an empty fabric
    that is actually busy.

Telemetry rides ``repro.obs``: per-job span lanes (``lane=<job>`` attrs
render as one Perfetto lane per job), ``service.queue_depth`` /
``service.replan.latency_s`` histograms and ``service.*`` counters — see
``docs/service.md`` for the operator runbook.
"""

from __future__ import annotations

import heapq
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

from repro.core.cluster import ClusterTopology, NetworkEvent
from repro.core.engine import ReplanEngine, ReplanResult
from repro.core.plans import ParallelPlan
from repro.core.reconfig import ReconfigCostModel
from repro.core.simulator import StepSim
from repro.obs import Obs, resolve_obs

from .admission import AdmissionQueue
from .cache import SharedStrategyCache
from .jobs import JobSpec


class LinkLoadBoard:
    """Per-link in-flight reshard bytes, by owning job, with expiry.

    When a job switches plans the service charges its route-expanded
    reshard traffic here for the switch's modeled duration; any other job
    pricing a switch meanwhile sees those bytes as background load on the
    shared links (:meth:`load` excludes the asking job's own traffic).
    Purely deterministic — entries expire by the service clock, not wall
    time.
    """

    def __init__(self) -> None:
        self._entries: list[tuple[str, float, dict[tuple[int, int],
                                                   float]]] = []
        self._lock = threading.Lock()

    def charge(self, owner: str, traffic: dict[tuple[int, int], float],
               now: float, duration: float) -> None:
        """Register ``owner``'s reshard ``traffic`` as in-flight for
        ``duration`` seconds of service-clock time."""
        if not traffic or duration <= 0:
            return
        with self._lock:
            self._entries.append((owner, now + duration, dict(traffic)))

    def gc(self, now: float) -> None:
        """Drop entries that have fully drained by ``now``."""
        with self._lock:
            self._entries = [e for e in self._entries if e[1] > now]

    def load(self, now: float, *, exclude: str | None = None
             ) -> dict[tuple[int, int], float]:
        """Aggregate in-flight bytes per link at ``now``, excluding
        ``exclude``'s own entries (a job never queues behind itself)."""
        out: dict[tuple[int, int], float] = {}
        with self._lock:
            for owner, expires, traffic in self._entries:
                if expires <= now or owner == exclude:
                    continue
                for key, v in traffic.items():
                    out[key] = out.get(key, 0.0) + v
        return out


class ContentionChargedReconfig(ReconfigCostModel):
    """A per-job :class:`~repro.core.reconfig.ReconfigCostModel` whose
    :meth:`cost` defaults ``edge_load`` to the background load the service
    froze for the current replan round (:meth:`set_background`).

    Freezing before the round dispatches keeps threaded rounds
    deterministic: every job prices against the same board snapshot no
    matter which thread finishes first.
    """

    def __init__(self, model, **kwargs):
        super().__init__(model, **kwargs)
        self._background: dict[tuple[int, int], float] = {}

    def set_background(self, edge_load: dict[tuple[int, int], float] | None
                       ) -> None:
        """Install the frozen per-link background bytes for the next
        pricing round (``None`` clears it)."""
        self._background = dict(edge_load) if edge_load else {}

    def cost(self, old, new, topo, *, edge_load=None):
        """:meth:`ReconfigCostModel.cost`, defaulting ``edge_load`` to the
        round's frozen background when the caller passes none."""
        if edge_load is None:
            edge_load = self._background
        return super().cost(old, new, topo, edge_load=edge_load)


@dataclass
class JobHandle:
    """One admitted job: its spec, device slice, per-job engine, and the
    current plan.  ``digests`` accumulates ``repr(plan)`` after admission
    and every replan — the byte-level identity record the serial ==
    threaded determinism gate compares."""

    spec: JobSpec
    device_ids: tuple[int, ...]
    engine: ReplanEngine
    reconfig: ContentionChargedReconfig
    tags: frozenset[str]
    state: str                           # running | finished
    plan: ParallelPlan
    predicted: StepSim
    admitted_s: float
    finish_s: float
    cold: bool
    replans: int = 0
    contended_switch_s: float = 0.0
    digests: list[str] = field(default_factory=list)


@dataclass
class ServiceReport:
    """Aggregate outcome of one :meth:`PlannerService.replay`."""

    arrivals: int = 0
    admitted: int = 0
    rejected: int = 0
    finished: int = 0
    events: int = 0
    replans: int = 0
    cold_searches: int = 0
    cache_hits: int = 0
    cache_hit_rate: float = 0.0
    invalidated: int = 0
    max_queue_depth: int = 0
    replan_walls: list[float] = field(default_factory=list)
    admit_walls: list[float] = field(default_factory=list)
    # job name -> tuple of repr(plan) after admission + each replan
    plan_digests: dict[str, tuple[str, ...]] = field(default_factory=dict)

    def percentile(self, q: float) -> float:
        """``q``-th percentile of the measured replan wall times (0 when
        no replans ran)."""
        if not self.replan_walls:
            return 0.0
        xs = sorted(self.replan_walls)
        i = min(len(xs) - 1, max(0, round(q / 100 * (len(xs) - 1))))
        return xs[int(i)]


class PlannerService:
    """In-process planner daemon multiplexing jobs on one shared cluster.

    The service owns the topology: callers :meth:`submit` job specs and
    feed :meth:`handle_event` the network timeline (or drive both at once
    with :meth:`replay`).  Per-job state lives in :class:`JobHandle`\\ s —
    one warm :class:`~repro.core.engine.ReplanEngine` per job, all sharing
    one :class:`~repro.service.cache.SharedStrategyCache` — and the
    :class:`LinkLoadBoard` carries cross-job reshard contention.

    ``workers > 1`` replans the affected jobs of one event concurrently;
    inputs are frozen before dispatch (per-job subtopologies, the board
    snapshot), so the outcome is byte-identical to ``workers=1``.
    """

    def __init__(self, topo: ClusterTopology, *, queue_capacity: int = 64,
                 workers: int = 1, max_candidates: int | None = None,
                 switch_horizon_s: float | None = None,
                 cache: SharedStrategyCache | None = None,
                 cache_entries: int = 512,
                 obs: Obs | None = None):
        # private copy: handle_event mutates topology state in place, and a
        # caller-shared instance would leak one replay's events into the next
        self.topo = topo.copy()
        self.obs = resolve_obs(obs)
        self.cache = cache if cache is not None \
            else SharedStrategyCache(max_entries=cache_entries, obs=self.obs)
        self.queue = AdmissionQueue(queue_capacity)
        self.board = LinkLoadBoard()
        self.workers = max(1, workers)
        self.max_candidates = max_candidates
        self.switch_horizon_s = switch_horizon_s
        self.clock = 0.0
        self.jobs: dict[str, JobHandle] = {}
        self._free: set[int] = set(topo.alive_ids())
        self._seq = 0
        self.report = ServiceReport()

    # -- admission -------------------------------------------------------------

    def submit(self, spec: JobSpec) -> bool:
        """Queue ``spec``; ``False`` = rejected (queue full, backpressure).
        Call :meth:`admit_ready` (or let :meth:`replay`) to actually admit."""
        if spec.name in self.jobs:
            raise ValueError(f"duplicate job name {spec.name!r}")
        ok = self.queue.offer(spec)
        self.report.arrivals += 1
        self.obs.inc("service.submitted")
        if not ok:
            self.report.rejected += 1
            self.obs.inc("service.rejected")
        self.obs.observe("service.queue_depth", self.queue.depth)
        self.report.max_queue_depth = max(self.report.max_queue_depth,
                                          self.queue.depth)
        return ok

    def _allocate(self, n: int) -> tuple[int, ...]:
        ids = tuple(sorted(self._free)[:n])
        self._free.difference_update(ids)
        return ids

    def admit_ready(self, now: float | None = None) -> list[JobHandle]:
        """Admit queued buckets while the head fits the free device pool.

        Head-of-line semantics: a high-priority job too big for the
        current free pool blocks lower-priority jobs behind it (no
        starvation of big jobs).  Twins in the head's bucket that do not
        fit re-enter the queue at the tail of their priority level.
        """
        now = self.clock if now is None else now
        admitted: list[JobHandle] = []
        while True:
            head = self.queue.peek()
            if head is None or head.n_devices > len(self._free):
                break
            spec, twins = self.queue.pop_bucket()
            for s in (spec, *twins):
                if s.n_devices <= len(self._free):
                    admitted.append(self._admit(s, now))
                else:
                    self.queue.offer(s)
        if admitted:
            self.obs.observe("service.queue_depth", self.queue.depth)
        return admitted

    def _admit(self, spec: JobSpec, now: float) -> JobHandle:
        t0 = time.perf_counter()
        ids = self._allocate(spec.n_devices)
        sub = self.topo.subtopology(ids)
        tags = frozenset(e.tag for link in sub.links.values()
                         for e in link.edges)
        reconfig = ContentionChargedReconfig(spec.model)
        engine = ReplanEngine(
            spec.model, global_batch=spec.global_batch, seq=spec.seq,
            cache=self.cache.strategy, max_candidates=self.max_candidates,
            gpus_per_node=spec.gpus_per_node, reconfig=reconfig,
            switch_horizon_s=self.switch_horizon_s, obs=self.obs)
        key = (self.topo.island_signature(ids), spec.signature())
        status, served = self.cache.acquire(key, ids)
        if status == "hit":
            plan, sim = served  # type: ignore[misc]
            engine.seed_incumbent(sub, plan, sim)
        else:
            try:
                res = engine.plan(sub)
            except Exception:
                self.cache.abandon(key)
                self._free.update(ids)
                raise
            plan, sim = res.plan, res.predicted
            self.cache.complete(key, plan, sim, ids, tags)
            self.report.cold_searches += 1
        wall = time.perf_counter() - t0
        job = JobHandle(spec=spec, device_ids=ids, engine=engine,
                        reconfig=reconfig, tags=tags, state="running",
                        plan=plan, predicted=sim, admitted_s=now,
                        finish_s=now + spec.duration_s
                        if spec.duration_s > 0 else float("inf"),
                        cold=(status != "hit"))
        job.digests.append(repr(plan))
        self.jobs[spec.name] = job
        self.report.admitted += 1
        self.report.admit_walls.append(wall)
        self.obs.inc("service.admitted")
        self.obs.inc("service.admit.cold" if job.cold
                     else "service.admit.cache_hit")
        self.obs.observe("service.admit.latency_s", wall)
        if self.obs.enabled:
            # the cold/hit outcome is only known now, so the span is
            # backdated to cover the whole admission (engine.py idiom)
            handle = self.obs.span("service.admit", job=spec.name,
                                   lane=spec.name, cold=job.cold,
                                   devices=len(ids))
            handle.span.t0 = time.perf_counter() - wall
            handle.__exit__(None, None, None)
        return job

    def finish_job(self, name: str, now: float | None = None) -> None:
        """Mark ``name`` finished and return its devices to the free pool
        (queued jobs may now admit — call :meth:`admit_ready`)."""
        job = self.jobs[name]
        if job.state == "finished":
            return
        job.state = "finished"
        self._free.update(d for d in job.device_ids
                          if self.topo.devices[d].alive)
        self.report.finished += 1
        self.obs.inc("service.finished")

    # -- event handling --------------------------------------------------------

    def _affected(self, event: NetworkEvent) -> list[JobHandle]:
        running = [j for j in self.jobs.values() if j.state == "running"]
        if event.kind in ("fail", "join", "slowdown"):
            return [j for j in running if event.device_id in j.device_ids]
        if event.selector is None:
            return running
        return [j for j in running if event.selector in j.tags]

    def handle_event(self, event: NetworkEvent
                     ) -> list[tuple[str, ReplanResult]]:
        """Apply ``event`` to the shared topology, invalidate exactly the
        affected cache entries, and replan the affected jobs (one frozen
        contention round — see class docstring).  Returns the per-job
        replan results in deterministic job-admission order."""
        self.clock = max(self.clock, event.time)
        self.topo.apply_event(event)
        self.board.gc(self.clock)
        dropped = self.cache.invalidate(event)
        self.report.invalidated += len(dropped)
        # pool bookkeeping: fail removes free devices, join returns a
        # device owned by no running job to the pool
        if event.kind == "fail":
            self._free.discard(event.device_id)
        elif event.kind == "join" and event.device_id is not None:
            owned = {d for j in self.jobs.values()
                     if j.state == "running" for d in j.device_ids}
            if event.device_id not in owned:
                self._free.add(event.device_id)
        affected = self._affected(event)
        self.report.events += 1
        self.obs.inc("service.events")
        if not affected:
            return []
        # freeze round inputs before dispatch: per-job subtopologies and
        # the board snapshot each job prices hysteresis against
        subs = [self.topo.subtopology(j.device_ids) for j in affected]
        for job in affected:
            job.reconfig.set_background(
                self.board.load(self.clock, exclude=job.spec.name))
        prev_plans = [j.plan for j in affected]

        def _one(i: int) -> ReplanResult:
            return affected[i].engine.replan(subs[i], event)

        if self.workers > 1 and len(affected) > 1:
            with ThreadPoolExecutor(max_workers=self.workers) as pool:
                results = list(pool.map(_one, range(len(affected))))
        else:
            results = [_one(i) for i in range(len(affected))]
        # joint re-pricing of the switches this round actually decided:
        # each switching job's reshard is charged onto the board for its
        # contended duration, so later rounds queue behind it
        switching = [i for i, res in enumerate(results)
                     if res.plan.structural_key()
                     != prev_plans[i].structural_key()]
        if switching:
            traffics = {i: affected[i].reconfig.edge_traffic(
                prev_plans[i], results[i].plan, subs[i]) for i in switching}
            for i in switching:
                load = dict(affected[i].reconfig._background)
                for j in switching:
                    if j == i:
                        continue
                    for key, v in traffics[j].items():
                        load[key] = load.get(key, 0.0) + v
                priced = affected[i].reconfig.cost(
                    prev_plans[i], results[i].plan, subs[i], edge_load=load)
                affected[i].contended_switch_s += priced.total_s
                self.board.charge(affected[i].spec.name, traffics[i],
                                  self.clock, priced.total_s)
                self.obs.observe("service.switch.contended_s",
                                 priced.total_s)
        out: list[tuple[str, ReplanResult]] = []
        for job, res in zip(affected, results):
            job.plan, job.predicted = res.plan, res.predicted
            job.replans += 1
            job.digests.append(repr(res.plan))
            job.reconfig.set_background(None)
            self.report.replans += 1
            self.report.replan_walls.append(res.wall_time)
            self.obs.observe("service.replan.latency_s", res.wall_time)
            if self.obs.enabled:
                # backdated to cover the engine's measured replan wall, so
                # each job's lane shows the replan as a real region
                handle = self.obs.span("service.replan", job=job.spec.name,
                                       lane=job.spec.name, path=res.path,
                                       kept=res.kept, event=event.kind)
                handle.span.t0 = time.perf_counter() - res.wall_time
                handle.__exit__(None, None, None)
            out.append((job.spec.name, res))
        return out

    # -- replay driver ---------------------------------------------------------

    def replay(self, specs: list[JobSpec],
               events: list[NetworkEvent] | None = None) -> ServiceReport:
        """Drive the whole timeline: merge job arrivals (``spec.arrival_s``)
        and network ``events`` in time order, admit / replan / finish as
        the clock advances, and return the filled :class:`ServiceReport`.

        Fully deterministic for a given input (ties break arrivals before
        events before finishes, then input order) — the serial == threaded
        identity gate replays the same inputs at ``workers=1`` and
        ``workers=N`` and compares ``plan_digests`` byte-for-byte.
        """
        timeline: list[tuple[float, int, int, str, object]] = []
        for k, spec in enumerate(specs):
            timeline.append((spec.arrival_s, 0, k, "arrival", spec))
        for k, ev in enumerate(events or []):
            timeline.append((ev.time, 1, k, "event", ev))
        timeline.sort(key=lambda it: (it[0], it[1], it[2]))
        finish_heap: list[tuple[float, int, str]] = []

        def _note_finishes(limit: float) -> None:
            while finish_heap and finish_heap[0][0] <= limit:
                t, _, name = heapq.heappop(finish_heap)
                self.clock = max(self.clock, t)
                self.finish_job(name, t)
                for job in self.admit_ready(t):
                    self._push_finish(finish_heap, job)

        for t, _kind_rank, _k, kind, payload in timeline:
            _note_finishes(t)
            self.clock = max(self.clock, t)
            if kind == "arrival":
                self.submit(payload)                 # type: ignore[arg-type]
                for job in self.admit_ready(t):
                    self._push_finish(finish_heap, job)
            else:
                self.handle_event(payload)           # type: ignore[arg-type]
        _note_finishes(float("inf"))
        rep = self.report
        rep.cache_hits = self.cache.hits
        rep.cache_hit_rate = self.cache.hit_rate
        rep.plan_digests = {name: tuple(j.digests)
                            for name, j in self.jobs.items()}
        return rep

    def _push_finish(self, heap: list, job: JobHandle) -> None:
        if job.finish_s != float("inf"):
            self._seq += 1
            heapq.heappush(heap, (job.finish_s, self._seq, job.spec.name))
