"""Docs gate (ISSUE 6 satellite, widened by ISSUE 10): intra-repo
markdown links must resolve and the public API must be documented.

Four stdlib-only checks, run by the CI ``docs`` job and locally via::

    python tools/check_docs.py

1. **Link check** — every relative link in ``README.md``, ``docs/*.md``
   and the other repo-root markdown files must point at an existing file
   (anchors are stripped; ``http(s)``/``mailto`` targets are skipped — CI
   must not depend on external availability).
2. **Docstring check** — every public module, class and function defined
   at module level under ``src/repro/core``, ``src/repro/obs``,
   ``src/repro/service``, ``src/repro/scenarios`` (plus ``benchmarks``
   and ``tools``) must carry a docstring.  Names with a leading
   underscore are private and exempt.  The gate covers the planner core
   and its service/scenario layers — not the auxiliary training stack
   (``repro.models``, ``repro.launch``, ...), which predates the gate;
   widen ``PY_ROOTS`` as those layers get audited.
3. **Service API coverage** — every public symbol exported by
   ``repro.service`` (ast-collected from its ``__init__``) must appear in
   ``docs/service.md``'s API table; stale docs fail the gate.
4. **Gate-table coverage** — every metric gated by
   ``benchmarks/compare.py`` (ast-collected ``Gate(...)`` first
   arguments) must appear in ``docs/benchmarks.md`` — the doc drift this
   PR swept (``mip_certified``, ``trace_overhead``, ...) cannot recur
   silently.

Exit code 1 with a per-violation listing on any failure.
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — target up to the first whitespace or closing paren;
# images (![alt](src)) match the same pattern and are checked too
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

MD_ROOTS = ("README.md", "ROADMAP.md", "CHANGES.md", "PAPER.md",
            "PAPERS.md", "ISSUE.md", "SNIPPETS.md")
DOC_DIRS = ("docs",)
PY_ROOTS = ("src/repro/core", "src/repro/obs", "src/repro/service",
            "src/repro/scenarios", "benchmarks", "tools")


def check_links() -> list[str]:
    """Broken relative links in the repo's markdown, as violation strings."""
    files: list[Path] = [REPO / n for n in MD_ROOTS if (REPO / n).exists()]
    for d in DOC_DIRS:
        files.extend(sorted((REPO / d).glob("**/*.md")))
    out: list[str] = []
    for md in files:
        for m in _LINK.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                out.append(f"{md.relative_to(REPO)}: broken link -> "
                           f"{target}")
    return out


def _missing_docstrings(py: Path) -> list[str]:
    tree = ast.parse(py.read_text(), filename=str(py))
    rel = py.relative_to(REPO)
    out: list[str] = []
    if ast.get_docstring(tree) is None:
        out.append(f"{rel}: missing module docstring")
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            if node.name.startswith("_"):
                continue
            if ast.get_docstring(node) is None:
                kind = "class" if isinstance(node, ast.ClassDef) \
                    else "function"
                out.append(f"{rel}:{node.lineno}: public {kind} "
                           f"{node.name} has no docstring")
    return out


def check_docstrings() -> list[str]:
    """Undocumented public module-level defs/classes, as violation
    strings."""
    out: list[str] = []
    for root in PY_ROOTS:
        base = REPO / root
        if not base.exists():
            continue
        for py in sorted(base.glob("**/*.py")):
            if py.name == "__main__.py":
                continue
            out.extend(_missing_docstrings(py))
    return out


def _exported_names(init_py: Path) -> list[str]:
    """Public names a package ``__init__`` re-exports (``__all__`` when
    assigned as a list/tuple literal, else the imported-name fallback)."""
    tree = ast.parse(init_py.read_text(), filename=str(init_py))
    for node in tree.body:
        if isinstance(node, ast.Assign) \
                and any(isinstance(t, ast.Name) and t.id == "__all__"
                        for t in node.targets) \
                and isinstance(node.value, (ast.List, ast.Tuple)):
            return [c.value for c in node.value.elts
                    if isinstance(c, ast.Constant) and isinstance(c.value,
                                                                  str)]
    names: list[str] = []
    for node in tree.body:
        if isinstance(node, ast.ImportFrom):
            names.extend(a.asname or a.name for a in node.names
                         if not (a.asname or a.name).startswith("_"))
    return names


def check_service_api() -> list[str]:
    """Public ``repro.service`` symbols absent from ``docs/service.md``
    (the API table must track the package), as violation strings."""
    init_py = REPO / "src/repro/service/__init__.py"
    doc = REPO / "docs/service.md"
    if not init_py.exists():
        return []
    if not doc.exists():
        return ["docs/service.md: missing (required by the service API "
                "coverage gate)"]
    text = doc.read_text()
    return [f"docs/service.md: public repro.service symbol {name!r} "
            f"not documented"
            for name in _exported_names(init_py) if name not in text]


def check_gate_tables() -> list[str]:
    """Gated metrics in ``benchmarks/compare.py`` absent from
    ``docs/benchmarks.md`` (gate-table drift), as violation strings."""
    compare = REPO / "benchmarks/compare.py"
    doc = REPO / "docs/benchmarks.md"
    if not compare.exists() or not doc.exists():
        return []
    text = doc.read_text()
    metrics: set[str] = set()
    for node in ast.walk(ast.parse(compare.read_text())):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "Gate" and node.args \
                and isinstance(node.args[0], ast.Constant):
            metrics.add(node.args[0].value)
    return [f"docs/benchmarks.md: gated metric {m!r} "
            f"(benchmarks/compare.py) not documented"
            for m in sorted(metrics) if m not in text]


def main() -> int:
    """Run all checks; print violations; exit 1 on any."""
    violations = (check_links() + check_docstrings() + check_service_api()
                  + check_gate_tables())
    if violations:
        print(f"[docs] FAIL: {len(violations)} violation(s)")
        for v in violations:
            print(f"  {v}")
        return 1
    print("[docs] PASS: links resolve, public API documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
