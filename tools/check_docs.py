"""Docs gate (ISSUE 6 satellite): intra-repo markdown links must resolve
and the ``repro.core`` public API must be documented.

Two stdlib-only checks, run by the CI ``docs`` job and locally via::

    python tools/check_docs.py

1. **Link check** — every relative link in ``README.md``, ``docs/*.md``
   and the other repo-root markdown files must point at an existing file
   (anchors are stripped; ``http(s)``/``mailto`` targets are skipped — CI
   must not depend on external availability).
2. **Docstring check** — every public module, class and function defined
   at module level under ``src/repro/core`` (plus ``benchmarks`` and
   ``tools``) must carry a docstring.  Names with a leading underscore are
   private and exempt.  The gate covers the planner core — the paper's
   contribution and this repo's public API — not the auxiliary training
   stack (``repro.models``, ``repro.launch``, ...), which predates the
   gate; widen ``PY_ROOTS`` as those layers get audited.

Exit code 1 with a per-violation listing on any failure.
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — target up to the first whitespace or closing paren;
# images (![alt](src)) match the same pattern and are checked too
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")

MD_ROOTS = ("README.md", "ROADMAP.md", "CHANGES.md", "PAPER.md",
            "PAPERS.md", "ISSUE.md", "SNIPPETS.md")
DOC_DIRS = ("docs",)
PY_ROOTS = ("src/repro/core", "src/repro/obs", "benchmarks", "tools")


def check_links() -> list[str]:
    """Broken relative links in the repo's markdown, as violation strings."""
    files: list[Path] = [REPO / n for n in MD_ROOTS if (REPO / n).exists()]
    for d in DOC_DIRS:
        files.extend(sorted((REPO / d).glob("**/*.md")))
    out: list[str] = []
    for md in files:
        for m in _LINK.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                out.append(f"{md.relative_to(REPO)}: broken link -> "
                           f"{target}")
    return out


def _missing_docstrings(py: Path) -> list[str]:
    tree = ast.parse(py.read_text(), filename=str(py))
    rel = py.relative_to(REPO)
    out: list[str] = []
    if ast.get_docstring(tree) is None:
        out.append(f"{rel}: missing module docstring")
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            if node.name.startswith("_"):
                continue
            if ast.get_docstring(node) is None:
                kind = "class" if isinstance(node, ast.ClassDef) \
                    else "function"
                out.append(f"{rel}:{node.lineno}: public {kind} "
                           f"{node.name} has no docstring")
    return out


def check_docstrings() -> list[str]:
    """Undocumented public module-level defs/classes, as violation
    strings."""
    out: list[str] = []
    for root in PY_ROOTS:
        base = REPO / root
        if not base.exists():
            continue
        for py in sorted(base.glob("**/*.py")):
            if py.name == "__main__.py":
                continue
            out.extend(_missing_docstrings(py))
    return out


def main() -> int:
    """Run both checks; print violations; exit 1 on any."""
    violations = check_links() + check_docstrings()
    if violations:
        print(f"[docs] FAIL: {len(violations)} violation(s)")
        for v in violations:
            print(f"  {v}")
        return 1
    print("[docs] PASS: links resolve, public API documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
