"""Trace/metrics summarizer CLI (ISSUE 7): self-time, percentiles, hit rates.

Loads a combined Perfetto trace + metrics file written by
:func:`repro.obs.write_trace` (or a bare metrics snapshot from
:func:`repro.obs.write_metrics`) and prints:

* a **self-time-per-phase table** — for every span name: call count, total
  time, and self time (total minus the time spent in child spans, computed
  from the ``span_id``/``parent_id`` links the exporter embeds in each
  event's ``args``), sorted by self time;
* **replan-latency percentiles** — p50/p95/p99 of the ``replan.latency_s``
  histogram (plus every other recorded histogram);
* **cache hit rates** — from the ``cache.hit``/``cache.miss`` counter pair,
  and the full counter listing.

Stdlib-only (the CI artifact can be inspected on any machine)::

    python tools/trace_report.py trace.json
    PYTHONPATH=src python -m tools.trace_report trace.json

Produce a trace to feed it, e.g. a traced ``cloud_spot`` harness replay::

    PYTHONPATH=src python - <<'EOF'
    from repro.obs import Obs, write_trace
    from repro.scenarios.harness import HarnessConfig, run_scenario
    from benchmarks.common import PAPER_MODELS
    obs = Obs()
    cfg = HarnessConfig(model=PAPER_MODELS["LLaMA_7B"], global_batch=64,
                        seq=2048, max_candidates=96, obs=obs)
    run_scenario(cfg, "cloud_spot", seed=7)
    write_trace(obs, "trace.json")
    EOF
    python tools/trace_report.py trace.json
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

METRICS_KEY = "reproMetrics"          # mirror of repro.obs.export.METRICS_KEY


def phase_table(events: list[dict]) -> list[dict]:
    """Aggregate complete-span events into per-name rows: count, total
    duration, and self time (duration minus direct children's durations,
    via the ``args.span_id``/``args.parent_id`` links), seconds."""
    dur_by_id: dict = {}
    parent: dict = {}
    name_by_id: dict = {}
    rows: dict[str, dict] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        args = ev.get("args", {})
        sid = args.get("span_id")
        dur = ev.get("dur", 0.0) / 1e6
        if sid is not None:
            dur_by_id[sid] = dur
            parent[sid] = args.get("parent_id")
            name_by_id[sid] = ev.get("name", "?")
        row = rows.setdefault(ev.get("name", "?"),
                              {"phase": ev.get("name", "?"), "count": 0,
                               "total_s": 0.0, "self_s": 0.0})
        row["count"] += 1
        row["total_s"] += dur
        row["self_s"] += dur
    for sid, dur in dur_by_id.items():
        pid = parent.get(sid)
        if pid in dur_by_id:
            rows[name_by_id[pid]]["self_s"] -= dur
    out = sorted(rows.values(), key=lambda r: -r["self_s"])
    for r in out:
        r["self_s"] = max(0.0, r["self_s"])    # clock skew across processes
    return out


def _fmt_s(x: float) -> str:
    if not isinstance(x, (int, float)) or not math.isfinite(x):
        return "-"
    return f"{x * 1e3:10.2f}ms" if x < 1.0 else f"{x:10.3f}s "


def render(doc: dict) -> str:
    """The full report for one loaded trace/metrics document."""
    lines: list[str] = []
    events = doc.get("traceEvents", [])
    metrics = doc.get(METRICS_KEY, doc if "traceEvents" not in doc else {})

    if events:
        lines.append("== self time per phase ==")
        lines.append(f"{'phase':<28} {'count':>7} {'total':>12} "
                     f"{'self':>12}")
        for r in phase_table(events):
            lines.append(f"{r['phase']:<28} {r['count']:>7} "
                         f"{_fmt_s(r['total_s']):>12} "
                         f"{_fmt_s(r['self_s']):>12}")
        workers = {ev.get('pid') for ev in events} - \
            {ev.get('pid') for ev in events
             if ev.get('args', {}).get('parent_id') is None}
        lines.append(f"{len(events)} spans across "
                     f"{len({ev.get('pid') for ev in events})} process "
                     f"lane(s) ({len(workers)} worker)")

    hists = {k: v for k, v in metrics.items()
             if isinstance(v, dict) and v.get("type") == "histogram"}
    counters = {k: v for k, v in metrics.items()
                if not isinstance(v, dict)}
    if hists:
        lines.append("")
        lines.append("== latency histograms (p50 / p95 / p99) ==")
        for name in sorted(hists):
            h = hists[name]
            lines.append(
                f"{name:<28} n={h.get('count', 0):>6}  "
                f"p50={_fmt_s(h.get('p50'))} p95={_fmt_s(h.get('p95'))} "
                f"p99={_fmt_s(h.get('p99'))} max={_fmt_s(h.get('max'))}")

    if counters:
        lines.append("")
        lines.append("== counters ==")
        hit, miss = counters.get("cache.hit", 0), counters.get("cache.miss", 0)
        if hit or miss:
            rate = hit / (hit + miss) if (hit + miss) else 0.0
            lines.append(f"{'cache hit rate':<28} {rate:7.1%}  "
                         f"({hit} hits / {miss} misses)")
        # fabric fidelity (ISSUE 8): how much relayed / re-routed traffic
        # the simulated run actually exercised
        relays = counters.get("fabric.relays", 0)
        rr_ev = counters.get("sim.reroute.events", 0)
        if relays or rr_ev:
            hops = counters.get("fabric.relay_hops", 0)
            lines.append(
                f"{'fabric fidelity':<28} {relays} relayed transfer(s), "
                f"{hops / relays if relays else 0.0:.1f} hops avg, "
                f"{counters.get('fabric.chunks', 0)} chunk(s); "
                f"{rr_ev} mid-flight reroute event(s) across "
                f"{counters.get('sim.reroute.steps', 0)} split step(s)")
        for name in sorted(counters):
            lines.append(f"{name:<28} {counters[name]:>10}")
    if not lines:
        lines.append("(empty trace: no spans, no metrics)")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    """CLI entry point: load the file, print the report."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="combined trace JSON (write_trace) or "
                                  "bare metrics snapshot (write_metrics)")
    args = ap.parse_args(argv)
    path = Path(args.trace)
    if not path.exists():
        print(f"[trace_report] no such file: {path}", file=sys.stderr)
        return 1
    try:
        print(render(json.loads(path.read_text())))
    except BrokenPipeError:                    # e.g. piped through head
        sys.stderr.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
